//! Quickstart: serve the GP surrogate over UM-Bridge, evaluate a few
//! points, print mean/uncertainty — the paper's section II.D example,
//! in Rust end to end (HTTP + PJRT, no Python at runtime).
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use std::sync::Arc;

use uqsched::json::Value;
use uqsched::models;
use uqsched::runtime::Engine;
use uqsched::umbridge::{serve_models, HttpModel};
use uqsched::workload::lhs;

fn main() -> anyhow::Result<()> {
    // 1. Model server (the paper's `umbridge.serve_models`).
    let engine = Arc::new(Engine::from_default_dir()?);
    let model = models::by_name(engine, models::GP_NAME)?;
    let server = serve_models(vec![model], 0)?;
    println!("GP surrogate serving at {}", server.url());

    // 2. Client (the paper's `umbridge.HTTPModel`).
    let mut client = HttpModel::connect(&server.url(), models::GP_NAME)?;
    let (ver, names) = client.info()?;
    println!("protocol {ver}, models {names:?}");
    println!("input sizes  {:?}", client.input_sizes()?);
    println!("output sizes {:?}", client.output_sizes()?);

    // 3. Evaluate a few LHS points of the Table-II parameter space.
    let cfg = Value::Obj(Default::default());
    println!("\n{:<58} {:>10} {:>10} {:>10}", "theta (7 GS2 inputs)",
             "gamma", "omega", "sd(gamma)");
    for p in lhs(8, 42) {
        let out = client.evaluate(&[p.to_vec()], &cfg)?;
        let mean = &out[0];
        let var = &out[1];
        println!("{:<58} {:>10.4} {:>10.4} {:>10.4}",
                 format!("{:.2?}", p), mean[0], mean[1], var[0].sqrt());
    }
    println!("\nquickstart OK");
    std::process::exit(0);
}
