//! The paper's section-VI future work, implemented: an adaptive GP
//! workflow mixing cheap surrogate predictions with costly gs2lite
//! simulations, driven by an uncertainty acquisition function — "loosely
//! dependent tasks" with vastly varying cost, scheduled through the live
//! stack.
//!
//! Loop: predict variance on a candidate pool via the GP artifact ->
//! evaluate the true simulator (gs2lite) at the most uncertain point ->
//! track how the surrogate's error at verified points evolves.  The GP
//! artifact's training set is baked, so this demonstrates the *workflow*
//! (delegation decision + mixed-cost scheduling), reporting surrogate
//! error against the simulator at every acquired point.
//!
//! Run: `cargo run --release --example adaptive_gp [-- --rounds 6]`

use std::sync::Arc;

use uqsched::cli::Args;
use uqsched::coordinator::start_live;
use uqsched::sched::LivePolicy;
use uqsched::json::Value;
use uqsched::models;
use uqsched::runtime::Engine;
use uqsched::umbridge::HttpModel;
use uqsched::workload::lhs;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rounds = args.usize_or("rounds", 6)?;
    let pool_n = args.usize_or("pool", 64)?;

    println!("=== adaptive GP workflow: {rounds} acquisition rounds over a \
              {pool_n}-point candidate pool ===");
    let engine = Arc::new(Engine::from_default_dir()?);
    engine.warmup(&["gp_predict_b16", "gs2_chunk"])?;

    // Cheap predictions run in-process (their cost is dwarfed by HTTP);
    // the costly simulator goes through the live scheduled stack.
    let gp = models::GpModel::new(engine.clone());
    let stack = start_live(
        engine.clone(),
        &[models::GS2_NAME],
        "hq",
        2,
        2000.0,
        true,
        LivePolicy::Fcfs,
    )?;
    let mut sim = HttpModel::connect(&stack.balancer.url(),
                                     models::GS2_NAME)?;
    let cfg = Value::Obj(Default::default());

    let pool = lhs(pool_n, 777);
    let mut acquired: Vec<usize> = Vec::new();
    println!("\nround  point  sd(gamma)  gp gamma   sim gamma  |err|  chunks");
    let mut errs = Vec::new();
    for round in 0..rounds {
        // 1. Surrogate variance over the pool (batched Pallas path).
        let rows: Vec<Vec<f64>> = pool.iter().map(|p| p.to_vec()).collect();
        let (means, vars) = gp.predict_batch(&rows)?;
        // 2. Acquisition: argmax posterior sd among unacquired points.
        let (best, sd) = vars
            .iter()
            .enumerate()
            .filter(|(i, _)| !acquired.contains(i))
            .map(|(i, v)| (i, v[0].sqrt()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("pool not exhausted");
        acquired.push(best);
        // 3. Delegate the costly simulation to the scheduled stack.
        let out = sim.evaluate(&[pool[best].to_vec()], &cfg)?;
        let sim_gamma = out[0][0];
        let gp_gamma = means[best][0];
        let err = (sim_gamma - gp_gamma).abs();
        errs.push(err);
        println!("{round:>5}  {best:>5}  {sd:>9.4}  {gp_gamma:>+9.4}  \
                  {sim_gamma:>+9.4}  {err:>5.3}  {:>6}", out[2][0]);
    }
    let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
    println!("\nmean |gp - simulator| at acquired points: {mean_err:.4} \
              (surrogate quality at its most uncertain points)");
    println!("adaptive_gp OK ({rounds} mixed-cost rounds through the \
              balancer)");
    std::process::exit(0);
}
