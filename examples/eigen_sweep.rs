//! eigen-100 sweep through BOTH live backends, reporting the per-job
//! makespan contrast the paper's Fig 3 shows for its fastest benchmark:
//! per-job SLURM submission pays queue + prolog per evaluation; the HQ
//! backend pays the allocation wait once, then ms-level dispatch.
//!
//! Run: `cargo run --release --example eigen_sweep [-- --evals 12]`

use std::sync::Arc;
use std::time::Instant;

use uqsched::cli::Args;
use uqsched::coordinator::start_live;
use uqsched::sched::LivePolicy;
use uqsched::json::Value;
use uqsched::metrics::BoxStats;
use uqsched::models;
use uqsched::runtime::Engine;
use uqsched::umbridge::HttpModel;

fn run_backend(engine: Arc<Engine>, backend: &str, evals: usize,
               time_scale: f64) -> anyhow::Result<Vec<f64>> {
    let stack = start_live(
        engine,
        &[models::EIGEN_SMALL_NAME],
        backend,
        2,
        time_scale,
        // Per-job servers: the configuration the paper measured.
        false,
        LivePolicy::Fcfs,
    )?;
    let mut client = HttpModel::connect(&stack.balancer.url(),
                                        models::EIGEN_SMALL_NAME)?;
    let cfg = Value::Obj(Default::default());
    let mut makespans = Vec::new();
    for i in 0..evals {
        let t0 = Instant::now();
        let out = client.evaluate(&[vec![(i + 1) as f64]], &cfg)?;
        makespans.push(t0.elapsed().as_secs_f64());
        assert_eq!(out[0].len(), 100); // 100 eigenvalues
    }
    Ok(makespans)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let evals = args.usize_or("evals", 10)?;
    let time_scale = args.f64_or("time-scale", 2000.0)?;

    println!("=== eigen-100 sweep: {evals} evaluations per backend, \
              per-job servers ===");
    let engine = Arc::new(Engine::from_default_dir()?);
    engine.warmup(&["eigen_small"])?;

    let slurm = run_backend(engine.clone(), "slurm", evals, time_scale)?;
    println!("slurm backend per-eval makespan [s]: {}",
             BoxStats::from(&slurm).row());

    let hq = run_backend(engine.clone(), "hq", evals, time_scale)?;
    println!("hq backend    per-eval makespan [s]: {}",
             BoxStats::from(&hq).row());

    let ms = slurm.iter().sum::<f64>() / slurm.len() as f64;
    let mh = hq.iter().sum::<f64>() / hq.len() as f64;
    println!("\nmean makespan: slurm {ms:.3}s vs hq {mh:.3}s -> {:.1}x \
              (paper Fig 3: HQ ~3x quicker on eigen-100)", ms / mh);
    println!("eigen_sweep OK");
    std::process::exit(0);
}
