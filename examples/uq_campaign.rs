//! End-to-end driver (DESIGN.md deliverable (b)): the full system on a
//! real workload, all layers composing.
//!
//! A UQ campaign over the gs2lite simulator through the live stack:
//! slurmlite daemon -> HQ-style backend -> load balancer -> model-server
//! threads executing AOT-compiled JAX/Pallas artifacts via PJRT.  The
//! campaign runs N seeded LHS evaluations with a fixed client queue
//! depth (the paper's protocol), then computes the quasilinear QoI
//! integral at the posterior-mean-fastest-growing point and prints the
//! full metrics report (makespan / CPU / overhead / SLR).
//!
//! Run: `cargo run --release --example uq_campaign [-- --evals 24]`

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use uqsched::cli::Args;
use uqsched::coordinator::start_live;
use uqsched::sched::LivePolicy;
use uqsched::json::Value;
use uqsched::metrics::BoxStats;
use uqsched::models;
use uqsched::runtime::Engine;
use uqsched::umbridge::HttpModel;
use uqsched::workload::lhs;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_evals = args.usize_or("evals", 24)?;
    let queue_depth = args.usize_or("queue", 4)?;
    // 1 paper-minute ~= 30 live ms: scheduler overheads compressed, the
    // gs2lite compute itself runs at natural speed.
    let time_scale = args.f64_or("time-scale", 2000.0)?;

    println!("=== UQ campaign: {n_evals} gs2lite evaluations, queue depth \
              {queue_depth}, HQ backend ===");
    let engine = Arc::new(Engine::from_default_dir()?);
    engine.warmup(&["gs2_chunk", "qoi_integral"])?;

    let stack = start_live(
        engine.clone(),
        &[models::GS2_NAME],
        "hq",
        queue_depth,
        time_scale,
        true,
        LivePolicy::Fcfs,
    )?;
    println!("balancer at {}", stack.balancer.url());

    // The campaign: N clients' worth of requests with a fixed number in
    // flight (the paper's queue-filling protocol), FCFS at the balancer.
    let points = lhs(n_evals, 20250710);
    let next = Arc::new(AtomicU64::new(0));
    let results: Arc<Mutex<Vec<(usize, f64, f64, f64, f64)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let url = stack.balancer.url();

    let mut threads = Vec::new();
    for _ in 0..queue_depth {
        let next = next.clone();
        let results = results.clone();
        let url = url.clone();
        let points = points.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = loop {
                match HttpModel::connect(&url, models::GS2_NAME) {
                    Ok(c) => break c,
                    Err(_) => std::thread::sleep(
                        std::time::Duration::from_millis(20)),
                }
            };
            let cfg = Value::Obj(Default::default());
            loop {
                let i = next.fetch_add(1, Ordering::SeqCst) as usize;
                if i >= points.len() {
                    break;
                }
                let t_submit = Instant::now();
                match client.evaluate(&[points[i].to_vec()], &cfg) {
                    Ok(out) => {
                        let makespan = t_submit.elapsed().as_secs_f64();
                        let gamma = out[0][0];
                        let omega = out[0][1];
                        let chunks = out[2][0];
                        results.lock().unwrap().push(
                            (i, gamma, omega, chunks, makespan));
                    }
                    Err(e) => eprintln!("eval {i} failed: {e:#}"),
                }
            }
        }));
    }
    for t in threads {
        let _ = t.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut rows = results.lock().unwrap().clone();
    rows.sort_by_key(|r| r.0);
    println!("\n  i  gamma     omega     chunks  makespan[s]");
    for (i, g, w, c, m) in &rows {
        println!("{i:>3}  {g:+.4}  {w:+.4}  {c:>6}  {m:>10.3}");
    }

    let makespans: Vec<f64> = rows.iter().map(|r| r.4).collect();
    let chunks: Vec<f64> = rows.iter().map(|r| r.3).collect();
    println!("\nper-eval makespan [s]: {}", BoxStats::from(&makespans).row());
    println!("chunk counts:          {}", BoxStats::from(&chunks).row());
    println!("campaign wall time: {wall:.1}s for {} evals ({}
 servers, \
              registration queries {})",
             rows.len(),
             stack.balancer.registry().total(),
             stack.balancer.registration_queries.load(Ordering::Relaxed));

    // QoI integral at the fastest-growing evaluated point (eq. (5) proxy),
    // through the QoI artifact directly.
    let best = rows
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("results");
    let th: Vec<f32> = points[best.0].iter().map(|&v| v as f32).collect();
    let qoi = engine.execute("qoi_integral", &[th])?;
    println!("\nQoI integral at the most unstable point (eval {}): Q = {:.6}",
             best.0, qoi[0][0]);
    println!("uq_campaign OK ({} evaluations end-to-end)", rows.len());
    std::process::exit(0);
}
