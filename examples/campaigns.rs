//! Campaign-plane tour: every shipped submission policy against both
//! scheduler stacks, on the sim plane (virtual time — runs in seconds).
//!
//! Shows what the campaign plane adds on top of the paper's protocol:
//!
//! * the paper's fixed-depth protocol as one policy among many,
//! * bursty open-loop arrivals where queue depth is an *output*,
//! * a multi-user mix with per-user fairness (Jain index over SLRs),
//! * runtime-heteroskedastic families defeating uniform time requests,
//! * an adaptive Bayesian-inversion-style policy whose batch sizes
//!   depend on the results observed so far,
//! * a flaky cluster: one seeded fault plan (worker crashes, failing
//!   attempts, retry budgets) replayed identically against all four
//!   cores, so the makespan deltas are pure recovery-policy cost,
//! * dependency DAGs through the same kernel: an MLDA multilevel
//!   campaign (coarse chains gate fine ones; per-level time-to-Nth is
//!   the headline metric, compared across hq / edf / gang) and a
//!   stage-in -> fanout computes -> reduce pipeline,
//!
//! and — via the `SchedulerCore` seam — that every policy runs
//! unchanged against a *third* and *fourth* scheduler (`worksteal`, the
//! partitioned work-stealing dispatcher, and `edf`, deadline-EDF) next
//! to the paper's two.
//!
//! Illustrative companion to `uqsched campaign` (this examples/ tree
//! sits outside the cargo package and is not built by it; run the same
//! scenarios with e.g. `cargo run --release -- campaign --policy bursty
//! --scheduler worksteal --tasks 60`).

use uqsched::campaign::{
    self, AdaptiveBayes, CampaignConfig, CampaignResult, Family, FixedDepth,
    HeteroFamilies, Mlda, MldaLevel, PoissonBurst, Sink, SlurmMode,
    StageInOut, Submission, Submitter, UserMix, UserStream,
};
use uqsched::cli::Args;
use uqsched::clock::{Micros, SEC};
use uqsched::cluster::ClusterSpec;
use uqsched::metrics::{BoxStats, JobRecord};
use uqsched::sched::FaultSpec;
use uqsched::workload::{App, RuntimeModel};

/// Open-loop wave submitter: the whole campaign arrives in **one**
/// [`Sink::submit_many`] call — a single sink reservation and one
/// kernel drain pass, where per-item [`Sink::submit`] would grow the
/// buffer and schedule follow-ups item by item.  The adaptive policy
/// below batches each of its rounds through the same API.
struct OneWave {
    app: App,
    n: u64,
    rtm: RuntimeModel,
    started: bool,
}

impl OneWave {
    fn new(app: App, n: u64, seed: u64) -> Self {
        OneWave { app, n, rtm: RuntimeModel::new(seed), started: false }
    }
}

impl Submitter for OneWave {
    fn label(&self) -> &'static str {
        "one-wave"
    }

    fn start(&mut self, sink: &mut Sink) {
        self.started = true;
        let (app, rtm) = (self.app, &self.rtm);
        sink.submit_many((0..self.n).map(|tag| Submission {
            tag,
            user: 0,
            app,
            duration: rtm.duration(app, tag),
        }));
    }

    fn wake(&mut self, _t: Micros, _token: u64, _sink: &mut Sink) {}

    fn completed(&mut self, _t: Micros, _rec: &JobRecord, _sink: &mut Sink) {}

    fn finished(&self, completed: u64) -> bool {
        self.started && completed >= self.n
    }
}

fn report(r: &CampaignResult) {
    let m = &r.metrics;
    println!(
        "  {:<16} {:<16} {:>6} evals  makespan {:>9.1} s  peak depth {:>6}  fairness {:.3}",
        m.policy,
        m.scheduler,
        m.completed,
        m.makespan as f64 / SEC as f64,
        m.peak_in_flight,
        m.fairness_jain,
    );
    if let Some(&(n, t)) = m.time_to.first() {
        println!(
            "  {:<33} first of {n} results after {:.1} s",
            "",
            t as f64 / SEC as f64
        );
    }
    for u in &m.per_user {
        println!(
            "  {:<33} user {}: {} evals, mean SLR {:.2}",
            "", u.user, u.completed, u.mean_slr
        );
    }
    println!(
        "  {:<33} overhead[s]: {}",
        "",
        BoxStats::from(&r.experiment.overheads_sec()).row()
    );
}

/// `report` plus the recovery counters the fault plane adds.
fn report_flaky(r: &CampaignResult) {
    report(r);
    let m = &r.metrics;
    println!(
        "  {:<33} {} retries, {} quarantined, {} worker crashes",
        "", m.retries, m.quarantined, m.worker_crashes
    );
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let tasks = args.u64_or("tasks", 60)?;
    let seed = args.u64_or("seed", 1)?;

    let mut cfg = CampaignConfig::paper(App::Gp, 4, seed);
    cfg.cluster = ClusterSpec::small(16);
    cfg.overheads.bg_interarrival = 120 * SEC;

    println!("== fixed depth (the paper's protocol) ==");
    for mode in [SlurmMode::Native, SlurmMode::UmBridge] {
        let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
        report(&campaign::run_slurm(&cfg, &mut sub, mode));
    }
    let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
    report(&campaign::run_hq(&cfg, &mut sub));
    let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
    report(&campaign::run_worksteal(&cfg, &mut sub));
    let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
    report(&campaign::run_edf(&cfg, &mut sub));

    println!("== bursty open-loop arrivals (Poisson bursts) ==");
    let mut sub = PoissonBurst::new(App::Gp, tasks, 2 * SEC, (1, 8), seed);
    report(&campaign::run_hq(&cfg, &mut sub));
    let mut sub = PoissonBurst::new(App::Gp, tasks, 2 * SEC, (1, 8), seed);
    report(&campaign::run_worksteal(&cfg, &mut sub));
    let mut sub = PoissonBurst::new(App::Gp, tasks, 2 * SEC, (1, 8), seed);
    report(&campaign::run_edf(&cfg, &mut sub));

    println!("== multi-user mix (two tenants, shared cluster) ==");
    let streams = vec![
        UserStream { user: 0, app: App::Gp, n_evals: tasks / 2, queue_depth: 2 },
        UserStream {
            user: 1,
            app: App::Eigen100,
            n_evals: tasks / 2,
            queue_depth: 2,
        },
    ];
    let mut sub = UserMix::new(streams.clone(), seed);
    report(&campaign::run_slurm(&cfg, &mut sub, SlurmMode::Native));
    let mut sub = UserMix::new(streams, seed);
    report(&campaign::run_hq(&cfg, &mut sub));

    println!("== heteroskedastic task families ==");
    let fams = vec![
        Family { app: App::Gp, weight: 3.0, sigma: 0.0 },
        Family { app: App::Gp, weight: 1.0, sigma: 1.0 },
    ];
    let mut sub = HeteroFamilies::new(fams, tasks, 4, seed);
    report(&campaign::run_hq(&cfg, &mut sub));

    println!("== adaptive batches (Bayesian-inversion style) ==");
    let mut sub = AdaptiveBayes::new(App::Gp, tasks, seed).with_batches(8, 4, 16);
    let r = campaign::run_hq(&cfg, &mut sub);
    report(&r);
    println!(
        "  {:<33} converged after {} rounds, {} of {} budget spent",
        "",
        sub.rounds(),
        r.metrics.completed,
        tasks
    );

    println!("== batched wave (whole campaign in one submit_many) ==");
    // The entire budget lands in the kernel as one burst: queue depth
    // peaks at `tasks`, and the sink grows exactly once.  Contrast with
    // the adaptive policy above, which meters the same API per round.
    let mut sub = OneWave::new(App::Gp, tasks, seed);
    report(&campaign::run_hq(&cfg, &mut sub));
    let mut sub = OneWave::new(App::Gp, tasks, seed);
    report(&campaign::run_worksteal(&cfg, &mut sub));

    println!("== flaky cluster (one seeded fault plan, all four cores) ==");
    // The same deterministic fault trace — a worker crash every ~2
    // virtual minutes, 5% of attempts failing, three attempts before a
    // task is quarantined — replayed against every core, so the
    // makespan inflation below is pure recovery-policy difference, not
    // luck.  `uqsched campaign --faults ...` exposes the same spec.
    let spec = FaultSpec::parse("crash=120s,fail=0.05,attempts=3,backoff=1s:30s,seed=7")
        .map_err(anyhow::Error::msg)?;
    println!("  fault plan: {}", spec.describe());
    cfg.faults = Some(spec);
    let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
    report_flaky(&campaign::run_slurm(&cfg, &mut sub, SlurmMode::Native));
    let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
    report_flaky(&campaign::run_hq(&cfg, &mut sub));
    let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
    report_flaky(&campaign::run_worksteal(&cfg, &mut sub));
    let mut sub = FixedDepth::new(App::Gp, tasks, 4, seed);
    report_flaky(&campaign::run_edf(&cfg, &mut sub));
    cfg.faults = None;

    println!("== MLDA multilevel campaign (per-level time-to-Nth) ==");
    // Three levels, coarsest first: lots of cheap coarse chains, fewer
    // medium ones, a handful of expensive fine evaluations.  Chains are
    // dependency edges — a child waits Blocked in the kernel until its
    // parent's record is terminal — so the per-level completion curves
    // below are pure scheduler policy, not submitter luck.
    let levels = || {
        vec![
            MldaLevel { count: (tasks / 2).max(4), runtime_scale: 0.5 },
            MldaLevel { count: (tasks / 4).max(2), runtime_scale: 1.0 },
            MldaLevel { count: (tasks / 8).max(1), runtime_scale: 2.0 },
        ]
    };
    let mlda = |seed| Mlda::new(App::Gp, levels(), seed).with_occupancy(4, 1, 16);
    let runs: [(&str, fn(&CampaignConfig, &mut dyn Submitter) -> CampaignResult); 3] = [
        ("hq", campaign::run_hq),
        ("edf", campaign::run_edf),
        ("gang", campaign::run_gang),
    ];
    for (name, run) in runs {
        let mut sub = mlda(seed);
        let r = run(&cfg, &mut sub);
        report(&r);
        let m = &r.metrics;
        println!(
            "  {:<33} {} edges | {} released | {} skipped | peak blocked {}",
            "", m.dep_edges, m.released, m.skipped, m.peak_blocked
        );
        for (user, ms) in &m.per_user_time_to {
            if let Some(&(n, t)) = ms.last() {
                println!(
                    "  {:<33} [{name}] level {user}: all {n} results by {:.1} s",
                    "",
                    t as f64 / SEC as f64
                );
            }
        }
    }

    println!("== stage-in / compute / reduce rounds ==");
    // Each round: one transfer task gates a fanout of computes, which
    // all gate one reduce — a data-intensive DAG with exact structure
    // (rounds x (fanout + 2) records, 2 x fanout edges per round).
    let mut sub = StageInOut::new(App::Gp, 8, 6, 2, seed);
    let r = campaign::run_hq(&cfg, &mut sub);
    report(&r);
    let m = &r.metrics;
    println!(
        "  {:<33} {} edges | {} released | peak blocked {}",
        "", m.dep_edges, m.released, m.peak_blocked
    );
    Ok(())
}
