"""gs2lite — reduced kinetic-ballooning dispersion model (GS2 stand-in).

The paper's expensive workload is linear GS2 in initial-value mode: the
gyrokinetic system is integrated until the fastest-growing mode dominates,
so wall-clock time is set by the spectral gap of the linearised operator
and is not predictable from the inputs.  We reproduce exactly that
*computational* structure on a reduced model (see DESIGN.md section 6):

* A complex linear operator ``A(theta)`` on a ballooning-angle grid,
  assembled from the seven Table-II inputs (safety factor q, magnetic
  shear s, electron density gradient, electron temperature gradient,
  beta, collision frequency nu, binormal wavelength k_y).
* Power iteration ``z <- A z / ||A z||`` finds the dominant mode; the
  Rayleigh quotient gives the complex frequency ``omega + i gamma``.
* The AOT artifact is one *chunk* of ``CHUNK_ITERS`` iterations with a
  residual output; the Rust model server loops fixed-shape chunk calls
  until the residual converges.  Runtime therefore varies with the input
  parameters and is unknown a-priori — the scheduling property the paper
  studies.

Physics flavour (not a validated gyrokinetic code — a workload-faithful
substitute): ``A = D + diag(V)`` where ``D`` is the field-line diffusion /
parallel-streaming stencil and ``V(theta)`` combines a ballooning-drive
well ``~ (dens + temp) * beta`` modulated by ``cos(theta)`` shaping (q, s
set the envelope), an imaginary drift-resonance part set by ``k_y`` and
the gradients, and collisional damping ``~ -nu``.

Complex arithmetic is carried in explicit (re, im) planes so the lowered
HLO is pure f32 (the Rust PJRT path never sees complex literals).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Ballooning-angle grid resolution and iterations per AOT chunk.
NGRID = 256
CHUNK_ITERS = 64
# Extended ballooning angle domain.
THETA_MAX = 4.0 * jnp.pi

# Table II of the paper: the seven varied GS2 inputs and their ranges.
PARAM_NAMES = (
    "safety_factor",
    "magnetic_shear",
    "electron_density_gradient",
    "electron_temperature_gradient",
    "beta",
    "collision_frequency",
    "binormal_wavelength",
)
PARAM_RANGES = (
    (2.0, 9.0),
    (0.0, 5.0),
    (0.0, 10.0),
    (0.5, 6.0),
    (0.0, 0.3),
    (0.0, 0.1),
    (0.0, 1.0),
)


def build_operator(theta_params: jax.Array, n: int = NGRID):
    """Assemble the (re, im) planes of the dispersion operator A(params).

    Args:
      theta_params: (7,) parameter vector in Table-II physical units.
      n: grid resolution.

    Returns:
      (ar, ai): two (n, n) f32 arrays, A = ar + i*ai.
    """
    p = theta_params.astype(jnp.float32)
    q, shear, dens, temp, beta, nu, ky = (p[i] for i in range(7))

    grid = jnp.linspace(-THETA_MAX, THETA_MAX, n, dtype=jnp.float32)
    dth = grid[1] - grid[0]

    # Parallel streaming / field-line diffusion: second-difference stencil
    # scaled by 1/(q R)^2 — higher safety factor -> weaker parallel
    # coupling -> slower conditioning of the dominant mode.
    kpar = 1.0 / (1.0 + q)
    lap = (
        -2.0 * jnp.eye(n, dtype=jnp.float32)
        + jnp.eye(n, k=1, dtype=jnp.float32)
        + jnp.eye(n, k=-1, dtype=jnp.float32)
    ) * (kpar / dth) ** 2

    # Ballooning envelope: secular shear term makes the effective
    # perpendicular wavenumber grow along the field line (saturated so
    # strongly-sheared corners stay marginal rather than instantly damped,
    # which is what gives the runtime distribution its heavy tail).
    kperp2 = ky**2 * (1.0 + (shear * grid - jnp.sin(grid)) ** 2)
    kperp2 = 60.0 * jnp.tanh(kperp2 / 60.0)

    # Drive: interchange/ballooning well fed by the pressure gradients,
    # finite-Larmor-radius damped at high kperp.
    drive = (dens + temp) * (0.55 + 0.45 * beta * 10.0) \
        * (jnp.cos(grid) + 0.35) / (1.0 + 0.5 * kperp2)

    # Real potential: drive well minus FLR stabilisation.
    v_re = drive - 0.18 * kperp2

    # Imaginary part: drift resonance (propagation) plus collisional
    # damping; the diamagnetic frequency scales with ky * gradients.
    omega_star = ky * (dens + 0.6 * temp) * 0.5
    v_im = omega_star * jnp.cos(0.5 * grid) - nu * 4.0 * (1.0 + kperp2)

    ar = 0.02 * lap + jnp.diag(0.12 * v_re)
    ai = jnp.diag(0.12 * v_im)
    # Weak non-normal coupling so the spectrum is genuinely complex.
    ai = ai + 0.004 * (jnp.eye(n, k=1, dtype=jnp.float32)
                       - jnp.eye(n, k=-1, dtype=jnp.float32))
    return ar, ai


def _cmatvec(ar, ai, zr, zi):
    """(ar + i ai) @ (zr + i zi) in explicit planes."""
    wr = ar @ zr - ai @ zi
    wi = ar @ zi + ai @ zr
    return wr, wi


def initial_state(n: int = NGRID):
    """Deterministic initial mode: a gaussian envelope (matches Rust side)."""
    grid = jnp.linspace(-THETA_MAX, THETA_MAX, n, dtype=jnp.float32)
    zr = jnp.exp(-0.5 * grid**2)
    zi = 0.1 * jnp.sin(grid) * zr
    nrm = jnp.sqrt(jnp.sum(zr**2 + zi**2))
    return jnp.stack([zr / nrm, zi / nrm], axis=1)   # (n, 2)


@functools.partial(jax.jit, static_argnames=("n", "iters"))
def chunk(theta_params: jax.Array, state: jax.Array,
          n: int = NGRID, iters: int = CHUNK_ITERS):
    """One AOT chunk: ``iters`` power iterations on A(theta_params).

    Args:
      theta_params: (7,) inputs.
      state: (n, 2) current mode vector (re, im planes), unit norm.

    Returns:
      state':   (n, 2) updated unit-norm mode vector.
      eig:      (2,)  Rayleigh-quotient estimate (gamma, omega) --
                growth rate = log|lambda| per unit "time", frequency =
                arg(lambda); reported in GS2-like units.
      residual: (1,)  ||A z - lambda z|| convergence measure.
    """
    ar, ai = build_operator(theta_params, n)
    shift = 1.5  # power-iteration shift keeps the dominant mode unique
    ars = ar + shift * jnp.eye(n, dtype=jnp.float32)

    def body(_, zs):
        zr, zi = zs[:, 0], zs[:, 1]
        wr, wi = _cmatvec(ars, ai, zr, zi)
        nrm = jnp.sqrt(jnp.sum(wr**2 + wi**2)) + 1e-30
        return jnp.stack([wr / nrm, wi / nrm], axis=1)

    out = jax.lax.fori_loop(0, iters, body, state.astype(jnp.float32))

    zr, zi = out[:, 0], out[:, 1]
    wr, wi = _cmatvec(ars, ai, zr, zi)
    # Rayleigh quotient lambda = z^H w  (z has unit norm).
    lam_r = jnp.sum(zr * wr + zi * wi)
    lam_i = jnp.sum(zr * wi - zi * wr)
    # Residual ||w - lambda z||.
    rr = wr - (lam_r * zr - lam_i * zi)
    ri = wi - (lam_r * zi + lam_i * zr)
    residual = jnp.sqrt(jnp.sum(rr**2 + ri**2))

    gamma = lam_r - shift          # growth rate (unstable if > 0)
    omega = lam_i                  # mode frequency
    eig = jnp.stack([gamma, omega])
    return out, eig, jnp.reshape(residual, (1,))


def solve_direct(theta_params, n: int = NGRID):
    """Ground truth via dense eigendecomposition (build-time only).

    Used to generate GP training data and to test ``chunk`` convergence.
    Returns (gamma, omega) of the eigenvalue with the largest |lambda +
    shift| — i.e. the mode power iteration converges to.
    """
    import numpy as np

    ar, ai = build_operator(jnp.asarray(theta_params), n)
    a = np.asarray(ar) + 1j * np.asarray(ai)
    lam = np.linalg.eigvals(a)
    shift = 1.5
    dom = lam[np.argmax(np.abs(lam + shift))]
    return float(dom.real), float(dom.imag)


def convergence_chunks(theta_params, tol: float = 1e-4,
                       max_chunks: int = 400, n: int = NGRID) -> int:
    """Number of chunk calls until residual < tol (build-time diagnostics).

    This is the quantity that makes gs2lite runtimes input-dependent; the
    sim-plane runtime model in Rust is calibrated against it.
    """
    state = initial_state(n)
    for c in range(1, max_chunks + 1):
        state, _eig, res = chunk(jnp.asarray(theta_params), state, n=n)
        if float(res[0]) < tol:
            return c
    return max_chunks
