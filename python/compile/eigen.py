"""Parallel-order cyclic Jacobi eigensolver (the paper's eigen benchmarks).

The paper's cheap/moderate workloads are ``numpy.linalg.eig`` on dense
n=100 and n=5000 matrices (LAPACK ``_geev``).  LAPACK custom-calls cannot
cross the HLO-text AOT boundary, so we implement the solver itself: a
*parallel-order* Jacobi method for symmetric matrices in which each round
applies n/2 disjoint Givens rotations as one orthogonal similarity
``A <- Q^T A Q`` — two dense matmuls, which is exactly the memory-bound
dense-algebra profile the paper's eigen benchmark exercises (and maps to
the MXU on real hardware rather than a scalar rotation loop).

The round-robin (circle method) schedule covering all n(n-1)/2 pairs in
n-1 rounds is precomputed and baked into the HLO as a constant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# Benchmark sizes: eigen-100 matches the paper; the paper's eigen-5000 is
# scaled to n=256 to keep the compiled artifact inside this testbed's CPU
# budget (see DESIGN.md section 2) while preserving the cheap-vs-moderate
# runtime contrast.
N_SMALL = 100
N_LARGE = 256
SWEEPS_SMALL = 12
SWEEPS_LARGE = 18
SWEEPS = SWEEPS_SMALL


def round_robin_schedule(n: int) -> np.ndarray:
    """(n-1, n//2, 2) disjoint-pair schedule via the circle method."""
    assert n % 2 == 0, "parallel Jacobi needs even n"
    players = list(range(n))
    rounds = []
    for _ in range(n - 1):
        pairs = []
        for k in range(n // 2):
            a, b = players[k], players[n - 1 - k]
            pairs.append((min(a, b), max(a, b)))
        rounds.append(pairs)
        # rotate all but the first
        players = [players[0]] + [players[-1]] + players[1:-1]
    return np.asarray(rounds, dtype=np.int32)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def jacobi_eigvals(a: jax.Array, sweeps: int = SWEEPS):
    """Eigenvalues of a symmetric matrix by parallel-order Jacobi.

    Args:
      a: (n, n) symmetric f32 matrix (only its symmetric part is used).
      sweeps: number of full sweeps (each = n-1 rounds of n/2 rotations).

    Returns:
      w:   (n,) eigenvalues, ascending.
      off: ()   final off-diagonal Frobenius norm (convergence measure).
    """
    n = a.shape[0]
    a = 0.5 * (a + a.T).astype(jnp.float32)
    sched = jnp.asarray(round_robin_schedule(n))       # (n-1, n/2, 2)
    eye = jnp.eye(n, dtype=jnp.float32)

    def round_body(r, a):
        pairs = jax.lax.dynamic_index_in_dim(sched, r % (n - 1), 0,
                                             keepdims=False)  # (n/2, 2)
        ps, qs = pairs[:, 0], pairs[:, 1]
        apq = a[ps, qs]
        app = a[ps, ps]
        aqq = a[qs, qs]
        theta = 0.5 * jnp.arctan2(2.0 * apq, aqq - app)
        c = jnp.cos(theta)
        s = jnp.sin(theta)
        q = eye.at[(ps, ps)].set(c)
        q = q.at[(qs, qs)].set(c)
        q = q.at[(ps, qs)].set(s)
        q = q.at[(qs, ps)].set(-s)
        a = q.T @ a @ q
        return 0.5 * (a + a.T)   # re-symmetrise against drift

    total_rounds = sweeps * (n - 1)
    a = jax.lax.fori_loop(0, total_rounds, round_body, a)

    w = jnp.sort(jnp.diagonal(a))
    off = jnp.sqrt(jnp.sum((a - jnp.diag(jnp.diagonal(a))) ** 2))
    return w, off


def random_symmetric(n: int, seed: int) -> np.ndarray:
    """Seeded benchmark matrix, matching the Rust-side generator.

    Uses SplitMix64 so the Rust workload generator can produce the exact
    same matrices (same seed -> same bits) without numpy.
    """
    x = np.uint64(seed)
    out = np.empty(n * n, dtype=np.float32)
    GOLDEN = np.uint64(0x9E3779B97F4A7C15)
    M1 = np.uint64(0xBF58476D1CE4E5B9)
    M2 = np.uint64(0x94D049BB133111EB)
    with np.errstate(over="ignore"):
        for i in range(n * n):
            x = x + GOLDEN
            z = x
            z = (z ^ (z >> np.uint64(30))) * M1
            z = (z ^ (z >> np.uint64(27))) * M2
            z = z ^ (z >> np.uint64(31))
            # top 24 bits -> [0, 1) -> [-1, 1)
            out[i] = (float(z >> np.uint64(40)) / float(1 << 24)) * 2.0 - 1.0
    a = out.reshape(n, n)
    return 0.5 * (a + a.T)
