"""L2 entry-point registry: every AOT artifact the Rust runtime loads.

Each entry is (name, build_fn) where ``build_fn(gp)`` returns
``(fn, example_args)``; ``aot.py`` lowers ``jax.jit(fn)`` at the example
shapes to HLO text.  All model constants (GP training set, Cholesky
factor, quadrature grids, Jacobi schedules) are baked into the HLO so the
Rust request path is pure PJRT execution.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import eigen, gp as gp_mod, gs2lite, qoi

# Batch sizes for the GP prediction artifacts: b16 serves interactive
# requests; b256 is the perf-bench / campaign shape.
GP_BATCH_SMALL = 16
GP_BATCH_LARGE = 256


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries(gp_params):
    """Return {name: (fn, example_specs)} for every artifact."""
    predict = gp_mod.make_predict_fn(gp_params)
    qoi_fn = qoi.make_qoi_fn(gp_params)

    def gs2_chunk(theta, state):
        return gs2lite.chunk(theta, state)

    def eigen_small(a):
        return eigen.jacobi_eigvals(a, sweeps=eigen.SWEEPS_SMALL)

    def eigen_large(a):
        return eigen.jacobi_eigvals(a, sweeps=eigen.SWEEPS_LARGE)

    return {
        "gp_predict_b16": (predict, [_spec((GP_BATCH_SMALL, 7))]),
        "gp_predict_b256": (predict, [_spec((GP_BATCH_LARGE, 7))]),
        "gs2_chunk": (gs2_chunk,
                      [_spec((7,)), _spec((gs2lite.NGRID, 2))]),
        "eigen_small": (eigen_small,
                        [_spec((eigen.N_SMALL, eigen.N_SMALL))]),
        "eigen_large": (eigen_large,
                        [_spec((eigen.N_LARGE, eigen.N_LARGE))]),
        "qoi_integral": (qoi_fn, [_spec((7,))]),
    }
