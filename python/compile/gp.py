"""Gaussian-process surrogate of gs2lite (the paper's GP workload).

The paper benchmarks a pre-trained GP (Hornsby et al. 2024) that maps the
seven Table-II inputs to (mode growth rate, mode frequency).  We train the
equivalent surrogate at build time on seeded LHS samples of the gs2lite
dispersion model, with an anisotropic RBF kernel and exact conditioning:

* hyperparameters (per-dimension lengthscales, signal variance, noise)
  are fitted by Adam on the exact log marginal likelihood;
* ``alpha = (K + sn2 I)^{-1} Y`` and the Cholesky factor ``L`` are baked
  into the prediction artifact as constants, so the Rust request path is
  a single PJRT execution with no host-side linear algebra;
* the prediction mean runs through the L1 Pallas kernel
  (:mod:`compile.kernels.rbf`); the variance path is a triangular solve
  against the baked ``L`` (a native HLO TriangularSolve op).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import gs2lite
from .kernels import rbf, ref


@dataclasses.dataclass
class GpParams:
    """Everything needed to evaluate the trained GP."""
    x_train: np.ndarray     # (N, 7) normalised inputs in [0, 1]
    alpha: np.ndarray       # (N, 2)
    chol: np.ndarray        # (N, N) lower Cholesky of K + sn2 I
    kinv: np.ndarray        # (N, N) inverse of K + sn2 I (baked so the
                            # variance path is pure matmul HLO; LAPACK
                            # custom-calls cannot cross the AOT boundary)
    inv_ls: np.ndarray      # (7,) inverse squared lengthscales
    sf2: float              # signal variance
    sn2: float              # noise variance
    y_mean: np.ndarray      # (2,) output standardisation
    y_std: np.ndarray       # (2,)
    lo: np.ndarray          # (7,) input range, for normalisation
    hi: np.ndarray          # (7,)


def lhs_sample(n: int, dim: int, seed: int) -> np.ndarray:
    """Seeded Latin hypercube in [0,1]^dim (paper section IV.B: seeded LHS)."""
    rng = np.random.default_rng(seed)
    u = (rng.permutation(n).reshape(-1, 1) if dim == 1 else
         np.stack([rng.permutation(n) for _ in range(dim)], axis=1))
    return (u + rng.uniform(size=(n, dim))) / n


def param_bounds() -> tuple[np.ndarray, np.ndarray]:
    lo = np.array([r[0] for r in gs2lite.PARAM_RANGES], dtype=np.float32)
    hi = np.array([r[1] for r in gs2lite.PARAM_RANGES], dtype=np.float32)
    return lo, hi


def training_data(n: int, seed: int, ngrid: int = gs2lite.NGRID):
    """LHS inputs + direct-solve (gamma, omega) targets of gs2lite."""
    lo, hi = param_bounds()
    x01 = lhs_sample(n, 7, seed).astype(np.float32)
    x_phys = lo + x01 * (hi - lo)
    y = np.empty((n, 2), dtype=np.float32)
    for i in range(n):
        g, w = gs2lite.solve_direct(x_phys[i], n=ngrid)
        y[i] = (g, w)
    return x01, x_phys, y


def _mll(params, x, y):
    """Exact negative log marginal likelihood, shared kernel over outputs."""
    log_ls, log_sf2, log_sn2 = params
    inv_ls = jnp.exp(-2.0 * log_ls)
    sf2 = jnp.exp(log_sf2)
    sn2 = jnp.exp(log_sn2) + 1e-6
    k = ref.rbf_kernel_matrix(x, x, inv_ls, sf2)
    n = x.shape[0]
    kn = k + sn2 * jnp.eye(n, dtype=jnp.float32)
    chol = jnp.linalg.cholesky(kn)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    quad = jnp.sum(alpha * y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    o = y.shape[1]
    return 0.5 * quad + 0.5 * o * logdet + 0.5 * o * n * jnp.log(2 * jnp.pi)


def train(x01: np.ndarray, y_raw: np.ndarray, steps: int = 250,
          lr: float = 0.05, seed: int = 0) -> GpParams:
    """Fit hyperparameters by Adam on the exact MLL; return baked params."""
    y_mean = y_raw.mean(axis=0)
    y_std = y_raw.std(axis=0) + 1e-8
    y = ((y_raw - y_mean) / y_std).astype(np.float32)
    x = jnp.asarray(x01, jnp.float32)
    yj = jnp.asarray(y)

    params = [jnp.full((7,), -0.7, jnp.float32),   # log lengthscales ~0.5
              jnp.asarray(0.0, jnp.float32),       # log sf2
              jnp.asarray(-4.0, jnp.float32)]      # log sn2

    loss_grad = jax.jit(jax.value_and_grad(lambda p: _mll(p, x, yj)))

    # Minimal Adam (no optax dependency needed at build time).
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    b1, b2, eps = 0.9, 0.999, 1e-8
    loss = None
    for t in range(1, steps + 1):
        loss, g = loss_grad(params)
        for i in range(len(params)):
            m[i] = b1 * m[i] + (1 - b1) * g[i]
            v[i] = b2 * v[i] + (1 - b2) * g[i] ** 2
            mh = m[i] / (1 - b1**t)
            vh = v[i] / (1 - b2**t)
            params[i] = params[i] - lr * mh / (jnp.sqrt(vh) + eps)

    log_ls, log_sf2, log_sn2 = params
    inv_ls = np.exp(-2.0 * np.asarray(log_ls))
    sf2 = float(np.exp(log_sf2))
    sn2 = float(np.exp(log_sn2)) + 1e-6

    k = np.asarray(ref.rbf_kernel_matrix(x, x, jnp.asarray(inv_ls), sf2))
    kn = k + sn2 * np.eye(len(x01), dtype=np.float32)
    chol = np.linalg.cholesky(kn.astype(np.float64)).astype(np.float32)
    alpha = np.linalg.solve(kn.astype(np.float64),
                            y.astype(np.float64)).astype(np.float32)
    kinv = np.linalg.inv(kn.astype(np.float64)).astype(np.float32)

    lo, hi = param_bounds()
    return GpParams(x_train=np.asarray(x01, np.float32), alpha=alpha,
                    chol=chol, kinv=kinv,
                    inv_ls=inv_ls.astype(np.float32), sf2=sf2,
                    sn2=sn2, y_mean=y_mean.astype(np.float32),
                    y_std=y_std.astype(np.float32), lo=lo, hi=hi)


def make_predict_fn(gp: GpParams):
    """Build the AOT prediction entry point with all constants baked.

    Signature: (B, 7) physical-units inputs ->
      mean (B, 2) physical units, var (B, 2) physical units^2.
    """
    xt = jnp.asarray(gp.x_train)
    alpha = jnp.asarray(gp.alpha)
    inv_ls = jnp.asarray(gp.inv_ls)
    sf2 = jnp.asarray(gp.sf2, jnp.float32)
    kinv = jnp.asarray(gp.kinv)
    lo = jnp.asarray(gp.lo)
    hi = jnp.asarray(gp.hi)
    y_mean = jnp.asarray(gp.y_mean)
    y_std = jnp.asarray(gp.y_std)

    def predict(x_phys):
        x01 = (x_phys.astype(jnp.float32) - lo) / (hi - lo)
        mean_n, kstar = rbf.rbf_mean(x01, xt, inv_ls, alpha, sf2)
        # var = sf2 - k*^T (K + sn2 I)^-1 k*, with the precision matrix
        # baked as a constant: two matmuls, no LAPACK custom-call.
        quad = jnp.sum((kstar @ kinv) * kstar, axis=1)
        var_lat = jnp.maximum(sf2 - quad, 0.0)  # (B,)
        mean = mean_n * y_std[None, :] + y_mean[None, :]
        var = var_lat[:, None] * (y_std[None, :] ** 2)
        return mean, var

    return predict


def predict_ref(gp: GpParams, x_phys: np.ndarray):
    """Numpy oracle for the baked predict fn (used by pytest)."""
    x01 = (np.asarray(x_phys, np.float32) - gp.lo) / (gp.hi - gp.lo)
    diff = x01[:, None, :] - gp.x_train[None, :, :]
    d2 = np.sum(diff**2 * gp.inv_ls[None, None, :], axis=-1)
    kstar = gp.sf2 * np.exp(-0.5 * d2)
    mean_n = kstar @ gp.alpha
    v = np.linalg.solve(np.tril(gp.chol), kstar.T)  # triangular solve
    var_lat = np.maximum(gp.sf2 - np.sum(v * v, axis=0), 0.0)
    mean = mean_n * gp.y_std[None, :] + gp.y_mean[None, :]
    var = var_lat[:, None] * gp.y_std[None, :] ** 2
    return mean, var
