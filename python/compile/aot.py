"""AOT pipeline: train the GP, lower every L2 entry point to HLO text.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):
  * ``<entry>.hlo.txt``   one per registry entry (six total)
  * ``manifest.json``     shapes/dtypes per entry + model metadata the
                          Rust side needs (grid sizes, parameter ranges,
                          GP hyperparameters, initial-state spec)
  * ``gp_train.npz``      cached training data (rebuilds are incremental)

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import eigen, gp as gp_mod, gs2lite, model
from .kernels import rbf

TRAIN_N = 224
TRAIN_SEED = 20250710
TRAIN_STEPS = 250


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _train_cache_key() -> str:
    h = hashlib.sha256()
    here = os.path.dirname(__file__)
    for f in ("gs2lite.py",):
        with open(os.path.join(here, f), "rb") as fh:
            h.update(fh.read())
    h.update(f"{TRAIN_N}:{TRAIN_SEED}:{gs2lite.NGRID}".encode())
    return h.hexdigest()[:16]


def get_training_data(art_dir: str):
    cache = os.path.join(art_dir, "gp_train.npz")
    key = _train_cache_key()
    if os.path.exists(cache):
        z = np.load(cache, allow_pickle=False)
        if str(z.get("key", "")) == key or (
                "key" in z.files and str(z["key"]) == key):
            return z["x01"], z["x_phys"], z["y"]
    print(f"[aot] generating GP training data: {TRAIN_N} direct solves "
          f"of gs2lite (n={gs2lite.NGRID}) ...", flush=True)
    x01, x_phys, y = gp_mod.training_data(TRAIN_N, TRAIN_SEED)
    np.savez(cache, x01=x01, x_phys=x_phys, y=y, key=np.str_(key))
    return x01, x_phys, y


def lower_entry(name, fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=TRAIN_STEPS)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    x01, x_phys, y = get_training_data(args.out_dir)
    print(f"[aot] training GP on {len(x01)} samples "
          f"({args.train_steps} Adam steps on exact MLL) ...", flush=True)
    gpp = gp_mod.train(x01, y, steps=args.train_steps)
    print(f"[aot] GP fitted: sf2={gpp.sf2:.4f} sn2={gpp.sn2:.6f} "
          f"ls={np.round(1/np.sqrt(gpp.inv_ls), 3).tolist()}", flush=True)

    entries = model.build_entries(gpp)
    manifest = {
        "format": "hlo-text",
        "time_scale_note": "see DESIGN.md section 7",
        "entries": {},
        "gs2": {
            "ngrid": gs2lite.NGRID,
            "chunk_iters": gs2lite.CHUNK_ITERS,
            "theta_max": float(gs2lite.THETA_MAX),
            "residual_tol": 1e-4,
            "max_chunks": 400,
        },
        "eigen": {
            "n_small": eigen.N_SMALL,
            "n_large": eigen.N_LARGE,
            "sweeps_small": eigen.SWEEPS_SMALL,
            "sweeps_large": eigen.SWEEPS_LARGE,
        },
        "gp": {
            "train_n": int(len(x01)),
            "train_seed": TRAIN_SEED,
            "sf2": float(gpp.sf2),
            "sn2": float(gpp.sn2),
            "lengthscales": (1.0 / np.sqrt(gpp.inv_ls)).tolist(),
            "y_mean": gpp.y_mean.tolist(),
            "y_std": gpp.y_std.tolist(),
        },
        "params": {
            "names": list(gs2lite.PARAM_NAMES),
            "lo": gpp.lo.tolist(),
            "hi": gpp.hi.tolist(),
        },
        "pallas": rbf.vmem_footprint_bytes(),
    }

    for name, (fn, specs) in entries.items():
        print(f"[aot] lowering {name} ...", flush=True)
        text = lower_entry(name, fn, specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                       for s in specs],
            "hlo_bytes": len(text),
        }
        print(f"[aot]   {path}: {len(text)} bytes", flush=True)

    # Golden test vectors: deterministic inputs -> expected outputs, so the
    # Rust runtime tests can assert end-to-end numerics across the AOT
    # boundary without a Python dependency.
    testvec = {}
    for name, (fn, specs) in entries.items():
        ins = []
        for i, spec in enumerate(specs):
            size = int(np.prod(spec.shape))
            v = np.sin(0.1 * (np.arange(size, dtype=np.float32) + 1 + i))
            if name.startswith("gp_predict") or name == "qoi_integral":
                lo = np.asarray(gpp.lo); hi = np.asarray(gpp.hi)
                u = v.reshape(spec.shape)
                u = lo + (0.5 + 0.5 * u) * (hi - lo)
                ins.append(u.astype(np.float32))
            elif name == "gs2_chunk" and i == 1:
                ins.append(np.asarray(gs2lite.initial_state(),
                                      dtype=np.float32))
            elif name == "gs2_chunk" and i == 0:
                lo = np.asarray(gpp.lo); hi = np.asarray(gpp.hi)
                u = 0.5 + 0.5 * v.reshape(spec.shape)
                ins.append((lo + u * (hi - lo)).astype(np.float32))
            elif name.startswith("eigen"):
                n = spec.shape[0]
                a = v.reshape(n, n)
                ins.append((0.5 * (a + a.T)).astype(np.float32))
            else:
                ins.append(v.reshape(spec.shape))
        outs = jax.jit(fn)(*[jnp.asarray(x) for x in ins])
        outs = jax.tree_util.tree_leaves(outs)
        testvec[name] = {
            "inputs": [x.reshape(-1).tolist() for x in ins],
            "input_shapes": [list(x.shape) for x in ins],
            "outputs": [np.asarray(o).reshape(-1).tolist() for o in outs],
            "output_shapes": [list(np.asarray(o).shape) for o in outs],
        }
    with open(os.path.join(args.out_dir, "testvec.json"), "w") as f:
        json.dump(testvec, f)
    print("[aot] testvec.json written.", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("[aot] manifest.json written; artifacts complete.", flush=True)


if __name__ == "__main__":
    main()
