"""Quasilinear quantity-of-interest integral (paper eq. (5) analogue).

The paper's end goal is a quasilinear saturation-rule integral over the
binormal wavenumber ``k_y`` and the ballooning parameter ``theta_0`` of a
weighted linear growth-rate field — evaluated either on GS2 itself or on
the GP surrogate.  We reproduce the *surrogate* path as a single AOT
artifact: tensor Gauss-Legendre quadrature over a (k_y, theta_0) grid of
GP-mean growth rates with a quasilinear spectral weight.

``theta_0`` shifts the ballooning envelope; in the gs2lite operator that
role is played by the magnetic-shear term, so the theta_0 axis is mapped
onto a shear offset window (documented substitution, DESIGN.md section 2).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import gp as gp_mod
from .kernels import rbf

# Quadrature resolutions (paper: "the accuracy ... depends on the number
# of evaluated points"; 24x16 = 384 surrogate evaluations per QoI).
N_KY = 24
N_THETA0 = 16
KY_RANGE = (0.05, 1.0)
THETA0_SHEAR_WINDOW = 1.0   # shear offset amplitude standing in for theta_0


def gauss_legendre(n: int, lo: float, hi: float):
    x, w = np.polynomial.legendre.leggauss(n)
    x = 0.5 * (hi - lo) * (x + 1.0) + lo
    w = 0.5 * (hi - lo) * w
    return x.astype(np.float32), w.astype(np.float32)


def spectral_weight(ky):
    """Quasilinear flux weight Lambda(k_y): peaked at intermediate k_y."""
    return ky**2 * jnp.exp(-3.0 * ky)


def make_qoi_fn(gp: gp_mod.GpParams):
    """Build the QoI entry point: (7,) base params -> (Q, gamma field).

    The grid overrides dim 6 (binormal wavelength, the k_y axis) and adds
    a theta_0-like offset to dim 1 (magnetic shear), clipped to Table-II
    ranges.  Output ``Q`` is the saturation-rule integral; the (N_KY,
    N_THETA0) growth-rate field is returned for inspection/plots.
    """
    ky_x, ky_w = gauss_legendre(N_KY, *KY_RANGE)
    t0_x, t0_w = gauss_legendre(N_THETA0, -THETA0_SHEAR_WINDOW,
                                THETA0_SHEAR_WINDOW)

    xt = jnp.asarray(gp.x_train)
    alpha = jnp.asarray(gp.alpha)
    inv_ls = jnp.asarray(gp.inv_ls)
    sf2 = jnp.asarray(gp.sf2, jnp.float32)
    lo = jnp.asarray(gp.lo)
    hi = jnp.asarray(gp.hi)
    y_mean = jnp.asarray(gp.y_mean)
    y_std = jnp.asarray(gp.y_std)

    kyg, t0g = jnp.meshgrid(jnp.asarray(ky_x), jnp.asarray(t0_x),
                            indexing="ij")          # (N_KY, N_THETA0)
    wgt = jnp.asarray(ky_w)[:, None] * jnp.asarray(t0_w)[None, :]

    def qoi(base_params):
        b = base_params.astype(jnp.float32)
        m = N_KY * N_THETA0
        x = jnp.broadcast_to(b[None, :], (m, 7))
        x = x.at[:, 6].set(kyg.reshape(-1))
        shear = jnp.clip(b[1] + t0g.reshape(-1), 0.0, 5.0)
        x = x.at[:, 1].set(shear)
        x01 = (x - lo) / (hi - lo)
        mean_n, _ = rbf.rbf_mean(x01, xt, inv_ls, alpha, sf2)
        mean = mean_n * y_std[None, :] + y_mean[None, :]
        gamma = mean[:, 0].reshape(N_KY, N_THETA0)
        # Saturation rule: positive growth only, quasilinear weight in ky.
        lam = spectral_weight(kyg)
        integrand = lam * jnp.maximum(gamma, 0.0) / (1.0 + jnp.maximum(gamma, 0.0))
        q = jnp.sum(wgt * integrand) / (2.0 * THETA0_SHEAR_WINDOW)
        return jnp.reshape(q, (1,)), gamma

    return qoi
