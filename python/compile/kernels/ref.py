"""Pure-jnp oracles for the Pallas kernels (correctness references).

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops only.  pytest (and the hypothesis
sweeps in ``python/tests``) assert the Pallas outputs against these.
"""

from __future__ import annotations

import jax.numpy as jnp


def rbf_kernel_matrix(xs, xt, inv_ls, sf2):
    """Reference RBF cross-kernel: K*[i,j] = sf2 exp(-0.5 sum_d il_d dx^2)."""
    xs = xs.astype(jnp.float32)
    xt = xt.astype(jnp.float32)
    diff = xs[:, None, :] - xt[None, :, :]            # (M, N, d)
    d2 = jnp.sum(diff * diff * inv_ls[None, None, :], axis=-1)
    return sf2 * jnp.exp(-0.5 * d2)


def rbf_mean(xs, xt, inv_ls, alpha, sf2):
    """Reference fused kernel+mean: returns (mean, kstar) like the kernel."""
    kstar = rbf_kernel_matrix(xs, xt, inv_ls, sf2)
    mean = kstar @ alpha.astype(jnp.float32)
    return mean, kstar


def gp_predict(xs, xt, inv_ls, alpha, sf2, chol, sn2):
    """Full-reference GP posterior: mean and per-point latent variance.

    ``chol`` is the lower Cholesky factor of ``K(xt, xt) + sn2 I``.
    Variance of the latent function: k(x,x) - || L^-1 k* ||^2.
    """
    import jax.scipy.linalg as jsl

    mean, kstar = rbf_mean(xs, xt, inv_ls, alpha, sf2)
    v = jsl.solve_triangular(chol, kstar.T, lower=True)   # (N, M)
    var = sf2 - jnp.sum(v * v, axis=0)
    return mean, jnp.maximum(var, 0.0)
