"""L1 Pallas kernel: fused RBF cross-kernel + GP mean contraction.

The GP-surrogate hot spot of the paper's workload mix is prediction:
given a batch of query points ``Xs`` and the training set ``Xt`` the server
must form the cross-kernel matrix ``K*[i, j] = sf2 * exp(-0.5 * sum_d
inv_ls[d] * (Xs[i,d] - Xt[j,d])**2)`` and the posterior mean
``mean = K* @ alpha``.

This module implements that as a single tiled Pallas kernel so that on a
real TPU each ``(BM, BN)`` tile of ``K*`` lives in VMEM, the distance /
exp part runs on the VPU, and the ``K* @ alpha`` contraction hits the MXU.
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (see DESIGN.md
"Hardware-Adaptation").

Tiling scheme
-------------
grid = (M // BM, N // BN); the j axis (training points) is the reduction
axis for the mean, so the mean output block is revisited for every j and
accumulated in place (initialised at j == 0).  ``K*`` is a plain (i, j)
output.  The feature dimension ``d`` is small (7 for the GS2 parameter
space) and padded to ``DPAD`` (zero inverse-lengthscale on padding lanes
contributes nothing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature-dim padding: 7 GS2 inputs -> 8 lanes.  Padding lanes carry
# inv_ls == 0 so they never contribute to the distance.
DPAD = 8

# Default tile sizes.  On TPU a (128, 128) f32 K* tile is 64 KiB, operand
# slabs (128, 8) are 4 KiB: comfortably inside a 16 MiB VMEM budget even
# with double buffering (see DESIGN.md section 8 for the footprint table).
DEF_BM = 128
DEF_BN = 128


def _rbf_mean_kernel(xs_ref, xt_ref, inv_ls_ref, alpha_ref, sf2_ref,
                     mean_ref, kstar_ref):
    """One (BM, BN) tile: K* tile plus its contribution to the mean."""
    j = pl.program_id(1)

    xs = xs_ref[...]            # (BM, DPAD)
    xt = xt_ref[...]            # (BN, DPAD)
    inv_ls = inv_ls_ref[...]    # (1, DPAD)
    sf2 = sf2_ref[0, 0]

    # Scaled squared distances via the expanded form so the cross term is
    # a single (BM, DPAD) x (DPAD, BN) matmul (MXU-friendly), and the
    # norms are cheap VPU row/col reductions.
    xs_w = xs * inv_ls                                  # (BM, DPAD)
    sq_s = jnp.sum(xs_w * xs, axis=1, keepdims=True)    # (BM, 1)
    sq_t = jnp.sum((xt * inv_ls) * xt, axis=1)          # (BN,)
    cross = jnp.dot(xs_w, xt.T,
                    preferred_element_type=jnp.float32)  # (BM, BN)
    d2 = sq_s + sq_t[None, :] - 2.0 * cross
    # Clamp tiny negative rounding residue before exp.
    d2 = jnp.maximum(d2, 0.0)
    k = sf2 * jnp.exp(-0.5 * d2)                        # (BM, BN)

    kstar_ref[...] = k.astype(kstar_ref.dtype)

    # Mean accumulation across the j (reduction) grid axis.
    contrib = jnp.dot(k, alpha_ref[...],
                      preferred_element_type=jnp.float32)  # (BM, O)

    @pl.when(j == 0)
    def _init():
        mean_ref[...] = contrib.astype(mean_ref.dtype)

    @pl.when(j != 0)
    def _acc():
        mean_ref[...] = (mean_ref[...] + contrib).astype(mean_ref.dtype)


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
    return x


def _pad_feat(x: jax.Array) -> jax.Array:
    d = x.shape[-1]
    if d < DPAD:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, DPAD - d)]
        x = jnp.pad(x, pad)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def rbf_mean(xs: jax.Array, xt: jax.Array, inv_ls: jax.Array,
             alpha: jax.Array, sf2: jax.Array,
             bm: int = DEF_BM, bn: int = DEF_BN):
    """Fused RBF cross-kernel and GP posterior mean.

    Args:
      xs:     (M, d) query points.
      xt:     (N, d) training points.
      inv_ls: (d,)   per-dimension inverse *squared* lengthscales.
      alpha:  (N, O) precomputed ``(K + sn2 I)^-1 Y``.
      sf2:    ()     signal variance.
      bm, bn: tile sizes (clamped to the padded problem size).

    Returns:
      mean:  (M, O) posterior mean ``K* @ alpha``.
      kstar: (M, N) cross-kernel matrix (consumed by the variance path).
    """
    m, d = xs.shape
    n = xt.shape[0]
    o = alpha.shape[1]
    f32 = jnp.float32

    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))

    xs_p = _pad_feat(_pad_rows(xs.astype(f32), bm))
    xt_p = _pad_feat(_pad_rows(xt.astype(f32), bn))
    alpha_p = _pad_rows(alpha.astype(f32), bn)
    inv_p = _pad_feat(inv_ls.astype(f32)[None, :])        # (1, DPAD)
    sf2_p = jnp.asarray(sf2, f32).reshape(1, 1)

    mp, np_ = xs_p.shape[0], xt_p.shape[0]
    grid = (mp // bm, np_ // bn)

    mean_p, kstar_p = pl.pallas_call(
        _rbf_mean_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, DPAD), lambda i, j: (i, 0)),   # xs
            pl.BlockSpec((bn, DPAD), lambda i, j: (j, 0)),   # xt
            pl.BlockSpec((1, DPAD), lambda i, j: (0, 0)),    # inv_ls
            pl.BlockSpec((bn, o), lambda i, j: (j, 0)),      # alpha
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),       # sf2
        ],
        out_specs=[
            pl.BlockSpec((bm, o), lambda i, j: (i, 0)),      # mean
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),     # kstar
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, o), f32),
            jax.ShapeDtypeStruct((mp, np_), f32),
        ],
        interpret=True,
    )(xs_p, xt_p, inv_p, alpha_p, sf2_p)

    return mean_p[:m], kstar_p[:m, :n]


def vmem_footprint_bytes(bm: int = DEF_BM, bn: int = DEF_BN,
                         o: int = 2, dtype_bytes: int = 4) -> dict:
    """Static VMEM footprint estimate for one grid step (perf deliverable).

    Double-buffered inputs (x2) plus single-buffered outputs, matching the
    schedule the BlockSpecs express on real hardware.
    """
    ins = (bm * DPAD + bn * DPAD + DPAD + bn * o + 1) * dtype_bytes * 2
    outs = (bm * o + bm * bn) * dtype_bytes
    return {
        "inputs_bytes": ins,
        "outputs_bytes": outs,
        "total_bytes": ins + outs,
        "vmem_budget_bytes": 16 * 1024 * 1024,
        "fits": ins + outs < 16 * 1024 * 1024,
    }


def mxu_utilization_estimate(m: int, n: int, o: int = 2, d: int = DPAD,
                             bm: int = DEF_BM, bn: int = DEF_BN) -> dict:
    """Analytic MXU-utilisation estimate for the kernel (perf deliverable).

    The cross-term matmul is (BM, DPAD) @ (DPAD, BN): with DPAD == 8 the
    128x128 systolic array is fed an 8-deep reduction, i.e. 8/128 of peak
    on the MXU pass; the exp/scale work is VPU-bound.  Reported so the
    DESIGN.md perf section can translate the paper's efficiency framing.
    """
    mxu_flops = 2 * m * n * d + 2 * m * n * o
    vpu_flops = 6 * m * n + 4 * m * d + 4 * n * d   # dist assembly + exp approx
    depth_eff = min(d, 128) / 128.0
    return {
        "mxu_flops": mxu_flops,
        "vpu_flops": vpu_flops,
        "reduction_depth_efficiency": depth_eff,
        "note": "d=8 reduction: MXU pass at 6.25% depth efficiency; "
                "dominant cost is VPU exp for small o",
    }
