"""gs2lite physics/workload sanity: the GS2 stand-in behaves like the paper
describes GS2 behaving (input-dependent, a-priori-unpredictable runtimes;
convergence to the dominant mode; deterministic per-input results)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import gp as gp_mod, gs2lite


def _params(seed, n=1):
    lo, hi = gp_mod.param_bounds()
    x01 = gp_mod.lhs_sample(n, 7, seed)
    return (lo + x01 * (hi - lo)).astype(np.float32)


class TestOperator:
    def test_shapes_and_dtype(self):
        ar, ai = gs2lite.build_operator(jnp.asarray(_params(0)[0]))
        assert ar.shape == (gs2lite.NGRID, gs2lite.NGRID)
        assert ai.shape == (gs2lite.NGRID, gs2lite.NGRID)
        assert ar.dtype == jnp.float32 and ai.dtype == jnp.float32

    def test_deterministic(self):
        p = jnp.asarray(_params(1)[0])
        a1 = gs2lite.build_operator(p)
        a2 = gs2lite.build_operator(p)
        assert np.array_equal(np.asarray(a1[0]), np.asarray(a2[0]))
        assert np.array_equal(np.asarray(a1[1]), np.asarray(a2[1]))

    def test_collisions_damp(self):
        """More collisionality must push the dominant growth rate down."""
        p = _params(2)[0].copy()
        p[5] = 0.0
        g0, _ = gs2lite.solve_direct(p)
        p[5] = 0.1
        g1, _ = gs2lite.solve_direct(p)
        assert g1 <= g0

    def test_gradients_drive(self):
        """Steeper gradients must not reduce the growth rate."""
        p = _params(3)[0].copy()
        p[2], p[3] = 0.5, 0.6
        g0, _ = gs2lite.solve_direct(p)
        p[2], p[3] = 9.0, 5.5
        g1, _ = gs2lite.solve_direct(p)
        assert g1 >= g0


class TestChunk:
    def test_state_stays_normalised(self):
        p = jnp.asarray(_params(4)[0])
        st_ = gs2lite.initial_state()
        out, _, _ = gs2lite.chunk(p, st_)
        nrm = float(jnp.sqrt(jnp.sum(out**2)))
        assert abs(nrm - 1.0) < 1e-4

    def test_residual_decreases_on_converging_case(self):
        # A strongly driven case: converges fast.
        p = np.array([3.0, 0.5, 8.0, 5.0, 0.25, 0.0, 0.4], np.float32)
        st_ = gs2lite.initial_state()
        residuals = []
        for _ in range(6):
            st_, _, r = gs2lite.chunk(jnp.asarray(p), st_)
            residuals.append(float(r[0]))
        assert residuals[-1] < residuals[0]

    def test_converges_to_direct_solve(self):
        p = np.array([3.0, 0.5, 8.0, 5.0, 0.25, 0.0, 0.4], np.float32)
        st_ = gs2lite.initial_state()
        eig = None
        for _ in range(60):
            st_, eig, r = gs2lite.chunk(jnp.asarray(p), st_)
            if float(r[0]) < 1e-5:
                break
        g, w = gs2lite.solve_direct(p)
        assert abs(float(eig[0]) - g) < 2e-3
        assert abs(float(eig[1]) - w) < 2e-3

    def test_chunk_is_deterministic(self):
        p = jnp.asarray(_params(5)[0])
        st_ = gs2lite.initial_state()
        a = gs2lite.chunk(p, st_)
        b = gs2lite.chunk(p, st_)
        assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
        assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))


class TestRuntimeDistribution:
    """The scheduling-relevant property: heavy-tailed, input-dependent cost."""

    def test_runtime_varies_across_parameter_space(self):
        counts = [gs2lite.convergence_chunks(p, max_chunks=120)
                  for p in _params(6, 12)]
        assert max(counts) >= 3 * min(counts), counts

    def test_unpredictable_from_single_input(self):
        """Two nearby inputs can have very different costs (no trivial
        predictor), while identical inputs cost the same."""
        p = _params(7)[0]
        c1 = gs2lite.convergence_chunks(p, max_chunks=120)
        c2 = gs2lite.convergence_chunks(p, max_chunks=120)
        assert c1 == c2


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_initial_state_unit_norm(seed):
    del seed  # state is deterministic; property kept for API stability
    st_ = gs2lite.initial_state()
    assert abs(float(jnp.sum(st_**2)) - 1.0) < 1e-5
