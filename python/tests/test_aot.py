"""AOT pipeline: every registry entry lowers to parseable HLO text, and the
lowered text has the properties the Rust loader depends on (single module,
f32-only I/O, tuple root)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, gp as gp_mod, model, qoi


@pytest.fixture(scope="module")
def small_gp():
    x01 = gp_mod.lhs_sample(24, 7, 9).astype(np.float32)
    y = np.stack([x01[:, 0], x01[:, 1] * 2.0], axis=1).astype(np.float32)
    return gp_mod.train(x01, y, steps=25)


@pytest.fixture(scope="module")
def entries(small_gp):
    return model.build_entries(small_gp)


class TestLowering:
    def test_all_entries_lower(self, entries):
        for name, (fn, specs) in entries.items():
            text = aot.lower_entry(name, fn, specs)
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_no_complex_types_in_hlo(self, entries):
        """The Rust literal path is f32-only; complex must never leak."""
        for name, (fn, specs) in entries.items():
            text = aot.lower_entry(name, fn, specs)
            assert "c64" not in text and "c128" not in text, name

    def test_no_custom_calls(self, entries):
        """LAPACK/Mosaic custom-calls cannot cross the AOT boundary."""
        for name, (fn, specs) in entries.items():
            text = aot.lower_entry(name, fn, specs)
            assert "custom-call" not in text, name

    def test_gp_predict_shapes(self, small_gp):
        fn = gp_mod.make_predict_fn(small_gp)
        x = jnp.zeros((16, 7), jnp.float32)
        mean, var = fn(x)
        assert mean.shape == (16, 2)
        assert var.shape == (16, 2)

    def test_qoi_scalar_output(self, small_gp):
        fn = qoi.make_qoi_fn(small_gp)
        q, gamma = fn(jnp.asarray(
            [5.0, 2.0, 5.0, 3.0, 0.1, 0.05, 0.5], dtype=jnp.float32))
        assert q.shape == (1,)
        assert gamma.shape == (qoi.N_KY, qoi.N_THETA0)
        assert np.isfinite(float(q[0]))


class TestQuadrature:
    def test_gauss_legendre_integrates_poly(self):
        x, w = qoi.gauss_legendre(8, 0.0, 2.0)
        # integral of x^3 over [0,2] = 4
        assert abs(float(np.sum(w * x**3)) - 4.0) < 1e-4

    def test_weights_positive_and_sum_to_length(self):
        x, w = qoi.gauss_legendre(16, -1.0, 3.0)
        assert (w > 0).all()
        assert abs(float(np.sum(w)) - 4.0) < 1e-4

    def test_spectral_weight_peaked_interior(self):
        ky = jnp.linspace(0.05, 1.0, 50)
        lam = np.asarray(qoi.spectral_weight(ky))
        peak = lam.argmax()
        assert 0 < peak < 49


class TestTrainCache:
    def test_cache_key_stable(self):
        assert aot._train_cache_key() == aot._train_cache_key()
