"""Jacobi eigensolver vs numpy.linalg.eigvalsh (the LAPACK ground truth)."""

import numpy as np
import pytest

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import eigen


class TestSchedule:
    @pytest.mark.parametrize("n", [4, 8, 100, 256])
    def test_round_robin_covers_all_pairs(self, n):
        sched = eigen.round_robin_schedule(n)
        assert sched.shape == (n - 1, n // 2, 2)
        seen = set()
        for rnd in sched:
            cols = set()
            for p, q in rnd:
                assert p < q
                assert p not in cols and q not in cols  # disjoint in round
                cols.update((p, q))
                seen.add((p, q))
        assert len(seen) == n * (n - 1) // 2

    def test_odd_n_rejected(self):
        with pytest.raises(AssertionError):
            eigen.round_robin_schedule(5)


class TestEigvals:
    @pytest.mark.parametrize("n,seed", [(8, 0), (16, 1), (100, 2)])
    def test_matches_lapack(self, n, seed):
        a = eigen.random_symmetric(n, seed)
        w, off = eigen.jacobi_eigvals(jnp.asarray(a), sweeps=14)
        wn = np.sort(np.linalg.eigvalsh(a))
        np.testing.assert_allclose(np.asarray(w), wn, rtol=1e-3, atol=2e-4)
        assert float(off) < 1e-2

    def test_large_case_converges(self):
        a = eigen.random_symmetric(eigen.N_LARGE, 7)
        w, off = eigen.jacobi_eigvals(jnp.asarray(a),
                                      sweeps=eigen.SWEEPS_LARGE)
        wn = np.sort(np.linalg.eigvalsh(a))
        np.testing.assert_allclose(np.asarray(w), wn, rtol=1e-3, atol=1e-3)

    def test_diagonal_matrix_is_fixed_point(self):
        d = np.diag(np.arange(1.0, 9.0, dtype=np.float32))
        w, off = eigen.jacobi_eigvals(jnp.asarray(d), sweeps=2)
        np.testing.assert_allclose(np.asarray(w), np.arange(1.0, 9.0),
                                   atol=1e-6)
        assert float(off) < 1e-6

    def test_uses_symmetric_part_only(self):
        rng = np.random.default_rng(3)
        a = rng.normal(size=(16, 16)).astype(np.float32)
        w1, _ = eigen.jacobi_eigvals(jnp.asarray(a), sweeps=12)
        w2, _ = eigen.jacobi_eigvals(jnp.asarray(0.5 * (a + a.T)), sweeps=12)
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)

    def test_trace_preserved(self):
        """Similarity transforms preserve the trace: sum(w) == tr(A)."""
        a = eigen.random_symmetric(64, 11)
        w, _ = eigen.jacobi_eigvals(jnp.asarray(a), sweeps=12)
        assert abs(float(np.sum(np.asarray(w))) - np.trace(a)) < 1e-2


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([4, 8, 12, 20, 32]), seed=st.integers(0, 2**31))
def test_eigvals_property(n, seed):
    a = eigen.random_symmetric(n, seed)
    w, off = eigen.jacobi_eigvals(jnp.asarray(a), sweeps=14)
    wn = np.sort(np.linalg.eigvalsh(a))
    np.testing.assert_allclose(np.asarray(w), wn, rtol=1e-3, atol=5e-4)
    # sorted ascending
    assert np.all(np.diff(np.asarray(w)) >= -1e-6)


class TestGenerator:
    def test_seeded_matrix_reproducible(self):
        a = eigen.random_symmetric(32, 5)
        b = eigen.random_symmetric(32, 5)
        assert np.array_equal(a, b)

    def test_different_seeds_differ(self):
        assert not np.array_equal(eigen.random_symmetric(32, 5),
                                  eigen.random_symmetric(32, 6))

    def test_symmetric_and_bounded(self):
        a = eigen.random_symmetric(48, 9)
        assert np.array_equal(a, a.T)
        assert np.abs(a).max() <= 1.0

    def test_known_first_value(self):
        """Pin the SplitMix64 stream so the Rust generator can be checked
        against the same constant."""
        a = eigen.random_symmetric(2, 42)
        # First draw of splitmix64(seed=42), top-24-bit mapping to [-1, 1).
        assert abs(a[0, 0] - 0.48312974) < 1e-6
