"""L1 correctness: Pallas RBF kernel vs the pure-jnp oracle.

The hypothesis sweep is the core correctness signal: shapes (including
non-multiples of the tile size, which exercise the padding path), tile
sizes, dtypes, and degenerate values.
"""

import numpy as np
import pytest

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import rbf, ref


def _rand(rng, *shape):
    return rng.uniform(-2.0, 2.0, size=shape).astype(np.float32)


def run_both(xs, xt, inv_ls, alpha, sf2, bm=32, bn=32):
    m1, k1 = rbf.rbf_mean(jnp.asarray(xs), jnp.asarray(xt),
                          jnp.asarray(inv_ls), jnp.asarray(alpha),
                          jnp.asarray(sf2), bm=bm, bn=bn)
    m2, k2 = ref.rbf_mean(jnp.asarray(xs), jnp.asarray(xt),
                          jnp.asarray(inv_ls), jnp.asarray(alpha),
                          jnp.asarray(sf2))
    return np.asarray(m1), np.asarray(k1), np.asarray(m2), np.asarray(k2)


class TestRbfMeanBasics:
    def test_exact_tile_multiple(self):
        rng = np.random.default_rng(0)
        m1, k1, m2, k2 = run_both(_rand(rng, 64, 7), _rand(rng, 64, 7),
                                  rng.uniform(0.5, 2.0, 7).astype(np.float32),
                                  _rand(rng, 64, 2), np.float32(1.0))
        np.testing.assert_allclose(k1, k2, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m1, m2, rtol=1e-4, atol=1e-4)

    def test_ragged_shapes(self):
        rng = np.random.default_rng(1)
        m1, k1, m2, k2 = run_both(_rand(rng, 37, 7), _rand(rng, 53, 7),
                                  rng.uniform(0.5, 2.0, 7).astype(np.float32),
                                  _rand(rng, 53, 2), np.float32(1.7))
        np.testing.assert_allclose(k1, k2, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m1, m2, rtol=1e-4, atol=1e-4)

    def test_single_row_and_col(self):
        rng = np.random.default_rng(2)
        m1, k1, m2, k2 = run_both(_rand(rng, 1, 7), _rand(rng, 1, 7),
                                  rng.uniform(0.5, 2.0, 7).astype(np.float32),
                                  _rand(rng, 1, 2), np.float32(0.5))
        np.testing.assert_allclose(k1, k2, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m1, m2, rtol=1e-4, atol=1e-4)

    def test_identical_points_give_sf2(self):
        """k(x, x) must equal the signal variance exactly."""
        rng = np.random.default_rng(3)
        x = _rand(rng, 8, 7)
        inv = rng.uniform(0.5, 2.0, 7).astype(np.float32)
        _, k1, _, _ = run_both(x, x, inv, _rand(rng, 8, 2), np.float32(2.5))
        np.testing.assert_allclose(np.diag(k1), 2.5, rtol=1e-5)

    def test_zero_lengthscale_dims_ignored(self):
        """inv_ls == 0 dims (the DPAD padding contract) contribute nothing."""
        rng = np.random.default_rng(4)
        xs, xt = _rand(rng, 16, 7), _rand(rng, 24, 7)
        al = _rand(rng, 24, 2)
        inv = rng.uniform(0.5, 2.0, 7).astype(np.float32)
        inv[3] = 0.0
        xs2 = xs.copy()
        xs2[:, 3] = 99.0   # differs only on the dead dimension
        _, k1, _, _ = run_both(xs, xt, inv, al, np.float32(1.0))
        _, k1b, _, _ = run_both(xs2, xt, inv, al, np.float32(1.0))
        np.testing.assert_allclose(k1, k1b, rtol=1e-6)

    def test_mean_is_kstar_times_alpha(self):
        rng = np.random.default_rng(5)
        xs, xt = _rand(rng, 40, 7), _rand(rng, 72, 7)
        al = _rand(rng, 72, 2)
        inv = rng.uniform(0.5, 2.0, 7).astype(np.float32)
        m1, k1, _, _ = run_both(xs, xt, inv, al, np.float32(1.0))
        np.testing.assert_allclose(m1, k1 @ al, rtol=1e-4, atol=1e-4)

    def test_default_tiles_large_problem(self):
        rng = np.random.default_rng(6)
        m1, k1, m2, k2 = run_both(_rand(rng, 256, 7), _rand(rng, 224, 7),
                                  rng.uniform(0.5, 2.0, 7).astype(np.float32),
                                  _rand(rng, 224, 2), np.float32(1.0),
                                  bm=rbf.DEF_BM, bn=rbf.DEF_BN)
        np.testing.assert_allclose(k1, k2, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m1, m2, rtol=1e-4, atol=1e-3)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    n=st.integers(1, 90),
    d=st.integers(1, 7),
    o=st.integers(1, 3),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    sf2=st.floats(0.1, 4.0),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_hypothesis(m, n, d, o, bm, bn, sf2, seed):
    """Property: Pallas == oracle across shape/tile/scale space."""
    rng = np.random.default_rng(seed)
    xs = _rand(rng, m, d)
    xt = _rand(rng, n, d)
    inv = rng.uniform(0.1, 3.0, d).astype(np.float32)
    al = _rand(rng, n, o)
    m1, k1, m2, k2 = run_both(xs, xt, inv, al, np.float32(sf2), bm=bm, bn=bn)
    np.testing.assert_allclose(k1, k2, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(m1, m2, rtol=2e-4, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_kernel_bf16_inputs(seed):
    """bf16 inputs upcast internally; tolerances follow bf16 resolution."""
    rng = np.random.default_rng(seed)
    xs = _rand(rng, 24, 7).astype(jnp.bfloat16)
    xt = _rand(rng, 40, 7).astype(jnp.bfloat16)
    inv = rng.uniform(0.1, 2.0, 7).astype(np.float32)
    al = _rand(rng, 40, 2)
    m1, k1 = rbf.rbf_mean(jnp.asarray(xs), jnp.asarray(xt),
                          jnp.asarray(inv), jnp.asarray(al),
                          jnp.asarray(1.0, jnp.float32), bm=16, bn=16)
    m2, k2 = ref.rbf_mean(jnp.asarray(xs), jnp.asarray(xt),
                          jnp.asarray(inv), jnp.asarray(al),
                          jnp.asarray(1.0, jnp.float32))
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               rtol=5e-2, atol=5e-1)


class TestPerfEstimators:
    def test_vmem_footprint_fits(self):
        fp = rbf.vmem_footprint_bytes()
        assert fp["fits"]
        assert fp["total_bytes"] < fp["vmem_budget_bytes"]

    def test_vmem_scales_with_tiles(self):
        small = rbf.vmem_footprint_bytes(bm=64, bn=64)
        big = rbf.vmem_footprint_bytes(bm=256, bn=256)
        assert big["total_bytes"] > small["total_bytes"]

    def test_mxu_estimate_counts_flops(self):
        est = rbf.mxu_utilization_estimate(256, 224)
        assert est["mxu_flops"] == 2 * 256 * 224 * 8 + 2 * 256 * 224 * 2
        assert 0.0 < est["reduction_depth_efficiency"] <= 1.0
