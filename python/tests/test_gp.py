"""GP training + baked-predict correctness (L2), including the exact
posterior identities the surrogate must satisfy."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import gp as gp_mod
from compile.kernels import ref


@pytest.fixture(scope="module")
def toy_gp():
    """Small GP trained on an analytic function (fast, deterministic)."""
    rng = np.random.default_rng(0)
    x01 = gp_mod.lhs_sample(48, 7, 123).astype(np.float32)
    # smooth target with two outputs
    y = np.stack([
        np.sin(2 * x01[:, 0]) + x01[:, 1] ** 2,
        np.cos(3 * x01[:, 2]) * x01[:, 3],
    ], axis=1).astype(np.float32)
    return gp_mod.train(x01, y, steps=60), x01, y


class TestLhs:
    def test_shape_and_range(self):
        x = gp_mod.lhs_sample(32, 7, 0)
        assert x.shape == (32, 7)
        assert (x >= 0).all() and (x < 1).all()

    def test_stratified(self):
        """Each dimension has exactly one sample per 1/n stratum."""
        n = 16
        x = gp_mod.lhs_sample(n, 7, 3)
        for d in range(7):
            bins = np.floor(x[:, d] * n).astype(int)
            assert sorted(bins) == list(range(n))

    def test_seeded(self):
        assert np.array_equal(gp_mod.lhs_sample(8, 7, 5),
                              gp_mod.lhs_sample(8, 7, 5))
        assert not np.array_equal(gp_mod.lhs_sample(8, 7, 5),
                                  gp_mod.lhs_sample(8, 7, 6))


class TestTraining:
    def test_interpolates_training_data(self, toy_gp):
        gp, x01, y = toy_gp
        lo, hi = gp.lo, gp.hi
        x_phys = lo + x01 * (hi - lo)
        fn = gp_mod.make_predict_fn(gp)
        mean, var = fn(jnp.asarray(x_phys))
        # with small fitted noise the posterior mean passes near the data
        err = np.abs(np.asarray(mean) - y)
        assert np.median(err) < 0.1, np.median(err)

    def test_variance_zero_at_training_points(self, toy_gp):
        gp, x01, y = toy_gp
        x_phys = gp.lo + x01 * (gp.hi - gp.lo)
        fn = gp_mod.make_predict_fn(gp)
        _, var = fn(jnp.asarray(x_phys))
        # latent variance at training inputs ~ noise level
        assert float(np.median(np.asarray(var))) < 0.1

    def test_variance_grows_off_data(self, toy_gp):
        gp, x01, _ = toy_gp
        fn = gp_mod.make_predict_fn(gp)
        x_on = gp.lo + x01[:8] * (gp.hi - gp.lo)
        # corner far from LHS samples
        x_off = np.tile(gp.hi * 0.999, (8, 1)).astype(np.float32)
        _, v_on = fn(jnp.asarray(x_on))
        _, v_off = fn(jnp.asarray(x_off))
        assert np.mean(np.asarray(v_off)) > np.mean(np.asarray(v_on))

    def test_alpha_solves_system(self, toy_gp):
        """alpha must satisfy (K + sn2 I) alpha = Y_standardised."""
        gp, x01, y = toy_gp
        k = np.asarray(ref.rbf_kernel_matrix(
            jnp.asarray(x01), jnp.asarray(x01),
            jnp.asarray(gp.inv_ls), gp.sf2))
        kn = k + gp.sn2 * np.eye(len(x01), dtype=np.float32)
        y_std = (y - gp.y_mean) / gp.y_std
        np.testing.assert_allclose(kn @ gp.alpha, y_std, atol=2e-3)

    def test_chol_factorises(self, toy_gp):
        gp, x01, _ = toy_gp
        k = np.asarray(ref.rbf_kernel_matrix(
            jnp.asarray(x01), jnp.asarray(x01),
            jnp.asarray(gp.inv_ls), gp.sf2))
        kn = k + gp.sn2 * np.eye(len(x01), dtype=np.float32)
        np.testing.assert_allclose(gp.chol @ gp.chol.T, kn,
                                   rtol=1e-4, atol=1e-4)


class TestPredictConsistency:
    def test_predict_fn_matches_numpy_oracle(self, toy_gp):
        gp, _, _ = toy_gp
        rng = np.random.default_rng(1)
        x01 = rng.uniform(size=(20, 7)).astype(np.float32)
        x_phys = gp.lo + x01 * (gp.hi - gp.lo)
        fn = gp_mod.make_predict_fn(gp)
        mean_j, var_j = fn(jnp.asarray(x_phys))
        mean_n, var_n = gp_mod.predict_ref(gp, x_phys)
        np.testing.assert_allclose(np.asarray(mean_j), mean_n,
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(var_j), var_n,
                                   rtol=1e-2, atol=1e-3)

    def test_variance_nonnegative(self, toy_gp):
        gp, _, _ = toy_gp
        rng = np.random.default_rng(2)
        x01 = rng.uniform(size=(64, 7)).astype(np.float32)
        x_phys = gp.lo + x01 * (gp.hi - gp.lo)
        fn = gp_mod.make_predict_fn(gp)
        _, var = fn(jnp.asarray(x_phys))
        assert (np.asarray(var) >= 0).all()
