//! One scheduler API: the [`SchedulerCore`] trait, its shared
//! [`Effect`] vocabulary, and the single generic event kernel
//! ([`kernel::run`]) every campaign runs through.
//!
//! The paper's central claim is scheduler-agnostic — the same UQ
//! workload runs against naive SLURM or UM-Bridge + HyperQueue and only
//! the scheduling layer changes.  Before this module the codebase
//! hard-coded exactly two schedulers behind divergent APIs
//! (`Action`/`HqAction`, `Timer`/`HqTimer`, `JobId`/`TaskId`) and two
//! hand-duplicated event loops.  Now there is one seam:
//!
//! ```text
//!   Submitter (what / when)      kernel::run<S>            SchedulerCore impls
//!   ┌───────────────┐ Submission ┌─────────────┐ Event   ┌──────────────────────┐
//!   │ fixed-depth   │ ─────────> │ one event   │ ──────> │ SlurmSched           │
//!   │ poisson-burst │  wake_at   │ heap, one   │         │   (SlurmCore)        │
//!   │ user-mix ...  │ <───────── │ drain loop  │ <────── │ MetaStack<HqCore>    │
//!   └───────────────┘ completed  └─────────────┘ Effect  │ MetaStack<WorkSteal> │
//!                                                        │ MetaStack<EdfCore>   │
//!   /Evaluate ───┐   realtime::RtDriver (wall clock)     │ MetaStack<GangCore>  │
//!   server up ───┼─> │ timer heap · ready queue │ ─────> │ LiveSched<HqCore>    │
//!   forward done ┘   (balancer forwarder pool)   Effect  │ LiveSched<WorkSteal> │
//!                                                        │ LiveSched<EdfCore>   │
//!                                                        │ LiveSched<GangCore>  │
//!                                                        └──────────────────────┘
//!
//!   All four HQ-family cores ride one shared lifecycle engine,
//!   [`table::TaskTable`] — each core is its ready structure (FCFS
//!   queue, per-worker deques, deadline heap, gang frontier) plus a
//!   placement policy; see `sched/table.rs`.
//! ```
//!
//! * **Events** flow kernel → core as trait-method calls: `submit`,
//!   `cancel`, `work-done`, `timer`, `capacity-change` — each an
//!   allocation-lean `*_into` sink method.
//! * **Effects** flow core → kernel in a caller-supplied buffer:
//!   set-timer, start, finish, retire.  Per-core id and timer types are
//!   zero-cost associated types, so `SlurmSched` keeps its `JobId`s and
//!   the HQ-style stacks keep their `TaskId`s with no tagging overhead.
//! * A **new scheduler costs one `impl`**, not a third copy of the
//!   driver: [`WorkStealCore`] (partitioned per-worker deques with
//!   stealing) and [`EdfCore`] (deadline-EDF, laxity tie-break) plug in
//!   behind [`hqlite::TaskCore`](crate::hqlite::TaskCore) and are
//!   reachable end-to-end from `uqsched campaign --scheduler
//!   worksteal|edf`, the metrics pipeline and the scale bench.
//! * The seam has **two drivers**: [`kernel::run`] owns virtual time
//!   (campaigns), and [`realtime::RtDriver`] owns the wall clock — the
//!   live balancer's dispatch plane, where `/Evaluate`s are `Submit`
//!   events, server registrations are worker capacity changes, and
//!   `uqsched balancer --scheduler fcfs|worksteal|edf` ablates the
//!   same cores under real HTTP load.
//!
//! Equivalence: `tests/campaign_equiv.rs` pins the kernel + adapters
//! record-for-record to the hand-written PR 1 loops preserved in
//! `experiments::reference`, for every app and both paper schedulers.

pub mod dag;
pub mod edf;
pub mod faults;
pub mod gang;
pub mod kernel;
pub mod realtime;
pub mod slurm;
pub mod stack;
pub mod table;
pub mod worksteal;

use std::fmt::Debug;
use std::hash::Hash;

use crate::campaign::submitter::Submission;
use crate::clock::Micros;
use crate::metrics::JobRecord;

pub use dag::{Admit, DepTracker};
pub use edf::EdfCore;
pub use faults::{FaultPlan, FaultSpec};
pub use gang::GangCore;
pub use kernel::{run, run_with_faults};
pub use realtime::{LivePolicy, LiveSched, RtDriver};
pub use slurm::SlurmSched;
pub use stack::{EdfSched, GangSched, HqSched, MetaStack, StackTimer,
                WorkStealSched};
pub use table::{slot_of, Slab, TaskTable};
pub use worksteal::WorkStealCore;

/// The workers a unit of work occupies, in the id space the driver used
/// for [`CapacityChange::WorkerUp`].  Empty when the core does not place
/// by worker (native SLURM background lanes); one element for the
/// single-worker cores; the full gang, ascending, for
/// [`GangCore`] — the first member is the *lead* (the server the
/// real-time driver leases).
/// The single-worker case is inline (no heap allocation): million-task
/// streams emit one `Start` per attempt, and boxing a one-element `Vec`
/// for each was the kernel's last per-event allocation.  Only true gangs
/// (> 1 member) carry a `Vec`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum WorkerSet {
    /// No placement information.
    #[default]
    Empty,
    /// A single-worker placement, stored inline.
    One(u64),
    /// A gang placement (ascending; the first member is the lead).
    Many(Vec<u64>),
}

impl WorkerSet {
    /// No placement information.
    pub fn empty() -> Self {
        WorkerSet::Empty
    }

    /// A single-worker placement.
    pub fn one(id: u64) -> Self {
        WorkerSet::One(id)
    }

    /// A gang placement (callers pass members ascending; the first is
    /// the lead).  Degenerate sizes normalise to `Empty`/`One` so
    /// equality never depends on which constructor built the set.
    pub fn many(mut ids: Vec<u64>) -> Self {
        match ids.len() {
            0 => WorkerSet::Empty,
            1 => WorkerSet::One(ids.pop().expect("len checked")),
            _ => WorkerSet::Many(ids),
        }
    }

    /// Adapter for the previous `Option<u64>` placement shape.
    pub fn from_opt(id: Option<u64>) -> Self {
        match id {
            Some(id) => WorkerSet::One(id),
            None => WorkerSet::Empty,
        }
    }

    /// The lead worker (None when the set is empty).
    pub fn primary(&self) -> Option<u64> {
        self.ids().first().copied()
    }

    /// All members, ascending.
    pub fn ids(&self) -> &[u64] {
        match self {
            WorkerSet::Empty => &[],
            WorkerSet::One(id) => std::slice::from_ref(id),
            WorkerSet::Many(ids) => ids,
        }
    }

    pub fn len(&self) -> usize {
        self.ids().len()
    }

    pub fn is_empty(&self) -> bool {
        matches!(self, WorkerSet::Empty)
    }

    pub fn contains(&self, id: u64) -> bool {
        self.ids().contains(&id)
    }
}

/// What the kernel must do in response to a core transition — the
/// unified action vocabulary shared by every scheduler.
#[derive(Clone, Debug)]
pub enum Effect<I, T> {
    /// Re-invoke the core's `on_timer_into` at this absolute time.
    SetTimer(Micros, T),
    /// The submitted work began executing: the kernel schedules
    /// `on_work_done_into` after the driver-owned duration, inflated by
    /// `contention` (1.0 where the scheduler models no co-location).
    /// Work the kernel did not submit (background jobs) is ignored; work
    /// may start more than once (requeue after a lost worker).
    /// `workers` names where the core placed the work — a [`WorkerSet`]
    /// so gang placement survives the seam (empty where the core does
    /// not place, one member for single-worker cores, the full gang for
    /// [`GangCore`]).  The virtual kernel validates but does not act on
    /// placement (every worker shares the simulated clock; see
    /// `kernel.rs`); the real-time driver leases the *lead* member.
    Start { id: I, contention: f64, workers: WorkerSet },
    /// Terminal record for a unit of work.  The kernel classifies it via
    /// [`SchedulerCore::classify`] and quantises times to the core's
    /// [`log_grain`](SchedulerCore::log_grain).
    Finish { id: I, record: JobRecord },
    /// The work was forcibly stopped (time limit).  Informational — the
    /// matching [`Effect::Finish`] carries the truncated record.
    Retire { id: I },
    /// The work left a worker without finishing (transient failure or
    /// worker loss) and will run again.  The kernel invalidates any
    /// in-flight completion it scheduled for the previous attempt — a
    /// requeued task's next [`Effect::Start`] opens a fresh epoch — and
    /// counts the retry.
    Requeued { id: I },
    /// Internal (core-originated) work entered the stream — depth
    /// tracking only.  Used by the HQ stack's registration pre-jobs.
    Queued,
    /// A dependency-blocked task left the Blocked state into Ready: its
    /// parents all reached terminal records and the kernel is submitting
    /// it to the core *now* (the core's own effects for that submission
    /// follow in the same buffer).  Emitted by the kernel's dependency
    /// layer ([`dag::DepTracker`]), never by a core; drivers without a
    /// dependency plane (the real-time balancer) ignore it.
    Released { tag: u64 },
}

/// How the kernel should account a [`Effect::Finish`] record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Completion {
    /// A campaign evaluation: counted, recorded, reported to the
    /// submitter.
    Evaluation,
    /// A registration pre-job (UM-Bridge readiness check): leaves the
    /// depth trajectory and pings `Submitter::registration_completed`,
    /// but is excluded from the records.
    Registration,
    /// Scheduler-internal work (background load): ignored.
    Background,
}

/// External capacity events a driver can inject (the campaign kernel
/// never generates these itself — capacity churn on the paper paths is
/// core-internal).  `tests/scheduler_props.rs` drives worker loss
/// through this seam mid-campaign; the live balancer's real-time driver
/// ([`realtime::RtDriver`]) routes model-server registrations and
/// retirements through exactly this seam.
#[derive(Clone, Copy, Debug)]
pub enum CapacityChange {
    /// A worker appeared: `id` is caller-chosen and names the worker in
    /// every later [`CapacityChange::WorkerLost`] and in
    /// [`Effect::Start`]`::worker`.  Cores whose capacity is internal
    /// (allocation-driven stacks) ignore it (default no-op).
    WorkerUp { id: u64, cores: u32 },
    /// A worker disappeared out from under the scheduler.  For
    /// allocation-driven stacks the id is the core-internal worker id;
    /// for live cores it is the id announced by `WorkerUp`.
    WorkerLost(u64),
}

/// A pluggable scheduler: everything the generic campaign kernel needs,
/// with per-core id/timer types as zero-cost associated types.
///
/// Implementations: [`SlurmSched`] (native or UM-Bridge SLURM),
/// [`MetaStack`] (UM-Bridge + a [`TaskCore`](crate::hqlite::TaskCore)
/// meta-scheduler — [`HqCore`](crate::hqlite::HqCore) or
/// [`WorkStealCore`]).
pub trait SchedulerCore {
    /// Unit-of-work id (SLURM `JobId`, HQ `TaskId`).
    type Id: Copy + Eq + Hash + Debug;
    /// Core timer payload delivered back through `on_timer_into`.
    type Timer: Debug;

    /// Scheduler label for reports ("SLURM", "HQ", "worksteal", ...).
    fn label(&self) -> &'static str;

    /// Log granularity applied to emitted records (paper section V:
    /// SLURM logs whole seconds, HQ milliseconds).
    fn log_grain(&self) -> Micros;

    /// Kick off periodic timers (and any registration pre-work).  Called
    /// once before the event loop starts.
    fn bootstrap_into(
        &mut self,
        t: Micros,
        out: &mut Vec<Effect<Self::Id, Self::Timer>>,
    );

    /// Submit one evaluation.  Returns the work id plus the
    /// driver-owned workload duration (the submission's compute time
    /// plus any per-job overhead this scheduler adds, e.g. model-server
    /// init); the kernel schedules `on_work_done_into` that long after
    /// the matching [`Effect::Start`].
    fn submit_into(
        &mut self,
        t: Micros,
        s: &Submission,
        out: &mut Vec<Effect<Self::Id, Self::Timer>>,
    ) -> (Self::Id, Micros);

    /// Cancel a unit of work.  Default: unsupported, no-op (HyperQueue
    /// exposes no per-task cancel on this path).
    fn cancel_into(
        &mut self,
        _t: Micros,
        _id: Self::Id,
        _out: &mut Vec<Effect<Self::Id, Self::Timer>>,
    ) {
    }

    /// A core timer elapsed.
    fn on_timer_into(
        &mut self,
        t: Micros,
        timer: Self::Timer,
        out: &mut Vec<Effect<Self::Id, Self::Timer>>,
    );

    /// The workload of `id` finished (scheduled by the kernel after
    /// [`Effect::Start`]).
    fn on_work_done_into(
        &mut self,
        t: Micros,
        id: Self::Id,
        out: &mut Vec<Effect<Self::Id, Self::Timer>>,
    );

    /// The workload of `id` failed mid-run (injected by a fault plan).
    /// `retry_in: Some(backoff)` means the retry budget allows another
    /// attempt: the core must free the worker, park the task, and arm a
    /// retry timer `backoff` from now (emitting [`Effect::Requeued`]).
    /// `None` means the budget is exhausted: the core must kill the task
    /// and emit a *truncated* [`Effect::Finish`] so the quarantine is
    /// reported, never silently dropped.  Default: cores without retry
    /// semantics treat the failure as a (poisoned) completion so no task
    /// is ever lost.
    fn on_work_failed_into(
        &mut self,
        t: Micros,
        id: Self::Id,
        _retry_in: Option<Micros>,
        out: &mut Vec<Effect<Self::Id, Self::Timer>>,
    ) {
        self.on_work_done_into(t, id, out);
    }

    /// External capacity change.  Default: no-op (cores without an
    /// elastic worker pool).
    fn on_capacity_change_into(
        &mut self,
        _t: Micros,
        _change: CapacityChange,
        _out: &mut Vec<Effect<Self::Id, Self::Timer>>,
    ) {
    }

    /// Is this parked timer dead (its task already finished)?  The
    /// kernel skips stale timers at pop instead of invoking the core —
    /// dead dispatch/limit timers no longer ride the heap as no-op
    /// transitions across a million-task campaign.  Default: never
    /// stale (cores that cannot tell must be called).
    fn timer_is_stale(&self, _timer: &Self::Timer) -> bool {
        false
    }

    /// Append the ids of currently live workers (the id space of
    /// [`CapacityChange::WorkerLost`]).  The fault plane samples crash
    /// victims from this set; cores without an addressable worker pool
    /// (native SLURM) leave it empty and are crash-immune.
    fn live_worker_ids(&self, _out: &mut Vec<u64>) {}

    /// Classify a terminal record (per-core: tag `u64::MAX` means
    /// background load under SLURM but a registration pre-job on the HQ
    /// stack).
    fn classify(&self, record: &JobRecord) -> Completion;
}
