//! Deterministic fault-injection plans for both scheduler planes.
//!
//! A [`FaultPlan`] is a *pure function of its seed*: every decision —
//! whether attempt `k` of task `tag` fails, where in the run it fails,
//! how much a slow task is inflated, when the next worker crashes and
//! which ordinal dies — is a keyed hash draw, never a shared sequential
//! RNG stream.  That is the property the scheduler ablations need: the
//! four cores consume events in different orders, but because no draw
//! depends on consumption order, the same `(seed, tag)` produces the
//! same per-task failure count, the same quarantine set and the same
//! crash schedule under every core.  "Same plan, same seed, same
//! failure trace" is structural, not coincidental.
//!
//! The plan is deliberately split from its *mechanics*: `faults.rs`
//! only answers questions ("does attempt 2 of tag 17 fail?"); the
//! virtual-time kernel ([`kernel::run_with_faults`](super::kernel::run_with_faults))
//! and the wall-clock driver ([`realtime::RtDriver`](super::realtime::RtDriver))
//! own injection, retry budgets and epoch-based invalidation.

use crate::clock::{Micros, SEC};
use crate::util::rng::Rng;

/// Draw streams — namespace the keyed hashes so e.g. the failure draw
/// for `(tag, attempt)` never collides with the slowdown draw.
const STREAM_FAIL: u64 = 0x01;
const STREAM_SLOW: u64 = 0x02;
const STREAM_POINT: u64 = 0x03;
const STREAM_CRASH: u64 = 0x04;
const STREAM_VICTIM: u64 = 0x05;

/// User-facing fault-plan parameters (`--faults` on the CLI).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Seed for every keyed draw (independent of the campaign seed so
    /// the same workload can be replayed under a different fault trace).
    pub seed: u64,
    /// Mean worker-crash interarrival (exponential); 0 disables crashes.
    pub crash_every: Micros,
    /// Per-attempt transient-failure probability (before family bias).
    pub task_fail_p: f64,
    /// Attempts before a task is quarantined (>= 1).
    pub max_attempts: u32,
    /// First retry backoff; doubles per failure.
    pub backoff_base: Micros,
    /// Backoff ceiling.
    pub backoff_cap: Micros,
    /// Probability an attempt runs slow (straggler injection).
    pub slow_p: f64,
    /// Duration multiplier applied to slow attempts.
    pub slow_factor: f64,
    /// Per-family failure bias: `task_fail_p` is multiplied by
    /// `family_bias[tag % len]`.  Empty = uniform.
    pub family_bias: Vec<f64>,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 1,
            crash_every: 0,
            task_fail_p: 0.0,
            max_attempts: 3,
            backoff_base: SEC,
            backoff_cap: 60 * SEC,
            slow_p: 0.0,
            slow_factor: 1.0,
            family_bias: Vec::new(),
        }
    }
}

impl FaultSpec {
    /// The bench/example preset: node loss every ~5 min, 2% transient
    /// failures with a 2x-biased odd family, 5% stragglers at 8x.
    pub fn flaky(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            crash_every: 300 * SEC,
            task_fail_p: 0.02,
            max_attempts: 4,
            backoff_base: SEC,
            backoff_cap: 60 * SEC,
            slow_p: 0.05,
            slow_factor: 8.0,
            family_bias: vec![1.0, 2.0],
        }
    }

    /// Parse the compact CLI spec, e.g.
    /// `crash=300s,fail=0.02,attempts=4,backoff=1s:60s,slow=0.05x8,bias=1:2,seed=9`.
    /// Every key is optional; unknown keys are errors.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec: expected key=value, got `{part}`"))?;
            match k {
                "seed" => spec.seed = parse_u64(v)?,
                "crash" => spec.crash_every = parse_dur(v)?,
                "fail" => spec.task_fail_p = parse_f64(v)?,
                "attempts" => {
                    spec.max_attempts = parse_u64(v)?.max(1) as u32;
                }
                "backoff" => {
                    let (base, cap) = v
                        .split_once(':')
                        .ok_or_else(|| format!("fault spec: backoff wants base:cap, got `{v}`"))?;
                    spec.backoff_base = parse_dur(base)?;
                    spec.backoff_cap = parse_dur(cap)?;
                }
                "slow" => {
                    let (p, f) = v
                        .split_once('x')
                        .ok_or_else(|| format!("fault spec: slow wants p x factor, got `{v}`"))?;
                    spec.slow_p = parse_f64(p)?;
                    spec.slow_factor = parse_f64(f)?;
                }
                "bias" => {
                    spec.family_bias = v
                        .split(':')
                        .map(parse_f64)
                        .collect::<Result<Vec<f64>, String>>()?;
                }
                _ => return Err(format!("fault spec: unknown key `{k}`")),
            }
        }
        Ok(spec)
    }

    /// One-line human label for reports.
    pub fn describe(&self) -> String {
        format!(
            "crash_every={}s fail_p={} attempts={} slow={}x{} seed={}",
            self.crash_every / SEC,
            self.task_fail_p,
            self.max_attempts,
            self.slow_p,
            self.slow_factor,
            self.seed
        )
    }
}

/// A compiled, queryable fault plan.  Cheap to clone; all state is the
/// spec itself — answers are recomputed keyed draws.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
}

/// FNV-style combine for keyed draws.
fn key(stream: u64, a: u64, b: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for v in [a, b] {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
        h ^= h >> 29;
    }
    h
}

impl FaultPlan {
    pub fn new(spec: FaultSpec) -> FaultPlan {
        FaultPlan { spec }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    pub fn max_attempts(&self) -> u32 {
        self.spec.max_attempts.max(1)
    }

    pub fn injects_crashes(&self) -> bool {
        self.spec.crash_every > 0
    }

    /// One keyed uniform in [0, 1).
    fn draw(&self, stream: u64, a: u64, b: u64) -> f64 {
        Rng::new(self.spec.seed ^ key(stream, a, b)).uniform()
    }

    /// Effective per-attempt failure probability for a task family.
    fn fail_p(&self, tag: u64) -> f64 {
        let bias = if self.spec.family_bias.is_empty() {
            1.0
        } else {
            self.spec.family_bias[(tag % self.spec.family_bias.len() as u64) as usize]
        };
        (self.spec.task_fail_p * bias).clamp(0.0, 1.0)
    }

    /// Number of leading attempts of `tag` that fail — a pure function
    /// of `(seed, tag)`, capped at `max_attempts` (== quarantine).  This
    /// is what makes the failure trace identical across cores: the k-th
    /// attempt's fate never depends on *when* the core ran it.
    pub fn fail_count(&self, tag: u64) -> u32 {
        let p = self.fail_p(tag);
        if p <= 0.0 {
            return 0;
        }
        let cap = self.max_attempts();
        let mut n = 0;
        while n < cap && self.draw(STREAM_FAIL, tag, n as u64) < p {
            n += 1;
        }
        n
    }

    /// Does the `attempt`-th run (1-based) of `tag` fail transiently?
    pub fn attempt_fails(&self, tag: u64, attempt: u32) -> bool {
        attempt <= self.fail_count(tag)
    }

    /// Will `tag` exhaust its retry budget and be quarantined?
    pub fn quarantines(&self, tag: u64) -> bool {
        self.fail_count(tag) >= self.max_attempts()
    }

    /// Duration multiplier for the `attempt`-th run of `tag`.
    pub fn slowdown(&self, tag: u64, attempt: u32) -> f64 {
        if self.spec.slow_p <= 0.0 || self.spec.slow_factor == 1.0 {
            return 1.0;
        }
        if self.draw(STREAM_SLOW, tag, attempt as u64) < self.spec.slow_p {
            self.spec.slow_factor.max(1.0)
        } else {
            1.0
        }
    }

    /// Where inside a `dur`-long attempt the failure strikes: in
    /// `[1, dur]`, so a failed attempt always burns some worker time.
    pub fn fail_point(&self, tag: u64, attempt: u32, dur: Micros) -> Micros {
        let frac = self.draw(STREAM_POINT, tag, attempt as u64);
        ((dur as f64 * frac) as Micros).clamp(1, dur.max(1))
    }

    /// Capped exponential backoff before retry number `fails + 1`.
    pub fn backoff(&self, fails: u32) -> Micros {
        let shift = fails.saturating_sub(1).min(20);
        self.spec
            .backoff_base
            .max(1)
            .saturating_mul(1u64 << shift)
            .min(self.spec.backoff_cap.max(1))
    }

    /// Gap before the `k`-th worker crash (exponential interarrival).
    pub fn crash_gap(&self, k: u64) -> Micros {
        let mut r = Rng::new(self.spec.seed ^ key(STREAM_CRASH, k, 0));
        (r.exponential(self.spec.crash_every as f64) as Micros).max(1)
    }

    /// Which of `n` (sorted) live workers the `k`-th crash kills.
    pub fn crash_victim(&self, k: u64, n: usize) -> usize {
        (Rng::new(self.spec.seed ^ key(STREAM_VICTIM, k, 0)).below(n as u64)) as usize
    }
}

fn parse_u64(s: &str) -> Result<u64, String> {
    s.parse::<u64>()
        .map_err(|_| format!("fault spec: bad integer `{s}`"))
}

fn parse_f64(s: &str) -> Result<f64, String> {
    s.parse::<f64>()
        .map_err(|_| format!("fault spec: bad number `{s}`"))
}

/// Duration with unit suffix: `500ms`, `300s`, `5m`; bare numbers are
/// seconds.
fn parse_dur(s: &str) -> Result<Micros, String> {
    let (num, mult) = if let Some(n) = s.strip_suffix("ms") {
        (n, crate::clock::MS)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, SEC)
    } else if let Some(n) = s.strip_suffix('m') {
        (n, 60 * SEC)
    } else {
        (s, SEC)
    };
    let v = num
        .parse::<f64>()
        .map_err(|_| format!("fault spec: bad duration `{s}`"))?;
    Ok((v * mult as f64) as Micros)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let s = FaultSpec::parse(
            "crash=300s,fail=0.02,attempts=4,backoff=1s:60s,slow=0.05x8,bias=1:2,seed=9",
        )
        .unwrap();
        assert_eq!(s.crash_every, 300 * SEC);
        assert_eq!(s.task_fail_p, 0.02);
        assert_eq!(s.max_attempts, 4);
        assert_eq!(s.backoff_base, SEC);
        assert_eq!(s.backoff_cap, 60 * SEC);
        assert_eq!(s.slow_p, 0.05);
        assert_eq!(s.slow_factor, 8.0);
        assert_eq!(s.family_bias, vec![1.0, 2.0]);
        assert_eq!(s.seed, 9);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultSpec::parse("nope=1").is_err());
        assert!(FaultSpec::parse("fail").is_err());
        assert!(FaultSpec::parse("backoff=1s").is_err());
        assert!(FaultSpec::parse("crash=xyz").is_err());
    }

    #[test]
    fn durations_parse_units() {
        assert_eq!(parse_dur("500ms").unwrap(), 500 * crate::clock::MS);
        assert_eq!(parse_dur("2s").unwrap(), 2 * SEC);
        assert_eq!(parse_dur("5m").unwrap(), 300 * SEC);
        assert_eq!(parse_dur("3").unwrap(), 3 * SEC);
    }

    #[test]
    fn fail_count_is_order_independent() {
        let p = FaultPlan::new(FaultSpec {
            task_fail_p: 0.5,
            max_attempts: 4,
            ..FaultSpec::default()
        });
        // Query in scrambled orders; answers must not drift.
        let a: Vec<u32> = (0..100).map(|t| p.fail_count(t)).collect();
        let b: Vec<u32> = (0..100).rev().map(|t| p.fail_count(t)).collect();
        for t in 0..100usize {
            assert_eq!(a[t], b[99 - t]);
        }
        // And attempt_fails agrees with the count.
        for t in 0..100u64 {
            let n = p.fail_count(t);
            for k in 1..=4u32 {
                assert_eq!(p.attempt_fails(t, k), k <= n);
            }
        }
    }

    #[test]
    fn quarantine_matches_budget_exhaustion() {
        let p = FaultPlan::new(FaultSpec {
            task_fail_p: 0.9,
            max_attempts: 3,
            ..FaultSpec::default()
        });
        let q: Vec<u64> = (0..200).filter(|&t| p.quarantines(t)).collect();
        assert!(!q.is_empty(), "0.9^3 should quarantine some of 200 tags");
        for &t in &q {
            assert_eq!(p.fail_count(t), 3);
        }
        // Non-quarantined tags fail strictly fewer than max_attempts.
        for t in (0..200).filter(|&t| !p.quarantines(t)) {
            assert!(p.fail_count(t) < 3);
        }
    }

    #[test]
    fn family_bias_shifts_failure_mass() {
        let p = FaultPlan::new(FaultSpec {
            task_fail_p: 0.2,
            max_attempts: 8,
            family_bias: vec![0.0, 4.0],
            ..FaultSpec::default()
        });
        let even: u32 = (0..400).step_by(2).map(|t| p.fail_count(t)).sum();
        let odd: u32 = (1..400).step_by(2).map(|t| p.fail_count(t)).sum();
        assert_eq!(even, 0, "bias 0.0 family must never fail");
        assert!(odd > 100, "bias 4.0 family should fail often, got {odd}");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = FaultPlan::new(FaultSpec {
            backoff_base: SEC,
            backoff_cap: 60 * SEC,
            ..FaultSpec::default()
        });
        assert_eq!(p.backoff(1), SEC);
        assert_eq!(p.backoff(2), 2 * SEC);
        assert_eq!(p.backoff(3), 4 * SEC);
        assert_eq!(p.backoff(7), 60 * SEC); // capped
        assert_eq!(p.backoff(40), 60 * SEC); // shift clamp
    }

    #[test]
    fn crash_schedule_is_seed_deterministic() {
        let a = FaultPlan::new(FaultSpec { crash_every: 300 * SEC, ..FaultSpec::default() });
        let b = FaultPlan::new(FaultSpec { crash_every: 300 * SEC, ..FaultSpec::default() });
        for k in 0..50 {
            assert_eq!(a.crash_gap(k), b.crash_gap(k));
            assert_eq!(a.crash_victim(k, 16), b.crash_victim(k, 16));
            assert!(a.crash_victim(k, 16) < 16);
        }
        let c = FaultPlan::new(FaultSpec {
            crash_every: 300 * SEC,
            seed: 2,
            ..FaultSpec::default()
        });
        assert!((0..50).any(|k| a.crash_gap(k) != c.crash_gap(k)));
    }

    #[test]
    fn fail_point_is_within_attempt() {
        let p = FaultPlan::new(FaultSpec { task_fail_p: 1.0, ..FaultSpec::default() });
        for tag in 0..50 {
            for attempt in 1..4 {
                let fp = p.fail_point(tag, attempt, 10 * SEC);
                assert!((1..=10 * SEC).contains(&fp));
            }
        }
        // Degenerate zero-length attempt still burns one microsecond.
        assert_eq!(p.fail_point(1, 1, 0), 1);
    }

    #[test]
    fn slowdown_only_inflates() {
        let p = FaultPlan::new(FaultSpec {
            slow_p: 0.3,
            slow_factor: 8.0,
            ..FaultSpec::default()
        });
        let mut slowed = 0;
        for tag in 0..300 {
            let f = p.slowdown(tag, 1);
            assert!(f == 1.0 || f == 8.0);
            if f > 1.0 {
                slowed += 1;
            }
        }
        assert!(slowed > 40 && slowed < 160, "slowed {slowed}/300 at p=0.3");
    }
}
