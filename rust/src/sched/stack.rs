//! [`MetaStack`]: the UM-Bridge + meta-scheduler stack (tasks dispatched
//! by a [`TaskCore`] onto workers inside bulk allocations obtained from
//! the SLURM core) behind the unified [`SchedulerCore`] seam.
//!
//! The stack owns everything the old `run_hq` driver hard-coded:
//! registration pre-jobs (reserved tag, excluded from records),
//! allocation submission to the SLURM core, worker registration when an
//! allocation launches, worker expiry when the allocation job ends, and
//! the two cores' action queues feeding each other until both drain.
//! The routing loop is reproduced **verbatim** from the PR 1/PR 2
//! drivers (alternating slurm/meta batches, swap-drain buffers), with
//! effects pushed in exactly the order the old loop issued its DES
//! schedules — `tests/campaign_equiv.rs` pins the equivalence.
//!
//! Generic over the meta-scheduler: `MetaStack<HqCore>` is the paper's
//! UM-Bridge + HyperQueue stack; `MetaStack<WorkStealCore>` swaps in the
//! partitioned work-stealing dispatcher, `MetaStack<EdfCore>` the
//! deadline-EDF one, and `MetaStack<GangCore>` the moldable gang
//! scheduler (whose `StartGang` actions surface as multi-member
//! [`Effect::Start`] worker sets).  A future task scheduler costs one
//! [`TaskCore`] impl.

use std::collections::{HashMap, HashSet};

use crate::campaign::driver::CampaignConfig;
use crate::campaign::submitter::Submission;
use crate::clock::{Micros, MS};
use crate::hqlite::{HqAction, HqCore, HqTimer, TaskCore, TaskId, TaskSpec};
use crate::metrics::JobRecord;
use crate::slurmlite::core::{Action, BatchCore, JobId, SlurmCore,
                             Timer as SlurmTimer, USER_EXPERIMENT};
use crate::workload::{scenario, App, Scenario};

use super::edf::EdfCore;
use super::gang::GangCore;
use super::worksteal::WorkStealCore;
use super::{CapacityChange, Completion, Effect, SchedulerCore, WorkerSet};

/// The paper's UM-Bridge + HyperQueue stack.
pub type HqSched = MetaStack<HqCore>;

/// The UM-Bridge stack over the partitioned work-stealing dispatcher.
pub type WorkStealSched = MetaStack<WorkStealCore>;

/// The UM-Bridge stack over the deadline-EDF dispatcher.
pub type EdfSched = MetaStack<EdfCore>;

/// The UM-Bridge stack over the moldable gang dispatcher.
pub type GangSched = MetaStack<GangCore>;

/// Composite timers: both cores' timers plus the stack's own lifecycle
/// events (registration pre-jobs, allocation expiry).
#[derive(Debug)]
pub enum StackTimer {
    /// Native-scheduler timer.
    Slurm(SlurmTimer),
    /// Meta-scheduler timer.
    Meta(HqTimer),
    /// Submit one registration pre-job (t = 0, one per pre-job).
    RegSubmit,
    /// A registration pre-job's server init finished.
    RegDone(TaskId),
    /// The allocation job reached its time limit.
    AllocEnd(JobId),
}

/// UM-Bridge + meta-scheduler stack as a single [`SchedulerCore`].
pub struct MetaStack<M: TaskCore> {
    label: &'static str,
    slurm: SlurmCore,
    meta: M,
    /// Allocation geometry follows the campaign's primary app.
    scen: Scenario,
    alloc_app: App,
    server_init: Micros,
    registration_jobs: u64,
    /// Native allocation job -> meta alloc tag.
    alloc_jobs: HashMap<JobId, u64>,
    /// Registration pre-job task ids (their work-done is stack-internal).
    reg_tasks: HashSet<TaskId>,
    // Reusable routing buffers: the cores append into `*_acts`; the
    // routing loop swaps each into a batch buffer before interpreting,
    // so interpretation can append follow-up actions without allocating.
    slurm_acts: Vec<Action>,
    meta_acts: Vec<HqAction>,
    slurm_batch: Vec<Action>,
    meta_batch: Vec<HqAction>,
}

impl<M: TaskCore> MetaStack<M> {
    /// Build the stack from a campaign configuration and a
    /// meta-scheduler (construct it with
    /// [`CampaignConfig::autoalloc`]-derived settings).
    pub fn new(cfg: &CampaignConfig, meta: M, label: &'static str) -> Self {
        MetaStack {
            label,
            slurm: SlurmCore::new(
                cfg.cluster.clone(),
                cfg.overheads.clone(),
                cfg.seed,
            ),
            meta,
            scen: scenario(cfg.app),
            alloc_app: cfg.app,
            server_init: cfg.overheads.server_init,
            registration_jobs: cfg.registration_jobs,
            alloc_jobs: HashMap::new(),
            reg_tasks: HashSet::new(),
            slurm_acts: Vec::new(),
            meta_acts: Vec::new(),
            slurm_batch: Vec::new(),
            meta_batch: Vec::new(),
        }
    }

    /// The meta-scheduler (introspection; used by tests and benches).
    pub fn meta(&self) -> &M {
        &self.meta
    }

    /// Route until both action queues drain (they feed each other),
    /// translating driver-facing actions into effects *in issue order*.
    fn route(&mut self, t: Micros, out: &mut Vec<Effect<TaskId, StackTimer>>) {
        loop {
            let mut progressed = false;
            std::mem::swap(&mut self.slurm_acts, &mut self.slurm_batch);
            let mut batch = std::mem::take(&mut self.slurm_batch);
            for a in batch.drain(..) {
                progressed = true;
                match a {
                    Action::Timer(tt, tm) => {
                        out.push(Effect::SetTimer(tt, StackTimer::Slurm(tm)));
                    }
                    Action::Launched { job, .. } => {
                        if self.alloc_jobs.contains_key(&job) {
                            // Allocation is up: a worker registers for
                            // the remaining allocation lifetime; the
                            // allocation job ends at its time limit.
                            let _ = self.meta.on_alloc_up_into(
                                t,
                                self.scen.hq_alloc_time,
                                self.scen.cpus,
                                &mut self.meta_acts,
                            );
                            out.push(Effect::SetTimer(
                                t + self.scen.hq_alloc_time,
                                StackTimer::AllocEnd(job),
                            ));
                        }
                        // Background jobs self-finish inside the core.
                    }
                    // Allocation/background completions carry no record
                    // the campaign cares about.
                    Action::Completed { .. } | Action::TimedOut { .. } => {}
                }
            }
            self.slurm_batch = batch;
            std::mem::swap(&mut self.meta_acts, &mut self.meta_batch);
            let mut batch = std::mem::take(&mut self.meta_batch);
            for a in batch.drain(..) {
                progressed = true;
                match a {
                    HqAction::SubmitAllocation { alloc_tag, req } => {
                        let id = self.slurm.submit_into(
                            t,
                            USER_EXPERIMENT,
                            u64::MAX - 1,
                            req,
                            &mut self.slurm_acts,
                        );
                        self.alloc_jobs.insert(id, alloc_tag);
                    }
                    HqAction::StartTask { task, worker } => {
                        if self.reg_tasks.contains(&task) {
                            // Registration pre-jobs run ~1 s of server
                            // init; their work-done is stack-internal.
                            out.push(Effect::SetTimer(
                                t + self.server_init,
                                StackTimer::RegDone(task),
                            ));
                        } else {
                            out.push(Effect::Start {
                                id: task,
                                contention: 1.0,
                                workers: WorkerSet::one(worker),
                            });
                        }
                    }
                    HqAction::StartGang { task, workers } => {
                        if self.reg_tasks.contains(&task) {
                            // A registration pre-job ganged across
                            // workers still just runs its server init.
                            out.push(Effect::SetTimer(
                                t + self.server_init,
                                StackTimer::RegDone(task),
                            ));
                        } else {
                            out.push(Effect::Start {
                                id: task,
                                contention: 1.0,
                                workers: WorkerSet::many(workers),
                            });
                        }
                    }
                    HqAction::Timer(tt, tm) => {
                        out.push(Effect::SetTimer(tt, StackTimer::Meta(tm)));
                    }
                    HqAction::TaskCompleted { task, record } => {
                        if record.tag == u64::MAX {
                            self.reg_tasks.remove(&task);
                        }
                        out.push(Effect::Finish { id: task, record });
                    }
                    HqAction::KillTask { task } => {
                        out.push(Effect::Retire { id: task });
                    }
                    HqAction::Requeued { task } => {
                        out.push(Effect::Requeued { id: task });
                    }
                }
            }
            self.meta_batch = batch;
            if !progressed {
                break;
            }
        }
    }
}

impl<M: TaskCore> SchedulerCore for MetaStack<M> {
    type Id = TaskId;
    type Timer = StackTimer;

    fn label(&self) -> &'static str {
        self.label
    }

    fn log_grain(&self) -> Micros {
        // HQ-style stacks log at millisecond accuracy.
        MS
    }

    fn bootstrap_into(
        &mut self,
        t: Micros,
        out: &mut Vec<Effect<TaskId, StackTimer>>,
    ) {
        for a in self.slurm.bootstrap(t) {
            if let Action::Timer(tt, tm) = a {
                out.push(Effect::SetTimer(tt, StackTimer::Slurm(tm)));
            }
        }
        // Registration pre-jobs go first (the balancer's readiness
        // checks), before the submitter seeds the campaign.
        for _ in 0..self.registration_jobs {
            out.push(Effect::SetTimer(t, StackTimer::RegSubmit));
        }
    }

    fn submit_into(
        &mut self,
        t: Micros,
        s: &Submission,
        out: &mut Vec<Effect<TaskId, StackTimer>>,
    ) -> (TaskId, Micros) {
        debug_assert!(s.tag != u64::MAX, "tag u64::MAX is reserved");
        let scen = scenario(s.app);
        // Worker geometry follows the campaign's primary app: a task
        // whose shape exceeds it would sit in the queue forever
        // (autoalloc cycling until the runaway guard).  Fail fast and
        // explain instead.
        assert!(
            scen.cpus <= self.scen.cpus
                && scen.hq_time_request <= self.scen.hq_alloc_time,
            "campaign submission '{}' (cores {}, time request {}) cannot fit \
             the '{}' allocation geometry (cores {}, walltime {}); pick a \
             CampaignConfig.app whose Table III row covers every submitted \
             app",
            s.app.label(),
            scen.cpus,
            scen.hq_time_request,
            self.alloc_app.label(),
            self.scen.cpus,
            self.scen.hq_alloc_time,
        );
        let tid = self.meta.submit_task_into(
            t,
            TaskSpec {
                tag: s.tag,
                cores: scen.cpus,
                time_request: scen.hq_time_request,
                time_limit: scen.hq_time_limit + self.server_init,
            },
            &mut self.meta_acts,
        );
        self.route(t, out);
        (tid, s.duration + self.server_init)
    }

    fn on_timer_into(
        &mut self,
        t: Micros,
        timer: StackTimer,
        out: &mut Vec<Effect<TaskId, StackTimer>>,
    ) {
        match timer {
            StackTimer::Slurm(tm) => {
                self.slurm.on_timer_into(t, tm, &mut self.slurm_acts);
            }
            StackTimer::Meta(tm) => {
                self.meta.on_timer_into(t, tm, &mut self.meta_acts);
            }
            StackTimer::RegSubmit => {
                // Registration jobs: ~1 s of server init only; tagged
                // with the reserved marker so completions are excluded
                // from the records.
                let tid = self.meta.submit_task_into(
                    t,
                    TaskSpec {
                        tag: u64::MAX,
                        cores: self.scen.cpus,
                        time_request: self.scen.hq_time_request,
                        time_limit: self.scen.hq_time_limit
                            + self.server_init,
                    },
                    &mut self.meta_acts,
                );
                self.reg_tasks.insert(tid);
                out.push(Effect::Queued);
            }
            StackTimer::RegDone(tid) => {
                self.meta.on_task_done_into(t, tid, &mut self.meta_acts);
            }
            StackTimer::AllocEnd(job) => {
                self.slurm.on_finish_into(t, job, &mut self.slurm_acts);
                if self.alloc_jobs.remove(&job).is_some() {
                    // Allocation ended: expire its worker so the meta
                    // core requeues tasks and requests replacement
                    // capacity.
                    self.meta.expire_workers_into(t, &mut self.meta_acts);
                }
            }
        }
        self.route(t, out);
    }

    fn on_work_done_into(
        &mut self,
        t: Micros,
        id: TaskId,
        out: &mut Vec<Effect<TaskId, StackTimer>>,
    ) {
        self.meta.on_task_done_into(t, id, &mut self.meta_acts);
        self.route(t, out);
    }

    fn on_work_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<Effect<TaskId, StackTimer>>,
    ) {
        self.meta.on_task_failed_into(t, id, retry_in, &mut self.meta_acts);
        self.route(t, out);
    }

    fn timer_is_stale(&self, timer: &StackTimer) -> bool {
        // Per-task meta timers die with their task; everything else
        // (periodic SLURM ticks, allocation lifecycle) stays live.
        match timer {
            StackTimer::Meta(
                HqTimer::Dispatched(id)
                | HqTimer::Limit(id)
                | HqTimer::Retry(id),
            ) => !self.meta.task_live(*id),
            _ => false,
        }
    }

    fn live_worker_ids(&self, out: &mut Vec<u64>) {
        self.meta.live_worker_ids_into(out);
    }

    fn on_capacity_change_into(
        &mut self,
        t: Micros,
        change: CapacityChange,
        out: &mut Vec<Effect<TaskId, StackTimer>>,
    ) {
        match change {
            CapacityChange::WorkerLost(wid) => {
                self.meta.on_worker_lost_into(t, wid, &mut self.meta_acts);
            }
            // Capacity on this stack comes from allocations obtained
            // through the SLURM core, never from external announcements.
            CapacityChange::WorkerUp { .. } => {}
        }
        self.route(t, out);
    }

    fn classify(&self, record: &JobRecord) -> Completion {
        // Tag u64::MAX marks a registration pre-job on this path.
        if record.tag == u64::MAX {
            Completion::Registration
        } else {
            Completion::Evaluation
        }
    }
}
