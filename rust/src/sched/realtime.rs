//! The real-time (wall-clock) scheduler kernel: the live balancer's
//! dispatch plane, driven through the same [`SchedulerCore`] seam the
//! campaigns use.
//!
//! Where [`kernel::run`](super::kernel::run) owns a *virtual*-time DES
//! (one event heap, simulated clock), [`RtDriver`] owns the *wall*-clock
//! equivalent: incoming `/Evaluate` requests become `Submit` events,
//! model-server registrations and retirements become worker
//! [`CapacityChange`] events, forwarder completions become `WorkDone`,
//! and [`Effect::SetTimer`] requests land in a monotonic timer heap
//! whose head deadline the balancer's forwarder condvar waits on.
//! [`Effect::Start`] effects queue up as ready work the forwarder pool
//! consumes — the scheduler core decides *order and placement*, the
//! forwarders execute.
//!
//! ```text
//!   /Evaluate ──────────► submit ─┐                ┌─► ready (id, worker)
//!   server registered ──► worker_up│   RtDriver    │        │ consumed by
//!   lease retired ──────► worker_lost  ┌────────┐  │        ▼ forwarder pool
//!   forward finished ───► work_done └─►│LiveCore│──┘  SetTimer ─► timer heap
//!                                      └────────┘      (condvar deadline)
//! ```
//!
//! [`LiveSched`] adapts any [`TaskCore`] (the HyperQueue-style
//! dispatcher seam) to this driver: each registered model server is one
//! single-core worker announced via [`CapacityChange::WorkerUp`], each
//! evaluation a one-core task whose time limit is the client's deadline
//! budget.  That makes every task dispatcher a live scheduling policy
//! for free — [`HqCore`] is the balancer's classic per-model FCFS
//! (`--scheduler fcfs`), [`WorkStealCore`] partitions the queue across
//! servers with stealing (`--scheduler worksteal`), and
//! [`EdfCore`](super::EdfCore) serves earliest-deadline-first
//! (`--scheduler edf`, one deadline heap per model).
//!
//! The balancer holds one `RtDriver` per model, all behind its dispatch
//! mutex — the driver itself is single-threaded by construction and
//! allocation-lean (one reusable effect buffer, like the virtual
//! kernel).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::time::Instant;

use crate::campaign::submitter::Submission;
use crate::clock::{Micros, MS, SEC};
use crate::cluster::JobRequest;
use crate::hqlite::{AutoAllocConfig, HqAction, HqCore, HqTimer, TaskCore,
                    TaskId, WorkerId, TaskSpec};
use crate::metrics::JobRecord;
use crate::workload::App;

use super::edf::EdfCore;
use super::gang::GangCore;
use super::worksteal::WorkStealCore;
use super::{CapacityChange, Completion, Effect, SchedulerCore, WorkerSet};

/// Lifetime of a live worker in the core's virtual clock: effectively
/// forever (a model server has no allocation walltime; it lives until
/// retired).  Far below `Micros::MAX` so `t + time_request` arithmetic
/// can never overflow.
const LIVE_WORKER_LIFE: Micros = Micros::MAX / 4;

/// Slack added to a task's deadline budget before it becomes the core's
/// kill limit, so the core-side limit timer never races the client's own
/// timeout (the front door answers 504 first; the core limit is the
/// backstop that frees the synthetic worker).
const LIVE_LIMIT_PAD: Micros = 5 * SEC;

/// The object-safe live scheduler core: every [`TaskCore`]-backed policy
/// shares `TaskId` ids and `HqTimer` timers, so the balancer can pick
/// its policy at runtime behind one box.
pub type LiveCore = Box<dyn SchedulerCore<Id = TaskId, Timer = HqTimer>
                        + Send>;

/// Which scheduling policy the live balancer dispatches with.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LivePolicy {
    /// Per-model FCFS ([`HqCore`]'s central queue) — the balancer's
    /// classic discipline and the default.
    #[default]
    Fcfs,
    /// Partitioned per-server queues with work stealing
    /// ([`WorkStealCore`]).
    WorkSteal,
    /// Earliest-deadline-first with laxity tie-break
    /// ([`EdfCore`](super::EdfCore)); the deadline is the client's
    /// request-timeout budget.
    Edf,
    /// Strict-FCFS gang dispatcher ([`GangCore`]); live evaluations are
    /// width-1 gangs (one server each), so the policy degenerates to
    /// head-of-line FCFS with atomic slot reservation.
    Gang,
}

impl LivePolicy {
    pub fn parse(s: &str) -> Option<LivePolicy> {
        match s {
            "fcfs" | "hq" => Some(LivePolicy::Fcfs),
            "worksteal" => Some(LivePolicy::WorkSteal),
            "edf" => Some(LivePolicy::Edf),
            "gang" => Some(LivePolicy::Gang),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LivePolicy::Fcfs => "fcfs",
            LivePolicy::WorkSteal => "worksteal",
            LivePolicy::Edf => "edf",
            LivePolicy::Gang => "gang",
        }
    }
}

/// The autoalloc geometry live cores run with: capacity is announced
/// externally (`WorkerUp`), never self-allocated (`backlog: 0`), one
/// worker per announcement (the [`LiveSched`] id-mirror contract), no
/// cap, and zero dispatch latency so `Start` effects come out of the
/// same pass that freed the capacity.
pub fn live_autoalloc() -> AutoAllocConfig {
    AutoAllocConfig {
        backlog: 0,
        workers_per_alloc: 1,
        max_worker_count: u32::MAX,
        alloc_request: JobRequest::new(1, 1, LIVE_WORKER_LIFE),
        dispatch_latency: 0,
    }
}

/// Build the boxed live core for a policy.
pub fn live_core(policy: LivePolicy) -> LiveCore {
    match policy {
        LivePolicy::Fcfs => {
            Box::new(LiveSched::new(HqCore::new(live_autoalloc()), "fcfs"))
        }
        LivePolicy::WorkSteal => Box::new(LiveSched::new(
            WorkStealCore::new(live_autoalloc()),
            "worksteal",
        )),
        LivePolicy::Edf => {
            Box::new(LiveSched::new(EdfCore::new(live_autoalloc()), "edf"))
        }
        // Width-1 gangs: every live evaluation is a one-server task, so
        // the gang machinery reduces to strict FCFS over servers.
        LivePolicy::Gang => Box::new(LiveSched::new(
            GangCore::new(live_autoalloc()).with_gang(1, 1),
            "gang",
        )),
    }
}

/// Any [`TaskCore`] as a live [`SchedulerCore`]: one registered server =
/// one single-core worker, one evaluation = one single-core task.
///
/// Contract: the meta core must be built with [`live_autoalloc`]
/// geometry — `workers_per_alloc == 1` and an unreachable worker cap —
/// so each `WorkerUp` admits exactly one worker, whose generational
/// slab id [`TaskCore::on_alloc_up_into`] returns; the adapter maps it
/// to the caller's id to translate `WorkerUp`/`WorkerLost` ids and the
/// worker named in each `Start` effect.
pub struct LiveSched<M: TaskCore> {
    meta: M,
    label: &'static str,
    acts: Vec<HqAction>,
    /// Caller (external) worker id -> core-internal worker id.
    ext2int: HashMap<u64, WorkerId>,
    /// Core-internal worker id -> caller id (for `Start::worker`).
    int2ext: HashMap<WorkerId, u64>,
}

impl<M: TaskCore> LiveSched<M> {
    pub fn new(meta: M, label: &'static str) -> Self {
        LiveSched {
            meta,
            label,
            acts: Vec::new(),
            ext2int: HashMap::new(),
            int2ext: HashMap::new(),
        }
    }

    /// The wrapped dispatcher (introspection; tests and /Stats).
    pub fn meta(&self) -> &M {
        &self.meta
    }

    /// Translate the scratch actions into effects, in issue order.
    fn flush(&mut self, out: &mut Vec<Effect<TaskId, HqTimer>>) {
        for a in self.acts.drain(..) {
            match a {
                // Live capacity is externally announced; a core built on
                // the live_autoalloc geometry never emits these.
                HqAction::SubmitAllocation { .. } => {}
                HqAction::StartTask { task, worker } => {
                    out.push(Effect::Start {
                        id: task,
                        contention: 1.0,
                        workers: WorkerSet::from_opt(
                            self.int2ext.get(&worker).copied(),
                        ),
                    });
                }
                HqAction::StartGang { task, workers } => {
                    // Translate every member to the caller's id space; a
                    // member whose mapping raced away (just-retired
                    // server) is dropped — the lead member carries the
                    // dispatch.
                    let ext: Vec<u64> = workers
                        .iter()
                        .filter_map(|w| self.int2ext.get(w).copied())
                        .collect();
                    out.push(Effect::Start {
                        id: task,
                        contention: 1.0,
                        workers: WorkerSet::many(ext),
                    });
                }
                HqAction::Timer(tt, tm) => {
                    out.push(Effect::SetTimer(tt, tm));
                }
                HqAction::TaskCompleted { task, record } => {
                    out.push(Effect::Finish { id: task, record });
                }
                HqAction::KillTask { task } => {
                    out.push(Effect::Retire { id: task });
                }
                HqAction::Requeued { task } => {
                    out.push(Effect::Requeued { id: task });
                }
            }
        }
    }
}

impl<M: TaskCore> SchedulerCore for LiveSched<M> {
    type Id = TaskId;
    type Timer = HqTimer;

    fn label(&self) -> &'static str {
        self.label
    }

    fn log_grain(&self) -> Micros {
        MS
    }

    fn bootstrap_into(
        &mut self,
        _t: Micros,
        _out: &mut Vec<Effect<TaskId, HqTimer>>,
    ) {
    }

    fn submit_into(
        &mut self,
        t: Micros,
        s: &Submission,
        out: &mut Vec<Effect<TaskId, HqTimer>>,
    ) -> (TaskId, Micros) {
        // `duration` carries the client's deadline budget: it becomes
        // the task's kill limit (plus pad) and, on the EDF core, its
        // absolute deadline.
        let id = self.meta.submit_task_into(
            t,
            TaskSpec {
                tag: s.tag,
                cores: 1,
                time_request: 0,
                time_limit: s.duration.saturating_add(LIVE_LIMIT_PAD),
            },
            &mut self.acts,
        );
        self.flush(out);
        (id, s.duration)
    }

    fn on_timer_into(
        &mut self,
        t: Micros,
        timer: HqTimer,
        out: &mut Vec<Effect<TaskId, HqTimer>>,
    ) {
        self.meta.on_timer_into(t, timer, &mut self.acts);
        self.flush(out);
    }

    fn on_work_done_into(
        &mut self,
        t: Micros,
        id: TaskId,
        out: &mut Vec<Effect<TaskId, HqTimer>>,
    ) {
        self.meta.on_task_done_into(t, id, &mut self.acts);
        self.flush(out);
    }

    fn on_work_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<Effect<TaskId, HqTimer>>,
    ) {
        self.meta.on_task_failed_into(t, id, retry_in, &mut self.acts);
        self.flush(out);
    }

    fn timer_is_stale(&self, timer: &HqTimer) -> bool {
        match timer {
            HqTimer::Dispatched(id)
            | HqTimer::Limit(id)
            | HqTimer::Retry(id) => !self.meta.task_live(*id),
        }
    }

    fn live_worker_ids(&self, out: &mut Vec<u64>) {
        let start = out.len();
        self.meta.live_worker_ids_into(out);
        // Translate the core's internal ids to the caller's ids.
        let mut w = start;
        for r in start..out.len() {
            if let Some(&ext) = self.int2ext.get(&(out[r] as WorkerId)) {
                out[w] = ext;
                w += 1;
            }
        }
        out.truncate(w);
    }

    fn on_capacity_change_into(
        &mut self,
        t: Micros,
        change: CapacityChange,
        out: &mut Vec<Effect<TaskId, HqTimer>>,
    ) {
        match change {
            CapacityChange::WorkerUp { id, cores } => {
                let before = self.meta.live_workers();
                let int = self.meta.on_alloc_up_into(
                    t,
                    LIVE_WORKER_LIFE,
                    cores,
                    &mut self.acts,
                );
                debug_assert_eq!(
                    self.meta.live_workers(),
                    before + 1,
                    "live core must admit exactly one worker per WorkerUp"
                );
                // Map before flushing: any `Start` effect this pass
                // buffered is translated below, after the mapping lands.
                if let Some(int) = int {
                    self.ext2int.insert(id, int);
                    self.int2ext.insert(int, id);
                }
            }
            CapacityChange::WorkerLost(id) => {
                if let Some(int) = self.ext2int.remove(&id) {
                    self.int2ext.remove(&int);
                    self.meta.on_worker_lost_into(t, int, &mut self.acts);
                }
            }
        }
        self.flush(out);
    }

    fn classify(&self, record: &JobRecord) -> Completion {
        if record.tag == u64::MAX {
            Completion::Background
        } else {
            Completion::Evaluation
        }
    }
}

/// One pending core timer; ordered by (due, sequence) so the heap pops
/// deterministically and the payload rides along uncompared.
struct TimerEntry(Micros, u64, HqTimer);

impl PartialEq for TimerEntry {
    fn eq(&self, o: &Self) -> bool {
        self.0 == o.0 && self.1 == o.1
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (self.0, self.1).cmp(&(o.0, o.1))
    }
}

/// Retry budget and backoff for live evaluations that fail on a lease
/// (the forwarder's HTTP round died with the server).  Live defaults
/// are aggressive — one fast retry on a replacement server before the
/// error surfaces to the client — because a live request is already
/// burning its deadline budget while it cools.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts per task (first run included).
    pub max_attempts: u32,
    /// First backoff; doubles per failure.
    pub backoff_base: Micros,
    /// Backoff ceiling.
    pub backoff_cap: Micros,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 2,
            backoff_base: 50 * MS,
            backoff_cap: SEC,
        }
    }
}

impl RetryPolicy {
    /// Backoff before attempt `fails + 1`, after `fails` failures
    /// (capped exponential, same shape as the fault plan's).
    pub fn backoff(&self, fails: u32) -> Micros {
        let shift = fails.saturating_sub(1).min(20);
        self.backoff_base
            .max(1)
            .saturating_mul(1u64 << shift)
            .min(self.backoff_cap.max(1))
    }
}

/// What [`RtDriver::work_failed`] decided for a failed evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recovery {
    /// The task re-enters the queue after `backoff`; `attempt` is the
    /// attempt number the retry will run as (2 = first retry).
    Retrying { attempt: u32, backoff: Micros },
    /// Retry budget exhausted after `attempts` attempts: the core
    /// reported a truncated record; the caller surfaces the error.
    Quarantined { attempts: u32 },
}

/// The wall-clock driver around one live core (the balancer holds one
/// per model).  Owns the monotonic clock origin, the timer heap fed by
/// `SetTimer` effects, and the ready queue fed by `Start` effects; every
/// entry point runs core transitions to quiescence (zero dispatch
/// latency means a capacity change or submission surfaces its `Start`s
/// before the call returns).
pub struct RtDriver {
    core: LiveCore,
    epoch: Instant,
    timers: BinaryHeap<Reverse<TimerEntry>>,
    timer_seq: u64,
    /// Dispatched work awaiting a forwarder: (task, bound worker).
    ready: VecDeque<(TaskId, Option<u64>)>,
    /// Reusable effect buffer (allocation-lean, like the DES kernel).
    effects: Vec<Effect<TaskId, HqTimer>>,
    /// Tasks submitted but not yet finished: a `Limit` timer whose task
    /// has left this set is stale and is pruned instead of lingering
    /// for the full deadline budget — the heap tracks in-flight work,
    /// not lifetime throughput.
    live: HashSet<TaskId>,
    retry: RetryPolicy,
    /// Accepted failures per in-flight task (cleared on completion).
    attempts: HashMap<TaskId, u32>,
    next_tag: u64,
}

impl RtDriver {
    pub fn new(core: LiveCore) -> RtDriver {
        RtDriver {
            core,
            epoch: Instant::now(),
            timers: BinaryHeap::new(),
            timer_seq: 0,
            ready: VecDeque::new(),
            effects: Vec::new(),
            live: HashSet::new(),
            retry: RetryPolicy::default(),
            attempts: HashMap::new(),
            next_tag: 0,
        }
    }

    /// Replace the retry policy (builder-style; the balancer sets this
    /// from its CLI knobs).
    pub fn with_retry(mut self, retry: RetryPolicy) -> RtDriver {
        self.retry = retry;
        self
    }

    /// Shorthand: driver over the boxed core for `policy`.
    pub fn for_policy(policy: LivePolicy) -> RtDriver {
        RtDriver::new(live_core(policy))
    }

    /// Scheduler label ("fcfs" | "worksteal" | "edf").
    pub fn label(&self) -> &'static str {
        self.core.label()
    }

    /// Wall-clock micros since this driver started.
    pub fn now(&self) -> Micros {
        self.epoch.elapsed().as_micros().min(u64::MAX as u128) as Micros
    }

    /// Interpret buffered effects: timers enter the heap, starts enter
    /// the ready queue; terminal records are the forwarder's business
    /// (resolved from the real HTTP result), so `Finish`/`Retire` are
    /// informational here.
    fn absorb(&mut self) {
        for e in self.effects.drain(..) {
            match e {
                Effect::SetTimer(tt, tm) => {
                    self.timers.push(Reverse(TimerEntry(
                        tt,
                        self.timer_seq,
                        tm,
                    )));
                    self.timer_seq += 1;
                }
                Effect::Start { id, workers, .. } => {
                    // A forwarder executes on one server: the gang's
                    // lead member (first id) carries the lease.
                    self.ready.push_back((id, workers.primary()));
                }
                Effect::Finish { id, .. } => {
                    self.live.remove(&id);
                    self.attempts.remove(&id);
                }
                Effect::Requeued { id } => {
                    // The task left its worker (failure or worker loss):
                    // a ready entry not yet claimed by a forwarder is
                    // stale — the core re-dispatches it itself.
                    self.ready.retain(|&(r, _)| r != id);
                }
                // No dependency plane on the live path: Released is a
                // campaign-kernel notification and cannot occur here.
                Effect::Retire { .. }
                | Effect::Queued
                | Effect::Released { .. } => {}
            }
        }
    }

    /// Is a timer entry for a task that already finished?  Dispatch
    /// latency, kill-limit, and retry-backoff timers all die with their
    /// task.
    fn is_stale(live: &HashSet<TaskId>, tm: &HqTimer) -> bool {
        match tm {
            HqTimer::Limit(id)
            | HqTimer::Dispatched(id)
            | HqTimer::Retry(id) => !live.contains(id),
        }
    }

    /// Drop finished tasks' timers: stale heads are popped eagerly (so
    /// `next_timer_due` never keys a condvar deadline to a dead task),
    /// and when stale entries dominate the heap is rebuilt — memory
    /// stays O(in-flight), not O(throughput × deadline budget).
    fn prune_timers(&mut self) {
        while let Some(Reverse(TimerEntry(_, _, tm))) = self.timers.peek() {
            if Self::is_stale(&self.live, tm) {
                self.timers.pop();
            } else {
                break;
            }
        }
        if self.timers.len() > 64
            && self.timers.len() / 4 > self.live.len().max(1)
        {
            let live = std::mem::take(&mut self.live);
            let timers = std::mem::take(&mut self.timers);
            self.timers = timers
                .into_iter()
                .filter(|Reverse(TimerEntry(_, _, tm))| {
                    !Self::is_stale(&live, tm)
                })
                .collect();
            self.live = live;
        }
    }

    /// Fire every timer due by now (the live analogue of the DES pop
    /// loop), then prune timers of finished tasks.  Cheap when nothing
    /// is due: one heap peek each.
    pub fn advance(&mut self) {
        loop {
            let now = self.now();
            match self.timers.peek() {
                Some(Reverse(TimerEntry(due, _, _))) if *due <= now => {}
                _ => break,
            }
            let Reverse(TimerEntry(due, _, tm)) = self.timers.pop().unwrap();
            // Fire at the *scheduled* time, not the (possibly later)
            // observation time — the DES contract.  Cores that compare
            // the fire time against an armed deadline (EDF's
            // stale-limit guard) rely on it being exact.
            self.core.on_timer_into(due, tm, &mut self.effects);
            self.absorb();
        }
        self.prune_timers();
    }

    /// Batch-apply entry point: like [`submit`](Self::submit) but
    /// without the trailing timer pass, so a shard thread draining an
    /// event batch applies N events and pays one [`pump`](Self::pump),
    /// not N `advance` passes.
    pub fn submit_batched(&mut self, budget: Micros) -> TaskId {
        let t = self.now();
        let s = Submission {
            tag: self.next_tag,
            user: 0,
            app: App::Gp, // shape is irrelevant live; LiveSched ignores it
            duration: budget,
        };
        self.next_tag += 1;
        let (id, _) = self.core.submit_into(t, &s, &mut self.effects);
        self.live.insert(id);
        self.absorb();
        id
    }

    /// Submit one evaluation with a deadline budget (the client's
    /// request timeout).  Returns the core's task id.
    pub fn submit(&mut self, budget: Micros) -> TaskId {
        let id = self.submit_batched(budget);
        self.pump();
        id
    }

    /// Batch-apply variant of [`work_done`](Self::work_done): no timer
    /// pass (call [`pump`](Self::pump) once per batch).
    pub fn work_done_batched(&mut self, id: TaskId) {
        let t = self.now();
        self.core.on_work_done_into(t, id, &mut self.effects);
        self.absorb();
    }

    /// A forward finished (or was skipped): free the capacity.
    pub fn work_done(&mut self, id: TaskId) {
        self.work_done_batched(id);
        self.pump();
    }

    /// Batch-apply variant of [`work_failed`](Self::work_failed): same
    /// retry-budget accounting, no timer pass.
    pub fn work_failed_batched(&mut self, id: TaskId) -> Recovery {
        let t = self.now();
        let fails = {
            let n = self.attempts.entry(id).or_insert(0);
            *n += 1;
            *n
        };
        let verdict = if fails >= self.retry.max_attempts {
            self.attempts.remove(&id);
            self.core.on_work_failed_into(t, id, None, &mut self.effects);
            Recovery::Quarantined { attempts: fails }
        } else {
            let backoff = self.retry.backoff(fails);
            self.core.on_work_failed_into(
                t,
                id,
                Some(backoff),
                &mut self.effects,
            );
            Recovery::Retrying { attempt: fails + 1, backoff }
        };
        self.absorb();
        verdict
    }

    /// A forward failed with its lease (server died mid-evaluation).
    /// Charges one attempt against the retry budget: within budget the
    /// core requeues the task behind a backoff timer (it will re-enter
    /// `next_ready`, typically placed on a replacement server); past
    /// budget the core kills it and reports a truncated record, and the
    /// caller surfaces the error to the client.
    pub fn work_failed(&mut self, id: TaskId) -> Recovery {
        let verdict = self.work_failed_batched(id);
        self.pump();
        verdict
    }

    /// The retry policy in force (introspection; /Stats).
    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    /// Batch-apply variant of [`worker_up`](Self::worker_up).
    pub fn worker_up_batched(&mut self, ext: u64, cores: u32) {
        let t = self.now();
        self.core.on_capacity_change_into(
            t,
            CapacityChange::WorkerUp { id: ext, cores },
            &mut self.effects,
        );
        self.absorb();
    }

    /// A model server registered: announce one worker under `ext` id.
    pub fn worker_up(&mut self, ext: u64, cores: u32) {
        self.worker_up_batched(ext, cores);
        self.pump();
    }

    /// Batch-apply variant of [`worker_lost`](Self::worker_lost).
    pub fn worker_lost_batched(&mut self, ext: u64) {
        self.ready.retain(|&(_, w)| w != Some(ext));
        let t = self.now();
        self.core.on_capacity_change_into(
            t,
            CapacityChange::WorkerLost(ext),
            &mut self.effects,
        );
        self.absorb();
    }

    /// A server retired or died: ready entries bound to it are stale
    /// (the core requeues and re-places their tasks), then the core
    /// processes the loss.
    pub fn worker_lost(&mut self, ext: u64) {
        self.worker_lost_batched(ext);
        self.pump();
    }

    /// One timer pass over the whole batch: fire everything due, prune
    /// stale timers.  The shard thread calls this once after applying a
    /// drained event batch via the `*_batched` entry points — a burst of
    /// N submissions pays one pump, not N.
    pub fn pump(&mut self) {
        self.advance();
    }

    /// Next dispatched task for a forwarder to execute.
    pub fn next_ready(&mut self) -> Option<(TaskId, Option<u64>)> {
        self.ready.pop_front()
    }

    /// Put a ready entry back (its server was momentarily unavailable).
    pub fn requeue_ready(&mut self, entry: (TaskId, Option<u64>)) {
        self.ready.push_back(entry);
    }

    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    /// Absolute due time (driver clock) of the next core timer — the
    /// forwarder condvar's wait deadline.
    pub fn next_timer_due(&self) -> Option<Micros> {
        self.timers.peek().map(|Reverse(TimerEntry(due, _, _))| *due)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_and_labels() {
        assert_eq!(LivePolicy::parse("fcfs"), Some(LivePolicy::Fcfs));
        assert_eq!(LivePolicy::parse("hq"), Some(LivePolicy::Fcfs));
        assert_eq!(LivePolicy::parse("worksteal"),
                   Some(LivePolicy::WorkSteal));
        assert_eq!(LivePolicy::parse("edf"), Some(LivePolicy::Edf));
        assert_eq!(LivePolicy::parse("gang"), Some(LivePolicy::Gang));
        assert_eq!(LivePolicy::parse("nope"), None);
        assert_eq!(LivePolicy::default(), LivePolicy::Fcfs);
        for p in [LivePolicy::Fcfs, LivePolicy::WorkSteal, LivePolicy::Edf,
                  LivePolicy::Gang] {
            assert_eq!(LivePolicy::parse(p.label()), Some(p));
        }
    }

    #[test]
    fn submit_then_capacity_dispatches_in_order() {
        for policy in [LivePolicy::Fcfs, LivePolicy::WorkSteal,
                       LivePolicy::Edf, LivePolicy::Gang] {
            let mut d = RtDriver::for_policy(policy);
            let a = d.submit(60 * SEC);
            let b = d.submit(60 * SEC);
            assert_eq!(d.ready_len(), 0, "{}: no capacity yet",
                       d.label());
            d.worker_up(7, 1);
            // One single-core worker: exactly one task dispatches, bound
            // to the announced id.
            let (first, worker) = d.next_ready().expect("dispatch");
            assert_eq!(first, a, "{}: equal deadlines serve FCFS",
                       d.label());
            assert_eq!(worker, Some(7));
            assert!(d.next_ready().is_none());
            d.work_done(first);
            let (second, worker) = d.next_ready().expect("second dispatch");
            assert_eq!(second, b);
            assert_eq!(worker, Some(7));
        }
    }

    #[test]
    fn edf_orders_by_deadline_budget() {
        let mut d = RtDriver::for_policy(LivePolicy::Edf);
        let slow = d.submit(600 * SEC); // generous budget, late deadline
        let urgent = d.submit(5 * SEC); // tight budget, early deadline
        d.worker_up(1, 1);
        let (first, _) = d.next_ready().expect("dispatch");
        assert_eq!(first, urgent, "EDF serves the tighter deadline first");
        d.work_done(first);
        let (second, _) = d.next_ready().expect("dispatch");
        assert_eq!(second, slow);
    }

    #[test]
    fn worker_lost_purges_and_redispatches() {
        let mut d = RtDriver::for_policy(LivePolicy::Fcfs);
        d.worker_up(1, 1);
        d.worker_up(2, 1);
        let a = d.submit(60 * SEC);
        let b = d.submit(60 * SEC);
        assert_eq!(d.ready_len(), 2, "two workers, both dispatch");
        // Worker 1 dies before any forward starts: its entry is purged,
        // its task re-placed on worker 2 (busy) or left pending.
        d.worker_lost(1);
        let mut seen = Vec::new();
        while let Some((id, w)) = d.next_ready() {
            assert_ne!(w, Some(1), "stale binding to the lost worker");
            seen.push(id);
        }
        // Whichever task was bound to worker 2 is still dispatched;
        // completing it must re-dispatch the other.
        assert_eq!(seen.len(), 1);
        d.work_done(seen[0]);
        let (next, w) = d.next_ready().expect("requeued task re-placed");
        assert_eq!(w, Some(2));
        assert!(next == a || next == b);
    }

    #[test]
    fn failed_work_retries_then_quarantines() {
        for policy in [LivePolicy::Fcfs, LivePolicy::WorkSteal,
                       LivePolicy::Edf, LivePolicy::Gang] {
            let mut d = RtDriver::for_policy(policy).with_retry(
                RetryPolicy {
                    max_attempts: 2,
                    backoff_base: 1,
                    backoff_cap: 1,
                },
            );
            d.worker_up(1, 1);
            d.worker_up(2, 1);
            let id = d.submit(60 * SEC);
            let (got, _) = d.next_ready().expect("dispatch");
            assert_eq!(got, id);
            // The server dies mid-forward: one retry, ~1µs backoff.
            match d.work_failed(id) {
                Recovery::Retrying { attempt, .. } => {
                    assert_eq!(attempt, 2, "{}", d.label())
                }
                r => panic!("{}: expected retry, got {r:?}", d.label()),
            }
            // Wait out the backoff; the task re-enters the ready queue.
            let redispatched = loop {
                d.advance();
                if let Some(e) = d.next_ready() {
                    break e;
                }
                std::thread::yield_now();
            };
            assert_eq!(redispatched.0, id, "{}", d.label());
            // A second failure exhausts the budget.
            match d.work_failed(id) {
                Recovery::Quarantined { attempts } => {
                    assert_eq!(attempts, 2, "{}", d.label())
                }
                r => panic!("{}: expected quarantine, got {r:?}",
                            d.label()),
            }
            assert!(d.next_ready().is_none(),
                    "{}: quarantined task must not redispatch",
                    d.label());
        }
    }

    #[test]
    fn batched_apply_matches_eager_apply() {
        for policy in [LivePolicy::Fcfs, LivePolicy::WorkSteal,
                       LivePolicy::Edf, LivePolicy::Gang] {
            // Batched: N events, one pump.
            let mut batched = RtDriver::for_policy(policy);
            batched.worker_up_batched(1, 1);
            batched.worker_up_batched(2, 1);
            let b1 = batched.submit_batched(60 * SEC);
            let b2 = batched.submit_batched(60 * SEC);
            let b3 = batched.submit_batched(60 * SEC);
            batched.pump();
            // Eager: one pump per event (the legacy entry points).
            let mut eager = RtDriver::for_policy(policy);
            eager.worker_up(1, 1);
            eager.worker_up(2, 1);
            let e1 = eager.submit(60 * SEC);
            let e2 = eager.submit(60 * SEC);
            let e3 = eager.submit(60 * SEC);
            assert_eq!((b1, b2, b3), (e1, e2, e3), "{}", batched.label());
            // Two single-core workers: both dispatch the same task set
            // in the same order regardless of batching.
            let mut bd = Vec::new();
            while let Some(e) = batched.next_ready() {
                bd.push(e);
            }
            let mut ed = Vec::new();
            while let Some(e) = eager.next_ready() {
                ed.push(e);
            }
            assert_eq!(bd, ed, "{}: batch apply drifted", batched.label());
            assert_eq!(bd.len(), 2, "{}", batched.label());
            batched.work_done_batched(bd[0].0);
            batched.pump();
            eager.work_done(ed[0].0);
            assert_eq!(batched.next_ready(), eager.next_ready(),
                       "{}: post-completion drift", batched.label());
        }
    }

    #[test]
    fn deadline_timer_surfaces_for_condvar_waits() {
        let mut d = RtDriver::for_policy(LivePolicy::Fcfs);
        d.worker_up(1, 1);
        let _ = d.submit(60 * SEC);
        // The dispatched task armed its kill-limit timer: the condvar
        // deadline must be visible and in the future.
        let due = d.next_timer_due().expect("limit timer armed");
        assert!(due > d.now());
    }
}
