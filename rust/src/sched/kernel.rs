//! The generic campaign event kernel: one event heap, one timer wheel,
//! one drain loop for every [`SchedulerCore`].
//!
//! [`run`] replaces the two hand-duplicated PR 2 driver bodies
//! (`campaign::run_slurm` / `run_hq`, themselves descendants of the
//! PR 1 experiment loops): it owns the DES, the driver-side duration and
//! user maps, the depth trajectory, the per-user accumulators, and the
//! submitter callbacks — everything scheduler-agnostic — while each
//! [`SchedulerCore`] impl owns everything scheduler-specific.
//!
//! # Event flow
//!
//! One iteration: pop the next `(t, event)`; feed it to the core (an
//! allocation-lean `*_into` transition appending into one reusable
//! effect buffer); interpret the effects in order — set-timer re-enters
//! the heap, start schedules the work-done event after the driver-owned
//! duration, finish classifies/quantises the record and notifies the
//! submitter, whose sink drains back into the heap.  Stop when the
//! submitter reports the campaign finished.
//!
//! # Cost
//!
//! Per event: O(core transition) + O(log heap) + O(1) kernel
//! bookkeeping (two hash-map ops and a depth-trajectory update), so
//! campaigns inherit the indexed cores' million-task scaling (PERF.md).
//! The effect buffer and the per-core action scratch buffers are reused
//! across the whole run.
//!
//! # Equivalence
//!
//! For single-submission events (the paper's `FixedDepth` protocol) the
//! kernel's DES schedule order is *identical* to the PR 1/PR 2 loops —
//! `tests/campaign_equiv.rs` pins the records bit-for-bit against
//! `experiments::reference`.  The only divergence is tie-breaking when
//! one wake emits several submissions (bursty/adaptive policies): the
//! kernel routes each submission's follow-up work as it is submitted,
//! where the old `run_hq` batched the routing — both are valid schedules
//! of the same virtual-time events, and those policies are pinned by
//! seed-determinism tests instead.

use std::collections::HashMap;

use crate::campaign::driver::CampaignResult;
use crate::campaign::metrics::{jain_fairness, CampaignMetrics, DepthTrack,
                               UserTrack};
use crate::campaign::submitter::{Sink, Submission, Submitter};
use crate::clock::{Des, Micros};
use crate::metrics::Experiment;

use super::{Completion, Effect, SchedulerCore};

/// Kernel-level DES events: everything scheduler-agnostic.  Core timers
/// ride along as the core's own associated timer type.
#[derive(Debug)]
enum Ev<I, T> {
    /// A core timer elapsed.
    Timer(T),
    /// A submitter wake requested via `Sink::wake_at`.
    Wake(u64),
    /// A deferred submission (emitted from a completion callback).
    Submit(Submission),
    /// The sampled workload duration of `id` elapsed.
    WorkDone(I),
}

/// Drain a submitter sink into the DES at time `t`: submissions become
/// deferred `Submit` events, wakes schedule at their requested times.
fn drain_sink<I, T>(sink: &mut Sink, des: &mut Des<Ev<I, T>>, t: Micros) {
    for s in sink.submissions.drain(..) {
        des.schedule(t, Ev::Submit(s));
    }
    for (tw, tok) in sink.wakes.drain(..) {
        des.schedule(tw, Ev::Wake(tok));
    }
}

/// Run a campaign: any [`Submitter`] against any [`SchedulerCore`].
///
/// Returns once the submitter reports the campaign finished (or the
/// event queue drains, whichever comes first).
pub fn run<S: SchedulerCore>(
    core: &mut S,
    sub: &mut dyn Submitter,
) -> CampaignResult {
    let mut des: Des<Ev<S::Id, S::Timer>> = Des::new();
    let mut exp = Experiment::new(core.label());
    let grain = core.log_grain();

    // Driver-owned workload state: durations live from submission to
    // completion (work can restart after a lost worker), user labels
    // from submission to completion.  Both maps hold in-flight work only.
    let mut durations: HashMap<S::Id, Micros> = HashMap::new();
    let mut users: HashMap<S::Id, u32> = HashMap::new();
    let mut depth = DepthTrack::new();
    let mut per_user = UserTrack::new();
    let mut submitted: u64 = 0;
    let mut completed: u64 = 0;

    // One reusable effect buffer for the whole run (see PERF.md).
    let mut effects: Vec<Effect<S::Id, S::Timer>> = Vec::new();
    core.bootstrap_into(0, &mut effects);
    for e in effects.drain(..) {
        match e {
            Effect::SetTimer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
            Effect::Queued => depth.submit(0),
            _ => {}
        }
    }

    let mut sink = Sink::new();
    sub.start(&mut sink);
    drain_sink(&mut sink, &mut des, 0);

    let mut guard: u64 = 0;
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway campaign");
        effects.clear();
        match ev {
            Ev::Timer(tm) => core.on_timer_into(t, tm, &mut effects),
            Ev::Wake(token) => {
                sub.wake(t, token, &mut sink);
                for s in sink.submissions.drain(..) {
                    let (id, dur) = core.submit_into(t, &s, &mut effects);
                    durations.insert(id, dur);
                    users.insert(id, s.user);
                    depth.submit(t);
                    submitted += 1;
                }
                for (tw, tok) in sink.wakes.drain(..) {
                    des.schedule(tw, Ev::Wake(tok));
                }
            }
            Ev::Submit(s) => {
                let (id, dur) = core.submit_into(t, &s, &mut effects);
                durations.insert(id, dur);
                users.insert(id, s.user);
                depth.submit(t);
                submitted += 1;
            }
            Ev::WorkDone(id) => core.on_work_done_into(t, id, &mut effects),
        }
        for e in effects.drain(..) {
            match e {
                Effect::SetTimer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Effect::Start { id, contention, .. } => {
                    // Work the kernel never submitted (background jobs)
                    // finishes itself inside the core.
                    if let Some(&d) = durations.get(&id) {
                        let dd = (d as f64 * contention) as Micros;
                        des.schedule(t + dd, Ev::WorkDone(id));
                    }
                }
                Effect::Queued => depth.submit(t),
                Effect::Retire { .. } => {}
                Effect::Finish { id, record } => {
                    durations.remove(&id);
                    match core.classify(&record) {
                        Completion::Background => {}
                        Completion::Registration => {
                            depth.complete(t);
                            sub.registration_completed(t, &mut sink);
                            drain_sink(&mut sink, &mut des, t);
                        }
                        Completion::Evaluation => {
                            completed += 1;
                            let rec = record.quantised(grain);
                            let user = users.remove(&id).unwrap_or(0);
                            per_user.complete(user, &rec);
                            depth.complete(t);
                            exp.records.push(rec.clone());
                            sub.completed(t, &rec, &mut sink);
                            drain_sink(&mut sink, &mut des, t);
                        }
                    }
                }
            }
        }
        if sub.finished(completed) {
            break;
        }
    }
    exp.records.sort_by_key(|r| r.tag);

    let per_user_stats = per_user.stats();
    let fairness = jain_fairness(&per_user_stats);
    let peak = depth.peak();
    let metrics = CampaignMetrics {
        policy: sub.label(),
        scheduler: core.label().to_string(),
        submitted,
        completed,
        makespan: exp.makespan(),
        time_to: CampaignMetrics::milestones(&exp),
        depth_trajectory: depth.into_samples(),
        peak_in_flight: peak,
        per_user: per_user_stats,
        fairness_jain: fairness,
        des_events: des.processed(),
    };
    CampaignResult { experiment: exp, metrics }
}
