//! The generic campaign event kernel: one event heap, one timer wheel,
//! one drain loop for every [`SchedulerCore`].
//!
//! [`run`] replaces the two hand-duplicated PR 2 driver bodies
//! (`campaign::run_slurm` / `run_hq`, themselves descendants of the
//! PR 1 experiment loops): it owns the DES, the driver-side duration and
//! user maps, the depth trajectory, the per-user accumulators, and the
//! submitter callbacks — everything scheduler-agnostic — while each
//! [`SchedulerCore`] impl owns everything scheduler-specific.
//!
//! # Event flow
//!
//! One iteration: pop the next `(t, event)`; feed it to the core (an
//! allocation-lean `*_into` transition appending into one reusable
//! effect buffer); interpret the effects in order — set-timer re-enters
//! the heap, start schedules the work-done event after the driver-owned
//! duration, finish classifies/quantises the record and notifies the
//! submitter, whose sink drains back into the heap.  Stop when the
//! submitter reports the campaign finished.
//!
//! # Fault plane
//!
//! [`run_with_faults`] threads an optional seeded [`FaultPlan`] through
//! the same loop.  With a plan active, each [`Effect::Start`] opens an
//! *attempt*: the kernel consults the plan (pure keyed draws — see
//! `faults.rs`) to decide whether this attempt fails, where it fails,
//! and how much a straggler inflates it, then schedules an epoch-tagged
//! `WorkDoneAt`/`WorkFailed` event.  Epochs are bumped on every `Start`
//! *and* every [`Effect::Requeued`], so a completion or failure racing a
//! worker-loss requeue arrives with a stale epoch and is dropped — no
//! task ever double-completes.  Failures route through
//! [`SchedulerCore::on_work_failed_into`] with either a backoff (retry
//! budget remaining) or `None` (quarantine: the core kills the task and
//! reports a truncated record).  Worker crashes are scheduled from the
//! plan's interarrival stream and kill a deterministic ordinal of the
//! core's sorted live-worker set.  With `plan == None` the event
//! schedule is byte-identical to the pre-fault kernel
//! (`tests/campaign_equiv.rs` pins it).
//!
//! # Cost
//!
//! Per event: O(core transition) + O(log heap) + O(1) kernel
//! bookkeeping (two hash-map ops and a depth-trajectory update), so
//! campaigns inherit the indexed cores' million-task scaling (PERF.md).
//! The effect buffer and the per-core action scratch buffers are reused
//! across the whole run.  Timers whose task already finished are
//! dropped at pop via [`SchedulerCore::timer_is_stale`] instead of
//! re-entering the core as no-op transitions.
//!
//! # Equivalence
//!
//! For single-submission events (the paper's `FixedDepth` protocol) the
//! kernel's DES schedule order is *identical* to the PR 1/PR 2 loops —
//! `tests/campaign_equiv.rs` pins the records bit-for-bit against
//! `experiments::reference`.  The only divergence is tie-breaking when
//! one wake emits several submissions (bursty/adaptive policies): the
//! kernel routes each submission's follow-up work as it is submitted,
//! where the old `run_hq` batched the routing — both are valid schedules
//! of the same virtual-time events, and those policies are pinned by
//! seed-determinism tests instead.

use std::collections::HashMap;

use crate::campaign::driver::CampaignResult;
use crate::campaign::metrics::{jain_fairness, CampaignMetrics, DepthTrack,
                               UserTrack};
use crate::campaign::submitter::{Sink, Submission, Submitter};
use crate::clock::{Des, Micros};
use crate::metrics::{Experiment, JobRecord};

use super::dag::{Admit, DepTracker};
use super::faults::FaultPlan;
use super::{CapacityChange, Completion, Effect, SchedulerCore};

/// Kernel-level DES events: everything scheduler-agnostic.  Core timers
/// ride along as the core's own associated timer type.
#[derive(Debug)]
enum Ev<I, T> {
    /// A core timer elapsed.
    Timer(T),
    /// A submitter wake requested via `Sink::wake_at`.
    Wake(u64),
    /// A deferred submission (emitted from a completion callback).
    Submit(Submission),
    /// A dependency-carrying submission (`Sink::submit_after`): consult
    /// the [`DepTracker`] — submit now, park as Blocked, or skip.
    SubmitBlocked(Submission, Vec<u64>),
    /// A parked task whose parents all finished ok: leaves Blocked into
    /// Ready — the kernel emits [`Effect::Released`] and submits it to
    /// the core at this instant.
    Release(Submission),
    /// A task whose ancestry failed (quarantine / truncation): emit a
    /// truncated zero-CPU record at this instant, never touching the
    /// core, and cascade to its own waiting descendants.
    Skipped(Submission),
    /// The sampled workload duration of `id` elapsed (clean plane).
    WorkDone(I),
    /// Epoch-tagged completion (fault plane): delivered only if the
    /// task's attempt epoch still matches.
    WorkDoneAt(I, u64),
    /// Epoch-tagged injected transient failure (fault plane).
    WorkFailed(I, u64),
    /// The `k`-th planned worker crash.
    Crash(u64),
}

/// Drain a submitter sink into the DES at time `t`: submissions become
/// deferred `Submit` events, wakes schedule at their requested times.
fn drain_sink<I, T>(sink: &mut Sink, des: &mut Des<Ev<I, T>>, t: Micros) {
    for s in sink.submissions.drain(..) {
        des.schedule(t, Ev::Submit(s));
    }
    for (s, parents) in sink.gated.drain(..) {
        des.schedule(t, Ev::SubmitBlocked(s, parents));
    }
    for (tw, tok) in sink.wakes.drain(..) {
        des.schedule(tw, Ev::Wake(tok));
    }
}

/// Per-task fault-plane bookkeeping (allocated only when a plan is
/// active, keyed by core id, dropped at `Finish`).
#[derive(Default)]
struct FaultBook<I> {
    /// id -> submission tag (the plan's draw key).
    tags: HashMap<I, u64>,
    /// id -> attempt epoch: bumped on every Start and Requeued; events
    /// carrying an older epoch are stale and dropped.
    epochs: HashMap<I, u64>,
    /// id -> number of Starts (the plan's 1-based attempt counter).
    execs: HashMap<I, u32>,
    /// id -> accepted transient failures (drives backoff + quarantine).
    fails: HashMap<I, u32>,
}

impl<I: Copy + Eq + std::hash::Hash> FaultBook<I> {
    fn track(&mut self, id: I, tag: u64) {
        self.tags.insert(id, tag);
        self.epochs.insert(id, 0);
    }

    fn forget(&mut self, id: &I) {
        self.tags.remove(id);
        self.epochs.remove(id);
        self.execs.remove(id);
        self.fails.remove(id);
    }

    fn bump_epoch(&mut self, id: I) -> u64 {
        let e = self.epochs.entry(id).or_insert(0);
        *e += 1;
        *e
    }

    fn epoch_is(&self, id: &I, ep: u64) -> bool {
        self.epochs.get(id) == Some(&ep)
    }
}

/// Run a campaign on a perfect cluster: any [`Submitter`] against any
/// [`SchedulerCore`], no injected faults.
pub fn run<S: SchedulerCore>(
    core: &mut S,
    sub: &mut dyn Submitter,
) -> CampaignResult {
    run_with_faults(core, sub, None)
}

/// Run a campaign, optionally under a seeded [`FaultPlan`] (worker
/// crashes, transient task failures, stragglers — see module docs).
///
/// Returns once the submitter reports the campaign finished (or the
/// event queue drains, whichever comes first).
pub fn run_with_faults<S: SchedulerCore>(
    core: &mut S,
    sub: &mut dyn Submitter,
    plan: Option<&FaultPlan>,
) -> CampaignResult {
    let mut des: Des<Ev<S::Id, S::Timer>> = Des::new();
    let mut exp = Experiment::new(core.label());
    let grain = core.log_grain();

    // Driver-owned workload state: durations live from submission to
    // completion (work can restart after a lost worker), user labels
    // from submission to completion.  Both maps hold in-flight work only.
    let mut durations: HashMap<S::Id, Micros> = HashMap::new();
    let mut users: HashMap<S::Id, u32> = HashMap::new();
    let mut depth = DepthTrack::new();
    let mut per_user = UserTrack::new();
    let mut submitted: u64 = 0;
    let mut completed: u64 = 0;

    // Dependency layer: task→parents edges, Blocked state, release on
    // terminal (see `dag.rs`).  Sits above the core — with no
    // `submit_after` calls the only cost is one terminal-set insert per
    // completion, and the event schedule is unchanged.
    let mut dep = DepTracker::new();
    let mut blocked = DepthTrack::new();
    let mut skipped: u64 = 0;

    // Fault-plane state (unused allocations when plan is None).
    let mut book: FaultBook<S::Id> = FaultBook::default();
    let mut retries: u64 = 0;
    let mut quarantined: u64 = 0;
    let mut worker_crashes: u64 = 0;
    let mut victim_scratch: Vec<u64> = Vec::new();

    // One reusable effect buffer for the whole run (see PERF.md).
    let mut effects: Vec<Effect<S::Id, S::Timer>> = Vec::new();
    core.bootstrap_into(0, &mut effects);
    for e in effects.drain(..) {
        match e {
            Effect::SetTimer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
            Effect::Queued => depth.submit(0),
            _ => {}
        }
    }
    if let Some(p) = plan {
        if p.injects_crashes() {
            des.schedule(p.crash_gap(0), Ev::Crash(0));
        }
    }

    let mut sink = Sink::new();
    sub.start(&mut sink);
    drain_sink(&mut sink, &mut des, 0);

    let mut guard: u64 = 0;
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway campaign");
        effects.clear();
        match ev {
            Ev::Timer(tm) => {
                // Dead-timer hygiene: a parked dispatch/limit/retry timer
                // whose task already finished never re-enters the core.
                if !core.timer_is_stale(&tm) {
                    core.on_timer_into(t, tm, &mut effects);
                }
            }
            Ev::Wake(token) => {
                sub.wake(t, token, &mut sink);
                for s in sink.submissions.drain(..) {
                    let (id, dur) = core.submit_into(t, &s, &mut effects);
                    durations.insert(id, dur);
                    users.insert(id, s.user);
                    if plan.is_some() {
                        book.track(id, s.tag);
                    }
                    depth.submit(t);
                    submitted += 1;
                }
                for (s, parents) in sink.gated.drain(..) {
                    des.schedule(t, Ev::SubmitBlocked(s, parents));
                }
                for (tw, tok) in sink.wakes.drain(..) {
                    des.schedule(tw, Ev::Wake(tok));
                }
            }
            Ev::Submit(s) => {
                let (id, dur) = core.submit_into(t, &s, &mut effects);
                durations.insert(id, dur);
                users.insert(id, s.user);
                if plan.is_some() {
                    book.track(id, s.tag);
                }
                depth.submit(t);
                submitted += 1;
            }
            Ev::SubmitBlocked(s, parents) => {
                // Counted as submitted the moment the campaign hands it
                // over, whatever the dependency layer decides — the
                // "records emitted == tasks submitted" invariant is over
                // this counter.
                submitted += 1;
                match dep.submit(s, &parents) {
                    Admit::Ready(s) => {
                        let (id, dur) = core.submit_into(t, &s, &mut effects);
                        durations.insert(id, dur);
                        users.insert(id, s.user);
                        if plan.is_some() {
                            book.track(id, s.tag);
                        }
                        depth.submit(t);
                    }
                    Admit::Blocked => blocked.submit(t),
                    Admit::Skip(s) => des.schedule(t, Ev::Skipped(s)),
                }
            }
            Ev::Release(s) => {
                // The Released effect rides the same buffer as the
                // core's own effects for this submission, so the release
                // is visible on the seam's effect stream.
                effects.push(Effect::Released { tag: s.tag });
                let (id, dur) = core.submit_into(t, &s, &mut effects);
                durations.insert(id, dur);
                users.insert(id, s.user);
                if plan.is_some() {
                    book.track(id, s.tag);
                }
                depth.submit(t);
            }
            Ev::Skipped(s) => {
                skipped += 1;
                completed += 1;
                let rec = JobRecord {
                    tag: s.tag,
                    submit: t,
                    start: t,
                    end: t,
                    cpu: 0,
                    truncated: true,
                }
                .quantised(grain);
                per_user.complete(s.user, &rec);
                exp.records.push(rec.clone());
                // A skip is terminal-failed: cascade to descendants in
                // virtual-time order.
                let (rel, skp) = dep.on_terminal(s.tag, false);
                for c in rel {
                    blocked.complete(t);
                    des.schedule(t, Ev::Release(c));
                }
                for c in skp {
                    blocked.complete(t);
                    des.schedule(t, Ev::Skipped(c));
                }
                sub.completed(t, &rec, &mut sink);
                drain_sink(&mut sink, &mut des, t);
            }
            Ev::WorkDone(id) => core.on_work_done_into(t, id, &mut effects),
            Ev::WorkDoneAt(id, ep) => {
                if book.epoch_is(&id, ep) {
                    core.on_work_done_into(t, id, &mut effects);
                }
            }
            Ev::WorkFailed(id, ep) => {
                if book.epoch_is(&id, ep) && durations.contains_key(&id) {
                    let plan = plan.expect("WorkFailed without a plan");
                    // Invalidate anything else in flight for this attempt.
                    book.bump_epoch(id);
                    let f = {
                        let f = book.fails.entry(id).or_insert(0);
                        *f += 1;
                        *f
                    };
                    if f >= plan.max_attempts() {
                        quarantined += 1;
                        core.on_work_failed_into(t, id, None, &mut effects);
                    } else {
                        let backoff = plan.backoff(f);
                        core.on_work_failed_into(
                            t, id, Some(backoff), &mut effects,
                        );
                    }
                }
            }
            Ev::Crash(k) => {
                let plan = plan.expect("Crash without a plan");
                victim_scratch.clear();
                core.live_worker_ids(&mut victim_scratch);
                victim_scratch.sort_unstable();
                victim_scratch.dedup();
                if !victim_scratch.is_empty() {
                    let v = victim_scratch
                        [plan.crash_victim(k, victim_scratch.len())];
                    worker_crashes += 1;
                    core.on_capacity_change_into(
                        t,
                        CapacityChange::WorkerLost(v),
                        &mut effects,
                    );
                }
                des.schedule(t + plan.crash_gap(k + 1), Ev::Crash(k + 1));
            }
        }
        for e in effects.drain(..) {
            match e {
                Effect::SetTimer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Effect::Start { id, contention, workers } => {
                    // Placement policy of the virtual plane, stated once:
                    // the kernel *validates* the worker set but does not
                    // act on it — in virtual time every worker advances
                    // at the same simulated rate, so where the work runs
                    // cannot change when it finishes (the real-time
                    // driver, by contrast, leases the set's lead
                    // member).  The check keeps gang placement honest on
                    // this plane: a core can never claim workers it does
                    // not have, so placement is carried — not silently
                    // dropped — end to end.
                    if cfg!(debug_assertions) && !workers.is_empty() {
                        victim_scratch.clear();
                        core.live_worker_ids(&mut victim_scratch);
                        debug_assert!(
                            workers
                                .ids()
                                .iter()
                                .all(|w| victim_scratch.contains(w)),
                            "core placed {id:?} on unknown workers \
                             {workers:?} (live: {victim_scratch:?})",
                        );
                    }
                    // Work the kernel never submitted (background jobs)
                    // finishes itself inside the core.
                    match plan {
                        None => {
                            if let Some(&d) = durations.get(&id) {
                                let dd = (d as f64 * contention) as Micros;
                                des.schedule(t + dd, Ev::WorkDone(id));
                            }
                        }
                        Some(p) => {
                            let dt = (durations.get(&id).copied())
                                .zip(book.tags.get(&id).copied());
                            if let Some((d, tag)) = dt {
                                let ep = book.bump_epoch(id);
                                let exec = {
                                    let x = book.execs.entry(id).or_insert(0);
                                    *x += 1;
                                    *x
                                };
                                let dd = (d as f64
                                    * contention
                                    * p.slowdown(tag, exec))
                                    as Micros;
                                // Fate is keyed on *accepted* failures, not
                                // raw starts: a crash-interrupted attempt
                                // (epoch invalidated, no failure accepted)
                                // does not consume a planned failure, so
                                // every core sees the same per-tag failure
                                // count whatever its crash interactions.
                                let f =
                                    book.fails.get(&id).copied().unwrap_or(0);
                                if p.attempt_fails(tag, f + 1) {
                                    let fp = p.fail_point(tag, exec, dd);
                                    des.schedule(
                                        t + fp,
                                        Ev::WorkFailed(id, ep),
                                    );
                                } else {
                                    des.schedule(
                                        t + dd,
                                        Ev::WorkDoneAt(id, ep),
                                    );
                                }
                            }
                        }
                    }
                }
                Effect::Queued => depth.submit(t),
                // Emitted by this kernel itself at Release time (just
                // before the core's submit effects); informational on
                // the interpretation side — `dep` already did the
                // bookkeeping.
                Effect::Released { .. } => {}
                Effect::Retire { .. } => {}
                Effect::Requeued { id } => {
                    // The task left its worker without finishing; any
                    // in-flight done/failed event is now stale.
                    retries += 1;
                    if plan.is_some() {
                        book.bump_epoch(id);
                    }
                }
                Effect::Finish { id, record } => {
                    durations.remove(&id);
                    book.forget(&id);
                    match core.classify(&record) {
                        Completion::Background => {}
                        Completion::Registration => {
                            depth.complete(t);
                            sub.registration_completed(t, &mut sink);
                            drain_sink(&mut sink, &mut des, t);
                        }
                        Completion::Evaluation => {
                            completed += 1;
                            let rec = record.quantised(grain);
                            let user = users.remove(&id).unwrap_or(0);
                            per_user.complete(user, &rec);
                            depth.complete(t);
                            exp.records.push(rec.clone());
                            // Dependency layer: this tag is terminal.  A
                            // truncated record (kill limit or fault-plane
                            // quarantine) poisons its descendants —
                            // they skip instead of running.
                            let ok = !rec.truncated;
                            let (rel, skp) = dep.on_terminal(rec.tag, ok);
                            for c in rel {
                                blocked.complete(t);
                                des.schedule(t, Ev::Release(c));
                            }
                            for c in skp {
                                blocked.complete(t);
                                des.schedule(t, Ev::Skipped(c));
                            }
                            sub.completed(t, &rec, &mut sink);
                            drain_sink(&mut sink, &mut des, t);
                        }
                    }
                }
            }
        }
        if sub.finished(completed) {
            break;
        }
    }
    exp.records.sort_by_key(|r| r.tag);

    let per_user_stats = per_user.stats();
    let per_user_time_to = per_user.time_to();
    let fairness = jain_fairness(&per_user_stats);
    let peak = depth.peak();
    let peak_blocked = blocked.peak();
    let metrics = CampaignMetrics {
        policy: sub.label(),
        scheduler: core.label().to_string(),
        submitted,
        completed,
        makespan: exp.makespan(),
        time_to: CampaignMetrics::milestones(&exp),
        depth_trajectory: depth.into_samples(),
        peak_in_flight: peak,
        per_user: per_user_stats,
        per_user_time_to,
        fairness_jain: fairness,
        des_events: des.processed(),
        retries,
        quarantined,
        worker_crashes,
        blocked_trajectory: blocked.into_samples(),
        peak_blocked,
        released: dep.released(),
        skipped,
        dep_edges: dep.edges(),
    };
    CampaignResult { experiment: exp, metrics }
}
