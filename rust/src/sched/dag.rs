//! The dependency layer: task→parents edges, the `Blocked` state, and
//! release-on-terminal bookkeeping — entirely **above** the
//! [`SchedulerCore`](super::SchedulerCore) seam.
//!
//! UQ campaigns are increasingly chained: MLDA/MLMC chains gate a fine
//! model evaluation on a coarse or surrogate one, and facility
//! workflows (Balsam) gate compute on stage-in transfers and reductions
//! on their fan-in.  [`DepTracker`] gives every scheduler core that DAG
//! vocabulary for free: the kernel consults it on each
//! `Ev::SubmitBlocked { parents }` event and on each terminal record,
//! and the core itself keeps seeing plain submissions — at *release*
//! time, once every parent is terminal.  No per-core code changes; all
//! five of slurm / hq / worksteal / edf / gang run DAG campaigns
//! unmodified.
//!
//! # State machine
//!
//! ```text
//!   submit_after(s, parents)
//!        │
//!        ▼            every parent terminal-ok
//!   ┌─────────┐   ┌──────────────────────────────► Ready ──► core.submit
//!   │ Blocked │───┤
//!   └─────────┘   └──────────────────────────────► Skipped ──► truncated
//!        ▲            any parent failed/quarantined           record
//!        │            (all parents terminal)
//!   parents pending
//! ```
//!
//! * A task with zero pending parents is admitted immediately
//!   ([`Admit::Ready`]), or skipped immediately when a parent already
//!   finished poisoned ([`Admit::Skip`]) — the late-edge path.
//! * A blocked task waits until **all** parents are terminal, then
//!   releases (every parent ok) or skips (any parent failed).  Skips
//!   cascade transitively through the kernel — a quarantined ancestor
//!   truncates its whole subtree, so no campaign ever deadlocks and
//!   "records emitted == tasks submitted" holds even under `--faults`.
//! * A parent is *failed* for dependency purposes iff its record is
//!   truncated (fault-plane quarantine or a kill-limit truncation) —
//!   the child was promised a result that never materialised.
//!
//! # Cost
//!
//! O(1) amortised per edge: `submit` does one hash probe per parent and
//! `on_terminal` pays one probe per waiting child of the finished task.
//! The terminal set grows O(completed tasks) — same order as the record
//! vector the kernel already keeps.  Unknown parent tags (never
//! submitted) stay pending forever by design; submitters own tag
//! hygiene, and the differential fuzz harness (`tests/core_fuzz.rs`,
//! DAG scripts) checks every generated script drains on every core.

use std::collections::HashMap;

use crate::campaign::submitter::Submission;

/// Immediate verdict for a dependency-carrying submission.
#[derive(Debug)]
pub enum Admit {
    /// Every parent already terminal and ok: submit to the core now.
    Ready(Submission),
    /// At least one parent pending: parked; the tracker will hand the
    /// submission back from [`DepTracker::on_terminal`].
    Blocked,
    /// A parent already finished poisoned: emit a truncated record now
    /// (the task never reaches the core).
    Skip(Submission),
}

/// A parked submission waiting on its remaining parents.
#[derive(Debug)]
struct Parked {
    sub: Submission,
    /// Parents not yet terminal.
    pending: u32,
    /// A terminal parent failed: when the last parent lands this task
    /// skips instead of releasing.
    doomed: bool,
}

/// Owns the task→parents edges and the Blocked→Ready/Skipped
/// bookkeeping for one campaign run.  Tags live in the campaign's tag
/// space (`Submission::tag` / `JobRecord::tag`).
#[derive(Debug, Default)]
pub struct DepTracker {
    /// tag -> finished ok (false = truncated/quarantined/skipped).
    terminal: HashMap<u64, bool>,
    /// parent tag -> tags of parked children waiting on it.
    waiting: HashMap<u64, Vec<u64>>,
    /// child tag -> parked state.
    parked: HashMap<u64, Parked>,
    /// Tasks currently parked (the blocked depth).
    blocked_now: u32,
    /// Cumulative releases (tasks that left Blocked into Ready).
    released: u64,
    /// Total edges registered (complexity accounting).
    edges: u64,
}

impl DepTracker {
    pub fn new() -> DepTracker {
        DepTracker::default()
    }

    /// Admit a submission with dependency edges.  `parents` may be
    /// empty (the zero-edge path — always [`Admit::Ready`], pinned
    /// byte-identical to a plain submit by `tests/campaign_equiv.rs`).
    pub fn submit(&mut self, sub: Submission, parents: &[u64]) -> Admit {
        self.edges += parents.len() as u64;
        let mut pending = 0u32;
        let mut doomed = false;
        for &p in parents {
            match self.terminal.get(&p) {
                Some(&ok) => doomed |= !ok,
                None => {
                    pending += 1;
                    self.waiting.entry(p).or_default().push(sub.tag);
                }
            }
        }
        if pending == 0 {
            return if doomed { Admit::Skip(sub) } else { Admit::Ready(sub) };
        }
        self.blocked_now += 1;
        self.parked.insert(sub.tag, Parked { sub, pending, doomed });
        Admit::Blocked
    }

    /// A task reached a terminal record (`ok = !record.truncated`).
    /// Returns the *directly* waiting children that just became
    /// unblocked, partitioned into releases and skips.  Skip cascades
    /// are the caller's business: each skip is itself terminal
    /// (`ok = false`) and must be fed back through `on_terminal` — the
    /// kernel does so from its `Skipped` event so cascades stay in
    /// virtual-time order.
    pub fn on_terminal(
        &mut self,
        tag: u64,
        ok: bool,
    ) -> (Vec<Submission>, Vec<Submission>) {
        self.terminal.insert(tag, ok);
        let mut releases = Vec::new();
        let mut skips = Vec::new();
        if let Some(children) = self.waiting.remove(&tag) {
            for c in children {
                let done = {
                    let p = self
                        .parked
                        .get_mut(&c)
                        .expect("waiting child without parked state");
                    p.pending -= 1;
                    p.doomed |= !ok;
                    p.pending == 0
                };
                if done {
                    let p = self.parked.remove(&c).unwrap();
                    self.blocked_now -= 1;
                    if p.doomed {
                        skips.push(p.sub);
                    } else {
                        self.released += 1;
                        releases.push(p.sub);
                    }
                }
            }
        }
        (releases, skips)
    }

    /// Tasks currently in the Blocked state.
    pub fn blocked_now(&self) -> u32 {
        self.blocked_now
    }

    /// Tasks that left Blocked into Ready so far.
    pub fn released(&self) -> u64 {
        self.released
    }

    /// Dependency edges registered so far.
    pub fn edges(&self) -> u64 {
        self.edges
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::App;

    fn sub(tag: u64) -> Submission {
        Submission { tag, user: 0, app: App::Gp, duration: 1 }
    }

    #[test]
    fn zero_edge_is_ready_immediately() {
        let mut d = DepTracker::new();
        assert!(matches!(d.submit(sub(1), &[]), Admit::Ready(s) if s.tag == 1));
        assert_eq!(d.blocked_now(), 0);
        assert_eq!(d.edges(), 0);
    }

    #[test]
    fn releases_on_last_parent_only() {
        let mut d = DepTracker::new();
        assert!(matches!(d.submit(sub(10), &[1, 2]), Admit::Blocked));
        assert_eq!(d.blocked_now(), 1);
        let (r, s) = d.on_terminal(1, true);
        assert!(r.is_empty() && s.is_empty(), "one parent still pending");
        let (r, s) = d.on_terminal(2, true);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].tag, 10);
        assert!(s.is_empty());
        assert_eq!(d.blocked_now(), 0);
        assert_eq!(d.released(), 1);
        assert_eq!(d.edges(), 2);
    }

    #[test]
    fn diamond_releases_join_after_both_arms() {
        // 1 -> {2, 3} -> 4 (both arms gate the join).
        let mut d = DepTracker::new();
        assert!(matches!(d.submit(sub(2), &[1]), Admit::Blocked));
        assert!(matches!(d.submit(sub(3), &[1]), Admit::Blocked));
        assert!(matches!(d.submit(sub(4), &[2, 3]), Admit::Blocked));
        let (r, _) = d.on_terminal(1, true);
        assert_eq!(r.len(), 2, "both arms release together");
        let (r, _) = d.on_terminal(2, true);
        assert!(r.is_empty());
        let (r, _) = d.on_terminal(3, true);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].tag, 4);
    }

    #[test]
    fn failed_parent_skips_descendants() {
        let mut d = DepTracker::new();
        assert!(matches!(d.submit(sub(5), &[1]), Admit::Blocked));
        assert!(matches!(d.submit(sub(6), &[5]), Admit::Blocked));
        let (r, s) = d.on_terminal(1, false);
        assert!(r.is_empty());
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].tag, 5);
        // The cascade: the caller reports the skip as terminal-failed.
        let (r, s) = d.on_terminal(5, false);
        assert!(r.is_empty());
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].tag, 6);
        assert_eq!(d.blocked_now(), 0);
        assert_eq!(d.released(), 0);
    }

    #[test]
    fn mixed_parents_one_failure_dooms_the_join() {
        let mut d = DepTracker::new();
        assert!(matches!(d.submit(sub(9), &[1, 2]), Admit::Blocked));
        d.on_terminal(1, true);
        let (r, s) = d.on_terminal(2, false);
        assert!(r.is_empty());
        assert_eq!(s.len(), 1, "any failed parent dooms the child");
    }

    #[test]
    fn late_edges_resolve_against_the_terminal_set() {
        let mut d = DepTracker::new();
        d.on_terminal(1, true);
        d.on_terminal(2, false);
        assert!(matches!(d.submit(sub(7), &[1]), Admit::Ready(_)));
        assert!(matches!(d.submit(sub(8), &[2]), Admit::Skip(_)));
        assert!(matches!(d.submit(sub(9), &[1, 2]), Admit::Skip(_)));
        assert_eq!(d.blocked_now(), 0);
    }

    #[test]
    fn deep_chain_releases_in_order() {
        let mut d = DepTracker::new();
        for i in 1..100u64 {
            assert!(matches!(d.submit(sub(i + 1), &[i]), Admit::Blocked));
        }
        assert_eq!(d.blocked_now(), 99);
        let mut tag = 1;
        for _ in 0..99 {
            let (r, s) = d.on_terminal(tag, true);
            assert!(s.is_empty());
            assert_eq!(r.len(), 1);
            assert_eq!(r[0].tag, tag + 1);
            tag = r[0].tag;
        }
        assert_eq!(d.blocked_now(), 0);
        assert_eq!(d.released(), 99);
    }
}
