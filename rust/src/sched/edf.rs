//! [`EdfCore`]: a deadline-EDF task scheduler — the fourth pluggable
//! core, and the first to run in **both** planes (campaign and live).
//!
//! Every task gets an absolute deadline at submission, `submit_t +
//! time_limit` (the kill limit is the natural hard deadline: past it the
//! result is discarded anyway).  The ready structure is one deadline
//! min-heap; dispatch always pops the earliest deadline, breaking ties
//! by **static laxity** (`time_limit - time_request`: the task with the
//! least slack between its expected runtime and its kill limit goes
//! first) and finally by task id, so a campaign remains a pure function
//! of its seed.
//!
//! EDF here is *strict*: if the earliest-deadline task cannot start on
//! any live worker (no free cores, or no allocation outliving its time
//! request), dispatch stops rather than backfilling a later-deadline
//! task around it — the discipline the classic uniprocessor optimality
//! result is about, and the property `tests/scheduler_props.rs` pins.
//! Starvation-freedom falls out of absolute deadlines: a waiting task's
//! deadline is fixed while every newcomer's is `now + limit`, so
//! sustained short-deadline load overtakes it only for a bounded window.
//!
//! The task/worker lifecycle lives in the shared
//! [`TaskTable`](crate::sched::table::TaskTable), built
//! [`with_exact_limit`](crate::sched::table::TaskTable::with_exact_limit):
//! only the `Limit` timer armed for the *current* run kills — a stale
//! limit from a pre-requeue run must not truncate the rerun, just as
//! requeued tasks keep their original deadline.  This file keeps only
//! the deadline heap and the strict-EDF pump.  The stack and the live
//! balancer treat all [`TaskCore`] implementations interchangeably: the
//! same [`AutoAllocConfig`] automatic allocation, the same expiry
//! min-heap, the same dispatch-latency and time-limit timers, the same
//! action vocabulary ([`HqAction`]/[`HqTimer`]).  In the campaign plane
//! it rides `MetaStack<EdfCore>` (`uqsched campaign --scheduler edf`);
//! in the live plane it rides [`LiveSched`](crate::sched::LiveSched)
//! (`uqsched balancer --scheduler edf`), where each model's front-door
//! queue is its own `EdfCore` — the per-model deadline heap.
//!
//! Cost (w = live workers, p = ready tasks): submission is O(log p) +
//! one pump; a pump pass pops each startable task at O(log p + w); a
//! blocked head costs O(w) once per pump.  See PERF.md.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::clock::Micros;
use crate::hqlite::{AutoAllocConfig, HqAction, HqTimer, TaskCore, TaskId,
                    TaskSpec, WorkerId};
use crate::sched::table::{FailVerdict, TableTask, TaskTable, TimerVerdict};

/// Heap key: earliest deadline first, then least static laxity, then
/// lowest task id (total order ⇒ deterministic pops).
type EdfKey = (Micros, Micros, TaskId);

/// The deadline-EDF task scheduler.
pub struct EdfCore {
    /// Shared task/worker lifecycle engine (exact limit guard).
    table: TaskTable,
    /// Deadline min-heap over Pending tasks.  May lazily contain ids of
    /// tasks that completed while requeued; dropped when popped.
    ready: BinaryHeap<Reverse<EdfKey>>,
}

impl EdfCore {
    pub fn new(cfg: AutoAllocConfig) -> Self {
        EdfCore {
            table: TaskTable::new(cfg).with_exact_limit(),
            ready: BinaryHeap::new(),
        }
    }

    /// Stats: dispatches performed.
    pub fn dispatches(&self) -> u64 {
        self.table.dispatches()
    }

    /// A task's heap key: (deadline, static laxity, id).  The deadline
    /// is fixed at submission — a requeue keeps it, which is what makes
    /// EDF starvation-free.
    fn key_of(task: &TableTask, id: TaskId) -> EdfKey {
        let laxity = task.spec.time_limit
            .saturating_sub(task.spec.time_request);
        (task.deadline, laxity, id)
    }

    /// Re-enter a (live, Pending) task into the ready heap with its
    /// original deadline.
    fn push_ready(&mut self, id: TaskId) {
        if let Some(task) = self.table.task(id) {
            let key = Self::key_of(task, id);
            self.ready.push(Reverse(key));
        }
    }

    /// Dispatch strictly earliest-deadline-first: pop the heap while the
    /// head can start on some worker (lowest-id host wins); a blocked
    /// head stops dispatch — no backfilling around it.  Then autoalloc
    /// tops up capacity for whatever is still pending.
    fn pump(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        while let Some(&Reverse((_, _, id))) = self.ready.peek() {
            if !self.table.is_pending(id) {
                // Stale entry (completed while requeued, or re-pushed by
                // a worker loss after an earlier pop): drop lazily.
                self.ready.pop();
                continue;
            }
            let host = self
                .table
                .worker_ids()
                .find(|&wid| self.table.can_start(t, id, wid));
            let Some(wid) = host else { break };
            self.ready.pop();
            self.table.reserve(t, id, &[wid], out);
        }
        self.table.autoalloc_into(out);
    }
}

impl TaskCore for EdfCore {
    fn submit_task_into(
        &mut self,
        t: Micros,
        spec: TaskSpec,
        out: &mut Vec<HqAction>,
    ) -> TaskId {
        let id = self.table.admit(t, spec);
        self.push_ready(id);
        self.pump(t, out);
        id
    }

    fn on_alloc_up_into(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
        out: &mut Vec<HqAction>,
    ) -> Option<WorkerId> {
        let first = self
            .table
            .admit_workers(t, time_limit, cores_per_worker)
            .first()
            .copied();
        self.pump(t, out);
        first
    }

    fn on_worker_lost_into(
        &mut self,
        t: Micros,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    ) {
        // No task lost: the in-flight set requeues with its original
        // deadlines (ascending task-id order, deterministic).
        for id in self.table.worker_lost(wid, out) {
            self.push_ready(id);
        }
        self.pump(t, out);
    }

    fn on_task_done_into(&mut self, t: Micros, id: TaskId,
                         out: &mut Vec<HqAction>) {
        // A stale duplicate completion (the driver's original done-timer
        // firing after a requeue) misses the table: no pump.
        if self.table.complete(t, id, false, out) {
            self.pump(t, out);
        }
    }

    fn on_task_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) {
        match self.table.fail(t, id, retry_in, out) {
            FailVerdict::Ignored => {}
            FailVerdict::Killed | FailVerdict::Cooling => self.pump(t, out),
        }
    }

    fn task_live(&self, id: TaskId) -> bool {
        self.table.task_live(id)
    }

    fn live_worker_ids_into(&self, out: &mut Vec<u64>) {
        self.table.live_worker_ids_into(out);
    }

    fn on_timer_into(&mut self, t: Micros, timer: HqTimer,
                     out: &mut Vec<HqAction>) {
        match self.table.timer(t, timer, out) {
            TimerVerdict::Ignored | TimerVerdict::Started => {}
            TimerVerdict::Killed => self.pump(t, out),
            TimerVerdict::Requeue(id) => {
                // Original deadline: retries never relax EDF order.
                self.push_ready(id);
                self.pump(t, out);
            }
        }
    }

    fn expire_workers_into(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        for wid in self.table.expire_due(t) {
            self.on_worker_lost_into(t, wid, out);
        }
    }

    fn pending_tasks(&self) -> usize {
        self.table.pending_tasks()
    }

    fn live_workers(&self) -> usize {
        self.table.live_workers()
    }

    fn allocs_waiting(&self) -> u32 {
        self.table.allocs_waiting()
    }

    fn resident_tasks(&self) -> usize {
        self.table.resident_tasks()
    }

    fn retired_count(&self) -> u64 {
        self.table.retired_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{MS, SEC};
    use crate::cluster::JobRequest;

    fn cfg() -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 1,
            workers_per_alloc: 1,
            max_worker_count: 4,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: 1 * MS,
        }
    }

    fn spec(tag: u64, limit: Micros) -> TaskSpec {
        TaskSpec { tag, cores: 16, time_request: SEC, time_limit: limit }
    }

    /// Run the core's outstanding actions to quiescence, each started
    /// task taking `dur`; records task ids in start order.
    fn settle(core: &mut EdfCore, mut acts: Vec<HqAction>, dur: Micros)
              -> Vec<TaskId> {
        use crate::clock::Des;
        #[derive(Debug)]
        enum Ev {
            Timer(HqTimer),
            Done(TaskId),
        }
        let mut des: Des<Ev> = Des::new();
        let mut starts = Vec::new();
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 100_000, "runaway settle");
            for a in std::mem::take(&mut acts) {
                match a {
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::StartTask { task, .. } => {
                        starts.push(task);
                        des.after(dur, Ev::Done(task));
                    }
                    _ => {}
                }
            }
            let Some((t, ev)) = des.pop() else { break };
            match ev {
                Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
                Ev::Done(id) => core.on_task_done_into(t, id, &mut acts),
            }
        }
        starts
    }

    #[test]
    fn pops_earliest_deadline_first() {
        // Serial 16-core tasks all queued *before* capacity appears,
        // with shuffled limits: start order must be ascending deadline.
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        let limits = [500 * SEC, 40 * SEC, 900 * SEC, 100 * SEC, 700 * SEC];
        let ids: Vec<TaskId> = limits
            .iter()
            .enumerate()
            .map(|(i, &l)| core.submit_task_into(0, spec(i as u64, l), &mut acts))
            .collect();
        acts.clear();
        let _ = core.on_alloc_up_into(SEC, 3600 * SEC, 16, &mut acts);
        let starts = settle(&mut core, acts, 2 * SEC);
        assert_eq!(starts.len(), 5);
        // All submitted at t=0 ⇒ deadline order == limit order.
        assert_eq!(starts, vec![ids[1], ids[3], ids[0], ids[4], ids[2]],
                   "EDF must pop in ascending deadline order");
        assert_eq!(core.retired_count(), 5);
        assert_eq!(core.resident_tasks(), 0);
    }

    #[test]
    fn equal_deadlines_break_ties_by_laxity_then_id() {
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        // All queued before capacity.  Same limit (deadline); task 2
        // has the larger time_request ⇒ less laxity ⇒ must go first
        // despite the higher id.
        let t1 = core.submit_task_into(0, TaskSpec {
            tag: 1, cores: 16, time_request: SEC, time_limit: 100 * SEC,
        }, &mut acts);
        let t2 = core.submit_task_into(0, TaskSpec {
            tag: 2, cores: 16, time_request: 50 * SEC,
            time_limit: 100 * SEC,
        }, &mut acts);
        let t3 = core.submit_task_into(0, TaskSpec {
            tag: 3, cores: 16, time_request: SEC, time_limit: 100 * SEC,
        }, &mut acts);
        acts.clear();
        let _ = core.on_alloc_up_into(SEC, 3600 * SEC, 16, &mut acts);
        let starts = settle(&mut core, acts, SEC);
        assert_eq!(starts, vec![t2, t1, t3],
                   "ties: least laxity first, then lowest id");
    }

    #[test]
    fn strict_edf_blocks_rather_than_backfills() {
        // Head needs 16 cores (deadline soonest); a later-deadline
        // 1-core task must NOT start around it while the head waits.
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        let _ = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts);
        // Occupy 8 cores.
        core.submit_task_into(0, TaskSpec {
            tag: 0, cores: 8, time_request: SEC, time_limit: 10 * SEC,
        }, &mut acts);
        acts.clear();
        // Head: needs all 16, earliest deadline among the waiters.
        core.submit_task_into(1, TaskSpec {
            tag: 1, cores: 16, time_request: SEC, time_limit: 20 * SEC,
        }, &mut acts);
        // Backfill candidate: 1 core, later deadline.
        core.submit_task_into(2, TaskSpec {
            tag: 2, cores: 1, time_request: SEC, time_limit: 500 * SEC,
        }, &mut acts);
        assert!(!acts.iter().any(|a| matches!(
            a,
            HqAction::Timer(_, HqTimer::Dispatched(_))
        )), "strict EDF must not backfill around a blocked head");
        assert_eq!(core.pending_tasks(), 2);
    }

    #[test]
    fn no_task_lost_on_worker_loss_and_deadline_preserved() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut acts = Vec::new();
        let w1 = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts).unwrap();
        let ids: Vec<TaskId> = (0..4)
            .map(|i| core.submit_task_into(0, spec(i, (100 + i) * SEC), &mut acts))
            .collect();
        assert_eq!(core.resident_tasks(), 4);
        acts.clear();
        core.on_worker_lost_into(SEC, w1, &mut acts);
        assert_eq!(core.pending_tasks(), 4, "in-flight work requeued");
        assert!(acts.iter().any(|a| matches!(
            a,
            HqAction::SubmitAllocation { .. }
        )));
        acts.clear();
        let _ = core.on_alloc_up_into(2 * SEC, 3600 * SEC, 16, &mut acts);
        let starts = settle(&mut core, acts, SEC);
        // Original deadlines survive the requeue: EDF order unchanged.
        assert_eq!(starts, ids);
        assert_eq!(core.retired_count(), 4);
        assert_eq!(core.resident_tasks(), 0);
    }

    #[test]
    fn stale_limit_from_first_run_does_not_truncate_requeued_run() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut acts = Vec::new();
        let w1 = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts).unwrap();
        let id = core.submit_task_into(0, spec(1, 100 * SEC), &mut acts);
        // First dispatch: Running at 1 ms, Limit armed for ~100 s.
        acts.clear();
        core.on_timer_into(1 * MS, HqTimer::Dispatched(id), &mut acts);
        assert!(acts.iter().any(|a| matches!(
            a,
            HqAction::StartTask { task, .. } if *task == id
        )));
        // Worker dies mid-run; the task requeues and re-dispatches.
        acts.clear();
        core.on_worker_lost_into(10 * SEC, w1, &mut acts);
        let _ = core.on_alloc_up_into(20 * SEC, 3600 * SEC, 16, &mut acts);
        acts.clear();
        core.on_timer_into(20 * SEC + MS, HqTimer::Dispatched(id),
                           &mut acts);
        assert!(acts.iter().any(|a| matches!(
            a,
            HqAction::StartTask { task, .. } if *task == id
        )));
        // The FIRST run's limit timer fires: it must not kill the rerun
        // (which has its own limit armed for start2 + 100 s).
        acts.clear();
        core.on_timer_into(100 * SEC + MS, HqTimer::Limit(id), &mut acts);
        assert!(acts.is_empty(), "stale limit must be ignored: {acts:?}");
        // The rerun completes normally, untruncated.
        acts.clear();
        core.on_task_done_into(110 * SEC, id, &mut acts);
        let rec = acts
            .iter()
            .find_map(|a| match a {
                HqAction::TaskCompleted { record, .. } => {
                    Some(record.clone())
                }
                _ => None,
            })
            .expect("completion record");
        assert!(!rec.truncated, "requeued run was wrongly truncated");
    }

    #[test]
    fn time_limit_kills_runaway() {
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        let _ = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts);
        core.submit_task_into(0, spec(9, 5 * SEC), &mut acts);
        // Run the dispatch timer, then let the limit fire (no Done).
        use crate::clock::Des;
        let mut des: Des<HqTimer> = Des::new();
        let mut records = Vec::new();
        loop {
            for a in std::mem::take(&mut acts) {
                match a {
                    HqAction::Timer(tt, tm) => des.schedule(tt, tm),
                    HqAction::TaskCompleted { record, .. } => {
                        records.push(record)
                    }
                    _ => {}
                }
            }
            let Some((t, tm)) = des.pop() else { break };
            core.on_timer_into(t, tm, &mut acts);
        }
        assert_eq!(records.len(), 1);
        assert!(records[0].truncated);
    }

    #[test]
    fn autoalloc_caps_respected() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut allocs = 0;
        let mut out = Vec::new();
        for i in 0..8u64 {
            out.clear();
            core.submit_task_into(i, spec(i, 100 * SEC), &mut out);
            allocs += out.iter().filter(|a| matches!(
                a,
                HqAction::SubmitAllocation { .. }
            )).count();
        }
        assert_eq!(allocs, 2, "backlog=2 caps queued allocs");
        assert_eq!(core.allocs_waiting(), 2);
        let mut out = Vec::new();
        let _ = core.on_alloc_up_into(10, 3600 * SEC, 16, &mut out);
        let _ = core.on_alloc_up_into(11, 3600 * SEC, 16, &mut out);
        let _ = core.on_alloc_up_into(12, 3600 * SEC, 16, &mut out);
        assert!(core.live_workers() <= 2);
    }

    #[test]
    fn expiry_heap_matches_worker_lifetimes() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 4,
            max_worker_count: 4,
            ..cfg()
        });
        let mut out = Vec::new();
        for i in 0..4u64 {
            core.submit_task_into(i, spec(i, 100 * SEC), &mut out);
        }
        let _ = core.on_alloc_up_into(0, 10 * SEC, 16, &mut out);
        let _ = core.on_alloc_up_into(0, 50 * SEC, 16, &mut out);
        assert_eq!(core.live_workers(), 2);
        core.expire_workers_into(5 * SEC, &mut out);
        assert_eq!(core.live_workers(), 2);
        core.expire_workers_into(20 * SEC, &mut out);
        assert_eq!(core.live_workers(), 1);
        core.expire_workers_into(60 * SEC, &mut out);
        assert_eq!(core.live_workers(), 0);
    }

    #[test]
    fn time_request_gates_dispatch() {
        let mut core = EdfCore::new(cfg());
        let mut out = Vec::new();
        let _ = core.on_alloc_up_into(0, 10 * SEC, 16, &mut out);
        core.submit_task_into(0, TaskSpec {
            tag: 1, cores: 1, time_request: 3600 * SEC,
            time_limit: 2 * 3600 * SEC,
        }, &mut out);
        assert_eq!(core.pending_tasks(), 1,
                   "task with long time request stays queued");
        assert!(!out.iter().any(|a| matches!(
            a,
            HqAction::Timer(_, HqTimer::Dispatched(_))
        )));
    }
}
