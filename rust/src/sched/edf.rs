//! [`EdfCore`]: a deadline-EDF task scheduler — the fourth pluggable
//! core, and the first to run in **both** planes (campaign and live).
//!
//! Every task gets an absolute deadline at submission, `submit_t +
//! time_limit` (the kill limit is the natural hard deadline: past it the
//! result is discarded anyway).  The ready structure is one deadline
//! min-heap; dispatch always pops the earliest deadline, breaking ties
//! by **static laxity** (`time_limit - time_request`: the task with the
//! least slack between its expected runtime and its kill limit goes
//! first) and finally by task id, so a campaign remains a pure function
//! of its seed.
//!
//! EDF here is *strict*: if the earliest-deadline task cannot start on
//! any live worker (no free cores, or no allocation outliving its time
//! request), dispatch stops rather than backfilling a later-deadline
//! task around it — the discipline the classic uniprocessor optimality
//! result is about, and the property `tests/scheduler_props.rs` pins.
//! Starvation-freedom falls out of absolute deadlines: a waiting task's
//! deadline is fixed while every newcomer's is `now + limit`, so
//! sustained short-deadline load overtakes it only for a bounded window.
//!
//! Everything around dispatch keeps hqlite's semantics so the stack and
//! the live balancer treat all [`TaskCore`] implementations
//! interchangeably: the same [`AutoAllocConfig`] automatic allocation,
//! the same expiry min-heap, the same dispatch-latency and time-limit
//! timers, the same action vocabulary ([`HqAction`]/[`HqTimer`]).  In
//! the campaign plane it rides `MetaStack<EdfCore>` (`uqsched campaign
//! --scheduler edf`); in the live plane it rides
//! [`LiveSched`](crate::sched::LiveSched) (`uqsched balancer
//! --scheduler edf`), where each model's front-door queue is its own
//! `EdfCore` — the per-model deadline heap.
//!
//! Cost (w = live workers, p = ready tasks): submission is O(log p) +
//! one pump; a pump pass pops each startable task at O(log p + w); a
//! blocked head costs O(w) once per pump.  See PERF.md.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};

use crate::clock::Micros;
use crate::hqlite::core::drain_due_workers;
use crate::hqlite::{AutoAllocConfig, HqAction, HqTimer, TaskCore, TaskId,
                    TaskSpec, WorkerId};
use crate::metrics::JobRecord;

#[derive(Clone, Copy, Debug, PartialEq)]
enum TaskState {
    Pending,
    Dispatched,
    Running,
    /// Failed transiently; off every worker, waiting out its retry
    /// backoff.  Re-enters the ready heap — with its *original*
    /// deadline — when the `Retry` timer fires.
    Cooling,
}

#[derive(Clone, Debug)]
struct Task {
    spec: TaskSpec,
    state: TaskState,
    submit_t: Micros,
    start_t: Micros,
    worker: WorkerId,
    /// Absolute deadline: `submit_t + spec.time_limit`, fixed at
    /// submission (a requeue after worker loss keeps it — deadlines do
    /// not reset, which is what makes EDF starvation-free).
    deadline: Micros,
}

#[derive(Clone, Debug)]
struct Worker {
    cores_free: u32,
    /// Virtual time at which the surrounding allocation expires.
    expires_t: Micros,
    /// Tasks currently dispatched to / running on this worker.
    running: BTreeSet<TaskId>,
}

/// Heap key: earliest deadline first, then least static laxity, then
/// lowest task id (total order ⇒ deterministic pops).
type EdfKey = (Micros, Micros, TaskId);

/// The deadline-EDF task scheduler.
pub struct EdfCore {
    cfg: AutoAllocConfig,
    /// In-flight tasks only; finished tasks are evicted.
    tasks: HashMap<TaskId, Task>,
    /// Deadline min-heap over Pending tasks.  May lazily contain ids of
    /// tasks that completed while requeued; dropped when popped.
    ready: BinaryHeap<Reverse<EdfKey>>,
    /// Live workers, id-ordered for deterministic host scans.
    workers: BTreeMap<WorkerId, Worker>,
    /// (expires_t, worker) min-heap; entries for already-lost workers
    /// are skipped lazily.
    expiry: BinaryHeap<Reverse<(Micros, WorkerId)>>,
    /// Live tasks currently Pending (ready heap minus stale entries).
    pending: usize,
    retired: u64,
    next_task: TaskId,
    next_worker: WorkerId,
    next_alloc_tag: u64,
    allocs_in_queue: u32,
    /// Stats: dispatches performed.
    pub dispatches: u64,
}

impl EdfCore {
    pub fn new(cfg: AutoAllocConfig) -> Self {
        EdfCore {
            cfg,
            tasks: HashMap::new(),
            ready: BinaryHeap::new(),
            workers: BTreeMap::new(),
            expiry: BinaryHeap::new(),
            pending: 0,
            retired: 0,
            next_task: 1,
            next_worker: 1,
            next_alloc_tag: 1,
            allocs_in_queue: 0,
            dispatches: 0,
        }
    }

    fn is_pending(&self, id: TaskId) -> bool {
        self.tasks.get(&id).map(|t| t.state) == Some(TaskState::Pending)
    }

    /// A task's heap key: (deadline, static laxity, id).
    fn key_of(task: &Task, id: TaskId) -> EdfKey {
        let laxity = task.spec.time_limit
            .saturating_sub(task.spec.time_request);
        (task.deadline, laxity, id)
    }

    /// Start `id` on `wid` now (capacity already checked).
    fn start(&mut self, t: Micros, id: TaskId, wid: WorkerId,
             out: &mut Vec<HqAction>) {
        let need = self.tasks[&id].spec.cores;
        let w = self.workers.get_mut(&wid).unwrap();
        w.cores_free -= need;
        w.running.insert(id);
        let task = self.tasks.get_mut(&id).unwrap();
        task.state = TaskState::Dispatched;
        task.worker = wid;
        self.pending -= 1;
        self.dispatches += 1;
        out.push(HqAction::Timer(
            t + self.cfg.dispatch_latency,
            HqTimer::Dispatched(id),
        ));
    }

    /// Can `wid` start `id` right now?  Needs the cores free and an
    /// allocation outliving the task's time request (HQ semantics).
    fn can_start(&self, t: Micros, id: TaskId, wid: WorkerId) -> bool {
        let w = &self.workers[&wid];
        let spec = &self.tasks[&id].spec;
        w.cores_free >= spec.cores && w.expires_t >= t + spec.time_request
    }

    /// Dispatch strictly earliest-deadline-first: pop the heap while the
    /// head can start on some worker (lowest-id host wins); a blocked
    /// head stops dispatch — no backfilling around it.  Then autoalloc
    /// tops up capacity for whatever is still pending.
    fn pump(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        while let Some(&Reverse((_, _, id))) = self.ready.peek() {
            if !self.is_pending(id) {
                // Stale entry (completed while requeued, or re-pushed by
                // a worker loss after an earlier pop): drop lazily.
                self.ready.pop();
                continue;
            }
            let host = self
                .workers
                .keys()
                .copied()
                .find(|&wid| self.can_start(t, id, wid));
            let Some(wid) = host else { break };
            self.ready.pop();
            self.start(t, id, wid, out);
        }
        self.autoalloc_into(out);
    }

    /// Submit allocations while there are pending tasks, the backlog
    /// allows it, and the worker cap is not reached (hqlite semantics).
    fn autoalloc_into(&mut self, out: &mut Vec<HqAction>) {
        while self.pending > 0
            && self.allocs_in_queue < self.cfg.backlog
            && self.workers.len() as u32
                + self.allocs_in_queue * self.cfg.workers_per_alloc
                < self.cfg.max_worker_count
        {
            self.allocs_in_queue += 1;
            let tag = self.next_alloc_tag;
            self.next_alloc_tag += 1;
            out.push(HqAction::SubmitAllocation {
                alloc_tag: tag,
                req: self.cfg.alloc_request,
            });
        }
    }

    fn complete(&mut self, t: Micros, id: TaskId, truncated: bool,
                out: &mut Vec<HqAction>) {
        // Finished tasks are evicted, so a stale duplicate completion
        // (the driver's original done-timer firing after a requeue)
        // simply misses the map.
        let Some(task) = self.tasks.remove(&id) else { return };
        if task.state == TaskState::Pending {
            // Completed while requeued: its heap entry is now stale and
            // will be lazily dropped.
            self.pending -= 1;
        }
        self.retired += 1;
        let record = JobRecord {
            tag: task.spec.tag,
            submit: task.submit_t,
            start: task.start_t,
            end: t,
            cpu: t.saturating_sub(task.start_t),
            truncated,
        };
        if let Some(w) = self.workers.get_mut(&task.worker) {
            if w.running.remove(&id) {
                w.cores_free += task.spec.cores;
            }
        }
        out.push(HqAction::TaskCompleted { task: id, record });
        self.pump(t, out);
    }
}

impl TaskCore for EdfCore {
    fn submit_task_into(
        &mut self,
        t: Micros,
        spec: TaskSpec,
        out: &mut Vec<HqAction>,
    ) -> TaskId {
        let id = self.next_task;
        self.next_task += 1;
        let task = Task {
            deadline: t.saturating_add(spec.time_limit),
            spec,
            state: TaskState::Pending,
            submit_t: t,
            start_t: 0,
            worker: 0,
        };
        self.ready.push(Reverse(Self::key_of(&task, id)));
        self.tasks.insert(id, task);
        self.pending += 1;
        self.pump(t, out);
        id
    }

    fn on_alloc_up_into(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
        out: &mut Vec<HqAction>,
    ) {
        self.allocs_in_queue = self.allocs_in_queue.saturating_sub(1);
        for _ in 0..self.cfg.workers_per_alloc {
            if self.workers.len() as u32 >= self.cfg.max_worker_count {
                break;
            }
            let wid = self.next_worker;
            self.next_worker += 1;
            self.workers.insert(
                wid,
                Worker {
                    cores_free: cores_per_worker,
                    expires_t: t.saturating_add(time_limit),
                    running: BTreeSet::new(),
                },
            );
            self.expiry.push(Reverse((t.saturating_add(time_limit), wid)));
        }
        self.pump(t, out);
    }

    fn on_worker_lost_into(
        &mut self,
        t: Micros,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    ) {
        if let Some(worker) = self.workers.remove(&wid) {
            // No task lost: the in-flight set requeues with its original
            // deadlines (ascending task-id order, deterministic).
            for id in worker.running {
                if let Some(task) = self.tasks.get_mut(&id) {
                    if matches!(
                        task.state,
                        TaskState::Running | TaskState::Dispatched
                    ) {
                        task.state = TaskState::Pending;
                        self.pending += 1;
                        let key = Self::key_of(task, id);
                        self.ready.push(Reverse(key));
                        out.push(HqAction::Requeued { task: id });
                    }
                }
            }
        }
        self.pump(t, out);
    }

    fn on_task_done_into(&mut self, t: Micros, id: TaskId,
                         out: &mut Vec<HqAction>) {
        self.complete(t, id, false, out)
    }

    fn on_task_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) {
        let Some(task) = self.tasks.get_mut(&id) else { return };
        if !matches!(task.state, TaskState::Dispatched | TaskState::Running) {
            return;
        }
        match retry_in {
            None => {
                out.push(HqAction::KillTask { task: id });
                self.complete(t, id, true, out);
            }
            Some(backoff) => {
                let wid = task.worker;
                let cores = task.spec.cores;
                task.state = TaskState::Cooling;
                if let Some(w) = self.workers.get_mut(&wid) {
                    if w.running.remove(&id) {
                        w.cores_free += cores;
                    }
                }
                out.push(HqAction::Requeued { task: id });
                out.push(HqAction::Timer(
                    t.saturating_add(backoff),
                    HqTimer::Retry(id),
                ));
                self.pump(t, out);
            }
        }
    }

    fn task_live(&self, id: TaskId) -> bool {
        self.tasks.contains_key(&id)
    }

    fn live_worker_ids_into(&self, out: &mut Vec<u64>) {
        out.extend(self.workers.keys().copied());
    }

    fn on_timer_into(&mut self, t: Micros, timer: HqTimer,
                     out: &mut Vec<HqAction>) {
        match timer {
            HqTimer::Dispatched(id) => {
                let Some(task) = self.tasks.get_mut(&id) else { return };
                if task.state != TaskState::Dispatched {
                    return;
                }
                task.state = TaskState::Running;
                task.start_t = t;
                let worker = task.worker;
                let limit = task.spec.time_limit;
                out.push(HqAction::StartTask { task: id, worker });
                out.push(HqAction::Timer(t.saturating_add(limit),
                                         HqTimer::Limit(id)));
            }
            HqTimer::Limit(id) => {
                // Only the timer armed for *this* run kills (it fires
                // exactly at start_t + time_limit).  A stale limit from
                // a pre-requeue run fires at the old start and must not
                // truncate the rerun — requeued tasks keep their full
                // limit, just as they keep their original deadline.
                let due = self
                    .tasks
                    .get(&id)
                    .filter(|task| task.state == TaskState::Running)
                    .map(|task| {
                        task.start_t.saturating_add(task.spec.time_limit)
                    });
                if due == Some(t) {
                    out.push(HqAction::KillTask { task: id });
                    self.complete(t, id, true, out);
                }
            }
            HqTimer::Retry(id) => {
                let Some(task) = self.tasks.get_mut(&id) else { return };
                if task.state != TaskState::Cooling {
                    return;
                }
                task.state = TaskState::Pending;
                self.pending += 1;
                // Original deadline: retries never relax EDF order.
                let key = Self::key_of(task, id);
                self.ready.push(Reverse(key));
                self.pump(t, out);
            }
        }
    }

    fn expire_workers_into(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        let expired = drain_due_workers(&mut self.expiry, t, |wid| {
            self.workers.contains_key(&wid)
        });
        for wid in expired {
            self.on_worker_lost_into(t, wid, out);
        }
    }

    fn pending_tasks(&self) -> usize {
        self.pending
    }

    fn live_workers(&self) -> usize {
        self.workers.len()
    }

    fn allocs_waiting(&self) -> u32 {
        self.allocs_in_queue
    }

    fn resident_tasks(&self) -> usize {
        self.tasks.len()
    }

    fn retired_count(&self) -> u64 {
        self.retired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{MS, SEC};
    use crate::cluster::JobRequest;

    fn cfg() -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 1,
            workers_per_alloc: 1,
            max_worker_count: 4,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: 1 * MS,
        }
    }

    fn spec(tag: u64, limit: Micros) -> TaskSpec {
        TaskSpec { tag, cores: 16, time_request: SEC, time_limit: limit }
    }

    /// Run the core's outstanding actions to quiescence, each started
    /// task taking `dur`; records task ids in start order.
    fn settle(core: &mut EdfCore, mut acts: Vec<HqAction>, dur: Micros)
              -> Vec<TaskId> {
        use crate::clock::Des;
        #[derive(Debug)]
        enum Ev {
            Timer(HqTimer),
            Done(TaskId),
        }
        let mut des: Des<Ev> = Des::new();
        let mut starts = Vec::new();
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 100_000, "runaway settle");
            for a in std::mem::take(&mut acts) {
                match a {
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::StartTask { task, .. } => {
                        starts.push(task);
                        des.after(dur, Ev::Done(task));
                    }
                    _ => {}
                }
            }
            let Some((t, ev)) = des.pop() else { break };
            match ev {
                Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
                Ev::Done(id) => core.on_task_done_into(t, id, &mut acts),
            }
        }
        starts
    }

    #[test]
    fn pops_earliest_deadline_first() {
        // Serial 16-core tasks all queued *before* capacity appears,
        // with shuffled limits: start order must be ascending deadline.
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        let limits = [500 * SEC, 40 * SEC, 900 * SEC, 100 * SEC, 700 * SEC];
        for (i, &l) in limits.iter().enumerate() {
            core.submit_task_into(0, spec(i as u64, l), &mut acts);
        }
        acts.clear();
        core.on_alloc_up_into(SEC, 3600 * SEC, 16, &mut acts);
        let starts = settle(&mut core, acts, 2 * SEC);
        assert_eq!(starts.len(), 5);
        // All submitted at t=0 ⇒ deadline order == limit order.  Task
        // ids are 1-based in submission order.
        assert_eq!(starts, vec![2, 4, 1, 5, 3],
                   "EDF must pop in ascending deadline order");
        assert_eq!(core.retired_count(), 5);
        assert_eq!(core.resident_tasks(), 0);
    }

    #[test]
    fn equal_deadlines_break_ties_by_laxity_then_id() {
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        // All queued before capacity.  Same limit (deadline); task 2
        // has the larger time_request ⇒ less laxity ⇒ must go first
        // despite the higher id.
        core.submit_task_into(0, TaskSpec {
            tag: 1, cores: 16, time_request: SEC, time_limit: 100 * SEC,
        }, &mut acts);
        core.submit_task_into(0, TaskSpec {
            tag: 2, cores: 16, time_request: 50 * SEC,
            time_limit: 100 * SEC,
        }, &mut acts);
        core.submit_task_into(0, TaskSpec {
            tag: 3, cores: 16, time_request: SEC, time_limit: 100 * SEC,
        }, &mut acts);
        acts.clear();
        core.on_alloc_up_into(SEC, 3600 * SEC, 16, &mut acts);
        let starts = settle(&mut core, acts, SEC);
        assert_eq!(starts, vec![2, 1, 3],
                   "ties: least laxity first, then lowest id");
    }

    #[test]
    fn strict_edf_blocks_rather_than_backfills() {
        // Head needs 16 cores (deadline soonest); a later-deadline
        // 1-core task must NOT start around it while the head waits.
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts);
        // Occupy 8 cores.
        core.submit_task_into(0, TaskSpec {
            tag: 0, cores: 8, time_request: SEC, time_limit: 10 * SEC,
        }, &mut acts);
        acts.clear();
        // Head: needs all 16, earliest deadline among the waiters.
        core.submit_task_into(1, TaskSpec {
            tag: 1, cores: 16, time_request: SEC, time_limit: 20 * SEC,
        }, &mut acts);
        // Backfill candidate: 1 core, later deadline.
        core.submit_task_into(2, TaskSpec {
            tag: 2, cores: 1, time_request: SEC, time_limit: 500 * SEC,
        }, &mut acts);
        assert!(!acts.iter().any(|a| matches!(
            a,
            HqAction::Timer(_, HqTimer::Dispatched(_))
        )), "strict EDF must not backfill around a blocked head");
        assert_eq!(core.pending_tasks(), 2);
    }

    #[test]
    fn no_task_lost_on_worker_loss_and_deadline_preserved() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut acts = Vec::new();
        core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts);
        for i in 0..4 {
            core.submit_task_into(0, spec(i, (100 + i) * SEC), &mut acts);
        }
        assert_eq!(core.resident_tasks(), 4);
        acts.clear();
        core.on_worker_lost_into(SEC, 1, &mut acts);
        assert_eq!(core.pending_tasks(), 4, "in-flight work requeued");
        assert!(acts.iter().any(|a| matches!(
            a,
            HqAction::SubmitAllocation { .. }
        )));
        acts.clear();
        core.on_alloc_up_into(2 * SEC, 3600 * SEC, 16, &mut acts);
        let starts = settle(&mut core, acts, SEC);
        // Original deadlines survive the requeue: EDF order unchanged.
        assert_eq!(starts, vec![1, 2, 3, 4]);
        assert_eq!(core.retired_count(), 4);
        assert_eq!(core.resident_tasks(), 0);
    }

    #[test]
    fn stale_limit_from_first_run_does_not_truncate_requeued_run() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut acts = Vec::new();
        core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts);
        core.submit_task_into(0, spec(1, 100 * SEC), &mut acts);
        // First dispatch: Running at 1 ms, Limit armed for ~100 s.
        acts.clear();
        core.on_timer_into(1 * MS, HqTimer::Dispatched(1), &mut acts);
        assert!(acts.iter().any(|a| matches!(
            a,
            HqAction::StartTask { task: 1, .. }
        )));
        // Worker dies mid-run; the task requeues and re-dispatches.
        acts.clear();
        core.on_worker_lost_into(10 * SEC, 1, &mut acts);
        core.on_alloc_up_into(20 * SEC, 3600 * SEC, 16, &mut acts);
        acts.clear();
        core.on_timer_into(20 * SEC + MS, HqTimer::Dispatched(1),
                           &mut acts);
        assert!(acts.iter().any(|a| matches!(
            a,
            HqAction::StartTask { task: 1, .. }
        )));
        // The FIRST run's limit timer fires: it must not kill the rerun
        // (which has its own limit armed for start2 + 100 s).
        acts.clear();
        core.on_timer_into(100 * SEC + MS, HqTimer::Limit(1), &mut acts);
        assert!(acts.is_empty(), "stale limit must be ignored: {acts:?}");
        // The rerun completes normally, untruncated.
        acts.clear();
        core.on_task_done_into(110 * SEC, 1, &mut acts);
        let rec = acts
            .iter()
            .find_map(|a| match a {
                HqAction::TaskCompleted { record, .. } => {
                    Some(record.clone())
                }
                _ => None,
            })
            .expect("completion record");
        assert!(!rec.truncated, "requeued run was wrongly truncated");
    }

    #[test]
    fn time_limit_kills_runaway() {
        let mut core = EdfCore::new(cfg());
        let mut acts = Vec::new();
        core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts);
        core.submit_task_into(0, spec(9, 5 * SEC), &mut acts);
        // Run the dispatch timer, then let the limit fire (no Done).
        use crate::clock::Des;
        let mut des: Des<HqTimer> = Des::new();
        let mut records = Vec::new();
        loop {
            for a in std::mem::take(&mut acts) {
                match a {
                    HqAction::Timer(tt, tm) => des.schedule(tt, tm),
                    HqAction::TaskCompleted { record, .. } => {
                        records.push(record)
                    }
                    _ => {}
                }
            }
            let Some((t, tm)) = des.pop() else { break };
            core.on_timer_into(t, tm, &mut acts);
        }
        assert_eq!(records.len(), 1);
        assert!(records[0].truncated);
    }

    #[test]
    fn autoalloc_caps_respected() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut allocs = 0;
        let mut out = Vec::new();
        for i in 0..8u64 {
            out.clear();
            core.submit_task_into(i, spec(i, 100 * SEC), &mut out);
            allocs += out.iter().filter(|a| matches!(
                a,
                HqAction::SubmitAllocation { .. }
            )).count();
        }
        assert_eq!(allocs, 2, "backlog=2 caps queued allocs");
        assert_eq!(core.allocs_waiting(), 2);
        let mut out = Vec::new();
        core.on_alloc_up_into(10, 3600 * SEC, 16, &mut out);
        core.on_alloc_up_into(11, 3600 * SEC, 16, &mut out);
        core.on_alloc_up_into(12, 3600 * SEC, 16, &mut out);
        assert!(core.live_workers() <= 2);
    }

    #[test]
    fn expiry_heap_matches_worker_lifetimes() {
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 4,
            max_worker_count: 4,
            ..cfg()
        });
        let mut out = Vec::new();
        for i in 0..4u64 {
            core.submit_task_into(i, spec(i, 100 * SEC), &mut out);
        }
        core.on_alloc_up_into(0, 10 * SEC, 16, &mut out);
        core.on_alloc_up_into(0, 50 * SEC, 16, &mut out);
        assert_eq!(core.live_workers(), 2);
        core.expire_workers_into(5 * SEC, &mut out);
        assert_eq!(core.live_workers(), 2);
        core.expire_workers_into(20 * SEC, &mut out);
        assert_eq!(core.live_workers(), 1);
        core.expire_workers_into(60 * SEC, &mut out);
        assert_eq!(core.live_workers(), 0);
    }

    #[test]
    fn time_request_gates_dispatch() {
        let mut core = EdfCore::new(cfg());
        let mut out = Vec::new();
        core.on_alloc_up_into(0, 10 * SEC, 16, &mut out);
        core.submit_task_into(0, TaskSpec {
            tag: 1, cores: 1, time_request: 3600 * SEC,
            time_limit: 2 * 3600 * SEC,
        }, &mut out);
        assert_eq!(core.pending_tasks(), 1,
                   "task with long time request stays queued");
        assert!(!out.iter().any(|a| matches!(
            a,
            HqAction::Timer(_, HqTimer::Dispatched(_))
        )));
    }
}
