//! [`WorkStealCore`]: a partitioned, work-stealing HyperQueue variant —
//! the third pluggable scheduler, proving the [`TaskCore`] seam is real.
//!
//! Where [`HqCore`](crate::hqlite::HqCore) keeps one central FCFS queue
//! the server scans on every dispatch, `WorkStealCore` partitions: every
//! task is assigned at submission to the least-loaded worker's private
//! deque (fewest queued tasks, ties to the lowest worker id), each
//! worker executes its own deque strictly FIFO, and a worker that goes
//! idle *steals* the newest task from the back of the longest deque —
//! the classic owner-takes-head / thief-takes-tail discipline, which
//! keeps the per-deque FIFO order of everything left behind intact.
//!
//! The task/worker lifecycle (timers, completion records, autoalloc,
//! Cooling/Retry recovery) lives in the shared
//! [`TaskTable`](crate::sched::table::TaskTable); this file keeps only
//! the ready structure — the per-worker deques and the shared backlog —
//! and the placement/steal policy.  The stack drivers treat every table
//! rider interchangeably: the same [`AutoAllocConfig`] automatic
//! allocation (backlog, workers-per-alloc, worker cap), the same expiry
//! min-heap, the same time-request gating (a task only starts on a
//! worker whose allocation outlives its `time_request`), the same
//! dispatch-latency and time-limit timers, and the same action
//! vocabulary ([`HqAction`]/[`HqTimer`]).
//!
//! Determinism: workers live in a `BTreeMap` and every scan (placement,
//! backlog drain, steal) runs in worker-id order with explicit
//! tie-breaking, so a campaign remains a pure function of its seed.
//!
//! Invariants (pinned by `tests/scheduler_props.rs`):
//! * no task is lost on [`on_worker_lost`](TaskCore::on_worker_lost) —
//!   the dead worker's deque and running set requeue onto the backlog;
//! * a steal never reorders the tasks remaining in the victim's deque.
//!
//! Cost (w = live workers, d = tasks started per pass): a pump pass is
//! O(w + d); submission placement is O(w); completion is O(log w) map
//! access + one pump.  See PERF.md for the full table.

use std::collections::{BTreeMap, VecDeque};

use crate::clock::Micros;
use crate::hqlite::{AutoAllocConfig, HqAction, HqTimer, TaskCore, TaskId,
                    TaskSpec, WorkerId};
use crate::sched::table::{FailVerdict, TaskTable, TimerVerdict};

/// The partitioned work-stealing task scheduler.
pub struct WorkStealCore {
    /// Shared task/worker lifecycle engine.
    table: TaskTable,
    /// Tasks no live worker could host at submission time (no worker up,
    /// or none with enough total cores).  Drained oldest-first as
    /// capacity appears.  May lazily contain ids of tasks that finished
    /// while requeued; they are dropped when next encountered.
    backlog: VecDeque<TaskId>,
    /// Per-worker private FIFO dispatch deques (pending tasks; may
    /// lazily hold ids of tasks evicted while queued — dropped when next
    /// encountered, like the backlog).  Keys mirror the table's live
    /// worker map.
    deques: BTreeMap<WorkerId, VecDeque<TaskId>>,
    /// Reusable worker-id scratch for pump passes (allocation-lean on
    /// the per-event hot path, like the kernel's effect buffer).
    wid_scratch: Vec<WorkerId>,
    /// Stats: dispatches that went through a steal.
    pub steals: u64,
}

impl WorkStealCore {
    pub fn new(cfg: AutoAllocConfig) -> Self {
        WorkStealCore {
            table: TaskTable::new(cfg),
            backlog: VecDeque::new(),
            deques: BTreeMap::new(),
            wid_scratch: Vec::new(),
            steals: 0,
        }
    }

    /// Stats: dispatches performed.
    pub fn dispatches(&self) -> u64 {
        self.table.dispatches()
    }

    /// Queued (not yet started) tasks on one worker's private deque.
    pub fn deque_len(&self, wid: WorkerId) -> usize {
        self.deques.get(&wid).map_or(0, |d| d.len())
    }

    /// Steal/FIFO invariant probe: every worker's private deque holds
    /// task ids in ascending (submission) order at all times — owners
    /// pop the front, thieves the back, placement appends — so any
    /// violation means an illegal mid-deque mutation.
    pub fn deques_fifo(&self) -> bool {
        self.deques.values().all(|d| {
            d.iter().zip(d.iter().skip(1)).all(|(a, b)| a < b)
        })
    }

    /// Assign a freshly submitted task to the least-loaded worker whose
    /// total cores could ever host it (ties: lowest id), or the backlog.
    fn place(&mut self, id: TaskId) {
        let need = self.table.task(id).expect("placing unknown task").spec.cores;
        let mut best: Option<(usize, WorkerId)> = None;
        for wid in self.table.worker_ids() {
            let w = self.table.worker(wid).expect("indexed worker live");
            if w.cores_total < need {
                continue;
            }
            let len = self.deques.get(&wid).map_or(0, |d| d.len());
            if best.map_or(true, |(bl, _)| len < bl) {
                best = Some((len, wid));
            }
        }
        match best {
            Some((_, wid)) => {
                self.deques.get_mut(&wid).unwrap().push_back(id)
            }
            None => self.backlog.push_back(id),
        }
    }

    /// One owner-dispatch sweep: every worker starts the front of its
    /// own deque while it can (strict per-deque FIFO).  Returns whether
    /// anything happened.
    fn dispatch_local(&mut self, t: Micros, out: &mut Vec<HqAction>) -> bool {
        let mut progressed = false;
        let mut wids = std::mem::take(&mut self.wid_scratch);
        wids.clear();
        wids.extend(self.deques.keys().copied());
        for &wid in &wids {
            loop {
                let Some(&front) = self.deques[&wid].front() else {
                    break;
                };
                if !self.table.is_pending(front) {
                    // Stale entry: the task completed while still
                    // queued (the live plane evicts cancelled Pending
                    // tasks via `on_task_done`).  Drop lazily, same
                    // discipline as the backlog.
                    self.deques.get_mut(&wid).unwrap().pop_front();
                    progressed = true;
                    continue;
                }
                if !self.table.can_start(t, front, wid) {
                    break;
                }
                self.deques.get_mut(&wid).unwrap().pop_front();
                self.table.reserve(t, front, &[wid], out);
                progressed = true;
            }
        }
        self.wid_scratch = wids;
        progressed
    }

    /// Drain the backlog oldest-first onto the lowest-id worker that can
    /// start each task immediately; head-of-line blocks (the backlog is
    /// the FCFS lane for work that never fit a partition).
    fn drain_backlog(&mut self, t: Micros, out: &mut Vec<HqAction>) -> bool {
        let mut progressed = false;
        while let Some(&front) = self.backlog.front() {
            if !self.table.is_pending(front) {
                self.backlog.pop_front();
                progressed = true;
                continue;
            }
            let pick = self
                .table
                .worker_ids()
                .find(|&wid| self.table.can_start(t, front, wid));
            let Some(wid) = pick else { break };
            self.backlog.pop_front();
            self.table.reserve(t, front, &[wid], out);
            progressed = true;
        }
        progressed
    }

    /// One steal attempt: the lowest-id idle worker (free cores, empty
    /// deque) takes the task at the *back* of the longest deque, if it
    /// can start it immediately.  Stealing from the tail leaves the
    /// victim's remaining FIFO order untouched.  Returns whether a task
    /// moved.
    fn steal_once(&mut self, t: Micros, out: &mut Vec<HqAction>) -> bool {
        let mut thieves = std::mem::take(&mut self.wid_scratch);
        thieves.clear();
        thieves.extend(self.table.worker_ids().filter(|&wid| {
            self.table
                .worker(wid)
                .map_or(false, |w| w.cores_free > 0)
                && self.deques.get(&wid).map_or(true, |d| d.is_empty())
        }));
        let mut stole = false;
        for &thief in &thieves {
            // Victim: longest deque (ties: lowest id), excluding the
            // thief (whose deque is empty anyway).
            let mut victim: Option<(usize, WorkerId)> = None;
            for (&wid, d) in self.deques.iter() {
                if wid == thief || d.is_empty() {
                    continue;
                }
                let len = d.len();
                if victim.map_or(true, |(bl, _)| len > bl) {
                    victim = Some((len, wid));
                }
            }
            let Some((_, vid)) = victim else { continue };
            let &tail = self.deques[&vid].back().unwrap();
            if !self.table.is_pending(tail) {
                // Stale tail (see dispatch_local): drop it and report
                // progress so the pump rescans.
                self.deques.get_mut(&vid).unwrap().pop_back();
                stole = true;
                break;
            }
            if self.table.can_start(t, tail, thief) {
                self.deques.get_mut(&vid).unwrap().pop_back();
                self.table.reserve(t, tail, &[thief], out);
                self.steals += 1;
                stole = true;
                break;
            }
            // This thief cannot host the steal candidate; try the next.
        }
        self.wid_scratch = thieves;
        stole
    }

    /// Dispatch to a fixed point: owners drain their deques, the backlog
    /// drains onto free capacity, idle workers steal — repeated until
    /// nothing moves — then autoalloc tops up capacity for whatever is
    /// still pending.
    fn pump(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        loop {
            let mut progressed = self.dispatch_local(t, out);
            progressed |= self.drain_backlog(t, out);
            while self.steal_once(t, out) {
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
        self.table.autoalloc_into(out);
    }
}

impl TaskCore for WorkStealCore {
    fn submit_task_into(
        &mut self,
        t: Micros,
        spec: TaskSpec,
        out: &mut Vec<HqAction>,
    ) -> TaskId {
        let id = self.table.admit(t, spec);
        self.place(id);
        self.pump(t, out);
        id
    }

    fn on_alloc_up_into(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
        out: &mut Vec<HqAction>,
    ) -> Option<WorkerId> {
        let admitted = self.table.admit_workers(t, time_limit, cores_per_worker);
        let first = admitted.first().copied();
        for &wid in admitted {
            self.deques.insert(wid, VecDeque::new());
        }
        self.pump(t, out);
        first
    }

    fn on_worker_lost_into(
        &mut self,
        t: Micros,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    ) {
        // No task lost: the private deque requeues in FIFO order, then
        // the in-flight set in ascending task-id order (deterministic),
        // all onto the shared backlog.
        if let Some(deque) = self.deques.remove(&wid) {
            for id in deque {
                if self.table.is_pending(id) {
                    self.backlog.push_back(id);
                }
            }
        }
        for id in self.table.worker_lost(wid, out) {
            self.backlog.push_back(id);
        }
        self.pump(t, out);
    }

    fn on_task_done_into(&mut self, t: Micros, id: TaskId,
                         out: &mut Vec<HqAction>) {
        // A stale duplicate completion (the driver's original done-timer
        // firing after a requeue) misses the table: no pump.
        if self.table.complete(t, id, false, out) {
            self.pump(t, out);
        }
    }

    fn on_timer_into(&mut self, t: Micros, timer: HqTimer,
                     out: &mut Vec<HqAction>) {
        match self.table.timer(t, timer, out) {
            TimerVerdict::Ignored | TimerVerdict::Started => {}
            TimerVerdict::Killed => self.pump(t, out),
            TimerVerdict::Requeue(id) => {
                self.backlog.push_back(id);
                self.pump(t, out);
            }
        }
    }

    fn on_task_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) {
        match self.table.fail(t, id, retry_in, out) {
            FailVerdict::Ignored => {}
            FailVerdict::Killed | FailVerdict::Cooling => self.pump(t, out),
        }
    }

    fn task_live(&self, id: TaskId) -> bool {
        self.table.task_live(id)
    }

    fn live_worker_ids_into(&self, out: &mut Vec<u64>) {
        self.table.live_worker_ids_into(out);
    }

    fn expire_workers_into(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        for wid in self.table.expire_due(t) {
            self.on_worker_lost_into(t, wid, out);
        }
    }

    fn pending_tasks(&self) -> usize {
        self.table.pending_tasks()
    }

    fn live_workers(&self) -> usize {
        self.table.live_workers()
    }

    fn allocs_waiting(&self) -> u32 {
        self.table.allocs_waiting()
    }

    fn resident_tasks(&self) -> usize {
        self.table.resident_tasks()
    }

    fn retired_count(&self) -> u64 {
        self.table.retired_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{Des, MS, SEC};
    use crate::cluster::JobRequest;
    use crate::metrics::JobRecord;

    fn cfg() -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 1,
            workers_per_alloc: 1,
            max_worker_count: 4,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: 1 * MS,
        }
    }

    fn spec(tag: u64, cores: u32) -> TaskSpec {
        TaskSpec {
            tag,
            cores,
            time_request: SEC,
            time_limit: 100 * SEC,
        }
    }

    /// Sim-drive: allocations come up `alloc_delay` after submission;
    /// tasks run `dur(task_id)`.
    fn drive(
        core: &mut WorkStealCore,
        submissions: Vec<(Micros, TaskSpec)>,
        alloc_delay: Micros,
        dur: impl Fn(TaskId) -> Micros,
    ) -> Vec<JobRecord> {
        #[derive(Debug)]
        enum Ev {
            Submit(TaskSpec),
            AllocUp,
            Timer(HqTimer),
            TaskDone(TaskId),
        }
        let mut des: Des<Ev> = Des::new();
        for (t, s) in submissions {
            des.schedule(t, Ev::Submit(s));
        }
        let mut records = Vec::new();
        let mut guard = 0;
        while let Some((t, ev)) = des.pop() {
            guard += 1;
            assert!(guard < 1_000_000, "runaway");
            let acts = match ev {
                Ev::Submit(s) => core.submit_task(t, s).1,
                Ev::AllocUp => core.on_alloc_up(t, 3600 * SEC, 16),
                Ev::Timer(tm) => core.on_timer(t, tm),
                Ev::TaskDone(id) => core.on_task_done(t, id),
            };
            for a in acts {
                match a {
                    HqAction::SubmitAllocation { .. } => {
                        des.schedule(t + alloc_delay, Ev::AllocUp)
                    }
                    HqAction::StartTask { task, .. }
                    | HqAction::StartGang { task, .. } => {
                        des.schedule(t + dur(task), Ev::TaskDone(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::TaskCompleted { record, .. } => {
                        records.push(record)
                    }
                    HqAction::KillTask { .. } => {}
                    HqAction::Requeued { .. } => {}
                }
            }
        }
        records
    }

    #[test]
    fn single_task_through_alloc() {
        let mut core = WorkStealCore::new(cfg());
        let recs = drive(
            &mut core,
            vec![(0, TaskSpec { tag: 1, cores: 1, time_request: SEC,
                                time_limit: 10 * SEC })],
            30 * SEC,
            |_| 2 * SEC,
        );
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert!(r.start >= 30 * SEC);
        assert!(r.start <= 30 * SEC + 10 * MS);
        assert_eq!(r.cpu, 2 * SEC);
        assert_eq!(core.retired_count(), 1);
        assert_eq!(core.resident_tasks(), 0);
    }

    #[test]
    fn partitions_spread_tasks_across_workers() {
        // Two 16-core workers, four 8-core tasks: least-loaded placement
        // splits them 2/2 and all four run in parallel.
        let mut core = WorkStealCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let subs: Vec<_> =
            (0..4).map(|i| (0, spec(i, 8))).collect();
        let recs = drive(&mut core, subs, SEC, |_| 10 * SEC);
        assert_eq!(recs.len(), 4);
        let starts: Vec<_> = recs.iter().map(|r| r.start).collect();
        let lo = *starts.iter().min().unwrap();
        let hi = *starts.iter().max().unwrap();
        assert!(hi - lo < 10 * MS, "all four start together: {starts:?}");
    }

    #[test]
    fn idle_worker_steals_from_longest_deque() {
        let mut core = WorkStealCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        // Worker 1 only, loaded with serial 16-core tasks…
        let mut out = Vec::new();
        let w1 = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut out).unwrap();
        for i in 0..6 {
            core.submit_task_into(0, spec(i, 16), &mut out);
        }
        assert_eq!(core.live_workers(), 1);
        assert!(core.deque_len(w1) >= 5, "one runs, the rest queue");
        // …then worker 2 appears idle: it must steal immediately.
        out.clear();
        let _ = core.on_alloc_up_into(1, 3600 * SEC, 16, &mut out);
        assert_eq!(core.live_workers(), 2);
        assert!(core.steals >= 1, "idle worker steals, {} steals", core.steals);
        let started_on_2 = out.iter().any(|a| matches!(
            a,
            HqAction::Timer(_, HqTimer::Dispatched(_))
        ));
        assert!(started_on_2, "steal dispatches on the thief");
    }

    /// Run the core's outstanding actions to quiescence, each started
    /// task taking `dur`, recording `(worker, task)` in start order.
    /// `SubmitAllocation` actions are ignored (no new capacity appears).
    fn settle(
        core: &mut WorkStealCore,
        mut acts: Vec<HqAction>,
        dur: Micros,
    ) -> Vec<(WorkerId, TaskId)> {
        #[derive(Debug)]
        enum Ev {
            Timer(HqTimer),
            Done(TaskId),
        }
        let mut des: Des<Ev> = Des::new();
        let mut starts: Vec<(WorkerId, TaskId)> = Vec::new();
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 100_000, "runaway settle");
            for a in std::mem::take(&mut acts) {
                match a {
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::StartTask { task, worker } => {
                        starts.push((worker, task));
                        des.after(dur, Ev::Done(task));
                    }
                    _ => {}
                }
            }
            let Some((t, ev)) = des.pop() else { break };
            match ev {
                Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
                Ev::Done(id) => core.on_task_done_into(t, id, &mut acts),
            }
        }
        starts
    }

    #[test]
    fn steal_preserves_victim_fifo_order() {
        // Worker 1 accumulates a deep deque of serial tasks; worker 2
        // arrives idle and steals from the tail.  The victim must still
        // run everything left in its deque in submission (FIFO) order.
        let mut core = WorkStealCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut acts = Vec::new();
        let w1 = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts).unwrap();
        let submitted: Vec<TaskId> = (1..=6)
            .map(|i| core.submit_task_into(0, spec(i, 16), &mut acts))
            .collect();
        assert!(core.deque_len(w1) >= 5);
        let w2 = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut acts).unwrap();
        assert!(core.steals >= 1, "idle second worker must steal");
        let starts = settle(&mut core, acts, 5 * SEC);
        assert_eq!(starts.len(), 6, "every task starts exactly once");
        // Owner-side FIFO: worker 1 replays its deque in ascending
        // task-id (= submission) order, steals notwithstanding.
        let on_w1: Vec<TaskId> = starts
            .iter()
            .filter(|&&(w, _)| w == w1)
            .map(|&(_, id)| id)
            .collect();
        let mut sorted = on_w1.clone();
        sorted.sort_unstable();
        assert_eq!(on_w1, sorted, "victim deque replayed out of order");
        // Nothing lost, nothing duplicated, and the thief did real work.
        let mut all: Vec<TaskId> = starts.iter().map(|&(_, id)| id).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all, submitted);
        assert!(starts.iter().any(|&(w, _)| w == w2));
        assert_eq!(core.retired_count(), 6);
    }

    #[test]
    fn eviction_of_queued_task_is_dropped_lazily_not_dispatched() {
        // Live-plane cancellation path: a Pending task sitting in a
        // worker's deque is completed (evicted) before it ever starts;
        // the stale deque entry must be dropped lazily — never
        // dispatched, never a panic.
        let mut core = WorkStealCore::new(cfg());
        let mut out = Vec::new();
        let w1 = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut out).unwrap();
        let t1 = core.submit_task_into(0, spec(1, 16), &mut out);
        let t2 = core.submit_task_into(0, spec(2, 16), &mut out);
        let t3 = core.submit_task_into(0, spec(3, 16), &mut out);
        // t1 dispatched; t2, t3 queued behind it.
        assert_eq!(core.deque_len(w1), 2);
        out.clear();
        core.on_task_done_into(SEC, t2, &mut out);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::TaskCompleted { task, .. } if *task == t2
        )));
        // The pump already skimmed the stale entry off the deque front.
        assert_eq!(core.deque_len(w1), 1);
        // Finishing t1 starts t3 — t2 is gone, not resurrected.
        out.clear();
        core.on_task_done_into(2 * SEC, t1, &mut out);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::Timer(_, HqTimer::Dispatched(id)) if *id == t3
        )));
        assert_eq!(core.resident_tasks(), 1, "only t3 remains in flight");
    }

    #[test]
    fn no_task_lost_on_worker_loss() {
        let mut core = WorkStealCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut out = Vec::new();
        let w1 = core.on_alloc_up_into(0, 3600 * SEC, 16, &mut out).unwrap();
        let submitted: Vec<TaskId> = (0..5)
            .map(|i| core.submit_task_into(0, spec(i, 16), &mut out))
            .collect();
        // One dispatched + four queued on worker 1.
        assert_eq!(core.resident_tasks(), 5);
        out.clear();
        core.on_worker_lost_into(SEC, w1, &mut out);
        // Everything is pending again (in-flight work requeued too) and
        // autoalloc asks for replacement capacity.
        assert_eq!(core.pending_tasks(), 5);
        assert_eq!(core.live_workers(), 0);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::SubmitAllocation { .. }
        )));
        // Capacity returns: all five run to completion.
        out.clear();
        let _ = core.on_alloc_up_into(2 * SEC, 3600 * SEC, 16, &mut out);
        let starts = settle(&mut core, out, SEC);
        let mut ids: Vec<TaskId> = starts.iter().map(|&(_, id)| id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids, submitted, "all five tasks restarted");
        assert_eq!(core.retired_count(), 5);
        assert_eq!(core.resident_tasks(), 0);
    }

    #[test]
    fn time_request_gates_dispatch() {
        let mut core = WorkStealCore::new(cfg());
        let mut out = Vec::new();
        // Allocation lives 10 s; task requests 3600 s: must NOT start.
        let _ = core.on_alloc_up_into(0, 10 * SEC, 16, &mut out);
        core.submit_task_into(0, TaskSpec {
            tag: 1, cores: 1, time_request: 3600 * SEC,
            time_limit: 2 * 3600 * SEC,
        }, &mut out);
        assert_eq!(core.pending_tasks(), 1,
                   "task with long time request stays queued");
        assert!(!out.iter().any(|a| matches!(
            a,
            HqAction::Timer(_, HqTimer::Dispatched(_))
        )));
    }

    #[test]
    fn time_limit_kills_runaway() {
        let mut core = WorkStealCore::new(cfg());
        let recs = drive(
            &mut core,
            vec![(0, TaskSpec { tag: 9, cores: 1, time_request: SEC,
                                time_limit: 5 * SEC })],
            SEC,
            |_| 60 * SEC,
        );
        assert_eq!(recs.len(), 1);
        assert!(recs[0].truncated);
        assert!(recs[0].cpu <= 5 * SEC + MS);
    }

    #[test]
    fn autoalloc_caps_respected() {
        let mut core = WorkStealCore::new(AutoAllocConfig {
            backlog: 2,
            max_worker_count: 2,
            ..cfg()
        });
        let mut allocs = 0;
        let mut out = Vec::new();
        for i in 0..8u64 {
            out.clear();
            core.submit_task_into(i, spec(i, 1), &mut out);
            allocs += out.iter().filter(|a| matches!(
                a,
                HqAction::SubmitAllocation { .. }
            )).count();
        }
        assert_eq!(allocs, 2, "backlog=2 caps queued allocs");
        assert_eq!(core.allocs_waiting(), 2);
        let mut out = Vec::new();
        let _ = core.on_alloc_up_into(10, 3600 * SEC, 16, &mut out);
        let _ = core.on_alloc_up_into(11, 3600 * SEC, 16, &mut out);
        let _ = core.on_alloc_up_into(12, 3600 * SEC, 16, &mut out);
        assert!(core.live_workers() <= 2);
    }

    #[test]
    fn expiry_heap_matches_worker_lifetimes() {
        let mut core = WorkStealCore::new(AutoAllocConfig {
            backlog: 4,
            max_worker_count: 4,
            ..cfg()
        });
        let mut out = Vec::new();
        for i in 0..4u64 {
            core.submit_task_into(i, spec(i, 16), &mut out);
        }
        let _ = core.on_alloc_up_into(0, 10 * SEC, 16, &mut out);
        let _ = core.on_alloc_up_into(0, 50 * SEC, 16, &mut out);
        assert_eq!(core.live_workers(), 2);
        core.expire_workers_into(5 * SEC, &mut out);
        assert_eq!(core.live_workers(), 2);
        core.expire_workers_into(20 * SEC, &mut out);
        assert_eq!(core.live_workers(), 1);
        core.expire_workers_into(60 * SEC, &mut out);
        assert_eq!(core.live_workers(), 0);
        core.expire_workers_into(61 * SEC, &mut out);
        assert_eq!(core.live_workers(), 0);
    }
}
