//! [`GangCore`]: moldable gang scheduling — the fifth pluggable core,
//! and the first whose tasks span a *set* of workers.
//!
//! The paper's GS2 simulations are MPI-parallel jobs: one logical task
//! occupies many nodes at once, and it either holds **all** of its slots
//! or none — a half-started MPI job is a deadlock, not a schedule.
//! `GangCore` models exactly that.  A task declares a moldable width
//! `min..=max` (workers); at dispatch the core collects the eligible
//! workers in ascending id order — each must have `spec.cores` free and
//! an allocation outliving the task's `time_request` — and
//!
//! * if at least `min` are eligible, it reserves `min(max, eligible)`
//!   members **atomically** through one
//!   [`TaskTable::reserve`](crate::sched::table::TaskTable::reserve)
//!   call (moldable: the gang widens to whatever is available up to
//!   `max`), emitting [`HqAction::StartGang`] when the set has more
//!   than one member;
//! * otherwise the frontier **holds**: strict head-of-line FCFS, no
//!   backfilling around an unsatisfiable gang — the same discipline
//!   strict EDF applies to its deadline head, here applied to width.
//!
//! Every release path — completion, transient failure, worker loss,
//! time-limit kill — frees *all* members through the shared table, so
//! no partial gang is ever observable ([`no_partial_gangs`]
//! (GangCore::no_partial_gangs) sweeps that invariant; the chaos suite
//! replays identical [`FaultPlan`](crate::campaign::FaultPlan) crash
//! traces against it).  Losing one member of an assembling or running
//! gang returns every surviving member's cores in the same transition
//! that requeues the task.
//!
//! Lifecycle (timers, records, autoalloc, Cooling/Retry) rides the
//! shared [`TaskTable`](crate::sched::table::TaskTable), so the stack
//! and the live balancer drive `GangCore` exactly like the other cores:
//! `uqsched campaign --scheduler gang` (via `MetaStack<GangCore>`) and
//! `uqsched balancer --scheduler gang` (via
//! [`LiveSched`](crate::sched::LiveSched), width 1..=1 per request —
//! the live front door dispatches single jobs).
//!
//! Cost (w = live workers, g = gang width): a dispatch attempt is O(w);
//! a started gang adds O(g log w) reservation work; completion frees
//! O(g) members.  See PERF.md.

use std::collections::VecDeque;

use crate::clock::Micros;
use crate::hqlite::{AutoAllocConfig, HqAction, HqTimer, TaskCore, TaskId,
                    TaskSpec, WorkerId};
use crate::sched::table::{slot_of, FailVerdict, TaskState, TaskTable,
                          TimerVerdict};

/// The moldable gang scheduler.
pub struct GangCore {
    /// Shared task/worker lifecycle engine.
    table: TaskTable,
    /// Strict FCFS frontier.  May lazily contain ids of tasks evicted
    /// while queued; dropped when next at the head.
    queue: VecDeque<TaskId>,
    /// Per-task moldable width `(min, max)`, indexed by the task id's
    /// slab *slot* (see [`slot_of`]).  A slot is only re-read after the
    /// table re-admits into it, which overwrites the entry first, so no
    /// removal bookkeeping is needed — Cooling tasks keep their width
    /// for the retry for free.
    bounds: Vec<(u32, u32)>,
    /// Width assigned to tasks submitted through the width-less
    /// [`TaskCore::submit_task_into`] seam (stack/balancer drivers).
    default_bounds: (u32, u32),
    /// Reusable member scratch for dispatch passes.
    members: Vec<WorkerId>,
}

impl GangCore {
    /// A gang core whose plain submissions are single-worker
    /// (`1..=1`) — drop-in for the existing driver seams.
    pub fn new(cfg: AutoAllocConfig) -> Self {
        GangCore {
            table: TaskTable::new(cfg),
            queue: VecDeque::new(),
            bounds: Vec::new(),
            default_bounds: (1, 1),
            members: Vec::new(),
        }
    }

    /// Set the moldable width `min..=max` applied to plain
    /// [`TaskCore::submit_task_into`] submissions (both clamped to at
    /// least 1; `max` to at least `min`).
    pub fn with_gang(mut self, min: u32, max: u32) -> Self {
        let min = min.max(1);
        self.default_bounds = (min, max.max(min));
        self
    }

    /// Stats: dispatches performed (a gang counts once).
    pub fn dispatches(&self) -> u64 {
        self.table.dispatches()
    }

    /// Submit a task with an explicit moldable width `min..=max`.
    pub fn submit_gang_task_into(
        &mut self,
        t: Micros,
        spec: TaskSpec,
        min: u32,
        max: u32,
        out: &mut Vec<HqAction>,
    ) -> TaskId {
        let min = min.max(1);
        let max = max.max(min);
        let id = self.table.admit(t, spec);
        let slot = slot_of(id);
        if slot >= self.bounds.len() {
            self.bounds.resize(slot + 1, self.default_bounds);
        }
        self.bounds[slot] = (min, max);
        self.queue.push_back(id);
        self.pump(t, out);
        id
    }

    /// The all-slots-or-none invariant, swept over every resident task:
    /// a Dispatched/Running gang holds a slot on *every* one of its
    /// members (each member is live and lists the task as running), and
    /// a Pending/Cooling task holds none.  The chaos suite calls this
    /// after every fault event.
    pub fn no_partial_gangs(&self) -> bool {
        self.table.iter_tasks().all(|(id, task)| match task.state {
            TaskState::Dispatched | TaskState::Running => {
                !task.workers.is_empty()
                    && task.workers.iter().all(|&m| {
                        self.table
                            .worker(m)
                            .map_or(false, |w| w.running.contains(&id))
                    })
            }
            TaskState::Pending | TaskState::Cooling => {
                task.workers.is_empty()
            }
        })
    }

    /// Workers currently reserved by `id` (empty unless in flight).
    pub fn gang_of(&self, id: TaskId) -> Vec<WorkerId> {
        self.table
            .task(id)
            .map(|task| task.workers.clone())
            .unwrap_or_default()
    }

    /// Strict head-of-line dispatch: assemble the head's gang or hold
    /// the frontier; then autoalloc tops up capacity for whatever is
    /// still pending.
    fn pump(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        loop {
            let Some(&front) = self.queue.front() else { break };
            if !self.table.is_pending(front) {
                // Stale entry: evicted while queued (live-plane cancel).
                self.queue.pop_front();
                continue;
            }
            let (min, max) = self
                .bounds
                .get(slot_of(front))
                .copied()
                .unwrap_or(self.default_bounds);
            self.members.clear();
            for wid in self.table.worker_ids() {
                if self.members.len() as u32 >= max {
                    break;
                }
                if self.table.can_start(t, front, wid) {
                    self.members.push(wid);
                }
            }
            if (self.members.len() as u32) < min {
                // Frontier holds: no backfilling around an
                // unsatisfiable gang.
                break;
            }
            self.queue.pop_front();
            // Atomic: every member's slots are taken in one table
            // transition — no assembly window with a partial gang.
            let members = std::mem::take(&mut self.members);
            self.table.reserve(t, front, &members, out);
            self.members = members;
        }
        self.table.autoalloc_into(out);
    }
}

impl TaskCore for GangCore {
    fn submit_task_into(
        &mut self,
        t: Micros,
        spec: TaskSpec,
        out: &mut Vec<HqAction>,
    ) -> TaskId {
        let (min, max) = self.default_bounds;
        self.submit_gang_task_into(t, spec, min, max, out)
    }

    fn on_alloc_up_into(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
        out: &mut Vec<HqAction>,
    ) -> Option<WorkerId> {
        let first = self
            .table
            .admit_workers(t, time_limit, cores_per_worker)
            .first()
            .copied();
        self.pump(t, out);
        first
    }

    fn on_worker_lost_into(
        &mut self,
        t: Micros,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    ) {
        // A lost member takes the whole gang down: the table frees every
        // surviving member's slots in the same transition that requeues
        // the task (ascending id order, deterministic).
        for id in self.table.worker_lost(wid, out) {
            self.queue.push_back(id);
        }
        self.pump(t, out);
    }

    fn on_task_done_into(&mut self, t: Micros, id: TaskId,
                         out: &mut Vec<HqAction>) {
        // A stale duplicate completion (the driver's original done-timer
        // firing after a requeue) misses the table: no pump.
        if self.table.complete(t, id, false, out) {
            self.pump(t, out);
        }
    }

    fn on_timer_into(&mut self, t: Micros, timer: HqTimer,
                     out: &mut Vec<HqAction>) {
        match self.table.timer(t, timer, out) {
            TimerVerdict::Ignored | TimerVerdict::Started => {}
            TimerVerdict::Killed => self.pump(t, out),
            TimerVerdict::Requeue(id) => {
                self.queue.push_back(id);
                self.pump(t, out);
            }
        }
    }

    fn on_task_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) {
        match self.table.fail(t, id, retry_in, out) {
            FailVerdict::Ignored => {}
            // Cooling keeps its width for the retry (slot entry stays).
            FailVerdict::Killed | FailVerdict::Cooling => self.pump(t, out),
        }
    }

    fn task_live(&self, id: TaskId) -> bool {
        self.table.task_live(id)
    }

    fn live_worker_ids_into(&self, out: &mut Vec<u64>) {
        self.table.live_worker_ids_into(out);
    }

    fn expire_workers_into(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        for wid in self.table.expire_due(t) {
            self.on_worker_lost_into(t, wid, out);
        }
    }

    fn pending_tasks(&self) -> usize {
        self.table.pending_tasks()
    }

    fn live_workers(&self) -> usize {
        self.table.live_workers()
    }

    fn allocs_waiting(&self) -> u32 {
        self.table.allocs_waiting()
    }

    fn resident_tasks(&self) -> usize {
        self.table.resident_tasks()
    }

    fn retired_count(&self) -> u64 {
        self.table.retired_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{MS, SEC};
    use crate::cluster::JobRequest;

    fn cfg(max_workers: u32) -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 2,
            workers_per_alloc: 1,
            max_worker_count: max_workers,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: 1 * MS,
        }
    }

    fn spec(tag: u64, cores: u32) -> TaskSpec {
        TaskSpec {
            tag,
            cores,
            time_request: SEC,
            time_limit: 100 * SEC,
        }
    }

    fn gang_starts(out: &[HqAction]) -> Vec<(TaskId, Vec<WorkerId>)> {
        out.iter()
            .filter_map(|a| match a {
                HqAction::StartGang { task, workers } => {
                    Some((*task, workers.clone()))
                }
                _ => None,
            })
            .collect()
    }

    #[test]
    fn moldable_gang_takes_every_eligible_worker_up_to_max() {
        let mut core = GangCore::new(cfg(4));
        let mut out = Vec::new();
        let mut ws = Vec::new();
        for _ in 0..3 {
            let w = core
                .on_alloc_up_into(0, 3600 * SEC, 16, &mut out)
                .expect("worker admitted");
            ws.push(w);
        }
        let id = core.submit_gang_task_into(0, spec(1, 8), 2, 4, &mut out);
        // 3 workers live, max 4: the gang molds to width 3.
        assert_eq!(core.gang_of(id), ws);
        assert!(core.no_partial_gangs());
        // The StartGang action lists every member once dispatched.
        out.clear();
        core.on_timer_into(1 * MS, HqTimer::Dispatched(id), &mut out);
        assert_eq!(gang_starts(&out), vec![(id, ws.clone())]);
        // Completion releases all three members' slots.
        out.clear();
        core.on_task_done_into(SEC, id, &mut out);
        assert!(core.no_partial_gangs());
        assert_eq!(core.resident_tasks(), 0);
        assert_eq!(core.retired_count(), 1);
    }

    #[test]
    fn frontier_holds_until_min_workers_are_eligible() {
        let mut core = GangCore::new(cfg(4));
        let mut out = Vec::new();
        let w1 = core
            .on_alloc_up_into(0, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        let id = core.submit_gang_task_into(0, spec(1, 16), 2, 2, &mut out);
        // Only one worker up: the gang must hold, all slots free.
        assert!(core.gang_of(id).is_empty());
        assert_eq!(core.pending_tasks(), 1);
        assert!(core.no_partial_gangs());
        // Strict head-of-line: a 1-wide newcomer must NOT overtake it.
        let solo = core.submit_gang_task_into(1, spec(2, 1), 1, 1, &mut out);
        assert!(core.gang_of(solo).is_empty(), "no backfill past the gang");
        // Second worker arrives: the head assembles atomically.
        out.clear();
        let w2 = core
            .on_alloc_up_into(2, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        assert_eq!(core.gang_of(id), vec![w1, w2]);
        // The 16-core gang filled both workers, so the solo task still
        // waits — it was held by FCFS before, by capacity now.
        assert!(core.gang_of(solo).is_empty());
        assert!(core.no_partial_gangs());
    }

    #[test]
    fn losing_a_member_releases_every_reserved_slot() {
        // Crash during gang assembly (the Dispatched latency window):
        // every reserved slot must come back, no partial gang remains.
        let mut core = GangCore::new(cfg(4));
        let mut out = Vec::new();
        let w1 = core
            .on_alloc_up_into(0, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        let w2 = core
            .on_alloc_up_into(0, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        let id = core.submit_gang_task_into(0, spec(1, 16), 2, 2, &mut out);
        assert_eq!(core.gang_of(id), vec![w1, w2]);
        // Member w2 dies before the Dispatched timer fires.
        out.clear();
        core.on_worker_lost_into(MS / 2, w2, &mut out);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::Requeued { task } if *task == id
        )));
        // Survivor's slots are fully released; the task is whole-pending.
        assert!(core.gang_of(id).is_empty());
        assert!(core.no_partial_gangs());
        assert_eq!(core.table.worker(w1).unwrap().cores_free, 16);
        // The stale Dispatched timer must not start a ghost gang.
        out.clear();
        core.on_timer_into(1 * MS, HqTimer::Dispatched(id), &mut out);
        assert!(gang_starts(&out).is_empty());
        assert!(core.no_partial_gangs());
        // A replacement worker restores width 2: the gang reassembles.
        out.clear();
        let w3 = core
            .on_alloc_up_into(SEC, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        assert_eq!(core.gang_of(id), vec![w1, w3]);
        assert!(core.no_partial_gangs());
    }

    #[test]
    fn transient_failure_parks_the_whole_gang_and_retries() {
        let mut core = GangCore::new(cfg(4));
        let mut out = Vec::new();
        let w1 = core
            .on_alloc_up_into(0, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        let w2 = core
            .on_alloc_up_into(0, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        let id = core.submit_gang_task_into(0, spec(1, 16), 2, 2, &mut out);
        core.on_timer_into(1 * MS, HqTimer::Dispatched(id), &mut out);
        // Mid-run transient failure: both members' cores come back.
        out.clear();
        core.on_task_failed_into(SEC, id, Some(5 * SEC), &mut out);
        assert!(core.gang_of(id).is_empty());
        assert!(core.no_partial_gangs());
        assert_eq!(core.table.worker(w1).unwrap().cores_free, 16);
        assert_eq!(core.table.worker(w2).unwrap().cores_free, 16);
        // Retry fires: the gang reassembles at full width.
        out.clear();
        core.on_timer_into(6 * SEC, HqTimer::Retry(id), &mut out);
        assert_eq!(core.gang_of(id), vec![w1, w2]);
        assert!(core.no_partial_gangs());
    }

    #[test]
    fn width_one_gang_degenerates_to_fcfs() {
        // The live plane runs GangCore with width 1..=1: plain FCFS
        // single-worker dispatch, StartTask (not StartGang) actions.
        let mut core = GangCore::new(cfg(2)).with_gang(1, 1);
        let mut out = Vec::new();
        let w1 = core
            .on_alloc_up_into(0, 3600 * SEC, 16, &mut out)
            .expect("worker admitted");
        let a = core.submit_task_into(0, spec(1, 16), &mut out);
        let b = core.submit_task_into(0, spec(2, 16), &mut out);
        assert_eq!(core.gang_of(a), vec![w1]);
        assert!(core.gang_of(b).is_empty());
        out.clear();
        core.on_timer_into(1 * MS, HqTimer::Dispatched(a), &mut out);
        assert!(out.iter().any(|x| matches!(
            x,
            HqAction::StartTask { task, worker } if *task == a && *worker == w1
        )), "single-member gangs start as plain StartTask: {out:?}");
        // a completes; b follows in FCFS order.
        out.clear();
        core.on_task_done_into(SEC, a, &mut out);
        assert_eq!(core.gang_of(b), vec![w1]);
        assert_eq!(core.retired_count(), 1);
    }

    #[test]
    fn autoalloc_tops_up_for_a_held_gang() {
        let mut core = GangCore::new(cfg(4));
        let mut out = Vec::new();
        // Width-3 gang with no workers: autoalloc must ask for capacity
        // (backlog=2 caps the queued allocations).
        let id = core.submit_gang_task_into(0, spec(1, 16), 3, 3, &mut out);
        let allocs = out.iter().filter(|a| matches!(
            a,
            HqAction::SubmitAllocation { .. }
        )).count();
        assert_eq!(allocs, 2);
        // Workers arrive one by one; the gang assembles only at three.
        out.clear();
        let mut ws = Vec::new();
        ws.push(core.on_alloc_up_into(1, 3600 * SEC, 16, &mut out).unwrap());
        ws.push(core.on_alloc_up_into(2, 3600 * SEC, 16, &mut out).unwrap());
        assert_eq!(core.pending_tasks(), 1, "held below min width");
        ws.push(core.on_alloc_up_into(3, 3600 * SEC, 16, &mut out).unwrap());
        assert_eq!(core.gang_of(id), ws);
        assert!(core.no_partial_gangs());
    }
}
