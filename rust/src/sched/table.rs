//! [`TaskTable`]: the shared task/worker lifecycle engine under every
//! HQ-family scheduler core.
//!
//! Before this module, `hqlite/core.rs`, `sched/worksteal.rs` and
//! `sched/edf.rs` each hand-maintained near-identical copies of the task
//! lifecycle: the task/worker structs, the dispatch-latency and
//! time-limit timers, completion records, alloc-up bookkeeping and
//! autoalloc, and the Cooling/Retry recovery machinery.  The table owns
//! all of that exactly once; a core shrinks to its *ready structure*
//! (FCFS queue, per-worker deques, deadline heap, gang frontier) plus a
//! *placement policy*, and calls back into the table for every state
//! transition.
//!
//! ```text
//!   HqCore ─┐                      ┌─ tasks: id -> TableTask
//!   WorkStealCore ─┤               │  workers: id -> TableWorker
//!   EdfCore ─┼──> TaskTable ──────>│  expiry min-heap, autoalloc
//!   GangCore ─┘   (lifecycle)      │  Dispatched/Limit/Retry timers
//!                                  └─ completion records, Requeued
//! ```
//!
//! Placement is a worker *set*: [`TableTask::workers`] holds every
//! worker whose cores the task occupies.  The single-worker cores always
//! reserve one-element sets; [`GangCore`](crate::sched::GangCore)
//! reserves moldable multi-worker gangs atomically through the same
//! [`reserve`](TaskTable::reserve) call, and every release path
//! (completion, failure, worker loss) frees *all* members — the
//! all-slots-or-none invariant the chaos suite pins.
//!
//! Behavioral-compatibility notes (the refactor is pinned record-for-
//! record by `tests/scheduler_props.rs` and `tests/campaign_equiv.rs`):
//!
//! * `pending` counts live `Pending` tasks.  It replaces `HqCore`'s
//!   `queue.len() - stale_in_queue` arithmetic — equivalent because every
//!   live Pending task sits in the FCFS queue exactly once and a task
//!   completed while requeued leaves exactly one stale entry behind.
//! * The `Limit` timer guard is configurable: the HQ and work-stealing
//!   cores kill any `Running` task (state-only guard), while the EDF core
//!   kills only when the timer armed for *this* run fires
//!   (`start_t + time_limit == now`) — [`TaskTable::with_exact_limit`].
//! * Arithmetic on virtual time saturates, matching `EdfCore`; for the
//!   other cores this is identical to the previous unchecked additions on
//!   every non-degenerate input.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, BTreeSet, HashMap};
use std::ops::Range;

use crate::clock::Micros;
use crate::hqlite::core::drain_due_workers;
use crate::hqlite::{AutoAllocConfig, HqAction, HqTimer, TaskId, TaskSpec,
                    WorkerId};
use crate::metrics::JobRecord;

/// Task lifecycle states, shared by every core riding the table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting in some core's ready structure.
    Pending,
    /// Slots reserved; the dispatch-latency timer is in flight.
    Dispatched,
    /// Started on its worker set; the limit timer is armed.
    Running,
    /// Failed transiently; off every worker, waiting out its retry
    /// backoff (re-enters the core's ready structure when `Retry` fires).
    Cooling,
}

/// One in-flight task (finished tasks are evicted from the table).
#[derive(Clone, Debug)]
pub struct TableTask {
    /// The submitted spec (tag, cores per worker, time request/limit).
    pub spec: TaskSpec,
    /// Current lifecycle state.
    pub state: TaskState,
    /// Submission time.
    pub submit_t: Micros,
    /// Start time of the current run (0 until first started).
    pub start_t: Micros,
    /// Workers whose cores this task currently occupies: empty while
    /// Pending/Cooling, one entry for single-worker cores, the full gang
    /// for moldable tasks.
    pub workers: Vec<WorkerId>,
    /// Absolute deadline, `submit_t + time_limit`, fixed at submission
    /// (requeues keep it — what makes EDF starvation-free).
    pub deadline: Micros,
}

/// One live worker (lost/expired workers leave the map).
#[derive(Clone, Debug)]
pub struct TableWorker {
    /// Cores this worker was provisioned with.
    pub cores_total: u32,
    /// Cores currently unreserved.
    pub cores_free: u32,
    /// Virtual time at which the surrounding allocation expires.
    pub expires_t: Micros,
    /// Tasks currently dispatched to / running on this worker.
    pub running: BTreeSet<TaskId>,
}

/// Outcome of [`TaskTable::timer`]; tells the core whether to pump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimerVerdict {
    /// Stale timer (evicted/requeued task); nothing happened.
    Ignored,
    /// `Dispatched` elapsed: the task is now Running, `StartTask` /
    /// `StartGang` and its limit timer were emitted.  No pump needed.
    Started,
    /// `Limit` fired on a running task: killed, truncated completion
    /// emitted, slots freed ([`TaskTable::freed`]).  The core must pump.
    Killed,
    /// `Retry` fired: the task is Pending again.  The core must re-enter
    /// it into its ready structure and pump.
    Requeue(TaskId),
}

/// Outcome of [`TaskTable::fail`]; tells the core whether to pump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailVerdict {
    /// Task absent or not in-flight; nothing happened.
    Ignored,
    /// Retry budget exhausted: killed, truncated completion emitted,
    /// slots freed ([`TaskTable::freed`]).  The core must pump.
    Killed,
    /// Transient failure: all slots released ([`TaskTable::freed`]), the
    /// task is Cooling and a `Retry` timer was emitted.  The core must
    /// pump.
    Cooling,
}

/// The shared lifecycle engine.  See the module docs for the seam.
pub struct TaskTable {
    cfg: AutoAllocConfig,
    /// In-flight tasks only; finished tasks are evicted.
    tasks: HashMap<TaskId, TableTask>,
    /// Live workers, id-ordered for deterministic scans.
    workers: BTreeMap<WorkerId, TableWorker>,
    /// (expires_t, worker) min-heap; entries for already-lost workers
    /// are skipped lazily.
    expiry: BinaryHeap<Reverse<(Micros, WorkerId)>>,
    /// Live tasks currently in the Pending state — drives autoalloc.
    pending: usize,
    retired: u64,
    next_task: TaskId,
    next_worker: WorkerId,
    next_alloc_tag: u64,
    allocs_in_queue: u32,
    /// EDF semantics: a `Limit` timer kills only if it is the one armed
    /// for the current run (`start_t + time_limit == now`).
    limit_exact: bool,
    /// Workers whose cores the last `complete`/`fail` released — read via
    /// [`freed`](TaskTable::freed) so cores can re-index availability
    /// without a per-event allocation.
    freed_scratch: Vec<WorkerId>,
    /// Stats: dispatches performed (a gang counts once).
    dispatches: u64,
}

impl TaskTable {
    /// A fresh table with the state-only limit guard (HQ semantics).
    pub fn new(cfg: AutoAllocConfig) -> Self {
        TaskTable {
            cfg,
            tasks: HashMap::new(),
            workers: BTreeMap::new(),
            expiry: BinaryHeap::new(),
            pending: 0,
            retired: 0,
            next_task: 1,
            next_worker: 1,
            next_alloc_tag: 1,
            allocs_in_queue: 0,
            limit_exact: false,
            freed_scratch: Vec::new(),
            dispatches: 0,
        }
    }

    /// Switch to the exact limit guard: a `Limit` timer kills only when
    /// it fires at precisely `start_t + time_limit` for the current run
    /// (EDF semantics — a stale limit from a pre-requeue run must not
    /// truncate the rerun).
    pub fn with_exact_limit(mut self) -> Self {
        self.limit_exact = true;
        self
    }

    // ---- admission ------------------------------------------------------

    /// Admit a task as Pending; the caller enqueues the returned id into
    /// its ready structure.
    pub fn admit(&mut self, t: Micros, spec: TaskSpec) -> TaskId {
        let id = self.next_task;
        self.next_task += 1;
        let deadline = t.saturating_add(spec.time_limit);
        self.tasks.insert(
            id,
            TableTask {
                spec,
                state: TaskState::Pending,
                submit_t: t,
                start_t: 0,
                workers: Vec::new(),
                deadline,
            },
        );
        self.pending += 1;
        id
    }

    /// A native allocation came up: start `workers_per_alloc` workers
    /// (bounded by `max_worker_count`), each living until the
    /// allocation's time limit.  Returns the new worker-id range so the
    /// caller can index them (availability sets, private deques).
    pub fn admit_workers(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
    ) -> Range<WorkerId> {
        self.allocs_in_queue = self.allocs_in_queue.saturating_sub(1);
        let first = self.next_worker;
        for _ in 0..self.cfg.workers_per_alloc {
            if self.workers.len() as u32 >= self.cfg.max_worker_count {
                break;
            }
            let wid = self.next_worker;
            self.next_worker += 1;
            let expires_t = t.saturating_add(time_limit);
            self.workers.insert(
                wid,
                TableWorker {
                    cores_total: cores_per_worker,
                    cores_free: cores_per_worker,
                    expires_t,
                    running: BTreeSet::new(),
                },
            );
            self.expiry.push(Reverse((expires_t, wid)));
        }
        first..self.next_worker
    }

    /// Submit allocations while there are pending tasks, the backlog
    /// allows it, and the worker cap is not reached (hqlite semantics).
    pub fn autoalloc_into(&mut self, out: &mut Vec<HqAction>) {
        while self.pending > 0
            && self.allocs_in_queue < self.cfg.backlog
            && self.workers.len() as u32
                + self.allocs_in_queue * self.cfg.workers_per_alloc
                < self.cfg.max_worker_count
        {
            self.allocs_in_queue += 1;
            let tag = self.next_alloc_tag;
            self.next_alloc_tag += 1;
            out.push(HqAction::SubmitAllocation {
                alloc_tag: tag,
                req: self.cfg.alloc_request,
            });
        }
    }

    // ---- dispatch -------------------------------------------------------

    /// Can `wid` host `id` right now?  Needs `spec.cores` free and an
    /// allocation outliving the task's time request (HQ semantics).
    /// False for unknown tasks/workers.
    pub fn can_start(&self, t: Micros, id: TaskId, wid: WorkerId) -> bool {
        let (Some(task), Some(w)) = (self.tasks.get(&id), self.workers.get(&wid))
        else {
            return false;
        };
        w.cores_free >= task.spec.cores
            && w.expires_t >= t.saturating_add(task.spec.time_request)
    }

    /// Atomically reserve `spec.cores` on *every* member for a Pending
    /// task (capacity already checked by the core's placement policy) and
    /// arm the dispatch-latency timer.  Single-worker cores pass one
    /// member; `GangCore` passes the whole gang — all slots are taken in
    /// one transition, so no partial gang is ever observable.
    pub fn reserve(
        &mut self,
        t: Micros,
        id: TaskId,
        members: &[WorkerId],
        out: &mut Vec<HqAction>,
    ) {
        debug_assert!(!members.is_empty(), "reserve with an empty gang");
        let task = self.tasks.get_mut(&id).expect("reserve: unknown task");
        debug_assert_eq!(task.state, TaskState::Pending);
        let need = task.spec.cores;
        task.state = TaskState::Dispatched;
        task.workers = members.to_vec();
        for &wid in members {
            let w = self.workers.get_mut(&wid).expect("reserve: dead worker");
            w.cores_free -= need;
            w.running.insert(id);
        }
        self.pending -= 1;
        self.dispatches += 1;
        out.push(HqAction::Timer(
            t.saturating_add(self.cfg.dispatch_latency),
            HqTimer::Dispatched(id),
        ));
    }

    // ---- release paths --------------------------------------------------

    /// Remove a worker.  Every task it hosted releases *all* of its slots
    /// (gang members on other workers included), turns Pending, and emits
    /// [`HqAction::Requeued`] — in ascending task-id order.  Returns the
    /// requeued ids for the core to re-enter into its ready structure.
    pub fn worker_lost(
        &mut self,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    ) -> Vec<TaskId> {
        let mut requeued = Vec::new();
        if let Some(worker) = self.workers.remove(&wid) {
            for id in worker.running {
                let Some(task) = self.tasks.get_mut(&id) else { continue };
                if !matches!(
                    task.state,
                    TaskState::Running | TaskState::Dispatched
                ) {
                    continue;
                }
                let need = task.spec.cores;
                for &m in &task.workers {
                    if m == wid {
                        continue; // the dead worker's slots died with it
                    }
                    if let Some(w) = self.workers.get_mut(&m) {
                        if w.running.remove(&id) {
                            w.cores_free += need;
                        }
                    }
                }
                task.workers.clear();
                task.state = TaskState::Pending;
                self.pending += 1;
                out.push(HqAction::Requeued { task: id });
                requeued.push(id);
            }
        }
        requeued
    }

    /// Complete a task: evict it, emit its [`JobRecord`], free every
    /// member's cores.  Returns false for a stale id (already evicted —
    /// e.g. the driver's original done-timer firing after a requeue).
    /// On true the core must pump; [`freed`](TaskTable::freed) lists the
    /// workers whose cores were released.
    pub fn complete(
        &mut self,
        t: Micros,
        id: TaskId,
        truncated: bool,
        out: &mut Vec<HqAction>,
    ) -> bool {
        self.freed_scratch.clear();
        let Some(task) = self.tasks.remove(&id) else { return false };
        if task.state == TaskState::Pending {
            // Completed while requeued: its ready-structure entry is now
            // stale and the owning core drops it lazily.
            self.pending -= 1;
        }
        self.retired += 1;
        let record = JobRecord {
            tag: task.spec.tag,
            submit: task.submit_t,
            start: task.start_t,
            end: t,
            // HQ CPU time: from task start on the worker (includes the
            // model-server init the driver folds into the duration).
            cpu: t.saturating_sub(task.start_t),
            truncated,
        };
        for &m in &task.workers {
            if let Some(w) = self.workers.get_mut(&m) {
                if w.running.remove(&id) {
                    w.cores_free += task.spec.cores;
                    self.freed_scratch.push(m);
                }
            }
        }
        out.push(HqAction::TaskCompleted { task: id, record });
        true
    }

    /// The task's attempt failed mid-run.  `Some(backoff)`: release every
    /// slot, park the task Cooling, arm `Retry`, emit `Requeued`.
    /// `None`: quarantine — kill and emit a truncated completion so the
    /// poison task is reported, never dropped.
    pub fn fail(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) -> FailVerdict {
        let Some(task) = self.tasks.get_mut(&id) else {
            return FailVerdict::Ignored;
        };
        if !matches!(task.state, TaskState::Dispatched | TaskState::Running) {
            return FailVerdict::Ignored;
        }
        match retry_in {
            None => {
                out.push(HqAction::KillTask { task: id });
                self.complete(t, id, true, out);
                FailVerdict::Killed
            }
            Some(backoff) => {
                let need = task.spec.cores;
                task.state = TaskState::Cooling;
                let members = std::mem::take(&mut task.workers);
                self.freed_scratch.clear();
                for &m in &members {
                    if let Some(w) = self.workers.get_mut(&m) {
                        if w.running.remove(&id) {
                            w.cores_free += need;
                            self.freed_scratch.push(m);
                        }
                    }
                }
                out.push(HqAction::Requeued { task: id });
                out.push(HqAction::Timer(
                    t.saturating_add(backoff),
                    HqTimer::Retry(id),
                ));
                FailVerdict::Cooling
            }
        }
    }

    /// Dispatch one timer.  See [`TimerVerdict`] for what the core must
    /// do afterwards.
    pub fn timer(
        &mut self,
        t: Micros,
        timer: HqTimer,
        out: &mut Vec<HqAction>,
    ) -> TimerVerdict {
        match timer {
            HqTimer::Dispatched(id) => {
                let Some(task) = self.tasks.get_mut(&id) else {
                    return TimerVerdict::Ignored;
                };
                if task.state != TaskState::Dispatched {
                    return TimerVerdict::Ignored;
                }
                task.state = TaskState::Running;
                task.start_t = t;
                let limit = task.spec.time_limit;
                match task.workers.as_slice() {
                    [worker] => out.push(HqAction::StartTask {
                        task: id,
                        worker: *worker,
                    }),
                    gang => out.push(HqAction::StartGang {
                        task: id,
                        workers: gang.to_vec(),
                    }),
                }
                out.push(HqAction::Timer(
                    t.saturating_add(limit),
                    HqTimer::Limit(id),
                ));
                TimerVerdict::Started
            }
            HqTimer::Limit(id) => {
                let due = self
                    .tasks
                    .get(&id)
                    .filter(|task| task.state == TaskState::Running)
                    .map(|task| {
                        task.start_t.saturating_add(task.spec.time_limit)
                    });
                let kill = match due {
                    Some(d) => !self.limit_exact || d == t,
                    None => false,
                };
                if kill {
                    out.push(HqAction::KillTask { task: id });
                    self.complete(t, id, true, out);
                    TimerVerdict::Killed
                } else {
                    TimerVerdict::Ignored
                }
            }
            HqTimer::Retry(id) => {
                let Some(task) = self.tasks.get_mut(&id) else {
                    return TimerVerdict::Ignored;
                };
                if task.state != TaskState::Cooling {
                    return TimerVerdict::Ignored;
                }
                task.state = TaskState::Pending;
                self.pending += 1;
                TimerVerdict::Requeue(id)
            }
        }
    }

    /// Pop every worker whose allocation lapsed at or before `t`; the
    /// core routes each through its worker-lost path.
    pub fn expire_due(&mut self, t: Micros) -> Vec<WorkerId> {
        drain_due_workers(&mut self.expiry, t, |wid| {
            self.workers.contains_key(&wid)
        })
    }

    // ---- introspection --------------------------------------------------

    /// Workers whose cores the last `complete`/`fail`/`timer(Limit)`
    /// call released (cores may still be partially busy).
    pub fn freed(&self) -> &[WorkerId] {
        &self.freed_scratch
    }

    /// The shared autoalloc configuration.
    pub fn cfg(&self) -> &AutoAllocConfig {
        &self.cfg
    }

    /// The task, if still in flight.
    pub fn task(&self, id: TaskId) -> Option<&TableTask> {
        self.tasks.get(&id)
    }

    /// Every resident (in-flight) task, unordered — invariant probes
    /// (e.g. [`GangCore::no_partial_gangs`](crate::sched::GangCore::no_partial_gangs))
    /// sweep this.
    pub fn iter_tasks(&self) -> impl Iterator<Item = (TaskId, &TableTask)> {
        self.tasks.iter().map(|(&id, task)| (id, task))
    }

    /// Is the task alive and waiting for dispatch?
    pub fn is_pending(&self, id: TaskId) -> bool {
        self.tasks.get(&id).map(|t| t.state) == Some(TaskState::Pending)
    }

    /// Is the task still resident (not yet completed)?
    pub fn task_live(&self, id: TaskId) -> bool {
        self.tasks.contains_key(&id)
    }

    /// The live-worker map, id-ordered (placement scans iterate this).
    pub fn workers_map(&self) -> &BTreeMap<WorkerId, TableWorker> {
        &self.workers
    }

    /// The worker, if live.
    pub fn worker(&self, wid: WorkerId) -> Option<&TableWorker> {
        self.workers.get(&wid)
    }

    /// Live tasks currently Pending.
    pub fn pending_tasks(&self) -> usize {
        self.pending
    }

    /// Live workers.
    pub fn live_workers(&self) -> usize {
        self.workers.len()
    }

    /// Allocations submitted to the native scheduler, not yet up.
    pub fn allocs_waiting(&self) -> u32 {
        self.allocs_in_queue
    }

    /// Tasks resident in the hot map (bounded by in-flight work).
    pub fn resident_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Tasks completed and evicted.
    pub fn retired_count(&self) -> u64 {
        self.retired
    }

    /// Dispatches performed (a gang counts once).
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Append the ids of live workers (crash-victim candidates for the
    /// fault plane), ascending.
    pub fn live_worker_ids_into(&self, out: &mut Vec<u64>) {
        out.extend(self.workers.keys().copied());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{MS, SEC};
    use crate::cluster::JobRequest;

    fn cfg() -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 2,
            workers_per_alloc: 1,
            max_worker_count: 4,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: MS,
        }
    }

    fn spec(tag: u64, cores: u32) -> TaskSpec {
        TaskSpec { tag, cores, time_request: SEC, time_limit: 100 * SEC }
    }

    #[test]
    fn gang_reserve_takes_and_releases_all_slots_atomically() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        tab.admit_workers(0, 3600 * SEC, 16);
        tab.admit_workers(0, 3600 * SEC, 16);
        let id = tab.admit(0, spec(1, 8));
        tab.reserve(0, id, &[1, 2], &mut out);
        assert_eq!(tab.worker(1).unwrap().cores_free, 8);
        assert_eq!(tab.worker(2).unwrap().cores_free, 8);
        assert!(tab.worker(1).unwrap().running.contains(&id));
        assert!(tab.worker(2).unwrap().running.contains(&id));
        // Completion frees every member.
        out.clear();
        assert!(tab.complete(SEC, id, false, &mut out));
        assert_eq!(tab.freed(), &[1, 2]);
        assert_eq!(tab.worker(1).unwrap().cores_free, 16);
        assert_eq!(tab.worker(2).unwrap().cores_free, 16);
    }

    #[test]
    fn losing_one_gang_member_releases_the_others() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        tab.admit_workers(0, 3600 * SEC, 16);
        tab.admit_workers(0, 3600 * SEC, 16);
        let id = tab.admit(0, spec(1, 16));
        tab.reserve(0, id, &[1, 2], &mut out);
        out.clear();
        let requeued = tab.worker_lost(1, &mut out);
        assert_eq!(requeued, vec![id]);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::Requeued { task } if *task == id
        )));
        // The surviving member's slots are back and hold nothing.
        let w2 = tab.worker(2).unwrap();
        assert_eq!(w2.cores_free, 16);
        assert!(w2.running.is_empty());
        assert!(tab.is_pending(id));
        assert_eq!(tab.task(id).unwrap().workers, Vec::<WorkerId>::new());
    }

    #[test]
    fn gang_start_action_lists_every_member() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        tab.admit_workers(0, 3600 * SEC, 16);
        tab.admit_workers(0, 3600 * SEC, 16);
        let id = tab.admit(0, spec(1, 4));
        tab.reserve(0, id, &[1, 2], &mut out);
        out.clear();
        assert_eq!(tab.timer(MS, HqTimer::Dispatched(id), &mut out),
                   TimerVerdict::Started);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::StartGang { task, workers }
                if *task == id && workers == &vec![1, 2]
        )));
        // Single-worker reservations still emit plain StartTask.
        let solo = tab.admit(0, spec(2, 4));
        tab.reserve(0, solo, &[1], &mut out);
        out.clear();
        tab.timer(2 * MS, HqTimer::Dispatched(solo), &mut out);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::StartTask { task, worker: 1 } if *task == solo
        )));
    }

    #[test]
    fn exact_limit_guard_ignores_stale_limits() {
        let mut tab = TaskTable::new(cfg()).with_exact_limit();
        let mut out = Vec::new();
        tab.admit_workers(0, 3600 * SEC, 16);
        let id = tab.admit(0, spec(1, 16));
        tab.reserve(0, id, &[1], &mut out);
        tab.timer(MS, HqTimer::Dispatched(id), &mut out);
        // A limit not matching start_t + time_limit is stale.
        out.clear();
        assert_eq!(tab.timer(50 * SEC, HqTimer::Limit(id), &mut out),
                   TimerVerdict::Ignored);
        assert!(out.is_empty());
        // The armed one (start_t = 1 ms) kills.
        assert_eq!(
            tab.timer(MS + 100 * SEC, HqTimer::Limit(id), &mut out),
            TimerVerdict::Killed
        );
        assert!(!tab.task_live(id));
    }

    #[test]
    fn pending_counter_tracks_requeue_retry_cycle() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        tab.admit_workers(0, 3600 * SEC, 16);
        let id = tab.admit(0, spec(1, 16));
        assert_eq!(tab.pending_tasks(), 1);
        tab.reserve(0, id, &[1], &mut out);
        assert_eq!(tab.pending_tasks(), 0);
        assert_eq!(tab.fail(MS, id, Some(SEC), &mut out),
                   FailVerdict::Cooling);
        assert_eq!(tab.freed(), &[1]);
        assert_eq!(tab.pending_tasks(), 0, "cooling is not pending");
        assert_eq!(tab.timer(MS + SEC, HqTimer::Retry(id), &mut out),
                   TimerVerdict::Requeue(id));
        assert_eq!(tab.pending_tasks(), 1);
        // Completing the task while Pending drops the counter.
        assert!(tab.complete(2 * SEC, id, false, &mut out));
        assert_eq!(tab.pending_tasks(), 0);
        assert_eq!(tab.retired_count(), 1);
    }
}
