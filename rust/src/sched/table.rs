//! [`TaskTable`]: the shared task/worker lifecycle engine under every
//! HQ-family scheduler core.
//!
//! Before this module, `hqlite/core.rs`, `sched/worksteal.rs` and
//! `sched/edf.rs` each hand-maintained near-identical copies of the task
//! lifecycle: the task/worker structs, the dispatch-latency and
//! time-limit timers, completion records, alloc-up bookkeeping and
//! autoalloc, and the Cooling/Retry recovery machinery.  The table owns
//! all of that exactly once; a core shrinks to its *ready structure*
//! (FCFS queue, per-worker deques, deadline heap, gang frontier) plus a
//! *placement policy*, and calls back into the table for every state
//! transition.
//!
//! # Slab-arena storage
//!
//! Tasks and workers live in dense, Vec-backed **generational slabs**
//! ([`Slab`]).  An id packs a slot index in its low [`SLOT_BITS`] bits
//! and a monotone sequence number above them:
//!
//! ```text
//!   id = (seq << 24) | slot          seq starts at 1, never reused
//!
//!   slots: [ (key, Some(T)) | (0, None) | (key, Some(T)) | ... ]
//!   free:  [ 1, ... ]                 <- evicted slots, LIFO reuse
//! ```
//!
//! * Lookup is one bounds check + one key compare — O(1), no hashing.
//! * Eviction pushes the slot onto the free list; the next admission
//!   reuses it under a **new** sequence number, so a stale id (old
//!   generation) can never resurrect: its key no longer matches and
//!   every table operation rejects it (`tests/core_fuzz.rs` pins this
//!   property on all five cores).
//! * Because the sequence number occupies the *high* bits, ascending id
//!   order is exactly admission order — the invariant the EDF tie-break,
//!   the lowest-id-first placement scans and the worker-lost requeue
//!   order all rely on.
//!
//! ```text
//!   HqCore ─┐                      ┌─ tasks: Slab<TableTask>
//!   WorkStealCore ─┤               │  workers: Slab<TableWorker>
//!   EdfCore ─┼──> TaskTable ──────>│  expiry min-heap, autoalloc
//!   GangCore ─┘   (lifecycle)      │  Dispatched/Limit/Retry timers
//!                                  └─ completion records, Requeued
//! ```
//!
//! Placement is a worker *set*: [`TableTask::workers`] holds every
//! worker whose cores the task occupies.  The single-worker cores always
//! reserve one-element sets; [`GangCore`](crate::sched::GangCore)
//! reserves moldable multi-worker gangs atomically through the same
//! [`reserve`](TaskTable::reserve) call, and every release path
//! (completion, failure, worker loss) frees *all* members — the
//! all-slots-or-none invariant the chaos suite pins.
//!
//! Steady-state operations are allocation-free: the member vectors of
//! evicted tasks are recycled through a bounded pool, worker `running`
//! sets are sorted vectors reusing their capacity, and `admit_workers`
//! returns a scratch slice instead of materialising a range.  The
//! counting-allocator rows in `BENCH_scale.json` (`allocs_per_task`)
//! assert the drain loop stays ≤ 2 allocations per task.
//!
//! Behavioral-compatibility notes (the refactor is pinned record-for-
//! record by `tests/scheduler_props.rs` and `tests/campaign_equiv.rs`):
//!
//! * `pending` counts live `Pending` tasks.  It replaces `HqCore`'s
//!   `queue.len() - stale_in_queue` arithmetic — equivalent because every
//!   live Pending task sits in the FCFS queue exactly once and a task
//!   completed while requeued leaves exactly one stale entry behind.
//! * The `Limit` timer guard is configurable: the HQ and work-stealing
//!   cores kill any `Running` task (state-only guard), while the EDF core
//!   kills only when the timer armed for *this* run fires
//!   (`start_t + time_limit == now`) — [`TaskTable::with_exact_limit`].
//! * Arithmetic on virtual time saturates, matching `EdfCore`; for the
//!   other cores this is identical to the previous unchecked additions on
//!   every non-degenerate input.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, BTreeSet};

use crate::clock::Micros;
use crate::hqlite::core::drain_due_workers;
use crate::hqlite::{AutoAllocConfig, HqAction, HqTimer, TaskId, TaskSpec,
                    WorkerId};
use crate::metrics::JobRecord;

/// Bits of an id reserved for the slab slot index (16M concurrent
/// residents per slab; the sequence number above has 40 bits — enough
/// for 10¹² admissions).
pub const SLOT_BITS: u32 = 24;
const SLOT_MASK: u64 = (1 << SLOT_BITS) - 1;

/// The slot index an id decodes to.  Cores use this to keep per-task
/// side tables as dense slot-indexed vectors instead of maps (a reused
/// slot is always re-initialised at admission before it can be read).
#[inline]
pub fn slot_of(id: u64) -> usize {
    (id & SLOT_MASK) as usize
}

/// A dense generational arena.  See the module docs for the id layout.
#[derive(Clone, Debug)]
pub struct Slab<T> {
    /// `(key, value)` per slot; `key == 0` marks a vacant slot (sequence
    /// numbers start at 1, so 0 is never a live id).
    slots: Vec<(u64, Option<T>)>,
    /// Vacant slot indices, reused LIFO.
    free: Vec<u32>,
    /// Next sequence number (generation); monotone, never reused.
    next_seq: u64,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab { slots: Vec::new(), free: Vec::new(), next_seq: 1, len: 0 }
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab::default()
    }

    /// Insert a value, returning its generational id.
    pub fn insert(&mut self, value: T) -> u64 {
        let slot = match self.free.pop() {
            Some(s) => s as usize,
            None => {
                self.slots.push((0, None));
                self.slots.len() - 1
            }
        };
        debug_assert!(slot as u64 <= SLOT_MASK, "slab slot space exhausted");
        let id = (self.next_seq << SLOT_BITS) | slot as u64;
        self.next_seq += 1;
        self.slots[slot] = (id, Some(value));
        self.len += 1;
        id
    }

    /// O(1) lookup; stale ids (old generation of a reused slot) miss.
    pub fn get(&self, id: u64) -> Option<&T> {
        match self.slots.get(slot_of(id)) {
            Some((key, Some(v))) if *key == id => Some(v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut T> {
        match self.slots.get_mut(slot_of(id)) {
            Some((key, Some(v))) if *key == id => Some(v),
            _ => None,
        }
    }

    /// Evict; the slot becomes reusable under the *next* generation.
    pub fn remove(&mut self, id: u64) -> Option<T> {
        let slot = slot_of(id);
        match self.slots.get_mut(slot) {
            Some(entry) if entry.0 == id && entry.1.is_some() => {
                entry.0 = 0;
                let v = entry.1.take();
                self.free.push(slot as u32);
                self.len -= 1;
                v
            }
            _ => None,
        }
    }

    pub fn contains(&self, id: u64) -> bool {
        self.get(id).is_some()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Every resident entry, in *slot* order (not id order).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &T)> {
        self.slots
            .iter()
            .filter_map(|(key, v)| v.as_ref().map(|v| (*key, v)))
    }
}

/// Task lifecycle states, shared by every core riding the table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting in some core's ready structure.
    Pending,
    /// Slots reserved; the dispatch-latency timer is in flight.
    Dispatched,
    /// Started on its worker set; the limit timer is armed.
    Running,
    /// Failed transiently; off every worker, waiting out its retry
    /// backoff (re-enters the core's ready structure when `Retry` fires).
    Cooling,
}

/// One in-flight task (finished tasks are evicted from the table).
#[derive(Clone, Debug)]
pub struct TableTask {
    /// The submitted spec (tag, cores per worker, time request/limit).
    pub spec: TaskSpec,
    /// Current lifecycle state.
    pub state: TaskState,
    /// Submission time.
    pub submit_t: Micros,
    /// Start time of the current run (0 until first started).
    pub start_t: Micros,
    /// Workers whose cores this task currently occupies: empty while
    /// Pending/Cooling, one entry for single-worker cores, the full gang
    /// for moldable tasks.  The backing vector is recycled through the
    /// table's pool when the task is evicted.
    pub workers: Vec<WorkerId>,
    /// Absolute deadline, `submit_t + time_limit`, fixed at submission
    /// (requeues keep it — what makes EDF starvation-free).
    pub deadline: Micros,
}

/// One live worker (lost/expired workers leave the slab).
#[derive(Clone, Debug)]
pub struct TableWorker {
    /// Cores this worker was provisioned with.
    pub cores_total: u32,
    /// Cores currently unreserved.
    pub cores_free: u32,
    /// Virtual time at which the surrounding allocation expires.
    pub expires_t: Micros,
    /// Tasks currently dispatched to / running on this worker — a sorted
    /// vector (ascending task id == admission order), so the worker-lost
    /// requeue order falls out of plain iteration.
    pub running: Vec<TaskId>,
}

/// Insert into a sorted id vector (no-op on duplicates).
fn sorted_insert(v: &mut Vec<u64>, id: u64) {
    if let Err(i) = v.binary_search(&id) {
        v.insert(i, id);
    }
}

/// Remove from a sorted id vector; true when the id was present.
fn sorted_remove(v: &mut Vec<u64>, id: u64) -> bool {
    match v.binary_search(&id) {
        Ok(i) => {
            v.remove(i);
            true
        }
        Err(_) => false,
    }
}

/// Outcome of [`TaskTable::timer`]; tells the core whether to pump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimerVerdict {
    /// Stale timer (evicted/requeued task); nothing happened.
    Ignored,
    /// `Dispatched` elapsed: the task is now Running, `StartTask` /
    /// `StartGang` and its limit timer were emitted.  No pump needed.
    Started,
    /// `Limit` fired on a running task: killed, truncated completion
    /// emitted, slots freed ([`TaskTable::freed`]).  The core must pump.
    Killed,
    /// `Retry` fired: the task is Pending again.  The core must re-enter
    /// it into its ready structure and pump.
    Requeue(TaskId),
}

/// Outcome of [`TaskTable::fail`]; tells the core whether to pump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailVerdict {
    /// Task absent or not in-flight; nothing happened.
    Ignored,
    /// Retry budget exhausted: killed, truncated completion emitted,
    /// slots freed ([`TaskTable::freed`]).  The core must pump.
    Killed,
    /// Transient failure: all slots released ([`TaskTable::freed`]), the
    /// task is Cooling and a `Retry` timer was emitted.  The core must
    /// pump.
    Cooling,
}

/// Cap on recycled member vectors kept for reuse.
const VEC_POOL_CAP: usize = 256;

/// The shared lifecycle engine.  See the module docs for the seam.
pub struct TaskTable {
    cfg: AutoAllocConfig,
    /// In-flight tasks only; finished tasks are evicted (slot reused).
    tasks: Slab<TableTask>,
    /// Live workers.
    workers: Slab<TableWorker>,
    /// Id-ordered index over live workers, for deterministic placement
    /// scans (the slab itself iterates in slot order).
    worker_index: BTreeSet<WorkerId>,
    /// (expires_t, worker) min-heap; entries for already-lost workers
    /// are skipped lazily.
    expiry: BinaryHeap<Reverse<(Micros, WorkerId)>>,
    /// Live tasks currently in the Pending state — drives autoalloc.
    pending: usize,
    retired: u64,
    next_alloc_tag: u64,
    allocs_in_queue: u32,
    /// EDF semantics: a `Limit` timer kills only if it is the one armed
    /// for the current run (`start_t + time_limit == now`).
    limit_exact: bool,
    /// Workers whose cores the last `complete`/`fail` released — read via
    /// [`freed`](TaskTable::freed) so cores can re-index availability
    /// without a per-event allocation.
    freed_scratch: Vec<WorkerId>,
    /// Ids admitted by the last `admit_workers` call (returned as a
    /// slice — no range arithmetic works on generational ids).
    admitted_scratch: Vec<WorkerId>,
    /// Recycled member vectors (capacity-preserving; bounded).
    vec_pool: Vec<Vec<WorkerId>>,
    /// Stats: dispatches performed (a gang counts once).
    dispatches: u64,
}

impl TaskTable {
    /// A fresh table with the state-only limit guard (HQ semantics).
    pub fn new(cfg: AutoAllocConfig) -> Self {
        TaskTable {
            cfg,
            tasks: Slab::new(),
            workers: Slab::new(),
            worker_index: BTreeSet::new(),
            expiry: BinaryHeap::new(),
            pending: 0,
            retired: 0,
            next_alloc_tag: 1,
            allocs_in_queue: 0,
            limit_exact: false,
            freed_scratch: Vec::new(),
            admitted_scratch: Vec::new(),
            vec_pool: Vec::new(),
            dispatches: 0,
        }
    }

    /// Switch to the exact limit guard: a `Limit` timer kills only when
    /// it fires at precisely `start_t + time_limit` for the current run
    /// (EDF semantics — a stale limit from a pre-requeue run must not
    /// truncate the rerun).
    pub fn with_exact_limit(mut self) -> Self {
        self.limit_exact = true;
        self
    }

    // ---- admission ------------------------------------------------------

    /// Admit a task as Pending; the caller enqueues the returned id into
    /// its ready structure.  Ids are generational slab keys whose
    /// ascending order is admission order.
    pub fn admit(&mut self, t: Micros, spec: TaskSpec) -> TaskId {
        let deadline = t.saturating_add(spec.time_limit);
        let workers = self.vec_pool.pop().unwrap_or_default();
        let id = self.tasks.insert(TableTask {
            spec,
            state: TaskState::Pending,
            submit_t: t,
            start_t: 0,
            workers,
            deadline,
        });
        self.pending += 1;
        id
    }

    /// A native allocation came up: start `workers_per_alloc` workers
    /// (bounded by `max_worker_count`), each living until the
    /// allocation's time limit.  Returns the new worker ids so the
    /// caller can index them (availability sets, private deques); the
    /// slice is scratch, valid until the next `admit_workers` call.
    pub fn admit_workers(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
    ) -> &[WorkerId] {
        self.allocs_in_queue = self.allocs_in_queue.saturating_sub(1);
        self.admitted_scratch.clear();
        for _ in 0..self.cfg.workers_per_alloc {
            if self.workers.len() as u32 >= self.cfg.max_worker_count {
                break;
            }
            let expires_t = t.saturating_add(time_limit);
            let wid = self.workers.insert(TableWorker {
                cores_total: cores_per_worker,
                cores_free: cores_per_worker,
                expires_t,
                running: Vec::new(),
            });
            self.worker_index.insert(wid);
            self.expiry.push(Reverse((expires_t, wid)));
            self.admitted_scratch.push(wid);
        }
        &self.admitted_scratch
    }

    /// Submit allocations while there are pending tasks, the backlog
    /// allows it, and the worker cap is not reached (hqlite semantics).
    pub fn autoalloc_into(&mut self, out: &mut Vec<HqAction>) {
        while self.pending > 0
            && self.allocs_in_queue < self.cfg.backlog
            && self.workers.len() as u32
                + self.allocs_in_queue * self.cfg.workers_per_alloc
                < self.cfg.max_worker_count
        {
            self.allocs_in_queue += 1;
            let tag = self.next_alloc_tag;
            self.next_alloc_tag += 1;
            out.push(HqAction::SubmitAllocation {
                alloc_tag: tag,
                req: self.cfg.alloc_request,
            });
        }
    }

    // ---- dispatch -------------------------------------------------------

    /// Can `wid` host `id` right now?  Needs `spec.cores` free and an
    /// allocation outliving the task's time request (HQ semantics).
    /// False for unknown (or stale-generation) tasks/workers.
    pub fn can_start(&self, t: Micros, id: TaskId, wid: WorkerId) -> bool {
        let (Some(task), Some(w)) = (self.tasks.get(id), self.workers.get(wid))
        else {
            return false;
        };
        w.cores_free >= task.spec.cores
            && w.expires_t >= t.saturating_add(task.spec.time_request)
    }

    /// Atomically reserve `spec.cores` on *every* member for a Pending
    /// task (capacity already checked by the core's placement policy) and
    /// arm the dispatch-latency timer.  Single-worker cores pass one
    /// member; `GangCore` passes the whole gang — all slots are taken in
    /// one transition, so no partial gang is ever observable.
    pub fn reserve(
        &mut self,
        t: Micros,
        id: TaskId,
        members: &[WorkerId],
        out: &mut Vec<HqAction>,
    ) {
        debug_assert!(!members.is_empty(), "reserve with an empty gang");
        let task = self.tasks.get_mut(id).expect("reserve: unknown task");
        debug_assert_eq!(task.state, TaskState::Pending);
        let need = task.spec.cores;
        task.state = TaskState::Dispatched;
        task.workers.clear();
        task.workers.extend_from_slice(members);
        for &wid in members {
            let w = self.workers.get_mut(wid).expect("reserve: dead worker");
            w.cores_free -= need;
            sorted_insert(&mut w.running, id);
        }
        self.pending -= 1;
        self.dispatches += 1;
        out.push(HqAction::Timer(
            t.saturating_add(self.cfg.dispatch_latency),
            HqTimer::Dispatched(id),
        ));
    }

    // ---- release paths --------------------------------------------------

    /// Remove a worker.  Every task it hosted releases *all* of its slots
    /// (gang members on other workers included), turns Pending, and emits
    /// [`HqAction::Requeued`] — in ascending task-id order.  Returns the
    /// requeued ids for the core to re-enter into its ready structure.
    pub fn worker_lost(
        &mut self,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    ) -> Vec<TaskId> {
        let mut requeued = Vec::new();
        if let Some(worker) = self.workers.remove(wid) {
            self.worker_index.remove(&wid);
            for id in worker.running {
                let Some(task) = self.tasks.get_mut(id) else { continue };
                if !matches!(
                    task.state,
                    TaskState::Running | TaskState::Dispatched
                ) {
                    continue;
                }
                let need = task.spec.cores;
                let members = std::mem::take(&mut task.workers);
                for &m in &members {
                    if m == wid {
                        continue; // the dead worker's slots died with it
                    }
                    if let Some(w) = self.workers.get_mut(m) {
                        if sorted_remove(&mut w.running, id) {
                            w.cores_free += need;
                        }
                    }
                }
                let task = self.tasks.get_mut(id).expect("task vanished");
                task.workers = members;
                task.workers.clear();
                task.state = TaskState::Pending;
                self.pending += 1;
                out.push(HqAction::Requeued { task: id });
                requeued.push(id);
            }
        }
        requeued
    }

    /// Complete a task: evict it, emit its [`JobRecord`], free every
    /// member's cores.  Returns false for a stale id (already evicted —
    /// e.g. the driver's original done-timer firing after a requeue).
    /// On true the core must pump; [`freed`](TaskTable::freed) lists the
    /// workers whose cores were released.
    pub fn complete(
        &mut self,
        t: Micros,
        id: TaskId,
        truncated: bool,
        out: &mut Vec<HqAction>,
    ) -> bool {
        self.freed_scratch.clear();
        let Some(task) = self.tasks.remove(id) else { return false };
        if task.state == TaskState::Pending {
            // Completed while requeued: its ready-structure entry is now
            // stale and the owning core drops it lazily.
            self.pending -= 1;
        }
        self.retired += 1;
        let record = JobRecord {
            tag: task.spec.tag,
            submit: task.submit_t,
            start: task.start_t,
            end: t,
            // HQ CPU time: from task start on the worker (includes the
            // model-server init the driver folds into the duration).
            cpu: t.saturating_sub(task.start_t),
            truncated,
        };
        let mut members = task.workers;
        for &m in &members {
            if let Some(w) = self.workers.get_mut(m) {
                if sorted_remove(&mut w.running, id) {
                    w.cores_free += task.spec.cores;
                    self.freed_scratch.push(m);
                }
            }
        }
        members.clear();
        if self.vec_pool.len() < VEC_POOL_CAP {
            self.vec_pool.push(members);
        }
        out.push(HqAction::TaskCompleted { task: id, record });
        true
    }

    /// The task's attempt failed mid-run.  `Some(backoff)`: release every
    /// slot, park the task Cooling, arm `Retry`, emit `Requeued`.
    /// `None`: quarantine — kill and emit a truncated completion so the
    /// poison task is reported, never dropped.
    pub fn fail(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) -> FailVerdict {
        let Some(task) = self.tasks.get_mut(id) else {
            return FailVerdict::Ignored;
        };
        if !matches!(task.state, TaskState::Dispatched | TaskState::Running) {
            return FailVerdict::Ignored;
        }
        match retry_in {
            None => {
                out.push(HqAction::KillTask { task: id });
                self.complete(t, id, true, out);
                FailVerdict::Killed
            }
            Some(backoff) => {
                let need = task.spec.cores;
                task.state = TaskState::Cooling;
                let mut members = std::mem::take(&mut task.workers);
                self.freed_scratch.clear();
                for &m in &members {
                    if let Some(w) = self.workers.get_mut(m) {
                        if sorted_remove(&mut w.running, id) {
                            w.cores_free += need;
                            self.freed_scratch.push(m);
                        }
                    }
                }
                // Hand the (cleared) vector back to the task so the
                // retry's reserve reuses its capacity.
                members.clear();
                if let Some(task) = self.tasks.get_mut(id) {
                    task.workers = members;
                }
                out.push(HqAction::Requeued { task: id });
                out.push(HqAction::Timer(
                    t.saturating_add(backoff),
                    HqTimer::Retry(id),
                ));
                FailVerdict::Cooling
            }
        }
    }

    /// Dispatch one timer.  See [`TimerVerdict`] for what the core must
    /// do afterwards.
    pub fn timer(
        &mut self,
        t: Micros,
        timer: HqTimer,
        out: &mut Vec<HqAction>,
    ) -> TimerVerdict {
        match timer {
            HqTimer::Dispatched(id) => {
                let Some(task) = self.tasks.get_mut(id) else {
                    return TimerVerdict::Ignored;
                };
                if task.state != TaskState::Dispatched {
                    return TimerVerdict::Ignored;
                }
                task.state = TaskState::Running;
                task.start_t = t;
                let limit = task.spec.time_limit;
                match task.workers.as_slice() {
                    [worker] => out.push(HqAction::StartTask {
                        task: id,
                        worker: *worker,
                    }),
                    gang => out.push(HqAction::StartGang {
                        task: id,
                        workers: gang.to_vec(),
                    }),
                }
                out.push(HqAction::Timer(
                    t.saturating_add(limit),
                    HqTimer::Limit(id),
                ));
                TimerVerdict::Started
            }
            HqTimer::Limit(id) => {
                let due = self
                    .tasks
                    .get(id)
                    .filter(|task| task.state == TaskState::Running)
                    .map(|task| {
                        task.start_t.saturating_add(task.spec.time_limit)
                    });
                let kill = match due {
                    Some(d) => !self.limit_exact || d == t,
                    None => false,
                };
                if kill {
                    out.push(HqAction::KillTask { task: id });
                    self.complete(t, id, true, out);
                    TimerVerdict::Killed
                } else {
                    TimerVerdict::Ignored
                }
            }
            HqTimer::Retry(id) => {
                let Some(task) = self.tasks.get_mut(id) else {
                    return TimerVerdict::Ignored;
                };
                if task.state != TaskState::Cooling {
                    return TimerVerdict::Ignored;
                }
                task.state = TaskState::Pending;
                self.pending += 1;
                TimerVerdict::Requeue(id)
            }
        }
    }

    /// Pop every worker whose allocation lapsed at or before `t`; the
    /// core routes each through its worker-lost path.
    pub fn expire_due(&mut self, t: Micros) -> Vec<WorkerId> {
        drain_due_workers(&mut self.expiry, t, |wid| {
            self.workers.contains(wid)
        })
    }

    // ---- introspection --------------------------------------------------

    /// Workers whose cores the last `complete`/`fail`/`timer(Limit)`
    /// call released (cores may still be partially busy).
    pub fn freed(&self) -> &[WorkerId] {
        &self.freed_scratch
    }

    /// The shared autoalloc configuration.
    pub fn cfg(&self) -> &AutoAllocConfig {
        &self.cfg
    }

    /// The task, if still in flight (stale generations miss).
    pub fn task(&self, id: TaskId) -> Option<&TableTask> {
        self.tasks.get(id)
    }

    /// Every resident (in-flight) task, slot order — invariant probes
    /// (e.g. [`GangCore::no_partial_gangs`](crate::sched::GangCore::no_partial_gangs))
    /// sweep this.
    pub fn iter_tasks(&self) -> impl Iterator<Item = (TaskId, &TableTask)> {
        self.tasks.iter()
    }

    /// Is the task alive and waiting for dispatch?
    pub fn is_pending(&self, id: TaskId) -> bool {
        self.tasks.get(id).map(|t| t.state) == Some(TaskState::Pending)
    }

    /// Is the task still resident (not yet completed)?
    pub fn task_live(&self, id: TaskId) -> bool {
        self.tasks.contains(id)
    }

    /// Live worker ids, ascending (placement scans iterate this; the
    /// first hit is the lowest-id candidate).
    pub fn worker_ids(&self) -> impl Iterator<Item = WorkerId> + '_ {
        self.worker_index.iter().copied()
    }

    /// The worker, if live (stale generations miss).
    pub fn worker(&self, wid: WorkerId) -> Option<&TableWorker> {
        self.workers.get(wid)
    }

    /// Live tasks currently Pending.
    pub fn pending_tasks(&self) -> usize {
        self.pending
    }

    /// Live workers.
    pub fn live_workers(&self) -> usize {
        self.workers.len()
    }

    /// Allocations submitted to the native scheduler, not yet up.
    pub fn allocs_waiting(&self) -> u32 {
        self.allocs_in_queue
    }

    /// Tasks resident in the hot map (bounded by in-flight work).
    pub fn resident_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Tasks completed and evicted.
    pub fn retired_count(&self) -> u64 {
        self.retired
    }

    /// Dispatches performed (a gang counts once).
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Append the ids of live workers (crash-victim candidates for the
    /// fault plane), ascending.
    pub fn live_worker_ids_into(&self, out: &mut Vec<u64>) {
        out.extend(self.worker_index.iter().copied());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{MS, SEC};
    use crate::cluster::JobRequest;

    fn cfg() -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 2,
            workers_per_alloc: 1,
            max_worker_count: 4,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: MS,
        }
    }

    fn spec(tag: u64, cores: u32) -> TaskSpec {
        TaskSpec { tag, cores, time_request: SEC, time_limit: 100 * SEC }
    }

    fn worker_up(tab: &mut TaskTable) -> WorkerId {
        tab.admit_workers(0, 3600 * SEC, 16)[0]
    }

    #[test]
    fn slab_ids_ascend_in_admission_order_and_reject_stale() {
        let mut slab: Slab<u32> = Slab::new();
        let a = slab.insert(10);
        let b = slab.insert(20);
        assert!(b > a, "admission order must be id order");
        assert_eq!(slab.get(a), Some(&10));
        assert_eq!(slab.remove(a), Some(10));
        // Slot reused under a fresh generation: higher id, same slot.
        let c = slab.insert(30);
        assert_eq!(slot_of(c), slot_of(a));
        assert!(c > b);
        // The stale id must miss every accessor.
        assert_eq!(slab.get(a), None);
        assert!(!slab.contains(a));
        assert_eq!(slab.get_mut(a), None);
        assert_eq!(slab.remove(a), None);
        assert_eq!(slab.get(c), Some(&30));
        assert_eq!(slab.len(), 2);
    }

    #[test]
    fn gang_reserve_takes_and_releases_all_slots_atomically() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        let w1 = worker_up(&mut tab);
        let w2 = worker_up(&mut tab);
        let id = tab.admit(0, spec(1, 8));
        tab.reserve(0, id, &[w1, w2], &mut out);
        assert_eq!(tab.worker(w1).unwrap().cores_free, 8);
        assert_eq!(tab.worker(w2).unwrap().cores_free, 8);
        assert!(tab.worker(w1).unwrap().running.contains(&id));
        assert!(tab.worker(w2).unwrap().running.contains(&id));
        // Completion frees every member.
        out.clear();
        assert!(tab.complete(SEC, id, false, &mut out));
        assert_eq!(tab.freed(), &[w1, w2]);
        assert_eq!(tab.worker(w1).unwrap().cores_free, 16);
        assert_eq!(tab.worker(w2).unwrap().cores_free, 16);
    }

    #[test]
    fn losing_one_gang_member_releases_the_others() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        let w1 = worker_up(&mut tab);
        let w2 = worker_up(&mut tab);
        let id = tab.admit(0, spec(1, 16));
        tab.reserve(0, id, &[w1, w2], &mut out);
        out.clear();
        let requeued = tab.worker_lost(w1, &mut out);
        assert_eq!(requeued, vec![id]);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::Requeued { task } if *task == id
        )));
        // The surviving member's slots are back and hold nothing.
        let sw = tab.worker(w2).unwrap();
        assert_eq!(sw.cores_free, 16);
        assert!(sw.running.is_empty());
        assert!(tab.is_pending(id));
        assert_eq!(tab.task(id).unwrap().workers, Vec::<WorkerId>::new());
    }

    #[test]
    fn gang_start_action_lists_every_member() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        let w1 = worker_up(&mut tab);
        let w2 = worker_up(&mut tab);
        let id = tab.admit(0, spec(1, 4));
        tab.reserve(0, id, &[w1, w2], &mut out);
        out.clear();
        assert_eq!(tab.timer(MS, HqTimer::Dispatched(id), &mut out),
                   TimerVerdict::Started);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::StartGang { task, workers }
                if *task == id && workers == &vec![w1, w2]
        )));
        // Single-worker reservations still emit plain StartTask.
        let solo = tab.admit(0, spec(2, 4));
        tab.reserve(0, solo, &[w1], &mut out);
        out.clear();
        tab.timer(2 * MS, HqTimer::Dispatched(solo), &mut out);
        assert!(out.iter().any(|a| matches!(
            a,
            HqAction::StartTask { task, worker } if *task == solo && *worker == w1
        )));
    }

    #[test]
    fn exact_limit_guard_ignores_stale_limits() {
        let mut tab = TaskTable::new(cfg()).with_exact_limit();
        let mut out = Vec::new();
        let w1 = worker_up(&mut tab);
        let id = tab.admit(0, spec(1, 16));
        tab.reserve(0, id, &[w1], &mut out);
        tab.timer(MS, HqTimer::Dispatched(id), &mut out);
        // A limit not matching start_t + time_limit is stale.
        out.clear();
        assert_eq!(tab.timer(50 * SEC, HqTimer::Limit(id), &mut out),
                   TimerVerdict::Ignored);
        assert!(out.is_empty());
        // The armed one (start_t = 1 ms) kills.
        assert_eq!(
            tab.timer(MS + 100 * SEC, HqTimer::Limit(id), &mut out),
            TimerVerdict::Killed
        );
        assert!(!tab.task_live(id));
    }

    #[test]
    fn pending_counter_tracks_requeue_retry_cycle() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        let w1 = worker_up(&mut tab);
        let id = tab.admit(0, spec(1, 16));
        assert_eq!(tab.pending_tasks(), 1);
        tab.reserve(0, id, &[w1], &mut out);
        assert_eq!(tab.pending_tasks(), 0);
        assert_eq!(tab.fail(MS, id, Some(SEC), &mut out),
                   FailVerdict::Cooling);
        assert_eq!(tab.freed(), &[w1]);
        assert_eq!(tab.pending_tasks(), 0, "cooling is not pending");
        assert_eq!(tab.timer(MS + SEC, HqTimer::Retry(id), &mut out),
                   TimerVerdict::Requeue(id));
        assert_eq!(tab.pending_tasks(), 1);
        // Completing the task while Pending drops the counter.
        assert!(tab.complete(2 * SEC, id, false, &mut out));
        assert_eq!(tab.pending_tasks(), 0);
        assert_eq!(tab.retired_count(), 1);
    }

    #[test]
    fn stale_task_generation_rejected_by_every_op() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        let w1 = worker_up(&mut tab);
        let stale = tab.admit(0, spec(1, 4));
        tab.reserve(0, stale, &[w1], &mut out);
        assert!(tab.complete(SEC, stale, false, &mut out));
        // The slot is reused by a fresh admission…
        let fresh = tab.admit(SEC, spec(2, 4));
        assert_eq!(slot_of(fresh), slot_of(stale));
        // …and the stale id now misses every table operation.
        out.clear();
        assert!(!tab.task_live(stale));
        assert!(!tab.is_pending(stale));
        assert!(tab.task(stale).is_none());
        assert!(!tab.can_start(SEC, stale, w1));
        assert!(!tab.complete(2 * SEC, stale, false, &mut out));
        assert_eq!(tab.fail(2 * SEC, stale, Some(SEC), &mut out),
                   FailVerdict::Ignored);
        assert_eq!(tab.timer(2 * SEC, HqTimer::Dispatched(stale), &mut out),
                   TimerVerdict::Ignored);
        assert_eq!(tab.timer(2 * SEC, HqTimer::Limit(stale), &mut out),
                   TimerVerdict::Ignored);
        assert_eq!(tab.timer(2 * SEC, HqTimer::Retry(stale), &mut out),
                   TimerVerdict::Ignored);
        assert!(out.is_empty());
        // The fresh resident is untouched.
        assert!(tab.is_pending(fresh));
        assert_eq!(tab.pending_tasks(), 1);
    }

    #[test]
    fn stale_worker_generation_rejected() {
        let mut tab = TaskTable::new(cfg());
        let mut out = Vec::new();
        let w1 = worker_up(&mut tab);
        tab.worker_lost(w1, &mut out);
        let w2 = worker_up(&mut tab);
        assert_eq!(slot_of(w2), slot_of(w1));
        assert!(tab.worker(w1).is_none());
        let id = tab.admit(0, spec(1, 4));
        assert!(!tab.can_start(0, id, w1));
        assert!(tab.can_start(0, id, w2));
        assert!(tab.worker_lost(w1, &mut out).is_empty());
        assert_eq!(tab.live_workers(), 1);
    }
}
