//! [`SlurmSched`]: the paper's SLURM paths (native and UM-Bridge)
//! behind the unified [`SchedulerCore`] seam.
//!
//! A thin translation layer over [`SlurmCore`]: slurmlite `Action`s map
//! 1:1 onto [`Effect`]s (`Timer` → set-timer, `Launched` → start,
//! `Completed` → finish, `TimedOut` → retire), so the adapter adds one
//! reusable scratch buffer and zero per-event allocation.  The
//! UM-Bridge flavour folds the model-server start-up into each job's
//! duration and the balancer's proxy latency into each submission
//! (Appendix A) — exactly what the old `run_slurm` driver hard-coded.

use std::collections::HashMap;

use crate::campaign::driver::{CampaignConfig, SlurmMode};
use crate::campaign::submitter::Submission;
use crate::clock::{Micros, MS, SEC};
use crate::metrics::JobRecord;
use crate::slurmlite::core::{Action, BatchCore, JobId, SlurmCore, Timer,
                             USER_EXPERIMENT};
use crate::workload::scenario;

use super::{Completion, Effect, SchedulerCore, WorkerSet};

/// Timer payload for [`SlurmSched`]: the wrapped [`SlurmCore`] timers
/// plus the retry-backoff timers this adapter owns.  SLURM retries a
/// transiently failed evaluation *in place* — the allocation survives
/// the failure (an `srun` step died, not the job), so the retry re-runs
/// on the same nodes after the backoff instead of re-entering the
/// queue.
#[derive(Clone, Copy, Debug)]
pub enum SlurmSchedTimer {
    /// A timer owned by the wrapped [`SlurmCore`].
    Core(Timer),
    /// Retry backoff elapsed for a transiently failed job.
    Retry(JobId),
}

/// SLURM native log granularity (whole seconds; paper section V).
const SLURM_LOG_GRAIN: Micros = SEC;

/// Campaign user -> scheduler user.  User 0 is the experiment user; the
/// scheduler reserves user 1 for background load, so other campaign
/// users shift past it (each stream gets its own submission quota).
pub(crate) fn slurm_user(user: u32) -> u32 {
    if user == 0 {
        USER_EXPERIMENT
    } else {
        user + 1
    }
}

/// The SLURM scheduler (native `sbatch`-per-evaluation, or the
/// UM-Bridge SLURM backend) as a [`SchedulerCore`].
pub struct SlurmSched {
    core: SlurmCore,
    label: &'static str,
    /// Extra workload duration per job (model-server init, UM-Bridge).
    per_job_extra: Micros,
    /// Extra submission latency (balancer proxy, UM-Bridge).
    submit_extra: Micros,
    /// Reusable action scratch, translated into effects per call.
    acts: Vec<Action>,
    /// Contention captured at launch, per running job: a retry re-runs
    /// in place with the same contention its allocation started under.
    running: HashMap<JobId, f64>,
}

impl SlurmSched {
    pub fn new(cfg: &CampaignConfig, mode: SlurmMode) -> SlurmSched {
        let (per_job_extra, submit_extra, label): (Micros, Micros, &str) =
            match mode {
                SlurmMode::Native => (0, 0, "SLURM"),
                SlurmMode::UmBridge => {
                    (cfg.overheads.server_init, 50 * MS, "UM-Bridge SLURM")
                }
            };
        SlurmSched {
            core: SlurmCore::new(
                cfg.cluster.clone(),
                cfg.overheads.clone(),
                cfg.seed,
            ),
            label,
            per_job_extra,
            submit_extra,
            acts: Vec::new(),
            running: HashMap::new(),
        }
    }

    /// Translate the scratch actions into effects, in order (the kernel
    /// interprets effects sequentially, so DES schedule order is
    /// preserved exactly).
    fn flush(&mut self, out: &mut Vec<Effect<JobId, SlurmSchedTimer>>) {
        for a in self.acts.drain(..) {
            out.push(match a {
                Action::Timer(tt, tm) => {
                    Effect::SetTimer(tt, SlurmSchedTimer::Core(tm))
                }
                Action::Launched { job, contention, node } => {
                    self.running.insert(job, contention);
                    Effect::Start {
                        id: job,
                        contention,
                        workers: WorkerSet::one(node as u64),
                    }
                }
                Action::TimedOut { job } => {
                    self.running.remove(&job);
                    Effect::Retire { id: job }
                }
                Action::Completed { job, record } => {
                    self.running.remove(&job);
                    Effect::Finish { id: job, record }
                }
            });
        }
    }
}

impl SchedulerCore for SlurmSched {
    type Id = JobId;
    type Timer = SlurmSchedTimer;

    fn label(&self) -> &'static str {
        self.label
    }

    fn log_grain(&self) -> Micros {
        SLURM_LOG_GRAIN
    }

    fn bootstrap_into(
        &mut self,
        t: Micros,
        out: &mut Vec<Effect<JobId, SlurmSchedTimer>>,
    ) {
        self.acts = self.core.bootstrap(t);
        self.flush(out);
    }

    fn submit_into(
        &mut self,
        t: Micros,
        s: &Submission,
        out: &mut Vec<Effect<JobId, SlurmSchedTimer>>,
    ) -> (JobId, Micros) {
        debug_assert!(s.tag != u64::MAX, "tag u64::MAX is reserved");
        let id = self.core.submit_into(
            t + self.submit_extra,
            slurm_user(s.user),
            s.tag,
            scenario(s.app).slurm_request(),
            &mut self.acts,
        );
        self.flush(out);
        (id, s.duration + self.per_job_extra)
    }

    fn cancel_into(
        &mut self,
        t: Micros,
        id: JobId,
        out: &mut Vec<Effect<JobId, SlurmSchedTimer>>,
    ) {
        self.core.cancel_into(t, id, &mut self.acts);
        self.flush(out);
    }

    fn on_timer_into(
        &mut self,
        t: Micros,
        timer: SlurmSchedTimer,
        out: &mut Vec<Effect<JobId, SlurmSchedTimer>>,
    ) {
        match timer {
            SlurmSchedTimer::Core(tm) => {
                self.core.on_timer_into(t, tm, &mut self.acts);
                self.flush(out);
            }
            SlurmSchedTimer::Retry(id) => {
                // Re-run in place on the surviving allocation.  The
                // kernel opens a fresh attempt (new epoch, new fate
                // draw) off this Start.
                if let Some(&contention) = self.running.get(&id) {
                    out.push(Effect::Start {
                        id,
                        contention,
                        workers: WorkerSet::empty(),
                    });
                }
            }
        }
    }

    fn on_work_done_into(
        &mut self,
        t: Micros,
        id: JobId,
        out: &mut Vec<Effect<JobId, SlurmSchedTimer>>,
    ) {
        self.core.on_finish_into(t, id, &mut self.acts);
        self.flush(out);
    }

    fn on_work_failed_into(
        &mut self,
        t: Micros,
        id: JobId,
        retry_in: Option<Micros>,
        out: &mut Vec<Effect<JobId, SlurmSchedTimer>>,
    ) {
        if !self.running.contains_key(&id) {
            return;
        }
        match retry_in {
            // Quarantine: cancel through the core so the job surfaces
            // as a truncated record instead of vanishing.
            None => {
                self.core.cancel_into(t, id, &mut self.acts);
                self.flush(out);
            }
            Some(backoff) => {
                out.push(Effect::Requeued { id });
                out.push(Effect::SetTimer(
                    t.saturating_add(backoff),
                    SlurmSchedTimer::Retry(id),
                ));
            }
        }
    }

    fn timer_is_stale(&self, timer: &SlurmSchedTimer) -> bool {
        match timer {
            // A retry for a job that already completed, timed out, or
            // was quarantined has nothing left to re-run.
            SlurmSchedTimer::Retry(id) => !self.running.contains_key(id),
            SlurmSchedTimer::Core(_) => false,
        }
    }

    fn classify(&self, record: &JobRecord) -> Completion {
        // Tag u64::MAX marks the core's own background load.
        if record.tag == u64::MAX {
            Completion::Background
        } else {
            Completion::Evaluation
        }
    }
}
