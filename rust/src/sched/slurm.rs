//! [`SlurmSched`]: the paper's SLURM paths (native and UM-Bridge)
//! behind the unified [`SchedulerCore`] seam.
//!
//! A thin translation layer over [`SlurmCore`]: slurmlite `Action`s map
//! 1:1 onto [`Effect`]s (`Timer` → set-timer, `Launched` → start,
//! `Completed` → finish, `TimedOut` → retire), so the adapter adds one
//! reusable scratch buffer and zero per-event allocation.  The
//! UM-Bridge flavour folds the model-server start-up into each job's
//! duration and the balancer's proxy latency into each submission
//! (Appendix A) — exactly what the old `run_slurm` driver hard-coded.

use crate::campaign::driver::{CampaignConfig, SlurmMode};
use crate::campaign::submitter::Submission;
use crate::clock::{Micros, MS, SEC};
use crate::metrics::JobRecord;
use crate::slurmlite::core::{Action, BatchCore, JobId, SlurmCore, Timer,
                             USER_EXPERIMENT};
use crate::workload::scenario;

use super::{Completion, Effect, SchedulerCore};

/// SLURM native log granularity (whole seconds; paper section V).
const SLURM_LOG_GRAIN: Micros = SEC;

/// Campaign user -> scheduler user.  User 0 is the experiment user; the
/// scheduler reserves user 1 for background load, so other campaign
/// users shift past it (each stream gets its own submission quota).
pub(crate) fn slurm_user(user: u32) -> u32 {
    if user == 0 {
        USER_EXPERIMENT
    } else {
        user + 1
    }
}

/// The SLURM scheduler (native `sbatch`-per-evaluation, or the
/// UM-Bridge SLURM backend) as a [`SchedulerCore`].
pub struct SlurmSched {
    core: SlurmCore,
    label: &'static str,
    /// Extra workload duration per job (model-server init, UM-Bridge).
    per_job_extra: Micros,
    /// Extra submission latency (balancer proxy, UM-Bridge).
    submit_extra: Micros,
    /// Reusable action scratch, translated into effects per call.
    acts: Vec<Action>,
}

impl SlurmSched {
    pub fn new(cfg: &CampaignConfig, mode: SlurmMode) -> SlurmSched {
        let (per_job_extra, submit_extra, label): (Micros, Micros, &str) =
            match mode {
                SlurmMode::Native => (0, 0, "SLURM"),
                SlurmMode::UmBridge => {
                    (cfg.overheads.server_init, 50 * MS, "UM-Bridge SLURM")
                }
            };
        SlurmSched {
            core: SlurmCore::new(
                cfg.cluster.clone(),
                cfg.overheads.clone(),
                cfg.seed,
            ),
            label,
            per_job_extra,
            submit_extra,
            acts: Vec::new(),
        }
    }

    /// Translate the scratch actions into effects, in order (the kernel
    /// interprets effects sequentially, so DES schedule order is
    /// preserved exactly).
    fn flush(&mut self, out: &mut Vec<Effect<JobId, Timer>>) {
        for a in self.acts.drain(..) {
            out.push(match a {
                Action::Timer(tt, tm) => Effect::SetTimer(tt, tm),
                Action::Launched { job, contention, node } => {
                    Effect::Start {
                        id: job,
                        contention,
                        worker: Some(node as u64),
                    }
                }
                Action::TimedOut { job } => Effect::Retire { id: job },
                Action::Completed { job, record } => {
                    Effect::Finish { id: job, record }
                }
            });
        }
    }
}

impl SchedulerCore for SlurmSched {
    type Id = JobId;
    type Timer = Timer;

    fn label(&self) -> &'static str {
        self.label
    }

    fn log_grain(&self) -> Micros {
        SLURM_LOG_GRAIN
    }

    fn bootstrap_into(
        &mut self,
        t: Micros,
        out: &mut Vec<Effect<JobId, Timer>>,
    ) {
        self.acts = self.core.bootstrap(t);
        self.flush(out);
    }

    fn submit_into(
        &mut self,
        t: Micros,
        s: &Submission,
        out: &mut Vec<Effect<JobId, Timer>>,
    ) -> (JobId, Micros) {
        debug_assert!(s.tag != u64::MAX, "tag u64::MAX is reserved");
        let id = self.core.submit_into(
            t + self.submit_extra,
            slurm_user(s.user),
            s.tag,
            scenario(s.app).slurm_request(),
            &mut self.acts,
        );
        self.flush(out);
        (id, s.duration + self.per_job_extra)
    }

    fn cancel_into(
        &mut self,
        t: Micros,
        id: JobId,
        out: &mut Vec<Effect<JobId, Timer>>,
    ) {
        self.core.cancel_into(t, id, &mut self.acts);
        self.flush(out);
    }

    fn on_timer_into(
        &mut self,
        t: Micros,
        timer: Timer,
        out: &mut Vec<Effect<JobId, Timer>>,
    ) {
        self.core.on_timer_into(t, timer, &mut self.acts);
        self.flush(out);
    }

    fn on_work_done_into(
        &mut self,
        t: Micros,
        id: JobId,
        out: &mut Vec<Effect<JobId, Timer>>,
    ) {
        self.core.on_finish_into(t, id, &mut self.acts);
        self.flush(out);
    }

    fn classify(&self, record: &JobRecord) -> Completion {
        // Tag u64::MAX marks the core's own background load.
        if record.tag == u64::MAX {
            Completion::Background
        } else {
            Completion::Evaluation
        }
    }
}
