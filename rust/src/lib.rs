//! uqsched — reproduction of "A Performance Analysis of Task Scheduling
//! for UQ Workflows on HPC Systems" (CS.DC 2025).
//!
//! The crate implements the paper's UM-Bridge load balancer together with
//! every substrate it depends on: an HTTP/JSON stack, a SLURM-like batch
//! scheduler (`slurmlite`), a HyperQueue-like meta-scheduler (`hqlite`),
//! a PJRT runtime executing AOT-compiled JAX/Pallas artifacts, the
//! GS2-surrogate workloads, and the metrics/benchmark harness that
//! regenerates every table and figure in the paper's evaluation.  On top
//! of the paper's fixed protocol, the [`campaign`] plane generalizes
//! *what gets submitted* — bursty, multi-user, heteroskedastic and
//! adaptive workload streams — and the [`sched`] plane generalizes
//! *what schedules them*: one [`SchedulerCore`](sched::SchedulerCore)
//! trait, two kernels (virtual-time for campaigns, wall-clock for the
//! live balancer), and pluggable scheduler implementations (SLURM,
//! UM-Bridge + HyperQueue, a partitioned work-stealing variant, and a
//! deadline-EDF core that serves in both planes).
//!
//! See README.md, docs/ARCHITECTURE.md and DESIGN.md for the
//! architecture and the experiment index.

pub mod campaign;
pub mod cli;
pub mod clock;
pub mod cluster;
pub mod coordinator;
pub mod experiments;
pub mod hqlite;
pub mod httpd;
pub mod json;
pub mod logging;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod sched;
pub mod slurmlite;
pub mod umbridge;
pub mod util;
pub mod workload;
