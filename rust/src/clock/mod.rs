//! Time: real clock for the live plane, discrete-event engine for the sim
//! plane.  All scheduler cores speak `Micros` so one state machine runs in
//! both planes (DESIGN.md section 3).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

/// Microseconds since an arbitrary epoch (experiment start).
pub type Micros = u64;

pub const MS: Micros = 1_000;
pub const SEC: Micros = 1_000_000;
pub const MIN: Micros = 60 * SEC;

/// Wall-clock time source for the live plane.
#[derive(Clone)]
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { epoch: Instant::now() }
    }

    pub fn now(&self) -> Micros {
        self.epoch.elapsed().as_micros() as Micros
    }

    pub fn sleep(d: Micros) {
        std::thread::sleep(std::time::Duration::from_micros(d));
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Discrete-event engine: a priority queue of `(time, seq, event)` with
/// FIFO tie-breaking, driving virtual time forward monotonically.
pub struct Des<E> {
    queue: BinaryHeap<Reverse<(Micros, u64, EventBox<E>)>>,
    now: Micros,
    seq: u64,
    processed: u64,
}

/// Wrapper so `E` needs no `Ord` — ordering is purely (time, seq).
struct EventBox<E>(E);

impl<E> PartialEq for EventBox<E> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<E> Eq for EventBox<E> {}
impl<E> PartialOrd for EventBox<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for EventBox<E> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<E> Des<E> {
    pub fn new() -> Self {
        Des { queue: BinaryHeap::new(), now: 0, seq: 0, processed: 0 }
    }

    pub fn now(&self) -> Micros {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `ev` at absolute virtual time `t` (clamped to now).
    pub fn schedule(&mut self, t: Micros, ev: E) {
        let t = t.max(self.now);
        self.queue.push(Reverse((t, self.seq, EventBox(ev))));
        self.seq += 1;
    }

    /// Schedule `ev` after a delay.
    pub fn after(&mut self, d: Micros, ev: E) {
        self.schedule(self.now + d, ev);
    }

    /// Pop the next event, advancing virtual time.
    pub fn pop(&mut self) -> Option<(Micros, E)> {
        let Reverse((t, _seq, b)) = self.queue.pop()?;
        debug_assert!(t >= self.now, "time went backwards");
        self.now = t;
        self.processed += 1;
        Some((t, b.0))
    }

    /// Time of the next scheduled event without popping it.
    pub fn peek_time(&self) -> Option<Micros> {
        self.queue.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }
}

impl<E> Default for Des<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_advances() {
        let c = RealClock::new();
        let t0 = c.now();
        RealClock::sleep(2 * MS);
        assert!(c.now() >= t0 + MS);
    }

    #[test]
    fn des_orders_by_time() {
        let mut d: Des<&str> = Des::new();
        d.schedule(30, "c");
        d.schedule(10, "a");
        d.schedule(20, "b");
        assert_eq!(d.pop().unwrap(), (10, "a"));
        assert_eq!(d.pop().unwrap(), (20, "b"));
        assert_eq!(d.pop().unwrap(), (30, "c"));
        assert!(d.pop().is_none());
    }

    #[test]
    fn des_fifo_on_ties() {
        let mut d: Des<u32> = Des::new();
        for i in 0..10 {
            d.schedule(5, i);
        }
        for i in 0..10 {
            assert_eq!(d.pop().unwrap(), (5, i));
        }
    }

    #[test]
    fn des_time_monotonic_even_with_past_schedules() {
        let mut d: Des<&str> = Des::new();
        d.schedule(100, "x");
        assert_eq!(d.pop().unwrap().0, 100);
        d.schedule(50, "past"); // clamped to now=100
        assert_eq!(d.pop().unwrap(), (100, "past"));
    }

    #[test]
    fn des_after_is_relative() {
        let mut d: Des<&str> = Des::new();
        d.schedule(100, "x");
        d.pop();
        d.after(5, "y");
        assert_eq!(d.pop().unwrap(), (105, "y"));
    }

    #[test]
    fn des_interleaved_schedule_pop() {
        let mut d: Des<u32> = Des::new();
        d.schedule(10, 1);
        let (t, _) = d.pop().unwrap();
        d.schedule(t + 10, 2);
        d.schedule(t + 5, 3);
        assert_eq!(d.pop().unwrap(), (15, 3));
        assert_eq!(d.pop().unwrap(), (20, 2));
        assert_eq!(d.processed(), 3);
    }
}
