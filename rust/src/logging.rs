//! Leveled stderr logging with per-component tags and a global level.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn set_level_from_str(s: &str) {
    set_level(match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        _ => Level::Info,
    });
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, component: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() % 100_000_000)
        .unwrap_or(0);
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{:>8}.{:03}] {tag} {component:<12} {msg}", t / 1000, t % 1000);
}

#[macro_export]
macro_rules! log_info {
    ($comp:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info, $comp,
                             &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($comp:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn, $comp,
                             &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($comp:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Error, $comp,
                             &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($comp:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug, $comp,
                             &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn from_str() {
        set_level_from_str("debug");
        assert!(enabled(Level::Debug));
        set_level_from_str("info");
        assert!(!enabled(Level::Debug));
    }
}
