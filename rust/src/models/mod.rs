//! Model servers: the paper's four benchmark applications as UM-Bridge
//! models over the PJRT runtime (DESIGN.md section 2).
//!
//! * [`GpModel`]    — GP surrogate of gs2lite (7 in -> mean/var out)
//! * [`Gs2Model`]   — gs2lite dispersion solver (chunked power iteration,
//!                    input-dependent runtime)
//! * [`EigenModel`] — eigen-100 / eigen-5000 dense eigenproblems
//! * [`QoiModel`]   — the quasilinear QoI integral over the GP surrogate
//!
//! `gp_ref` is a dependency-free Rust GP used for Fig 2 and as a second
//! oracle against the PJRT path.

pub mod gp_ref;

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::json::Value;
use crate::runtime::Engine;
use crate::umbridge::Model;
use crate::util::Rng;

/// Names used on the wire (match the paper's benchmark names).
pub const GP_NAME: &str = "gp";
pub const GS2_NAME: &str = "gs2";
pub const EIGEN_SMALL_NAME: &str = "eigen-100";
pub const EIGEN_LARGE_NAME: &str = "eigen-5000";
pub const QOI_NAME: &str = "qoi";

/// Build a model by wire name.
pub fn by_name(engine: Arc<Engine>, name: &str) -> Result<Arc<dyn Model>> {
    Ok(match name {
        GP_NAME => Arc::new(GpModel::new(engine)),
        GS2_NAME => Arc::new(Gs2Model::new(engine)),
        EIGEN_SMALL_NAME => Arc::new(EigenModel::small(engine)),
        EIGEN_LARGE_NAME => Arc::new(EigenModel::large(engine)),
        QOI_NAME => Arc::new(QoiModel::new(engine)),
        other => bail!("unknown model '{other}'"),
    })
}

pub fn all_names() -> Vec<&'static str> {
    vec![GP_NAME, GS2_NAME, EIGEN_SMALL_NAME, EIGEN_LARGE_NAME, QOI_NAME]
}

// ---------------------------------------------------------------------------

/// Engine-free stand-in model with a configurable contract and service
/// time.  The balancer plane (tests, `selftest` smoke, `hotpath`
/// multi-model bench) uses it to exercise routing, leasing and
/// backpressure without PJRT artifacts: output vector `j` is filled
/// with `sum(inputs) + j`, so clients can verify end-to-end routing.
pub struct SyntheticModel {
    name: String,
    inputs: Vec<usize>,
    outputs: Vec<usize>,
    delay: std::time::Duration,
}

impl SyntheticModel {
    pub fn new(name: &str, inputs: &[usize], outputs: &[usize])
               -> SyntheticModel {
        SyntheticModel {
            name: name.to_string(),
            inputs: inputs.to_vec(),
            outputs: outputs.to_vec(),
            delay: std::time::Duration::ZERO,
        }
    }

    /// Simulated service time per evaluation.
    pub fn with_delay(mut self, delay: std::time::Duration) -> SyntheticModel {
        self.delay = delay;
        self
    }
}

impl Model for SyntheticModel {
    fn name(&self) -> &str {
        &self.name
    }
    fn input_sizes(&self) -> Vec<usize> {
        self.inputs.clone()
    }
    fn output_sizes(&self) -> Vec<usize> {
        self.outputs.clone()
    }
    fn evaluate(&self, inputs: &[Vec<f64>], _config: &Value)
                -> Result<Vec<Vec<f64>>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let sum: f64 = inputs.iter().flatten().sum();
        Ok(self
            .outputs
            .iter()
            .enumerate()
            .map(|(j, &len)| vec![sum + j as f64; len])
            .collect())
    }
}

// ---------------------------------------------------------------------------

/// GP surrogate: input (7) -> outputs (mean[2], var[2]).
pub struct GpModel {
    engine: Arc<Engine>,
    batch: usize,
}

impl GpModel {
    pub fn new(engine: Arc<Engine>) -> Self {
        let batch = engine
            .manifest()
            .entries
            .get("gp_predict_b16")
            .and_then(|e| e.input_shapes.first())
            .and_then(|s| s.first().copied())
            .unwrap_or(16);
        GpModel { engine, batch }
    }

    /// Batched prediction (the hot path the balancer perf bench drives):
    /// rows of 7 inputs -> (means, vars) rows of 2.
    pub fn predict_batch(&self, rows: &[Vec<f64>])
                         -> Result<(Vec<[f64; 2]>, Vec<[f64; 2]>)> {
        let mut means = Vec::with_capacity(rows.len());
        let mut vars = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch) {
            let mut flat = vec![0f32; self.batch * 7];
            for (i, r) in chunk.iter().enumerate() {
                if r.len() != 7 {
                    bail!("gp input must have 7 parameters, got {}", r.len());
                }
                for (j, &v) in r.iter().enumerate() {
                    flat[i * 7 + j] = v as f32;
                }
            }
            // Pad rows repeat the last real row (harmless).
            for i in chunk.len()..self.batch {
                for j in 0..7 {
                    flat[i * 7 + j] = flat[(chunk.len().max(1) - 1) * 7 + j];
                }
            }
            let out = self.engine.execute("gp_predict_b16", &[flat])?;
            let (mean, var) = (&out[0], &out[1]);
            for i in 0..chunk.len() {
                means.push([mean[i * 2] as f64, mean[i * 2 + 1] as f64]);
                vars.push([var[i * 2] as f64, var[i * 2 + 1] as f64]);
            }
        }
        Ok((means, vars))
    }
}

impl Model for GpModel {
    fn name(&self) -> &str {
        GP_NAME
    }
    fn input_sizes(&self) -> Vec<usize> {
        vec![7]
    }
    fn output_sizes(&self) -> Vec<usize> {
        vec![2, 2]
    }
    fn evaluate(&self, inputs: &[Vec<f64>], _config: &Value)
                -> Result<Vec<Vec<f64>>> {
        let (means, vars) = self.predict_batch(&inputs[..1])?;
        Ok(vec![means[0].to_vec(), vars[0].to_vec()])
    }
}

// ---------------------------------------------------------------------------

/// gs2lite: input (7) -> outputs (gamma/omega [2], residual [1],
/// chunks-used [1]).  The server loops fixed-shape PJRT chunk calls until
/// the residual converges — runtime is input-dependent and a-priori
/// unknown, the paper's scheduling challenge.
pub struct Gs2Model {
    engine: Arc<Engine>,
}

impl Gs2Model {
    pub fn new(engine: Arc<Engine>) -> Self {
        Gs2Model { engine }
    }

    /// Deterministic initial state (matches
    /// `python/compile/gs2lite.py::initial_state`).
    pub fn initial_state(&self) -> Vec<f32> {
        let m = &self.engine.manifest().gs2;
        let n = m.ngrid;
        let tm = m.theta_max as f32;
        let mut zr = vec![0f32; n];
        let mut zi = vec![0f32; n];
        for i in 0..n {
            let th = -tm + 2.0 * tm * (i as f32) / ((n - 1) as f32);
            zr[i] = (-0.5 * th * th).exp();
            zi[i] = 0.1 * th.sin() * zr[i];
        }
        let nrm = (zr.iter().map(|v| v * v).sum::<f32>()
            + zi.iter().map(|v| v * v).sum::<f32>())
        .sqrt();
        let mut state = vec![0f32; n * 2];
        for i in 0..n {
            state[i * 2] = zr[i] / nrm;
            state[i * 2 + 1] = zi[i] / nrm;
        }
        state
    }

    /// Run to convergence; returns (gamma, omega, residual, chunks).
    pub fn solve(&self, theta: &[f64], max_chunks_override: Option<usize>)
                 -> Result<(f64, f64, f64, usize)> {
        if theta.len() != 7 {
            bail!("gs2 input must have 7 parameters, got {}", theta.len());
        }
        let meta = self.engine.manifest().gs2.clone();
        let tol = meta.residual_tol;
        let max_chunks = max_chunks_override.unwrap_or(meta.max_chunks);
        let th: Vec<f32> = theta.iter().map(|&v| v as f32).collect();
        let mut state = self.initial_state();
        let mut eig = [0f64; 2];
        let mut res = f64::INFINITY;
        let mut chunks = 0;
        while chunks < max_chunks {
            let out = self
                .engine
                .execute("gs2_chunk", &[th.clone(), state.clone()])?;
            state = out[0].clone();
            eig = [out[1][0] as f64, out[1][1] as f64];
            res = out[2][0] as f64;
            chunks += 1;
            if res < tol {
                break;
            }
        }
        Ok((eig[0], eig[1], res, chunks))
    }
}

impl Model for Gs2Model {
    fn name(&self) -> &str {
        GS2_NAME
    }
    fn input_sizes(&self) -> Vec<usize> {
        vec![7]
    }
    fn output_sizes(&self) -> Vec<usize> {
        vec![2, 1, 1]
    }
    fn evaluate(&self, inputs: &[Vec<f64>], config: &Value)
                -> Result<Vec<Vec<f64>>> {
        let max_chunks = config
            .get("max_chunks")
            .and_then(|v| v.as_usize());
        let (g, w, res, chunks) = self.solve(&inputs[0], max_chunks)?;
        Ok(vec![vec![g, w], vec![res], vec![chunks as f64]])
    }
}

// ---------------------------------------------------------------------------

/// Dense symmetric eigenproblem (paper's eigen-100/eigen-5000, LAPACK
/// `_geev` stand-in).  Input: (1) seed; the benchmark matrix is generated
/// from the shared SplitMix64 stream so Rust and Python agree bit-for-bit.
pub struct EigenModel {
    engine: Arc<Engine>,
    entry: &'static str,
    wire: &'static str,
    n: usize,
}

impl EigenModel {
    pub fn small(engine: Arc<Engine>) -> Self {
        let n = engine.manifest().eigen.n_small;
        EigenModel { engine, entry: "eigen_small", wire: EIGEN_SMALL_NAME, n }
    }

    pub fn large(engine: Arc<Engine>) -> Self {
        let n = engine.manifest().eigen.n_large;
        EigenModel { engine, entry: "eigen_large", wire: EIGEN_LARGE_NAME, n }
    }

    pub fn solve_seed(&self, seed: u64) -> Result<(Vec<f64>, f64)> {
        let a = Rng::symmetric_matrix(seed, self.n);
        let out = self.engine.execute(self.entry, &[a])?;
        let w = out[0].iter().map(|&v| v as f64).collect();
        Ok((w, out[1][0] as f64))
    }
}

impl Model for EigenModel {
    fn name(&self) -> &str {
        self.wire
    }
    fn input_sizes(&self) -> Vec<usize> {
        vec![1]
    }
    fn output_sizes(&self) -> Vec<usize> {
        vec![self.n, 1]
    }
    fn evaluate(&self, inputs: &[Vec<f64>], _config: &Value)
                -> Result<Vec<Vec<f64>>> {
        let seed = inputs[0]
            .first()
            .copied()
            .ok_or_else(|| anyhow!("eigen input: seed required"))? as u64;
        let (w, off) = self.solve_seed(seed)?;
        Ok(vec![w, vec![off]])
    }
}

// ---------------------------------------------------------------------------

/// Quasilinear QoI integral over the GP surrogate (paper eq. (5) proxy).
pub struct QoiModel {
    engine: Arc<Engine>,
    field_len: usize,
}

impl QoiModel {
    pub fn new(engine: Arc<Engine>) -> Self {
        QoiModel { engine, field_len: 24 * 16 }
    }
}

impl Model for QoiModel {
    fn name(&self) -> &str {
        QOI_NAME
    }
    fn input_sizes(&self) -> Vec<usize> {
        vec![7]
    }
    fn output_sizes(&self) -> Vec<usize> {
        vec![1, self.field_len]
    }
    fn evaluate(&self, inputs: &[Vec<f64>], _config: &Value)
                -> Result<Vec<Vec<f64>>> {
        let th: Vec<f32> = inputs[0].iter().map(|&v| v as f32).collect();
        if th.len() != 7 {
            bail!("qoi input must have 7 parameters");
        }
        let out = self.engine.execute("qoi_integral", &[th])?;
        Ok(vec![
            vec![out[0][0] as f64],
            out[1].iter().map(|&v| v as f64).collect(),
        ])
    }
}
