//! Dependency-free Gaussian process in pure Rust.
//!
//! Two jobs:
//! 1. regenerate the paper's Fig 2 (prior/posterior illustration on toy
//!    1-D data) without any Python at bench time;
//! 2. act as a second, independent oracle for the PJRT GP path in
//!    integration tests (Rust math vs Pallas kernel numerics).

use crate::util::Rng;

/// Dense column-major symmetric solve via Cholesky (small n).
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve L y = b (lower triangular).
pub fn solve_lower(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Solve L^T x = y (upper triangular from lower factor).
pub fn solve_upper_t(l: &[f64], n: usize, y: &[f64]) -> Vec<f64> {
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// 1-D RBF kernel.
pub fn k1(a: f64, b: f64, ls: f64, sf2: f64) -> f64 {
    let d = (a - b) / ls;
    sf2 * (-0.5 * d * d).exp()
}

/// A 1-D GP conditioned on observations, for the Fig 2 illustration.
pub struct Gp1d {
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    pub ls: f64,
    pub sf2: f64,
    pub sn2: f64,
    chol: Vec<f64>,
    alpha: Vec<f64>,
}

impl Gp1d {
    pub fn fit(xs: Vec<f64>, ys: Vec<f64>, ls: f64, sf2: f64, sn2: f64) -> Gp1d {
        let n = xs.len();
        let mut k = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = k1(xs[i], xs[j], ls, sf2);
            }
            k[i * n + i] += sn2;
        }
        let chol = cholesky(&k, n).expect("PD kernel");
        let y0 = solve_lower(&chol, n, &ys);
        let alpha = solve_upper_t(&chol, n, &y0);
        Gp1d { xs, ys, ls, sf2, sn2, chol, alpha }
    }

    /// Posterior mean and variance at query points.
    pub fn predict(&self, xq: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let n = self.xs.len();
        let mut mean = Vec::with_capacity(xq.len());
        let mut var = Vec::with_capacity(xq.len());
        for &x in xq {
            let ks: Vec<f64> = self
                .xs
                .iter()
                .map(|&xi| k1(x, xi, self.ls, self.sf2))
                .collect();
            let m: f64 = ks.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
            let v = solve_lower(&self.chol, n, &ks);
            let q: f64 = v.iter().map(|z| z * z).sum();
            mean.push(m);
            var.push((self.sf2 - q).max(0.0));
        }
        (mean, var)
    }

    /// Posterior covariance matrix at query points (for sample draws).
    pub fn posterior_cov(&self, xq: &[f64]) -> Vec<f64> {
        let n = self.xs.len();
        let m = xq.len();
        // V[i][j] column of solve_lower per query point.
        let mut vcols: Vec<Vec<f64>> = Vec::with_capacity(m);
        for &x in xq {
            let ks: Vec<f64> = self
                .xs
                .iter()
                .map(|&xi| k1(x, xi, self.ls, self.sf2))
                .collect();
            vcols.push(solve_lower(&self.chol, n, &ks));
        }
        let mut cov = vec![0f64; m * m];
        for i in 0..m {
            for j in 0..m {
                let kxx = k1(xq[i], xq[j], self.ls, self.sf2);
                let dot: f64 =
                    vcols[i].iter().zip(&vcols[j]).map(|(a, b)| a * b).sum();
                cov[i * m + j] = kxx - dot;
            }
        }
        cov
    }

    /// Draw `count` functions from the posterior at `xq` (seeded).
    pub fn sample_posterior(&self, xq: &[f64], count: usize, seed: u64)
                            -> Vec<Vec<f64>> {
        let m = xq.len();
        let (mean, _) = self.predict(xq);
        let mut cov = self.posterior_cov(xq);
        // Jitter for PD.
        for i in 0..m {
            cov[i * m + i] += 1e-9;
        }
        let l = cholesky(&cov, m).expect("posterior cov PD");
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                let z: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
                (0..m)
                    .map(|i| {
                        mean[i]
                            + (0..=i.min(m - 1))
                                .map(|k| l[i * m + k] * z[k])
                                .sum::<f64>()
                    })
                    .collect()
            })
            .collect()
    }
}

/// The Fig 2 dataset from the paper's illustration: 4 training points on
/// a smooth function, queries on a dense grid.
pub fn fig2_data() -> (Gp1d, Vec<f64>) {
    let xs = vec![-4.0, -1.5, 1.0, 3.5];
    let ys: Vec<f64> = xs.iter().map(|&x: &f64| (0.7 * x).sin()).collect();
    let gp = Gp1d::fit(xs, ys, 1.6, 1.0, 1e-6);
    let grid: Vec<f64> = (0..121).map(|i| -6.0 + 12.0 * i as f64 / 120.0).collect();
    (gp, grid)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_roundtrip() {
        // A = M M^T for random M is PD.
        let n = 5;
        let mut rng = Rng::new(3);
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] =
                    (0..n).map(|k| m[i * n + k] * m[j * n + k]).sum::<f64>()
                        + if i == j { 0.5 } else { 0.0 };
            }
        }
        let l = cholesky(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let v: f64 =
                    (0..n).map(|k| l[i * n + k] * l[j * n + k]).sum();
                assert!((v - a[i * n + j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let n = 4;
        let a: Vec<f64> = vec![
            4.0, 1.0, 0.5, 0.2,
            1.0, 3.0, 0.3, 0.1,
            0.5, 0.3, 2.0, 0.4,
            0.2, 0.1, 0.4, 1.5,
        ];
        let l = cholesky(&a, n).unwrap();
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let y = solve_lower(&l, n, &b);
        let x = solve_upper_t(&l, n, &y);
        // Check A x = b.
        for i in 0..n {
            let s: f64 = (0..n).map(|j| a[i * n + j] * x[j]).sum();
            assert!((s - b[i]).abs() < 1e-9, "row {i}: {s} vs {}", b[i]);
        }
    }

    #[test]
    fn gp_interpolates_noiseless_data() {
        let (gp, _) = fig2_data();
        let (mean, var) = gp.predict(&gp.xs.clone());
        for (m, (y, v)) in mean.iter().zip(gp.ys.iter().zip(&var)) {
            assert!((m - y).abs() < 1e-3, "{m} vs {y}");
            assert!(*v < 1e-3);
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let (gp, _) = fig2_data();
        let (_, v_far) = gp.predict(&[10.0]);
        let (_, v_near) = gp.predict(&[1.0]);
        assert!(v_far[0] > v_near[0]);
        assert!(v_far[0] <= gp.sf2 + 1e-9);
    }

    #[test]
    fn posterior_draws_hit_training_points() {
        let (gp, _) = fig2_data();
        let draws = gp.sample_posterior(&gp.xs.clone(), 3, 42);
        assert_eq!(draws.len(), 3);
        for d in &draws {
            for (a, b) in d.iter().zip(&gp.ys) {
                assert!((a - b).abs() < 0.05, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn draws_are_seeded() {
        let (gp, grid) = fig2_data();
        let a = gp.sample_posterior(&grid, 2, 7);
        let b = gp.sample_posterior(&grid, 2, 7);
        assert_eq!(a, b);
        let c = gp.sample_posterior(&grid, 2, 8);
        assert_ne!(a, c);
    }
}
