//! Cluster model: node inventory, job resource requests, and the
//! calibrated overhead model (DESIGN.md section 7).
//!
//! The overhead model is the substitution for a production SLURM
//! deployment: every constant is either stated in the paper, standard for
//! production SLURM, or derived from the paper's figures; the `scale`
//! factor maps paper seconds onto live-plane milliseconds so that every
//! *ratio* the paper reports is preserved.

use crate::clock::{Micros, MS, SEC};

/// Static description of the machine.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub cores_per_node: u32,
    pub ram_gb_per_node: u32,
}

impl ClusterSpec {
    /// Hamilton8 (paper section IV): 120 standard nodes, 2x AMD EPYC 7702
    /// (128 cores), 246 GB usable RAM.
    pub fn hamilton8() -> Self {
        ClusterSpec { nodes: 120, cores_per_node: 128, ram_gb_per_node: 246 }
    }

    /// Small profile for unit tests and the live plane.
    pub fn small(nodes: usize) -> Self {
        ClusterSpec { nodes, cores_per_node: 16, ram_gb_per_node: 64 }
    }

    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node as u64
    }
}

/// Resources requested for one batch job / allocation.
///
/// `Copy`: four scalar fields, passed around constantly on the scheduler
/// hot paths (autoalloc used to clone one per submission).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobRequest {
    pub cores: u32,
    pub ram_gb: u32,
    /// Hard kill limit (SLURM `--time`, HQ job time limit).
    pub time_limit: Micros,
    /// HQ-only scheduling hint (job time request); `None` under SLURM —
    /// the feature Table I marks as HQ-exclusive.
    pub time_request: Option<Micros>,
}

impl JobRequest {
    pub fn new(cores: u32, ram_gb: u32, time_limit: Micros) -> Self {
        JobRequest { cores, ram_gb, time_limit, time_request: None }
    }

    pub fn with_time_request(mut self, tr: Micros) -> Self {
        self.time_request = Some(tr);
        self
    }
}

/// Calibrated scheduler overheads.  All values in `Micros` at *paper
/// scale* (i.e. real Hamilton8-like magnitudes); use [`OverheadModel::scaled`]
/// for the live plane.
#[derive(Clone, Debug)]
pub struct OverheadModel {
    /// sbatch submission round-trip (client -> slurmctld).
    pub submit_latency: Micros,
    /// Scheduler wake-up period (main scheduling loop).
    pub sched_cycle: Micros,
    /// Per-job prolog / environment re-initialisation on the node.  The
    /// paper attributes SLURM's higher CPU time on GS2 to exactly this.
    pub prolog: Micros,
    /// Per-job epilog / cleanup.
    pub epilog: Micros,
    /// UM-Bridge model-server start-up per job ("approximately 1 second
    /// regardless of the application", section V).
    pub server_init: Micros,
    /// HQ per-task dispatch latency ("order of milliseconds", section V).
    pub hq_dispatch: Micros,
    /// CPU-time inflation per co-located foreign job on the same node
    /// (filesystem/memory-bandwidth contention, section V).
    pub contention_per_neighbor: f64,
    /// Background (other users') job arrivals: mean inter-arrival time.
    pub bg_interarrival: Micros,
    /// Background job duration mean (exponential).
    pub bg_duration: Micros,
    /// Background job core range.
    pub bg_cores: (u32, u32),
    /// Per-user soft submission quota after which priority decays (the
    /// paper: "SLURM ... deprioritises a user's submissions once they
    /// have reached a certain number of submissions").
    pub user_quota: u32,
    /// Extra queue-priority penalty per job beyond the quota, expressed
    /// in microseconds of effective queue age lost.
    pub quota_penalty: Micros,
    /// Backfill proxy: queue delay proportional to the *requested* time
    /// limit (long-walltime jobs cannot backfill into short gaps — the
    /// paper's "grossly overstating the required time limit" effect).
    /// Delay = factor * min(limit, backfill_cap) * U(0.5, 1.5).
    pub backfill_delay_factor: f64,
    pub backfill_cap: Micros,
}

impl OverheadModel {
    /// Paper-scale defaults (production SLURM magnitudes).
    pub fn paper() -> Self {
        OverheadModel {
            submit_latency: 300 * MS,
            sched_cycle: 30 * SEC,
            prolog: 4 * SEC,
            epilog: 1 * SEC,
            server_init: 1 * SEC,
            hq_dispatch: 1 * MS,
            contention_per_neighbor: 0.03,
            bg_interarrival: 12 * SEC,
            bg_duration: 45 * 60 * SEC,
            bg_cores: (8, 128),
            user_quota: 40,
            quota_penalty: 60 * SEC,
            backfill_delay_factor: 0.05,
            backfill_cap: 240 * 60 * SEC,
        }
    }

    /// A quiet cluster (no background load) — used by property tests so
    /// invariants are load-independent.
    pub fn quiet() -> Self {
        let mut m = Self::paper();
        m.bg_interarrival = Micros::MAX;
        m.backfill_delay_factor = 0.0;
        m
    }

    /// Compress all host-side constants by `1/scale` for the live plane
    /// (e.g. `scaled(60.0)` maps 1 paper-minute onto 1 live second).
    /// `hq_dispatch` is left unscaled: it is already at the millisecond
    /// floor of a real dispatcher.
    pub fn scaled(&self, scale: f64) -> Self {
        let s = |v: Micros| -> Micros { ((v as f64 / scale) as Micros).max(1) };
        OverheadModel {
            submit_latency: s(self.submit_latency),
            sched_cycle: s(self.sched_cycle),
            prolog: s(self.prolog),
            epilog: s(self.epilog),
            server_init: s(self.server_init),
            hq_dispatch: self.hq_dispatch,
            contention_per_neighbor: self.contention_per_neighbor,
            bg_interarrival: if self.bg_interarrival == Micros::MAX {
                Micros::MAX
            } else {
                s(self.bg_interarrival)
            },
            bg_duration: s(self.bg_duration),
            bg_cores: self.bg_cores,
            user_quota: self.user_quota,
            quota_penalty: s(self.quota_penalty),
            backfill_delay_factor: self.backfill_delay_factor,
            backfill_cap: s(self.backfill_cap),
        }
    }
}

/// Mutable per-node allocation state.
#[derive(Clone, Debug)]
pub struct NodeState {
    pub free_cores: u32,
    pub free_ram_gb: u32,
    /// Number of distinct jobs currently on the node (contention input).
    pub jobs: u32,
}

/// Tracks free resources across the cluster with first-fit placement.
#[derive(Clone, Debug)]
pub struct Inventory {
    pub spec: ClusterSpec,
    pub nodes: Vec<NodeState>,
}

impl Inventory {
    pub fn new(spec: ClusterSpec) -> Self {
        let nodes = (0..spec.nodes)
            .map(|_| NodeState {
                free_cores: spec.cores_per_node,
                free_ram_gb: spec.ram_gb_per_node,
                jobs: 0,
            })
            .collect();
        Inventory { spec, nodes }
    }

    /// First-fit: find a node with enough free cores and RAM.
    pub fn find_fit(&self, req: &JobRequest) -> Option<usize> {
        self.nodes
            .iter()
            .position(|n| n.free_cores >= req.cores && n.free_ram_gb >= req.ram_gb)
    }

    pub fn allocate(&mut self, node: usize, req: &JobRequest) {
        let n = &mut self.nodes[node];
        assert!(n.free_cores >= req.cores && n.free_ram_gb >= req.ram_gb,
                "oversubscription on node {node}");
        n.free_cores -= req.cores;
        n.free_ram_gb -= req.ram_gb;
        n.jobs += 1;
    }

    pub fn release(&mut self, node: usize, req: &JobRequest) {
        let n = &mut self.nodes[node];
        n.free_cores += req.cores;
        n.free_ram_gb += req.ram_gb;
        n.jobs = n.jobs.saturating_sub(1);
        assert!(n.free_cores <= self.spec.cores_per_node,
                "double release on node {node}");
    }

    /// Co-located job count on a node (excluding the job itself).
    pub fn neighbors(&self, node: usize) -> u32 {
        self.nodes[node].jobs.saturating_sub(1)
    }

    pub fn used_cores(&self) -> u64 {
        self.spec.total_cores()
            - self.nodes.iter().map(|n| n.free_cores as u64).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hamilton8_profile() {
        let c = ClusterSpec::hamilton8();
        assert_eq!(c.total_cores(), 120 * 128);
    }

    #[test]
    fn first_fit_and_release() {
        let mut inv = Inventory::new(ClusterSpec::small(2));
        let req = JobRequest::new(16, 8, SEC);
        let n0 = inv.find_fit(&req).unwrap();
        inv.allocate(n0, &req);
        assert_eq!(inv.nodes[n0].free_cores, 0);
        // Second identical job must land on the other node.
        let n1 = inv.find_fit(&req).unwrap();
        assert_ne!(n0, n1);
        inv.allocate(n1, &req);
        assert!(inv.find_fit(&req).is_none());
        inv.release(n0, &req);
        assert_eq!(inv.find_fit(&req), Some(n0));
    }

    #[test]
    #[should_panic(expected = "oversubscription")]
    fn oversubscription_panics() {
        let mut inv = Inventory::new(ClusterSpec::small(1));
        let req = JobRequest::new(16, 8, SEC);
        inv.allocate(0, &req);
        inv.allocate(0, &req);
    }

    #[test]
    fn ram_constrains_fit() {
        let inv = Inventory::new(ClusterSpec::small(1));
        assert!(inv.find_fit(&JobRequest::new(1, 65, SEC)).is_none());
        assert!(inv.find_fit(&JobRequest::new(1, 64, SEC)).is_some());
    }

    #[test]
    fn neighbors_counts_colocation() {
        let mut inv = Inventory::new(ClusterSpec::small(1));
        let req = JobRequest::new(2, 4, SEC);
        inv.allocate(0, &req);
        inv.allocate(0, &req);
        inv.allocate(0, &req);
        assert_eq!(inv.neighbors(0), 2);
    }

    #[test]
    fn scaling_preserves_ratios() {
        let m = OverheadModel::paper();
        let s = m.scaled(60.0);
        let r0 = m.sched_cycle as f64 / m.prolog as f64;
        let r1 = s.sched_cycle as f64 / s.prolog as f64;
        assert!((r0 - r1).abs() / r0 < 0.01);
        assert_eq!(s.hq_dispatch, m.hq_dispatch); // floor, unscaled
    }

    #[test]
    fn quiet_model_has_no_bg() {
        assert_eq!(OverheadModel::quiet().bg_interarrival, Micros::MAX);
        // and stays off after scaling
        assert_eq!(OverheadModel::quiet().scaled(60.0).bg_interarrival,
                   Micros::MAX);
    }
}
