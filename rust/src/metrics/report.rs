//! Figure/table rendering: grouped boxplot panels (the paper's Figs 3-6)
//! as ASCII + CSV files under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use super::boxplot::BoxStats;

/// One boxplot cell: (group label, series label, values).
pub struct Cell {
    pub group: String,
    pub series: String,
    pub values: Vec<f64>,
}

/// A figure panel (e.g. "makespan, 2 jobs"): cells grouped by application
/// with one box per scheduler, exactly the paper's layout.
pub struct Panel {
    pub title: String,
    pub unit: String,
    pub cells: Vec<Cell>,
    /// Log-scale axis for rendering (the paper's overhead plots span
    /// orders of magnitude).
    pub log: bool,
}

impl Panel {
    pub fn new(title: &str, unit: &str, log: bool) -> Panel {
        Panel { title: title.into(), unit: unit.into(), cells: vec![], log }
    }

    pub fn push(&mut self, group: &str, series: &str, values: Vec<f64>) {
        self.cells.push(Cell {
            group: group.into(),
            series: series.into(),
            values,
        });
    }

    fn axis(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for c in &self.cells {
            for &v in &c.values {
                if v.is_finite() {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
            }
        }
        if !lo.is_finite() {
            return (0.0, 1.0);
        }
        if self.log {
            (lo.max(1e-9), hi.max(lo.max(1e-9) * 10.0))
        } else {
            (lo.min(0.0), hi.max(lo + 1e-9))
        }
    }

    /// ASCII rendering with a shared axis across cells.
    pub fn render(&self) -> String {
        let (lo, hi) = self.axis();
        let width = 56usize;
        let mut out = String::new();
        let _ = writeln!(out, "## {} [{}]", self.title, self.unit);
        let _ = writeln!(
            out,
            "   axis: {:.4} .. {:.4} {}",
            lo, hi, if self.log { "(log)" } else { "" }
        );
        for c in &self.cells {
            let vals: Vec<f64> = if self.log {
                c.values.iter().map(|v| v.max(1e-9).log10()).collect()
            } else {
                c.values.clone()
            };
            let (alo, ahi) = if self.log {
                (lo.log10(), hi.log10())
            } else {
                (lo, hi)
            };
            let s = BoxStats::from(&vals);
            let raw = BoxStats::from(&c.values);
            let _ = writeln!(
                out,
                "   {:>12} {:>6} |{}| med={:.4}",
                c.group,
                c.series,
                s.ascii(alo, ahi, width),
                raw.median
            );
        }
        out
    }

    /// CSV rows: group,series,n,min,q1,median,q3,max,mean,outliers.
    pub fn csv(&self) -> String {
        let mut out =
            String::from("group,series,n,min,q1,median,q3,max,mean,n_outliers\n");
        for c in &self.cells {
            let s = BoxStats::from(&c.values);
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{},{},{},{}",
                c.group, c.series, s.n, s.min, s.q1, s.median, s.q3, s.max,
                s.mean, s.outliers.len()
            );
        }
        out
    }

    /// Raw per-value CSV (for external plotting).
    pub fn csv_raw(&self) -> String {
        let mut out = String::from("group,series,value\n");
        for c in &self.cells {
            for &v in &c.values {
                let _ = writeln!(out, "{},{},{}", c.group, c.series, v);
            }
        }
        out
    }

    pub fn save(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.csv())?;
        std::fs::write(dir.join(format!("{stem}_raw.csv")), self.csv_raw())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn panel() -> Panel {
        let mut p = Panel::new("makespan, 2 jobs", "s", false);
        p.push("eigen-100", "SLURM", vec![30.0, 35.0, 33.0, 60.0]);
        p.push("eigen-100", "HQ", vec![10.0, 11.0, 12.0, 11.5]);
        p
    }

    #[test]
    fn renders_all_cells() {
        let r = panel().render();
        assert!(r.contains("SLURM"));
        assert!(r.contains("HQ"));
        assert!(r.contains("makespan"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let c = panel().csv();
        let lines: Vec<&str> = c.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("group,series"));
        assert!(lines[1].starts_with("eigen-100,SLURM,4,"));
    }

    #[test]
    fn csv_raw_one_row_per_value() {
        let c = panel().csv_raw();
        assert_eq!(c.trim().lines().count(), 1 + 8);
    }

    #[test]
    fn log_axis_handles_wide_range() {
        let mut p = Panel::new("overhead", "s", true);
        p.push("gs2", "SLURM", vec![100.0, 200.0, 150.0]);
        p.push("gs2", "HQ", vec![0.001, 0.002, 0.0015]);
        let r = p.render();
        assert!(r.contains("(log)"));
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("uqsched_test_report");
        let _ = std::fs::remove_dir_all(&dir);
        panel().save(&dir, "fig_test").unwrap();
        assert!(dir.join("fig_test.csv").exists());
        assert!(dir.join("fig_test_raw.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
