//! Lock-free latency histogram for the live balancer plane.
//!
//! Power-of-two microsecond buckets (bucket `i` covers
//! `[2^i, 2^(i+1))` µs), recorded with relaxed atomics so the forwarder
//! hot path pays two `fetch_add`s and one `fetch_max` per sample — no
//! mutex, no allocation.  Quantiles are reconstructed from the bucket
//! counts at snapshot time (upper-bound estimate, i.e. a quantile is
//! reported as the top edge of the bucket it falls in).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::json::Value;

/// Number of log2 buckets: covers up to 2^39 µs ≈ 6.4 days.
const BUCKETS: usize = 40;

/// Lock-free log2 latency histogram (microsecond domain).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_index(us: u64) -> usize {
        // floor(log2(us)) for us >= 1; 0 µs lands in bucket 0.
        let i = 63 - (us | 1).leading_zeros() as usize;
        i.min(BUCKETS - 1)
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let count: u64 = counts.iter().sum();
        let sum_us = self.sum_us.load(Ordering::Relaxed);
        let max_us = self.max_us.load(Ordering::Relaxed);
        let quantile = |q: f64| -> u64 {
            if count == 0 {
                return 0;
            }
            let rank = ((count as f64) * q).ceil().max(1.0) as u64;
            let mut seen = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                seen += c;
                if seen >= rank {
                    // Upper edge of the bucket, capped by the true max.
                    return (1u64 << (i + 1)).min(max_us.max(1));
                }
            }
            max_us
        };
        HistogramSnapshot {
            count,
            mean_us: if count == 0 { 0.0 } else { sum_us as f64 / count as f64 },
            p50_us: quantile(0.50),
            p90_us: quantile(0.90),
            p99_us: quantile(0.99),
            max_us,
        }
    }

    /// JSON for the `/Stats` endpoint and the bench reports.
    pub fn json(&self) -> Value {
        self.snapshot().json()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time view of a [`Histogram`].
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean_us: f64,
    /// Quantiles are bucket upper bounds (log2 µs buckets).
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl HistogramSnapshot {
    pub fn json(&self) -> Value {
        Value::obj(vec![
            ("count", Value::num(self.count as f64)),
            ("mean_us", Value::num(self.mean_us)),
            ("p50_us", Value::num(self.p50_us as f64)),
            ("p90_us", Value::num(self.p90_us as f64)),
            ("p99_us", Value::num(self.p99_us as f64)),
            ("max_us", Value::num(self.max_us as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.mean_us, 0.0);
    }

    #[test]
    fn bucket_index_is_log2() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 1);
        assert_eq!(Histogram::bucket_index(1024), 10);
        assert_eq!(Histogram::bucket_index(u64::MAX), 39);
    }

    #[test]
    fn records_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(Duration::from_micros(100)); // bucket 6 [64,128)
        }
        for _ in 0..10 {
            h.record(Duration::from_micros(10_000)); // bucket 13
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.max_us, 10_000);
        // p50 falls in the 100 µs bucket: upper edge 128.
        assert_eq!(s.p50_us, 128);
        assert_eq!(s.p90_us, 128);
        // p99 falls in the 10 ms bucket; capped by the true max.
        assert_eq!(s.p99_us, 10_000);
        let mean = (90.0 * 100.0 + 10.0 * 10_000.0) / 100.0;
        assert!((s.mean_us - mean).abs() < 1e-9);
    }

    #[test]
    fn max_is_exact() {
        let h = Histogram::new();
        h.record(Duration::from_micros(7));
        h.record(Duration::from_micros(777));
        h.record(Duration::from_micros(77));
        assert_eq!(h.snapshot().max_us, 777);
    }

    #[test]
    fn json_has_all_fields() {
        let h = Histogram::new();
        h.record(Duration::from_micros(50));
        let v = h.json();
        for k in ["count", "mean_us", "p50_us", "p90_us", "p99_us", "max_us"] {
            assert!(v.get(k).is_some(), "missing {k}");
        }
    }
}
