//! Metrics: the paper's measured quantities (section IV.A) and their
//! presentation (boxplot statistics, ASCII rendering, CSV export).
//!
//! * makespan  — job end minus job submission
//! * CPU time  — timer starts when the job starts on the node
//! * overhead  — makespan - CPU time (queueing deliberately included)
//! * SLR       — makespan / CPU time (Schedule Length Ratio, [39])

use crate::clock::{Micros, SEC};

pub mod boxplot;
pub mod histogram;
pub mod report;

pub use boxplot::BoxStats;
pub use histogram::{Histogram, HistogramSnapshot};

/// Per-job timing record (native-log equivalent).
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    /// Caller correlation id (evaluation index).
    pub tag: u64,
    /// Submission time.
    pub submit: Micros,
    /// Job start on the node (allocation granted).
    pub start: Micros,
    /// Job end.
    pub end: Micros,
    /// CPU time (from job start, includes environment setup).
    pub cpu: Micros,
    /// True if killed by a time limit / cancellation.
    pub truncated: bool,
}

impl JobRecord {
    pub fn makespan(&self) -> Micros {
        self.end.saturating_sub(self.submit)
    }

    /// Scheduling overhead: makespan minus CPU time.
    pub fn overhead(&self) -> Micros {
        self.makespan().saturating_sub(self.cpu)
    }

    /// Per-job Schedule Length Ratio.
    pub fn slr(&self) -> f64 {
        if self.cpu == 0 {
            1.0
        } else {
            self.makespan() as f64 / self.cpu as f64
        }
    }

    /// Apply log granularity (paper: SLURM logs whole seconds, with
    /// "extra checks ... to prevent erroneous results such as negative
    /// overhead"; if the rounded makespan underflows the CPU time, set it
    /// to the CPU time and assume zero overhead).
    pub fn quantised(&self, granularity: Micros) -> JobRecord {
        let q = |v: Micros| (v / granularity) * granularity;
        let mut r = JobRecord {
            tag: self.tag,
            submit: q(self.submit),
            start: q(self.start),
            end: q(self.end),
            cpu: self.cpu, // SLURM keeps CPU time at microsecond accuracy
            truncated: self.truncated,
        };
        if r.end.saturating_sub(r.submit) < r.cpu {
            // The paper's workaround, reproduced.
            r.end = r.submit + r.cpu;
        }
        r
    }
}

/// A finished benchmark: one scheduler x application x queue-depth cell.
#[derive(Clone, Debug, Default)]
pub struct Experiment {
    pub label: String,
    pub records: Vec<JobRecord>,
}

impl Experiment {
    pub fn new(label: &str) -> Self {
        Experiment { label: label.to_string(), records: Vec::new() }
    }

    /// Whole-experiment makespan: last end minus first submit.
    pub fn makespan(&self) -> Micros {
        let first = self.records.iter().map(|r| r.submit).min().unwrap_or(0);
        let last = self.records.iter().map(|r| r.end).max().unwrap_or(0);
        last.saturating_sub(first)
    }

    pub fn total_cpu(&self) -> Micros {
        self.records.iter().map(|r| r.cpu).sum()
    }

    /// Experiment-level SLR (the paper's headline formulation).
    pub fn slr(&self) -> f64 {
        let cpu = self.total_cpu();
        if cpu == 0 {
            1.0
        } else {
            self.makespan() as f64 / cpu as f64
        }
    }

    pub fn makespans_sec(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.makespan() as f64 / SEC as f64).collect()
    }

    pub fn cpus_sec(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.cpu as f64 / SEC as f64).collect()
    }

    pub fn overheads_sec(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.overhead() as f64 / SEC as f64).collect()
    }

    pub fn slrs(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.slr()).collect()
    }

    /// All record end times, ascending — the completion curve.  The
    /// campaign plane derives its time-to-Nth milestones from one call
    /// (sorting once instead of per milestone).
    pub fn ends_sorted(&self) -> Vec<Micros> {
        let mut ends: Vec<Micros> =
            self.records.iter().map(|r| r.end).collect();
        ends.sort_unstable();
        ends
    }

    /// Virtual time from campaign start (t = 0) to the `n`th completed
    /// record (1-indexed, in completion order).  `None` when fewer than
    /// `n` records exist or `n == 0`.  Campaign-plane metric: how fast
    /// results accumulate, independent of per-job overheads.  For many
    /// milestones at once, use [`Experiment::ends_sorted`].
    pub fn time_to_nth_result(&self, n: usize) -> Option<Micros> {
        if n == 0 || n > self.records.len() {
            return None;
        }
        Some(self.ends_sorted()[n - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::MS;

    fn rec(submit: Micros, start: Micros, end: Micros, cpu: Micros) -> JobRecord {
        JobRecord { tag: 0, submit, start, end, cpu, truncated: false }
    }

    #[test]
    fn per_job_metrics() {
        let r = rec(0, 10 * SEC, 30 * SEC, 15 * SEC);
        assert_eq!(r.makespan(), 30 * SEC);
        assert_eq!(r.overhead(), 15 * SEC);
        assert!((r.slr() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_cpu_slr_is_one() {
        let r = rec(0, 0, 0, 0);
        assert_eq!(r.slr(), 1.0);
    }

    #[test]
    fn quantisation_prevents_negative_overhead() {
        // 400 ms job inside one log second: naive rounding would give
        // makespan 0 < cpu.
        let r = rec(100 * MS, 150 * MS, 500 * MS, 350 * MS);
        let q = r.quantised(SEC);
        assert!(q.makespan() >= q.cpu);
        assert_eq!(q.overhead(), 0);
    }

    #[test]
    fn quantisation_floors_to_grain() {
        let r = rec(1_400 * MS, 2_300 * MS, 9_900 * MS, 2 * SEC);
        let q = r.quantised(SEC);
        assert_eq!(q.submit, 1 * SEC);
        assert_eq!(q.end, 9 * SEC);
        assert_eq!(q.cpu, 2 * SEC); // untouched
    }

    #[test]
    fn experiment_makespan_spans_all() {
        let mut e = Experiment::new("x");
        e.records.push(rec(5 * SEC, 6 * SEC, 20 * SEC, 10 * SEC));
        e.records.push(rec(0, 1 * SEC, 9 * SEC, 8 * SEC));
        assert_eq!(e.makespan(), 20 * SEC);
        assert_eq!(e.total_cpu(), 18 * SEC);
        assert!((e.slr() - 20.0 / 18.0).abs() < 1e-12);
    }

    #[test]
    fn time_to_nth_is_sorted_ends() {
        let mut e = Experiment::new("x");
        e.records.push(rec(0, SEC, 30 * SEC, 10 * SEC));
        e.records.push(rec(0, SEC, 10 * SEC, 8 * SEC));
        e.records.push(rec(0, SEC, 20 * SEC, 8 * SEC));
        assert_eq!(e.time_to_nth_result(1), Some(10 * SEC));
        assert_eq!(e.time_to_nth_result(2), Some(20 * SEC));
        assert_eq!(e.time_to_nth_result(3), Some(30 * SEC));
        assert_eq!(e.time_to_nth_result(4), None);
        assert_eq!(e.time_to_nth_result(0), None);
    }

    #[test]
    fn truncated_flag_carried() {
        let mut r = rec(0, 0, SEC, SEC);
        r.truncated = true;
        assert!(r.quantised(SEC).truncated);
    }
}
