//! Boxplot statistics (Tukey) and ASCII rendering — the paper presents
//! every result (Figs 3-6) as boxplots; these are the numbers behind them.

/// Five-number summary with 1.5-IQR whiskers and explicit outliers.
#[derive(Clone, Debug, PartialEq)]
pub struct BoxStats {
    pub n: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    pub whisker_lo: f64,
    pub whisker_hi: f64,
    pub outliers: Vec<f64>,
}

/// Linear-interpolation quantile on a sorted slice (numpy default).
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

impl BoxStats {
    pub fn from(values: &[f64]) -> BoxStats {
        let mut v: Vec<f64> = values.iter().copied()
            .filter(|x| x.is_finite()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if v.is_empty() {
            return BoxStats {
                n: 0, min: f64::NAN, q1: f64::NAN, median: f64::NAN,
                q3: f64::NAN, max: f64::NAN, mean: f64::NAN,
                whisker_lo: f64::NAN, whisker_hi: f64::NAN,
                outliers: vec![],
            };
        }
        let q1 = quantile(&v, 0.25);
        let median = quantile(&v, 0.5);
        let q3 = quantile(&v, 0.75);
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = v.iter().copied().find(|&x| x >= lo_fence)
            .unwrap_or(v[0]);
        let whisker_hi = v.iter().rev().copied().find(|&x| x <= hi_fence)
            .unwrap_or(v[v.len() - 1]);
        let outliers: Vec<f64> = v.iter().copied()
            .filter(|&x| x < whisker_lo || x > whisker_hi).collect();
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        BoxStats {
            n: v.len(),
            min: v[0],
            q1,
            median,
            q3,
            max: v[v.len() - 1],
            mean,
            whisker_lo,
            whisker_hi,
            outliers,
        }
    }

    /// One-line summary, the row format the bench harnesses print.
    pub fn row(&self) -> String {
        format!(
            "n={:<4} min={:<10.3} q1={:<10.3} med={:<10.3} q3={:<10.3} \
             max={:<10.3} mean={:<10.3} outliers={}",
            self.n, self.min, self.q1, self.median, self.q3, self.max,
            self.mean, self.outliers.len()
        )
    }

    /// ASCII boxplot on a shared [lo, hi] axis, `width` chars wide.
    pub fn ascii(&self, lo: f64, hi: f64, width: usize) -> String {
        if self.n == 0 || hi <= lo {
            return " ".repeat(width);
        }
        let pos = |x: f64| -> usize {
            let f = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
            ((f * (width.saturating_sub(1)) as f64).round() as usize)
                .min(width - 1)
        };
        let mut row = vec![b' '; width];
        let (wl, q1, md, q3, wh) = (
            pos(self.whisker_lo), pos(self.q1), pos(self.median),
            pos(self.q3), pos(self.whisker_hi),
        );
        for c in row.iter_mut().take(q1).skip(wl) {
            *c = b'-';
        }
        for c in row.iter_mut().take(wh + 1).skip(q3) {
            *c = b'-';
        }
        for c in row.iter_mut().take(q3 + 1).skip(q1) {
            *c = b'=';
        }
        row[wl] = b'|';
        row[wh] = b'|';
        row[md] = b'#';
        for &o in &self.outliers {
            row[pos(o)] = b'o';
        }
        String::from_utf8(row).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_number_summary() {
        let s = BoxStats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert!(s.outliers.is_empty());
    }

    #[test]
    fn detects_outliers() {
        let mut v: Vec<f64> = (0..20).map(|i| 10.0 + i as f64 * 0.1).collect();
        v.push(1000.0);
        let s = BoxStats::from(&v);
        assert_eq!(s.outliers, vec![1000.0]);
        assert!(s.whisker_hi < 1000.0);
        assert_eq!(s.max, 1000.0);
    }

    #[test]
    fn single_value() {
        let s = BoxStats::from(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.q1, 7.0);
        assert_eq!(s.n, 1);
    }

    #[test]
    fn empty_is_nan() {
        let s = BoxStats::from(&[]);
        assert_eq!(s.n, 0);
        assert!(s.median.is_nan());
    }

    #[test]
    fn nan_inputs_filtered() {
        let s = BoxStats::from(&[1.0, f64::NAN, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn quantile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&v, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn ascii_renders_box() {
        let s = BoxStats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let a = s.ascii(0.0, 6.0, 40);
        assert_eq!(a.len(), 40);
        assert!(a.contains('#'));
        assert!(a.contains('='));
    }
}
