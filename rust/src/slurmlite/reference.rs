//! Reference slurmlite core: the pre-index seed semantics, kept verbatim.
//!
//! This is the O(n)-everything implementation the indexed
//! [`SlurmCore`](super::core::SlurmCore) replaced: a flat pending `Vec`
//! re-sorted every scheduler pass, `Vec::retain` cancellation, and a
//! jobs map that grows forever.  It exists for two reasons:
//!
//! 1. **Equivalence testing** — `tests/scheduler_props.rs` drives random
//!    traces through both cores and asserts identical action/record
//!    streams; the reference pins the seed semantics.
//! 2. **Baseline benchmarking** — `benches/scale.rs` measures the
//!    speedup of the indexed core against this one.
//!
//! Behaviour matches the seed exactly; both cores consume the RNG in the
//! same order, so identical seeds produce identical background load.

use std::collections::HashMap;

use crate::cluster::{ClusterSpec, Inventory, JobRequest, OverheadModel};
use crate::clock::Micros;
use crate::metrics::JobRecord;
use crate::util::Rng;

use super::core::{Action, JobId, JobState, Timer, USER_BACKGROUND};

// `id`/`run_t`/`contention` mirror the seed's bookkeeping; they are
// write-only here but kept so the struct layout matches the original.
#[allow(dead_code)]
#[derive(Clone, Debug)]
struct Job {
    id: JobId,
    user: u32,
    tag: u64,
    req: JobRequest,
    state: JobState,
    submit_t: Micros,
    eligible_t: Micros,
    alloc_t: Micros,
    run_t: Micros,
    node: usize,
    contention: f64,
    bg_duration: Option<Micros>,
}

/// Seed-semantics scheduler core (naive pending queue).
pub struct ReferenceSlurmCore {
    inv: Inventory,
    model: OverheadModel,
    jobs: HashMap<JobId, Job>,
    pending: Vec<JobId>,
    next_id: JobId,
    user_submits: HashMap<u32, u32>,
    rng: Rng,
    bg_started: bool,
    pub cycles: u64,
}

impl ReferenceSlurmCore {
    pub fn new(spec: ClusterSpec, model: OverheadModel, seed: u64) -> Self {
        ReferenceSlurmCore {
            inv: Inventory::new(spec),
            model,
            jobs: HashMap::new(),
            pending: Vec::new(),
            next_id: 1,
            user_submits: HashMap::new(),
            rng: Rng::new(seed),
            bg_started: false,
            cycles: 0,
        }
    }

    pub fn model(&self) -> &OverheadModel {
        &self.model
    }

    pub fn bootstrap(&mut self, t: Micros) -> Vec<Action> {
        let mut acts = vec![Action::Timer(t + self.model.sched_cycle, Timer::Cycle)];
        if self.model.bg_interarrival != Micros::MAX && !self.bg_started {
            self.bg_started = true;
            let dt = self.rng.exponential(self.model.bg_interarrival as f64);
            acts.push(Action::Timer(t + dt as Micros, Timer::BgArrival));
        }
        acts
    }

    pub fn submit(
        &mut self,
        t: Micros,
        user: u32,
        tag: u64,
        req: JobRequest,
    ) -> (JobId, Vec<Action>) {
        let id = self.next_id;
        self.next_id += 1;
        *self.user_submits.entry(user).or_insert(0) += 1;
        let bf = (self.model.backfill_delay_factor
            * req.time_limit.min(self.model.backfill_cap) as f64
            * self.rng.range(0.5, 1.5)) as Micros;
        let eligible_t = t + self.model.submit_latency + bf;
        self.jobs.insert(
            id,
            Job {
                id,
                user,
                tag,
                req,
                state: JobState::Submitting,
                submit_t: t,
                eligible_t,
                alloc_t: 0,
                run_t: 0,
                node: usize::MAX,
                contention: 1.0,
                bg_duration: None,
            },
        );
        (id, vec![Action::Timer(eligible_t, Timer::Eligible(id))])
    }

    pub fn cancel(&mut self, t: Micros, id: JobId) -> Vec<Action> {
        let Some(job) = self.jobs.get_mut(&id) else { return vec![] };
        match job.state {
            JobState::Pending | JobState::Submitting => {
                job.state = JobState::Cancelled;
                self.pending.retain(|&p| p != id);
                let job = &self.jobs[&id];
                vec![Action::Completed {
                    job: id,
                    record: JobRecord {
                        tag: job.tag,
                        submit: job.submit_t,
                        start: t,
                        end: t,
                        cpu: 0,
                        truncated: true,
                    },
                }]
            }
            JobState::Starting | JobState::Running => self.finish_inner(t, id, true),
            _ => vec![],
        }
    }

    pub fn on_finish(&mut self, t: Micros, id: JobId) -> Vec<Action> {
        self.finish_inner(t, id, false)
    }

    pub fn on_timer(&mut self, t: Micros, timer: Timer) -> Vec<Action> {
        match timer {
            Timer::Cycle => self.on_cycle(t),
            Timer::Eligible(id) => {
                if let Some(j) = self.jobs.get_mut(&id) {
                    if j.state == JobState::Submitting {
                        j.state = JobState::Pending;
                        self.pending.push(id);
                    }
                }
                vec![]
            }
            Timer::Start(id) => self.on_prolog_done(t, id),
            Timer::Limit(id) => {
                let timed_out = matches!(
                    self.jobs.get(&id).map(|j| j.state),
                    Some(JobState::Running) | Some(JobState::Starting)
                );
                if timed_out {
                    let mut acts = vec![Action::TimedOut { job: id }];
                    acts.extend(self.finish_inner(t, id, true));
                    acts
                } else {
                    vec![]
                }
            }
            Timer::BgArrival => self.on_bg_arrival(t),
            Timer::BgFinish(id) => self.on_finish(t, id),
        }
    }

    /// One scheduler pass: full clone + sort of the pending queue (the
    /// seed behaviour the indexed core is benchmarked against).
    fn on_cycle(&mut self, t: Micros) -> Vec<Action> {
        self.cycles += 1;
        let mut acts = Vec::new();

        let mut order: Vec<JobId> = self.pending.clone();
        let prio = |core: &Self, id: JobId| -> i64 {
            let j = &core.jobs[&id];
            let submits = *core.user_submits.get(&j.user).unwrap_or(&0);
            let excess = submits.saturating_sub(core.model.user_quota) as i64;
            j.eligible_t as i64
                + excess * core.model.quota_penalty as i64
                    * if j.user == USER_BACKGROUND { 0 } else { 1 }
        };
        order.sort_by_key(|&id| prio(self, id));

        for id in order {
            let job = &self.jobs[&id];
            if job.state != JobState::Pending {
                continue;
            }
            if let Some(node) = self.inv.find_fit(&job.req) {
                self.inv.allocate(node, &job.req);
                let job = self.jobs.get_mut(&id).unwrap();
                job.state = JobState::Starting;
                job.alloc_t = t;
                job.node = node;
                self.pending.retain(|&p| p != id);
                acts.push(Action::Timer(t + self.model.prolog, Timer::Start(id)));
                acts.push(Action::Timer(
                    t + self.model.prolog + job.req.time_limit,
                    Timer::Limit(id),
                ));
            }
        }

        acts.push(Action::Timer(t + self.model.sched_cycle, Timer::Cycle));
        acts
    }

    fn on_prolog_done(&mut self, t: Micros, id: JobId) -> Vec<Action> {
        let Some(job) = self.jobs.get_mut(&id) else { return vec![] };
        if job.state != JobState::Starting {
            return vec![];
        }
        job.state = JobState::Running;
        job.run_t = t;
        let node = job.node;
        let bg = job.bg_duration;
        let neighbors = self.inv.neighbors(node);
        let contention =
            1.0 + self.model.contention_per_neighbor * neighbors as f64;
        self.jobs.get_mut(&id).unwrap().contention = contention;
        let mut acts = vec![Action::Launched { job: id, node, contention }];
        if let Some(dur) = bg {
            acts.push(Action::Timer(t + dur, Timer::BgFinish(id)));
        }
        acts
    }

    fn finish_inner(&mut self, t: Micros, id: JobId, truncated: bool) -> Vec<Action> {
        let Some(job) = self.jobs.get_mut(&id) else { return vec![] };
        if !matches!(job.state, JobState::Running | JobState::Starting) {
            return vec![];
        }
        job.state = if truncated { JobState::Cancelled } else { JobState::Done };
        let node = job.node;
        let req = job.req;
        let cpu = t.saturating_sub(job.alloc_t);
        let record = JobRecord {
            tag: job.tag,
            submit: job.submit_t,
            start: job.alloc_t,
            end: t,
            cpu,
            truncated,
        };
        self.inv.release(node, &req);
        vec![Action::Completed { job: id, record }]
    }

    fn on_bg_arrival(&mut self, t: Micros) -> Vec<Action> {
        if self.pending.len() > 512 {
            let dt = self.rng.exponential(self.model.bg_interarrival as f64);
            return vec![Action::Timer(t + dt as Micros, Timer::BgArrival)];
        }
        let (lo, hi) = self.model.bg_cores;
        let cores = lo + (self.rng.below((hi - lo + 1) as u64) as u32);
        let dur = self.rng.exponential(self.model.bg_duration as f64) as Micros;
        let req = JobRequest::new(cores, (cores / 2).max(4), dur * 4 + 1);
        let (id, mut acts) = self.submit(t, USER_BACKGROUND, u64::MAX, req);
        self.jobs.get_mut(&id).unwrap().bg_duration = Some(dur);
        let dt = self.rng.exponential(self.model.bg_interarrival as f64);
        acts.push(Action::Timer(t + dt as Micros, Timer::BgArrival));
        acts
    }

    // ---- Introspection ---------------------------------------------------

    pub fn state_of(&self, id: JobId) -> Option<JobState> {
        self.jobs.get(&id).map(|j| j.state)
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn running_count(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| matches!(j.state, JobState::Running | JobState::Starting))
            .count()
    }

    pub fn used_cores(&self) -> u64 {
        self.inv.used_cores()
    }

    /// Jobs resident in the (never-evicting) map.
    pub fn resident_jobs(&self) -> usize {
        self.jobs.len()
    }
}
