//! The slurmlite scheduling state machine.
//!
//! Pure logic: every method takes the current time `t` and returns
//! actions for the driver (DES or real-time daemon) to interpret.  The
//! driver owns workload durations — slurmlite only learns a job is done
//! when the driver calls [`SlurmCore::on_finish`].
//!
//! # Scale architecture (see PERF.md)
//!
//! UQ workflows submit 10⁵–10⁶ similar jobs, so every per-event cost
//! must stay (amortised) logarithmic in the pending-queue depth:
//!
//! * The pending queue is a set of per-user `BTreeSet<(eligible_t, seq,
//!   id)>` lanes.  Within one user every job carries the same quota
//!   offset, so lane order *is* priority order; a scheduler pass merges
//!   the lane heads instead of re-sorting the whole queue.
//! * Placement failures are cached per pass in a dominance frontier: once
//!   a `(cores, ram)` shape fails, any shape requesting at least as much
//!   is skipped without touching the inventory, and the pass terminates
//!   outright when the frontier covers the queue-wide minimum request —
//!   O(started + 1) per cycle for the homogeneous queues UQ produces.
//! * `cancel` removes the tree entry directly: O(log n), replacing the
//!   seed's O(n) `Vec::retain`.
//! * Terminal jobs are evicted from the hot `jobs` map into a dense
//!   append-only final-state archive (1 byte/job), so the map is bounded
//!   by in-flight work no matter how many jobs have retired.
//! * Every transition appends into a caller-supplied action buffer
//!   (the [`BatchCore`] trait's `*_into` methods); the allocating
//!   wrappers are provided (default) trait methods for call sites where
//!   a fresh `Vec` per event is fine (live daemon, tests).

use std::collections::{BTreeSet, HashMap};

use crate::cluster::{ClusterSpec, Inventory, JobRequest, OverheadModel};
use crate::clock::Micros;
use crate::metrics::JobRecord;
use crate::util::Rng;

pub type JobId = u64;

/// User id 0 is the experiment user; background load uses user 1.
pub const USER_EXPERIMENT: u32 = 0;
pub const USER_BACKGROUND: u32 = 1;

/// Pending-lane key: (eligible time, admission sequence, job id).  The
/// sequence is assigned when the job becomes Pending and reproduces the
/// seed's stable-sort tie-breaking (queue entry order).
type PendKey = (Micros, u64, JobId);

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JobState {
    /// Submitted, not yet eligible (sbatch RPC in flight).
    Submitting,
    /// In the pending queue.
    Pending,
    /// Allocated; prolog running on the node.
    Starting,
    /// Running the user workload.
    Running,
    /// Finished (kept for record queries).
    Done,
    Cancelled,
}

/// What the driver must do in response to a core transition.
#[derive(Clone, Debug)]
pub enum Action {
    /// Re-invoke the core at this absolute time (timer).
    Timer(Micros, Timer),
    /// The job finished its prolog and is now running the workload: the
    /// driver starts the real workload (live) or schedules `on_finish`
    /// after the sampled duration (sim).  `contention` is the CPU-time
    /// inflation factor from co-located jobs.
    Launched { job: JobId, node: usize, contention: f64 },
    /// Job hit its time limit; driver must stop the workload.
    TimedOut { job: JobId },
    /// Terminal record for a completed/cancelled job.
    Completed { job: JobId, record: JobRecord },
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Timer {
    /// Scheduler pass.
    Cycle,
    /// Submission RPC done; job becomes pending.
    Eligible(JobId),
    /// Prolog done; job starts running.
    Start(JobId),
    /// Time-limit enforcement.
    Limit(JobId),
    /// Background-load arrival.
    BgArrival,
    /// Background job completion.
    BgFinish(JobId),
}

/// The SLURM-style batch-core event surface.
///
/// The `*_into` sink methods are the primary API (append actions into a
/// caller-supplied buffer — allocation-lean on the million-task sim
/// paths); the Vec-returning wrappers are provided methods for low-rate
/// callers (live daemon, tests), so the `let mut out = Vec::new()`
/// boilerplate lives here exactly once.
pub trait BatchCore {
    /// sbatch, appending actions into a reusable buffer.
    fn submit_into(
        &mut self,
        t: Micros,
        user: u32,
        tag: u64,
        req: JobRequest,
        out: &mut Vec<Action>,
    ) -> JobId;

    /// scancel, appending actions into a reusable buffer.
    fn cancel_into(&mut self, t: Micros, id: JobId, out: &mut Vec<Action>);

    /// Workload-completion signal, appending into a reusable buffer.
    fn on_finish_into(&mut self, t: Micros, id: JobId, out: &mut Vec<Action>);

    /// Timer dispatch, appending into a reusable buffer.
    fn on_timer_into(&mut self, t: Micros, timer: Timer, out: &mut Vec<Action>);

    /// sbatch: submit a job.  Returns the id plus actions.
    fn submit(
        &mut self,
        t: Micros,
        user: u32,
        tag: u64,
        req: JobRequest,
    ) -> (JobId, Vec<Action>) {
        let mut out = Vec::new();
        let id = self.submit_into(t, user, tag, req, &mut out);
        (id, out)
    }

    /// scancel.
    fn cancel(&mut self, t: Micros, id: JobId) -> Vec<Action> {
        let mut out = Vec::new();
        self.cancel_into(t, id, &mut out);
        out
    }

    /// Driver signals the workload completed.
    fn on_finish(&mut self, t: Micros, id: JobId) -> Vec<Action> {
        let mut out = Vec::new();
        self.on_finish_into(t, id, &mut out);
        out
    }

    /// Timer dispatch.
    fn on_timer(&mut self, t: Micros, timer: Timer) -> Vec<Action> {
        let mut out = Vec::new();
        self.on_timer_into(t, timer, &mut out);
        out
    }
}

#[derive(Clone, Debug)]
struct Job {
    #[allow(dead_code)] // diagnostic mirror of the map key
    id: JobId,
    user: u32,
    tag: u64,
    req: JobRequest,
    state: JobState,
    submit_t: Micros,
    eligible_t: Micros,
    alloc_t: Micros,
    #[allow(dead_code)] // kept for squeue-style debugging
    run_t: Micros,
    node: usize,
    #[allow(dead_code)] // kept for squeue-style debugging
    contention: f64,
    /// Pending-lane sequence (admission order; valid while Pending).
    pend_seq: u64,
    /// Background jobs carry their own duration (self-finishing).
    bg_duration: Option<Micros>,
}

/// Terminal states in the retired-job archive (1 byte per job ever
/// submitted; the hot map holds in-flight jobs only).
const FINAL_NONE: u8 = 0;
const FINAL_DONE: u8 = 1;
const FINAL_CANCELLED: u8 = 2;

/// The scheduler core.
pub struct SlurmCore {
    inv: Inventory,
    model: OverheadModel,
    /// In-flight jobs only (Submitting/Pending/Starting/Running).
    jobs: HashMap<JobId, Job>,
    /// Priority-indexed pending queue, one ordered lane per user.
    pending: HashMap<u32, BTreeSet<PendKey>>,
    pending_len: usize,
    pend_seq: u64,
    /// Conservative lower bounds over every request that ever entered the
    /// pending queue (monotone; never raised).  Used to terminate a
    /// scheduler pass early once the failure frontier covers them.
    min_cores_floor: u32,
    min_ram_floor: u32,
    /// Append-only archive of terminal states, indexed by `JobId` (ids
    /// are dense and sequential, so this is a flat byte array).
    final_states: Vec<u8>,
    retired: u64,
    next_id: JobId,
    user_submits: HashMap<u32, u32>,
    rng: Rng,
    bg_started: bool,
    /// Statistics: scheduler passes run.
    pub cycles: u64,
}

impl SlurmCore {
    pub fn new(spec: ClusterSpec, model: OverheadModel, seed: u64) -> Self {
        SlurmCore {
            inv: Inventory::new(spec),
            model,
            jobs: HashMap::new(),
            pending: HashMap::new(),
            pending_len: 0,
            pend_seq: 0,
            min_cores_floor: u32::MAX,
            min_ram_floor: u32::MAX,
            final_states: Vec::new(),
            retired: 0,
            next_id: 1,
            user_submits: HashMap::new(),
            rng: Rng::new(seed),
            bg_started: false,
            cycles: 0,
        }
    }

    pub fn model(&self) -> &OverheadModel {
        &self.model
    }

    /// Kick off periodic timers (first cycle + background load).  Call
    /// once after construction.
    pub fn bootstrap(&mut self, t: Micros) -> Vec<Action> {
        let mut acts = vec![Action::Timer(t + self.model.sched_cycle, Timer::Cycle)];
        if self.model.bg_interarrival != Micros::MAX && !self.bg_started {
            self.bg_started = true;
            let dt = self.rng.exponential(self.model.bg_interarrival as f64);
            acts.push(Action::Timer(t + dt as Micros, Timer::BgArrival));
        }
        acts
    }

    // ---- Introspection (squeue-like) ------------------------------------

    pub fn state_of(&self, id: JobId) -> Option<JobState> {
        if let Some(j) = self.jobs.get(&id) {
            return Some(j.state);
        }
        match self.final_states.get(id as usize) {
            Some(&FINAL_DONE) => Some(JobState::Done),
            Some(&FINAL_CANCELLED) => Some(JobState::Cancelled),
            _ => None,
        }
    }

    pub fn pending_count(&self) -> usize {
        self.pending_len
    }

    pub fn running_count(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| matches!(j.state, JobState::Running | JobState::Starting))
            .count()
    }

    pub fn used_cores(&self) -> u64 {
        self.inv.used_cores()
    }

    /// Node of an in-flight job (terminal jobs are archived without
    /// placement detail).
    pub fn node_of(&self, id: JobId) -> Option<usize> {
        self.jobs.get(&id).and_then(|j| {
            (j.node != usize::MAX).then_some(j.node)
        })
    }

    /// Jobs resident in the hot map (bounded by in-flight work).
    pub fn resident_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Jobs evicted to the terminal-state archive.
    pub fn retired_count(&self) -> u64 {
        self.retired
    }
}

impl BatchCore for SlurmCore {
    fn submit_into(
        &mut self,
        t: Micros,
        user: u32,
        tag: u64,
        req: JobRequest,
        out: &mut Vec<Action>,
    ) -> JobId {
        let id = self.next_id;
        self.next_id += 1;
        *self.user_submits.entry(user).or_insert(0) += 1;
        // Backfill proxy: long requested walltimes queue longer (the
        // scheduler cannot slot them into reservation gaps).
        let bf = (self.model.backfill_delay_factor
            * req.time_limit.min(self.model.backfill_cap) as f64
            * self.rng.range(0.5, 1.5)) as Micros;
        let eligible_t = t + self.model.submit_latency + bf;
        self.jobs.insert(
            id,
            Job {
                id,
                user,
                tag,
                req,
                state: JobState::Submitting,
                submit_t: t,
                eligible_t,
                alloc_t: 0,
                run_t: 0,
                node: usize::MAX,
                contention: 1.0,
                pend_seq: 0,
                bg_duration: None,
            },
        );
        out.push(Action::Timer(eligible_t, Timer::Eligible(id)));
        id
    }

    fn cancel_into(&mut self, t: Micros, id: JobId, out: &mut Vec<Action>) {
        let Some(job) = self.jobs.get(&id) else { return };
        match job.state {
            JobState::Pending | JobState::Submitting => {
                if job.state == JobState::Pending {
                    let key = (job.eligible_t, job.pend_seq, id);
                    let user = job.user;
                    if let Some(lane) = self.pending.get_mut(&user) {
                        if lane.remove(&key) {
                            self.pending_len -= 1;
                        }
                    }
                }
                let job = self.retire(id, FINAL_CANCELLED);
                out.push(Action::Completed {
                    job: id,
                    record: JobRecord {
                        tag: job.tag,
                        submit: job.submit_t,
                        start: t,
                        end: t,
                        cpu: 0,
                        truncated: true,
                    },
                });
            }
            JobState::Starting | JobState::Running => {
                self.finish_inner(t, id, true, out)
            }
            _ => {}
        }
    }

    fn on_finish_into(&mut self, t: Micros, id: JobId, out: &mut Vec<Action>) {
        self.finish_inner(t, id, false, out)
    }

    fn on_timer_into(&mut self, t: Micros, timer: Timer, out: &mut Vec<Action>) {
        match timer {
            Timer::Cycle => self.on_cycle(t, out),
            Timer::Eligible(id) => {
                if let Some(j) = self.jobs.get_mut(&id) {
                    if j.state == JobState::Submitting {
                        j.state = JobState::Pending;
                        j.pend_seq = self.pend_seq;
                        self.pend_seq += 1;
                        let key = (j.eligible_t, j.pend_seq, id);
                        let user = j.user;
                        self.min_cores_floor = self.min_cores_floor.min(j.req.cores);
                        self.min_ram_floor = self.min_ram_floor.min(j.req.ram_gb);
                        self.pending.entry(user).or_default().insert(key);
                        self.pending_len += 1;
                    }
                }
            }
            Timer::Start(id) => self.on_prolog_done(t, id, out),
            Timer::Limit(id) => {
                let timed_out = matches!(
                    self.jobs.get(&id).map(|j| j.state),
                    Some(JobState::Running) | Some(JobState::Starting)
                );
                if timed_out {
                    out.push(Action::TimedOut { job: id });
                    self.finish_inner(t, id, true, out);
                }
            }
            Timer::BgArrival => self.on_bg_arrival(t, out),
            Timer::BgFinish(id) => self.on_finish_into(t, id, out),
        }
    }
}

// Private transition helpers (shared by the trait impl above).
impl SlurmCore {
    /// One scheduler pass: place pending jobs in priority order.
    ///
    /// Priority: older eligible time first, with per-user quota decay
    /// (a user past the quota ages `quota_penalty` slower per excess
    /// submission — the Hamilton8 behaviour in section IV).  The offset
    /// is uniform within a user, so each user lane is already sorted;
    /// this pass k-way-merges the lane heads (k = number of users) and
    /// first-fits each candidate, caching placement failures in a
    /// dominance frontier so homogeneous queues cost O(started + 1)
    /// instead of O(pending · nodes).
    fn on_cycle(&mut self, t: Micros, out: &mut Vec<Action>) {
        self.cycles += 1;

        // Lane construction: per-user priority offset, computed once (the
        // submit counters cannot change mid-pass).
        let pending = &self.pending;
        let mut lanes: Vec<(i64, u32, std::iter::Peekable<std::collections::btree_set::Iter<'_, PendKey>>)> =
            Vec::with_capacity(pending.len());
        for (&user, lane) in pending.iter() {
            if lane.is_empty() {
                continue;
            }
            let submits = *self.user_submits.get(&user).unwrap_or(&0);
            let excess = submits.saturating_sub(self.model.user_quota) as i64;
            let off = if user == USER_BACKGROUND {
                0
            } else {
                excess * self.model.quota_penalty as i64
            };
            lanes.push((off, user, lane.iter().peekable()));
        }

        // First-fit with implicit backfill: any job that fits may start
        // this cycle even if an earlier job does not fit.
        let mut started: Vec<(u32, PendKey)> = Vec::new();
        // Request shapes that failed placement this pass.  Free resources
        // only shrink within a pass, so a failed shape stays failed and
        // dominates every request at least as large.
        let mut failed: Vec<(u32, u32)> = Vec::new();
        loop {
            // Pick the lane whose head has the lowest (priority, seq).
            // Sequence numbers are globally unique, so the choice is
            // deterministic regardless of lane enumeration order.
            let mut best: Option<(i64, u64, usize)> = None;
            for (i, lane) in lanes.iter_mut().enumerate() {
                if let Some(&&(elig, seq, _)) = lane.2.peek() {
                    let prio = elig as i64 + lane.0;
                    if best.map_or(true, |(bp, bs, _)| (prio, seq) < (bp, bs)) {
                        best = Some((prio, seq, i));
                    }
                }
            }
            let Some((_, _, li)) = best else { break };
            let &(elig, seq, id) = lanes[li].2.next().unwrap();
            let user = lanes[li].1;

            let Some(job) = self.jobs.get(&id) else {
                debug_assert!(false, "pending lane entry without job");
                continue;
            };
            debug_assert_eq!(job.state, JobState::Pending);
            let req = job.req;
            if failed.iter().any(|&(c, r)| c <= req.cores && r <= req.ram_gb) {
                continue;
            }
            match self.inv.find_fit(&req) {
                Some(node) => {
                    self.inv.allocate(node, &req);
                    let job = self.jobs.get_mut(&id).unwrap();
                    job.state = JobState::Starting;
                    job.alloc_t = t;
                    job.node = node;
                    started.push((user, (elig, seq, id)));
                    out.push(Action::Timer(t + self.model.prolog, Timer::Start(id)));
                    out.push(Action::Timer(
                        t + self.model.prolog + req.time_limit,
                        Timer::Limit(id),
                    ));
                }
                None => {
                    // Keep the frontier a minimal antichain.
                    failed.retain(|&(c, r)| !(req.cores <= c && req.ram_gb <= r));
                    failed.push((req.cores, req.ram_gb));
                    // Frontier covers the smallest request the queue has
                    // ever seen: nothing further down can fit either.
                    if req.cores <= self.min_cores_floor
                        && req.ram_gb <= self.min_ram_floor
                    {
                        break;
                    }
                }
            }
        }
        drop(lanes);

        for (user, key) in started {
            if let Some(lane) = self.pending.get_mut(&user) {
                lane.remove(&key);
            }
            self.pending_len -= 1;
        }

        out.push(Action::Timer(t + self.model.sched_cycle, Timer::Cycle));
    }

    fn on_prolog_done(&mut self, t: Micros, id: JobId, out: &mut Vec<Action>) {
        let Some(job) = self.jobs.get_mut(&id) else { return };
        if job.state != JobState::Starting {
            return;
        }
        job.state = JobState::Running;
        job.run_t = t;
        let node = job.node;
        let bg = job.bg_duration;
        let neighbors = self.inv.neighbors(node);
        let contention =
            1.0 + self.model.contention_per_neighbor * neighbors as f64;
        self.jobs.get_mut(&id).unwrap().contention = contention;
        out.push(Action::Launched { job: id, node, contention });
        if let Some(dur) = bg {
            // Background jobs finish themselves relative to launch.
            out.push(Action::Timer(t + dur, Timer::BgFinish(id)));
        }
    }

    fn finish_inner(&mut self, t: Micros, id: JobId, truncated: bool, out: &mut Vec<Action>) {
        let Some(job) = self.jobs.get(&id) else { return };
        if !matches!(job.state, JobState::Running | JobState::Starting) {
            return;
        }
        let job = self.retire(
            id,
            if truncated { FINAL_CANCELLED } else { FINAL_DONE },
        );
        // CPU time starts when the job starts on the node (paper section
        // IV.A: "the timer begins when the job starts") — it therefore
        // *includes* the prolog/environment setup, which is exactly why
        // the paper sees higher SLURM CPU time on long jobs.
        let cpu = t.saturating_sub(job.alloc_t);
        let record = JobRecord {
            tag: job.tag,
            submit: job.submit_t,
            start: job.alloc_t,
            end: t,
            cpu,
            truncated,
        };
        self.inv.release(job.node, &job.req);
        out.push(Action::Completed { job: id, record });
    }

    /// Evict a job from the hot map into the terminal-state archive.
    fn retire(&mut self, id: JobId, final_state: u8) -> Job {
        let job = self.jobs.remove(&id).expect("retire of unknown job");
        let idx = id as usize;
        if self.final_states.len() <= idx {
            self.final_states.resize(idx + 1, FINAL_NONE);
        }
        self.final_states[idx] = final_state;
        self.retired += 1;
        job
    }

    fn on_bg_arrival(&mut self, t: Micros, out: &mut Vec<Action>) {
        // Keep the background queue bounded (production schedulers cap
        // per-user queued jobs); beyond the cap, arrivals balk.
        if self.pending_len > 512 {
            let dt = self.rng.exponential(self.model.bg_interarrival as f64);
            out.push(Action::Timer(t + dt as Micros, Timer::BgArrival));
            return;
        }
        // Sample a background job and submit it as user 1.
        let (lo, hi) = self.model.bg_cores;
        let cores = lo + (self.rng.below((hi - lo + 1) as u64) as u32);
        let dur = self.rng.exponential(self.model.bg_duration as f64) as Micros;
        let req = JobRequest::new(cores, (cores / 2).max(4), dur * 4 + 1);
        let id = self.submit_into(t, USER_BACKGROUND, u64::MAX, req, out);
        // Background jobs finish themselves `dur` after launch (see
        // on_prolog_done).
        self.jobs.get_mut(&id).unwrap().bg_duration = Some(dur);
        let dt = self.rng.exponential(self.model.bg_interarrival as f64);
        out.push(Action::Timer(t + dt as Micros, Timer::BgArrival));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{Des, MS, SEC};

    /// Drive a core to completion in a DES, with fixed workload durations
    /// per tag, returning completed records.
    pub fn drive(
        core: &mut SlurmCore,
        submissions: Vec<(Micros, JobRequest, Micros)>, // (t, req, duration)
    ) -> Vec<JobRecord> {
        #[derive(Debug)]
        enum Ev {
            Timer(Timer),
            Submit(JobRequest, Micros),
            Finish(JobId),
        }
        let mut des: Des<Ev> = Des::new();
        let mut durations: HashMap<JobId, Micros> = HashMap::new();
        let mut records = Vec::new();
        let expected = submissions.len();
        for a in core.bootstrap(0) {
            if let Action::Timer(t, tm) = a {
                des.schedule(t, Ev::Timer(tm));
            }
        }
        for (t, req, dur) in submissions {
            des.schedule(t, Ev::Submit(req, dur));
        }
        let mut guard = 0u64;
        while let Some((t, ev)) = des.pop() {
            guard += 1;
            assert!(guard < 3_000_000, "runaway simulation");
            let acts = match ev {
                Ev::Timer(tm) => core.on_timer(t, tm),
                Ev::Submit(req, dur) => {
                    let (id, acts) = core.submit(t, USER_EXPERIMENT, dur, req);
                    durations.insert(id, dur);
                    acts
                }
                Ev::Finish(id) => core.on_finish(t, id),
            };
            for a in acts {
                match a {
                    Action::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    Action::Launched { job, contention, .. } => {
                        if let Some(d) = durations.get(&job) {
                            let dd = (*d as f64 * contention) as Micros;
                            des.schedule(t + dd, Ev::Finish(job));
                        }
                    }
                    Action::Completed { record, .. } => {
                        if record.tag != u64::MAX {
                            records.push(record);
                        }
                    }
                    Action::TimedOut { .. } => {}
                }
            }
            // Stop once every experiment job has a record (background
            // load would keep the event stream alive forever).
            if records.len() >= expected {
                break;
            }
        }
        records
    }

    fn quiet_core() -> SlurmCore {
        SlurmCore::new(ClusterSpec::small(4), OverheadModel::quiet(), 1)
    }

    #[test]
    fn single_job_lifecycle() {
        let mut core = quiet_core();
        let recs = drive(&mut core,
                         vec![(0, JobRequest::new(4, 8, 100 * SEC), 5 * SEC)]);
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        let m = OverheadModel::quiet();
        // start >= submit + submit_latency (one cycle boundary), cpu
        // includes prolog + workload.
        assert!(r.start >= r.submit + m.submit_latency);
        assert!(r.cpu >= m.prolog + 5 * SEC);
        assert!(r.end > r.start);
        assert!(!r.truncated);
    }

    #[test]
    fn cpu_time_includes_prolog() {
        let mut core = quiet_core();
        let recs = drive(&mut core,
                         vec![(0, JobRequest::new(1, 4, 100 * SEC), 1 * SEC)]);
        let m = OverheadModel::quiet();
        assert!(recs[0].cpu >= m.prolog + SEC);
        assert!(recs[0].cpu < m.prolog + SEC + 100 * MS);
    }

    #[test]
    fn overhead_is_submit_plus_queue() {
        let mut core = quiet_core();
        let recs = drive(&mut core,
                         vec![(0, JobRequest::new(1, 4, 100 * SEC), SEC)]);
        let r = &recs[0];
        let overhead = (r.end - r.submit) - r.cpu;
        // On an empty cluster: submit latency + up-to-one cycle.
        let m = OverheadModel::quiet();
        assert!(overhead >= m.submit_latency);
        assert!(overhead <= m.submit_latency + m.sched_cycle + MS);
    }

    #[test]
    fn jobs_queue_when_cluster_full() {
        // 4 nodes x 16 cores; five 16-core jobs: the fifth must wait.
        let mut core = quiet_core();
        let subs: Vec<_> = (0..5)
            .map(|_| (0, JobRequest::new(16, 8, 1000 * SEC), 10 * SEC))
            .collect();
        let recs = drive(&mut core, subs);
        assert_eq!(recs.len(), 5);
        let mut starts: Vec<_> = recs.iter().map(|r| r.start).collect();
        starts.sort();
        // Four start together in the first cycle; the fifth a cycle after
        // a slot frees.
        assert!(starts[4] >= starts[3] + 9 * SEC);
    }

    #[test]
    fn time_limit_enforced() {
        let mut core = quiet_core();
        // 2 s limit, 60 s workload -> truncated near the limit.
        let recs = drive(&mut core,
                         vec![(0, JobRequest::new(1, 4, 2 * SEC), 60 * SEC)]);
        assert_eq!(recs.len(), 1);
        assert!(recs[0].truncated);
        let m = OverheadModel::quiet();
        assert!(recs[0].cpu <= m.prolog + 2 * SEC + MS);
    }

    #[test]
    fn cancel_pending_job() {
        let mut core = quiet_core();
        let (id, _) = core.submit(0, USER_EXPERIMENT, 7,
                                  JobRequest::new(1, 4, SEC));
        // Make it pending.
        core.on_timer(core.model().submit_latency, Timer::Eligible(id));
        assert_eq!(core.state_of(id), Some(JobState::Pending));
        let acts = core.cancel(core.model().submit_latency + 1, id);
        assert_eq!(core.state_of(id), Some(JobState::Cancelled));
        assert!(matches!(acts[0], Action::Completed { .. }));
        assert_eq!(core.pending_count(), 0);
    }

    #[test]
    fn contention_inflates_neighbors() {
        // Two 1-core jobs on a 1-node cluster share the node.
        let mut core = SlurmCore::new(ClusterSpec::small(1),
                                      OverheadModel::quiet(), 1);
        let recs = drive(&mut core, vec![
            (0, JobRequest::new(1, 4, 1000 * SEC), 10 * SEC),
            (0, JobRequest::new(1, 4, 1000 * SEC), 10 * SEC),
        ]);
        assert_eq!(recs.len(), 2);
        // At least one of them started with a neighbor -> cpu inflated
        // beyond prolog + 10 s.
        let m = OverheadModel::quiet();
        let max_cpu = recs.iter().map(|r| r.cpu).max().unwrap();
        assert!(max_cpu > m.prolog + 10 * SEC);
    }

    #[test]
    fn background_load_delays_queue() {
        // Heavy background stream on a tiny cluster: our job waits longer
        // than on a quiet one.
        let mut busy = OverheadModel::paper();
        busy.bg_interarrival = 2 * SEC;
        busy.bg_duration = 600 * SEC;
        busy.bg_cores = (16, 16);
        let mut core = SlurmCore::new(ClusterSpec::small(2), busy, 3);
        // Give background a head start by submitting at t = 60 s.
        let recs = drive(&mut core,
                         vec![(60 * SEC, JobRequest::new(16, 8, 3600 * SEC),
                               SEC)]);
        let wait_busy = recs[0].start - recs[0].submit;

        let mut core_q = quiet_core();
        let recs_q = drive(&mut core_q,
                           vec![(60 * SEC, JobRequest::new(16, 8, 3600 * SEC),
                                 SEC)]);
        let wait_quiet = recs_q[0].start - recs_q[0].submit;
        assert!(wait_busy > wait_quiet, "{wait_busy} vs {wait_quiet}");
    }

    #[test]
    fn user_quota_decays_priority() {
        // Many submissions from the experiment user: later jobs should
        // still complete, but the core tracks the quota.
        let mut m = OverheadModel::quiet();
        m.user_quota = 2;
        m.quota_penalty = 10 * SEC;
        let mut core = SlurmCore::new(ClusterSpec::small(4), m, 1);
        let subs: Vec<_> = (0..6)
            .map(|i| (i * SEC, JobRequest::new(1, 4, 100 * SEC), SEC))
            .collect();
        let recs = drive(&mut core, subs);
        assert_eq!(recs.len(), 6);
    }

    #[test]
    fn no_core_oversubscription_during_runs() {
        let mut core = quiet_core();
        let subs: Vec<_> = (0..20)
            .map(|i| (i * 100 * MS, JobRequest::new(8, 8, 1000 * SEC),
                      3 * SEC))
            .collect();
        let recs = drive(&mut core, subs);
        assert_eq!(recs.len(), 20);
        assert_eq!(core.used_cores(), 0); // everything released
    }

    #[test]
    fn terminal_jobs_evicted_from_hot_map() {
        let mut core = quiet_core();
        let subs: Vec<_> = (0..10)
            .map(|_| (0, JobRequest::new(1, 4, 100 * SEC), SEC))
            .collect();
        let recs = drive(&mut core, subs);
        assert_eq!(recs.len(), 10);
        // Every experiment job retired out of the hot map; states remain
        // queryable through the archive.
        assert_eq!(core.resident_jobs(), 0);
        assert_eq!(core.retired_count(), 10);
        for id in 1..=10u64 {
            assert_eq!(core.state_of(id), Some(JobState::Done));
        }
        assert_eq!(core.state_of(999), None);
    }

    #[test]
    fn cancel_submitting_job_never_becomes_pending() {
        let mut core = quiet_core();
        let (id, acts) = core.submit(0, USER_EXPERIMENT, 3,
                                     JobRequest::new(1, 4, SEC));
        let &Action::Timer(te, Timer::Eligible(eid)) = &acts[0] else {
            panic!("expected eligible timer");
        };
        assert_eq!(eid, id);
        // Cancel while the sbatch RPC is still in flight…
        let acts = core.cancel(te / 2, id);
        assert!(matches!(acts[0], Action::Completed { ref record, .. }
                         if record.truncated));
        assert_eq!(core.state_of(id), Some(JobState::Cancelled));
        // …then the eligible timer fires late: must stay cancelled and
        // never enter the pending index.
        core.on_timer(te, Timer::Eligible(id));
        assert_eq!(core.pending_count(), 0);
        assert_eq!(core.state_of(id), Some(JobState::Cancelled));
    }
}
