//! slurmlite — a from-scratch SLURM-like batch scheduler.
//!
//! This is the substrate substitution for the paper's native scheduler
//! (DESIGN.md section 2): FIFO queue with priority aging and per-user
//! quota decay, first-fit node placement, per-job submission latency,
//! prolog/epilog costs, node-sharing contention, and a stochastic
//! background-load stream standing in for Hamilton8's ~700 competing
//! jobs.  The core is a pure state machine driven by explicit times, so
//! the same logic runs under the discrete-event engine (benches) and a
//! real-time daemon (live examples).

pub mod core;
pub mod daemon;
pub mod reference;

pub use self::core::{Action, BatchCore, JobId, JobState, SlurmCore};
pub use self::daemon::SlurmDaemon;
pub use self::reference::ReferenceSlurmCore;
