//! Real-time driver for [`SlurmCore`]: the live-plane `slurmctld`.
//!
//! A daemon thread owns the core plus a timer queue and replays core
//! timers against the wall clock (scaled overheads).  Job lifecycle
//! events are delivered to an event sink — the coordinator's backends
//! spawn/stop model servers from it.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::cluster::{ClusterSpec, JobRequest, OverheadModel};
use crate::clock::{Des, Micros, RealClock};
use crate::metrics::JobRecord;

use super::core::{Action, BatchCore, JobId, SlurmCore, Timer};

/// Events delivered to the daemon's sink.
#[derive(Clone, Debug)]
pub enum DaemonEvent {
    Launched { job: JobId, node: usize, contention: f64 },
    TimedOut { job: JobId },
    Completed { job: JobId, record: JobRecord },
}

pub type EventSink = Arc<dyn Fn(DaemonEvent) + Send + Sync>;

struct Shared {
    core: SlurmCore,
    timers: Des<Timer>,
    pending: Vec<Action>,
    stopping: bool,
}

/// Live slurmlite daemon.
pub struct SlurmDaemon {
    shared: Arc<(Mutex<Shared>, Condvar)>,
    clock: RealClock,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl SlurmDaemon {
    pub fn start(
        spec: ClusterSpec,
        model: OverheadModel,
        seed: u64,
        sink: EventSink,
    ) -> SlurmDaemon {
        let clock = RealClock::new();
        let mut core = SlurmCore::new(spec, model, seed);
        let mut timers: Des<Timer> = Des::new();
        // Bootstrap timers at t=0.
        let mut pending_events = Vec::new();
        for a in core.bootstrap(0) {
            route(a, &mut timers, &mut pending_events);
        }
        let shared = Arc::new((
            Mutex::new(Shared {
                core,
                timers,
                pending: pending_events,
                stopping: false,
            }),
            Condvar::new(),
        ));

        let sh = shared.clone();
        let ck = clock.clone();
        let thread = std::thread::Builder::new()
            .name("slurmlite".into())
            .spawn(move || daemon_loop(sh, ck, sink))
            .expect("spawn slurmlite daemon");

        SlurmDaemon { shared, clock, thread: Some(thread) }
    }

    /// sbatch.
    pub fn submit(&self, user: u32, tag: u64, req: JobRequest) -> JobId {
        let now = self.clock.now();
        let (lock, cv) = &*self.shared;
        let mut sh = lock.lock().unwrap();
        let (id, acts) = sh.core.submit(now, user, tag, req);
        let mut evs = Vec::new();
        for a in acts {
            route(a, &mut sh.timers, &mut evs);
        }
        debug_assert!(evs.is_empty(), "submit produced immediate events");
        cv.notify_all();
        id
    }

    /// Driver signal: the job's workload is done.
    pub fn finish(&self, id: JobId) {
        let now = self.clock.now();
        let (lock, cv) = &*self.shared;
        let mut sh = lock.lock().unwrap();
        let acts = sh.core.on_finish(now, id);
        for a in acts {
            match a {
                Action::Timer(t, tm) => sh.timers.schedule(t, tm),
                // Completed records surface via pending queue: handled by
                // the loop on next wake; deliver inline is also fine but
                // we keep all sink calls on the daemon thread.
                other => sh_push(&mut sh, other),
            }
        }
        cv.notify_all();
    }

    /// scancel.
    pub fn cancel(&self, id: JobId) {
        let now = self.clock.now();
        let (lock, cv) = &*self.shared;
        let mut sh = lock.lock().unwrap();
        let acts = sh.core.cancel(now, id);
        for a in acts {
            match a {
                Action::Timer(t, tm) => sh.timers.schedule(t, tm),
                other => sh_push(&mut sh, other),
            }
        }
        cv.notify_all();
    }

    pub fn pending_count(&self) -> usize {
        self.shared.0.lock().unwrap().core.pending_count()
    }

    pub fn running_count(&self) -> usize {
        self.shared.0.lock().unwrap().core.running_count()
    }

    pub fn now(&self) -> Micros {
        self.clock.now()
    }

    pub fn shutdown(&mut self) {
        let (lock, cv) = &*self.shared;
        lock.lock().unwrap().stopping = true;
        cv.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for SlurmDaemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// Immediate (non-timer) actions raised outside the daemon thread are
// queued and delivered from the daemon thread so all sink calls share one
// thread.
fn sh_push(sh: &mut Shared, a: Action) {
    sh.pending.push(a);
}

impl Shared {
    fn drain_pending(&mut self) -> Vec<Action> {
        std::mem::take(&mut self.pending)
    }
}

// -- daemon loop -----------------------------------------------------------

fn route(a: Action, timers: &mut Des<Timer>, out: &mut Vec<Action>) {
    match a {
        Action::Timer(t, tm) => timers.schedule(t, tm),
        other => out.push(other),
    }
}

fn daemon_loop(
    shared: Arc<(Mutex<Shared>, Condvar)>,
    clock: RealClock,
    sink: EventSink,
) {
    let (lock, cv) = &*shared;
    loop {
        let mut to_deliver: Vec<Action> = Vec::new();
        let mut wait: Duration = Duration::from_millis(50);
        {
            let mut sh = lock.lock().unwrap();
            if sh.stopping {
                return;
            }
            let now = clock.now();
            // Fire all due timers.
            loop {
                match sh.timers.peek_time() {
                    Some(t) if t <= now => {}
                    Some(t) => {
                        wait = Duration::from_micros((t - now).min(50_000));
                        break;
                    }
                    None => break,
                }
                if let Some((_t, tm)) = sh.timers.pop() {
                    // Drive the core with the real clock so core time is
                    // monotone even when timers fire late.
                    let acts = sh.core.on_timer(now, tm);
                    for a in acts {
                        route(a, &mut sh.timers, &mut to_deliver);
                    }
                }
            }
            to_deliver.extend(sh.drain_pending());
            if to_deliver.is_empty() {
                let _unused = cv.wait_timeout(sh, wait).unwrap();
            }
        }
        // Deliver outside the lock.
        for a in to_deliver {
            match a {
                Action::Launched { job, node, contention } => {
                    sink(DaemonEvent::Launched { job, node, contention })
                }
                Action::TimedOut { job } => sink(DaemonEvent::TimedOut { job }),
                Action::Completed { job, record } => {
                    sink(DaemonEvent::Completed { job, record })
                }
                Action::Timer(..) => unreachable!(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{MS, SEC};
    use std::sync::mpsc;

    fn fast_model() -> OverheadModel {
        // Live-plane compressed: 1 paper-second ~ 2 ms.
        OverheadModel::quiet().scaled(500.0)
    }

    #[test]
    fn live_job_lifecycle() {
        let (tx, rx) = mpsc::channel();
        let sink: EventSink = Arc::new(move |e| {
            let _ = tx.send(e);
        });
        let daemon = SlurmDaemon::start(ClusterSpec::small(2), fast_model(),
                                        1, sink);
        let id = daemon.submit(0, 42, JobRequest::new(2, 4, 60 * SEC));
        // Wait for launch.
        let launched = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("launch event");
        match launched {
            DaemonEvent::Launched { job, .. } => assert_eq!(job, id),
            other => panic!("unexpected {other:?}"),
        }
        daemon.finish(id);
        let completed = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("completion event");
        match completed {
            DaemonEvent::Completed { job, record } => {
                assert_eq!(job, id);
                assert_eq!(record.tag, 42);
                assert!(record.end >= record.start);
            }
            other => panic!("unexpected {other:?}"),
        }
        drop(daemon);
    }

    #[test]
    fn live_cancel_pending() {
        let (tx, rx) = mpsc::channel();
        let sink: EventSink = Arc::new(move |e| {
            let _ = tx.send(e);
        });
        // Full cluster: job can never start.
        let daemon = SlurmDaemon::start(ClusterSpec::small(1), fast_model(),
                                        1, sink);
        let id = daemon.submit(0, 7, JobRequest::new(64, 4, SEC)); // too big
        std::thread::sleep(Duration::from_millis(100));
        daemon.cancel(id);
        let ev = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        match ev {
            DaemonEvent::Completed { record, .. } => assert!(record.truncated),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn shutdown_joins() {
        let sink: EventSink = Arc::new(|_| {});
        let mut daemon = SlurmDaemon::start(ClusterSpec::small(1),
                                            fast_model(), 1, sink);
        daemon.shutdown();
        // Second shutdown is a no-op.
        daemon.shutdown();
    }

    #[test]
    fn timers_fire_roughly_on_time() {
        let (tx, rx) = mpsc::channel();
        let sink: EventSink = Arc::new(move |e| {
            let _ = tx.send(e);
        });
        let model = fast_model();
        let min_latency = model.submit_latency + model.prolog; // µs
        let daemon = SlurmDaemon::start(ClusterSpec::small(2), model, 1, sink);
        let t0 = daemon.now();
        let _id = daemon.submit(0, 1, JobRequest::new(1, 4, 60 * SEC));
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            DaemonEvent::Launched { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        let dt = daemon.now() - t0;
        assert!(dt >= min_latency, "launched too early: {dt}");
        assert!(dt < min_latency + 500 * MS, "launched too late: {dt}");
    }
}
