//! DAG-capable workload policies: multilevel MLDA chains and
//! Balsam-style stage-in/compute/stage-out rounds.
//!
//! Both policies speak the dependency vocabulary the kernel's
//! [`DepTracker`](crate::sched::DepTracker) layer provides through
//! [`Sink::submit_after`]: a gated submission enters the scheduler only
//! once every parent reached a terminal record, and a failed ancestor
//! propagates truncated `Skipped` records instead — closed loops never
//! deadlock, even under `--faults`.
//!
//! * [`Mlda`] — L-level delayed-acceptance chains in the style of
//!   multilevel Bayesian inversion (Loi, Wille & Reinarz, PAPERS.md): a
//!   coarse evaluation gates the fine one, chains extend level-by-level
//!   under a seeded promotion draw, surprising results spawn
//!   result-dependent refinement children, and the number of open
//!   chains adapts online to the gated backlog (level occupancy).
//!   Levels map to campaign users, so the per-level completion curves
//!   land in [`CampaignMetrics::per_user_time_to`]
//!   (crate::campaign::CampaignMetrics::per_user_time_to).
//! * [`StageInOut`] — data-intensive rounds (Balsam, PAPERS.md):
//!   a stage-in transfer gates N computes, whose fan-in gates one
//!   reduce; several rounds run in flight, the next launching as a
//!   reduce lands.
//!
//! Determinism contract (same as every submitter): all randomness is
//! keyed on the seed and task tags, so a campaign is a pure function of
//! `(config, policy, seed)` — `tests/campaign_equiv.rs` pins repeats
//! byte-for-byte.

use std::collections::HashMap;

use crate::clock::{Micros, SEC};
use crate::metrics::JobRecord;
use crate::util::Rng;
use crate::workload::{App, RuntimeModel};

use super::submitter::{Sink, Submission, Submitter};

// ---------------------------------------------------------------------------
// MLDA: multilevel delayed-acceptance chains.
// ---------------------------------------------------------------------------

/// One MLDA level: how many tasks its budget allows and how its runtime
/// scales against the app's calibrated model (coarse levels < 1, fine
/// levels > 1).
#[derive(Clone, Debug)]
pub struct MldaLevel {
    pub count: u64,
    pub runtime_scale: f64,
}

/// Parse a `--levels` spec: comma-separated `count:runtime_scale` pairs,
/// coarsest first — e.g. `32:0.5,16:1,8:2`.
pub fn parse_levels(spec: &str) -> Result<Vec<MldaLevel>, String> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let fields: Vec<&str> = part.trim().split(':').collect();
        if fields.len() != 2 {
            return Err(format!(
                "bad level '{part}' (want count:runtime_scale)"
            ));
        }
        let count: u64 = fields[0]
            .parse()
            .map_err(|_| format!("bad count in '{part}'"))?;
        let runtime_scale: f64 = fields[1]
            .parse()
            .map_err(|_| format!("bad scale in '{part}'"))?;
        if runtime_scale <= 0.0 {
            return Err(format!("non-positive scale in '{part}'"));
        }
        out.push(MldaLevel { count, runtime_scale });
    }
    if out.is_empty() || out[0].count == 0 {
        return Err("level 0 needs a non-zero count".to_string());
    }
    Ok(out)
}

/// Multilevel delayed-acceptance chains: each chain starts at level 0
/// (coarse) and extends level-by-level under a seeded per-task
/// promotion draw, every extension gated on its parent
/// ([`Sink::submit_after`]) — the fine model runs only after the coarse
/// one delivered.  Completions feed back twice: a *surprising*
/// pseudo-QoI (outside `refine_z` standard deviations of the running
/// mean) spawns a result-dependent refinement child at the next level,
/// and the count of open chains (`occ0`) adapts online to the gated
/// backlog so no level starves or drowns.
pub struct Mlda {
    app: App,
    levels: Vec<MldaLevel>,
    remaining: Vec<u64>,
    promote_p: f64,
    refine_z: f64,
    occ0: u64,
    occ_min: u64,
    occ_max: u64,
    rtm: RuntimeModel,
    seed: u64,
    next_tag: u64,
    submitted: u64,
    completed: u64,
    /// Level-0 tasks in flight (chain admission control).
    roots_out: u64,
    /// Gated (level > 0) tasks in flight — blocked, running or skipped
    /// but not yet reported; the occupancy controller's observable.
    gated_out: u64,
    level_of: HashMap<u64, u32>,
    /// Running pseudo-QoI moments (Welford) for refinement decisions.
    qoi_n: u64,
    qoi_mean: f64,
    qoi_m2: f64,
    refined: u64,
    occupancy_trace: Vec<(Micros, u64)>,
    started: bool,
}

impl Mlda {
    /// `levels` is coarsest-first; level 0 must have a non-zero count.
    pub fn new(app: App, levels: Vec<MldaLevel>, seed: u64) -> Self {
        assert!(!levels.is_empty(), "Mlda needs at least one level");
        assert!(levels[0].count > 0, "level 0 needs a non-zero count");
        let remaining = levels.iter().map(|l| l.count).collect();
        Mlda {
            app,
            levels,
            remaining,
            promote_p: 0.7,
            refine_z: 1.5,
            occ0: 8,
            occ_min: 1,
            occ_max: 64,
            rtm: RuntimeModel::new(seed),
            seed,
            next_tag: 0,
            submitted: 0,
            completed: 0,
            roots_out: 0,
            gated_out: 0,
            level_of: HashMap::new(),
            qoi_n: 0,
            qoi_mean: 0.0,
            qoi_m2: 0.0,
            refined: 0,
            occupancy_trace: Vec::new(),
            started: false,
        }
    }

    /// Override the per-task promotion probability (chain extension).
    pub fn with_promote(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.promote_p = p;
        self
    }

    /// Override the refinement surprise threshold in standard
    /// deviations (`<= 0` disables result-dependent refinements).
    pub fn with_refine_z(mut self, z: f64) -> Self {
        self.refine_z = z;
        self
    }

    /// Override the initial/min/max level-0 occupancy targets.
    pub fn with_occupancy(mut self, init: u64, min: u64, max: u64) -> Self {
        assert!(min >= 1 && init >= min && max >= init);
        self.occ0 = init;
        self.occ_min = min;
        self.occ_max = max;
        self
    }

    /// The occupancy controller's decisions `(t, occ0)` over the run.
    pub fn occupancy_trace(&self) -> &[(Micros, u64)] {
        &self.occupancy_trace
    }

    /// Result-dependent refinement children spawned so far.
    pub fn refined(&self) -> u64 {
        self.refined
    }

    /// Seeded per-tag draw in `[0, 1)` — order-independent, so repeats
    /// are byte-identical whatever the completion interleaving.
    fn draw(&self, tag: u64, salt: u64) -> f64 {
        Rng::new(
            self.seed
                ^ salt
                ^ (tag + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
        .uniform()
    }

    fn alloc(&mut self, level: usize) -> Submission {
        let tag = self.next_tag;
        self.next_tag += 1;
        self.remaining[level] -= 1;
        let base = self.rtm.duration(self.app, tag) as f64;
        let duration =
            (base * self.levels[level].runtime_scale).max(1.0) as Micros;
        self.level_of.insert(tag, level as u32);
        self.submitted += 1;
        Submission { tag, user: level as u32, app: self.app, duration }
    }

    /// Open one chain: a level-0 root plus its pre-gated extensions up
    /// to the promotion draw's stopping point (or a drained budget).
    fn submit_chain(&mut self, sink: &mut Sink) {
        if self.remaining[0] == 0 {
            return;
        }
        let root = self.alloc(0);
        let mut parent = root.tag;
        sink.submit(root);
        self.roots_out += 1;
        for l in 1..self.levels.len() {
            if self.remaining[l] == 0
                || self.draw(parent, 0x51D0) >= self.promote_p
            {
                break;
            }
            let s = self.alloc(l);
            let tag = s.tag;
            sink.submit_after(s, &[parent]);
            self.gated_out += 1;
            parent = tag;
        }
    }

    /// Noisy pseudo-QoI from a record (log CPU seconds + seeded
    /// observation noise — the same observable `AdaptiveBayes` uses),
    /// folded into the running moments; returns whether it surprises.
    fn qoi_surprises(&mut self, rec: &JobRecord) -> bool {
        let mut r = Rng::new(
            self.seed
                ^ 0xC0A7
                ^ (rec.tag + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let cpu_s = (rec.cpu.max(1) as f64) / SEC as f64;
        let q = cpu_s.ln() + 0.05 * r.normal();
        self.qoi_n += 1;
        let delta = q - self.qoi_mean;
        self.qoi_mean += delta / self.qoi_n as f64;
        self.qoi_m2 += delta * (q - self.qoi_mean);
        if self.refine_z <= 0.0 || self.qoi_n < 8 {
            return false;
        }
        let sd = (self.qoi_m2 / self.qoi_n as f64).sqrt();
        (q - self.qoi_mean).abs() > self.refine_z * sd.max(1e-12)
    }
}

impl Submitter for Mlda {
    fn label(&self) -> &'static str {
        "mlda"
    }

    fn start(&mut self, sink: &mut Sink) {
        self.started = true;
        let k = self.occ0;
        for _ in 0..k {
            if self.remaining[0] == 0 {
                break;
            }
            self.submit_chain(sink);
        }
    }

    fn wake(&mut self, _t: Micros, _token: u64, _sink: &mut Sink) {}

    fn completed(&mut self, t: Micros, rec: &JobRecord, sink: &mut Sink) {
        self.completed += 1;
        let lvl = self.level_of.remove(&rec.tag).unwrap_or(0) as usize;
        if lvl == 0 {
            self.roots_out = self.roots_out.saturating_sub(1);
        } else {
            self.gated_out = self.gated_out.saturating_sub(1);
        }

        // Result-dependent child: a surprising (and untruncated) result
        // at level l buys one refinement evaluation at level l+1, gated
        // on the completed task — the late-edge path (its parent is
        // already terminal, so the dependency layer admits it at once).
        if !rec.truncated
            && lvl + 1 < self.levels.len()
            && self.remaining[lvl + 1] > 0
            && self.qoi_surprises(rec)
        {
            let s = self.alloc(lvl + 1);
            sink.submit_after(s, &[rec.tag]);
            self.gated_out += 1;
            self.refined += 1;
        }

        // Online level-occupancy adaptation: a deep gated backlog means
        // open chains are outpacing the fine levels — throttle root
        // admission; a dry one means the fine levels are starved —
        // open more chains.
        let old = self.occ0;
        if self.gated_out > 4 * self.occ0 {
            self.occ0 = (self.occ0 - 1).max(self.occ_min);
        } else if self.gated_out < self.occ0 {
            self.occ0 = (self.occ0 + 1).min(self.occ_max);
        }
        if self.occ0 != old {
            self.occupancy_trace.push((t, self.occ0));
        }

        // Keep the chain frontier at the occupancy target.  Running
        // this on *every* completion (not just roots) maintains the
        // invariant: whenever submitted == completed, the level-0
        // budget is spent — so `finished` below can never fire early.
        while self.roots_out < self.occ0 && self.remaining[0] > 0 {
            self.submit_chain(sink);
        }
    }

    fn finished(&self, _completed: u64) -> bool {
        self.started
            && self.completed >= self.submitted
            && self.remaining[0] == 0
    }
}

// ---------------------------------------------------------------------------
// Stage-in / compute / stage-out rounds.
// ---------------------------------------------------------------------------

/// Transfer and reduce duration scales against the app's calibrated
/// compute model (data staging is cheaper than the solve).
const TRANSFER_SCALE: f64 = 0.25;
const REDUCE_SCALE: f64 = 0.25;

/// Balsam-style data-intensive rounds: one stage-in transfer gates
/// `fanout` computes, whose fan-in gates one reduce (stage-out).  Whole
/// rounds are pre-submitted through the dependency layer; `inflight`
/// rounds overlap, and each completed (or skipped) reduce launches the
/// next round — so the campaign drains even when a fault quarantines a
/// transfer and its whole round skips.
pub struct StageInOut {
    app: App,
    rounds: u64,
    fanout: u64,
    inflight: u64,
    rtm: RuntimeModel,
    next_round: u64,
    rounds_done: u64,
    next_tag: u64,
    /// reduce tag -> round index (removed when the reduce reports).
    reduce_of: HashMap<u64, u64>,
}

impl StageInOut {
    pub fn new(
        app: App,
        rounds: u64,
        fanout: u64,
        inflight: u64,
        seed: u64,
    ) -> Self {
        assert!(rounds >= 1 && fanout >= 1 && inflight >= 1);
        StageInOut {
            app,
            rounds,
            fanout,
            inflight,
            rtm: RuntimeModel::new(seed),
            next_round: 0,
            rounds_done: 0,
            next_tag: 0,
            reduce_of: HashMap::new(),
        }
    }

    /// Every round is transfer + fanout computes + reduce.
    pub fn total_tasks(&self) -> u64 {
        self.rounds * (self.fanout + 2)
    }

    fn alloc(&mut self, user: u32, scale: f64) -> Submission {
        let tag = self.next_tag;
        self.next_tag += 1;
        let base = self.rtm.duration(self.app, tag) as f64;
        Submission {
            tag,
            user,
            app: self.app,
            duration: (base * scale).max(1.0) as Micros,
        }
    }

    fn launch_round(&mut self, sink: &mut Sink) {
        if self.next_round >= self.rounds {
            return;
        }
        let round = self.next_round;
        self.next_round += 1;
        let transfer = self.alloc(0, TRANSFER_SCALE);
        let tin = transfer.tag;
        sink.submit(transfer);
        let mut computes = Vec::with_capacity(self.fanout as usize);
        for _ in 0..self.fanout {
            let c = self.alloc(1, 1.0);
            computes.push(c.tag);
            sink.submit_after(c, &[tin]);
        }
        let reduce = self.alloc(2, REDUCE_SCALE);
        self.reduce_of.insert(reduce.tag, round);
        sink.submit_after(reduce, &computes);
    }
}

impl Submitter for StageInOut {
    fn label(&self) -> &'static str {
        "stageio"
    }

    fn start(&mut self, sink: &mut Sink) {
        for _ in 0..self.inflight.min(self.rounds) {
            self.launch_round(sink);
        }
    }

    fn wake(&mut self, _t: Micros, _token: u64, _sink: &mut Sink) {}

    fn completed(&mut self, _t: Micros, rec: &JobRecord, sink: &mut Sink) {
        // The reduce is the last record of its round (it is gated on
        // every compute, which are gated on the transfer) — its report,
        // skipped or not, retires the round and admits the next.
        if self.reduce_of.remove(&rec.tag).is_some() {
            self.rounds_done += 1;
            self.launch_round(sink);
        }
    }

    fn finished(&self, _completed: u64) -> bool {
        self.rounds_done >= self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(
        sink: &mut Sink,
    ) -> (Vec<Submission>, Vec<(Submission, Vec<u64>)>) {
        (
            std::mem::take(&mut sink.submissions),
            std::mem::take(&mut sink.gated),
        )
    }

    fn rec(tag: u64, cpu: Micros) -> JobRecord {
        JobRecord { tag, submit: 0, start: 0, end: cpu, cpu, truncated: false }
    }

    #[test]
    fn mlda_chains_gate_fine_on_coarse() {
        let levels = vec![
            MldaLevel { count: 8, runtime_scale: 0.5 },
            MldaLevel { count: 8, runtime_scale: 1.0 },
            MldaLevel { count: 8, runtime_scale: 2.0 },
        ];
        let mut m = Mlda::new(App::Gp, levels, 7)
            .with_promote(1.0)
            .with_occupancy(2, 1, 4);
        let mut sink = Sink::new();
        m.start(&mut sink);
        let (plain, gated) = drain(&mut sink);
        // Two chains, each a full 3-level column (promote 1.0).
        assert_eq!(plain.len(), 2);
        assert_eq!(gated.len(), 4);
        for s in &plain {
            assert_eq!(s.user, 0);
        }
        // Every gated task names exactly its chain predecessor.
        for (s, parents) in &gated {
            assert_eq!(parents.len(), 1);
            assert!(s.user >= 1);
            assert!(parents[0] < s.tag, "parent precedes child");
        }
        // Fine levels run longer than coarse under the scale knob.
        let coarse = plain[0].duration;
        let finest = gated
            .iter()
            .find(|(s, _)| s.user == 2)
            .map(|(s, _)| s.duration)
            .unwrap();
        assert!(finest > coarse, "runtime scales with level");
    }

    #[test]
    fn mlda_never_finishes_with_budget_or_flight_pending() {
        let levels = vec![
            MldaLevel { count: 6, runtime_scale: 1.0 },
            MldaLevel { count: 6, runtime_scale: 2.0 },
        ];
        let mut m = Mlda::new(App::Gp, levels, 3)
            .with_promote(0.5)
            .with_refine_z(0.0)
            .with_occupancy(2, 1, 8);
        let mut sink = Sink::new();
        m.start(&mut sink);
        assert!(!m.finished(0), "open chains pending");
        let mut pending: Vec<Submission> = Vec::new();
        let mut done = 0u64;
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 1000, "mlda did not drain");
            let (plain, gated) = drain(&mut sink);
            pending.extend(plain);
            pending.extend(gated.into_iter().map(|(s, _)| s));
            let Some(s) = pending.pop() else { break };
            done += 1;
            m.completed(done * SEC, &rec(s.tag, s.duration), &mut sink);
            if m.finished(done) {
                break;
            }
        }
        assert!(m.finished(done));
        // All six level-0 roots were spent.
        assert_eq!(m.remaining[0], 0);
    }

    #[test]
    fn mlda_occupancy_adapts_upward_when_backlog_dry() {
        let levels = vec![
            MldaLevel { count: 64, runtime_scale: 1.0 },
            MldaLevel { count: 4, runtime_scale: 2.0 },
        ];
        let mut m = Mlda::new(App::Gp, levels, 5)
            .with_promote(0.0) // no chains: gated backlog stays dry
            .with_refine_z(0.0)
            .with_occupancy(2, 1, 16);
        let mut sink = Sink::new();
        m.start(&mut sink);
        let (plain, _) = drain(&mut sink);
        for s in &plain {
            m.completed(SEC, &rec(s.tag, s.duration), &mut sink);
        }
        assert!(
            m.occ0 > 2,
            "dry gated backlog must raise the occupancy target"
        );
        assert!(!m.occupancy_trace().is_empty());
    }

    #[test]
    fn stageio_round_shape_and_fanin() {
        let mut s = StageInOut::new(App::Gp, 3, 4, 2, 9);
        assert_eq!(s.total_tasks(), 18);
        let mut sink = Sink::new();
        s.start(&mut sink);
        let (plain, gated) = drain(&mut sink);
        // Two rounds in flight: 2 transfers, 2x(4 computes + 1 reduce).
        assert_eq!(plain.len(), 2);
        assert_eq!(gated.len(), 10);
        let reduces: Vec<&(Submission, Vec<u64>)> =
            gated.iter().filter(|(s, _)| s.user == 2).collect();
        assert_eq!(reduces.len(), 2);
        for (_, parents) in &reduces {
            assert_eq!(parents.len(), 4, "reduce fans in over every compute");
        }
        for (c, parents) in gated.iter().filter(|(s, _)| s.user == 1) {
            assert_eq!(parents.len(), 1, "compute gates on its transfer");
            assert!(plain.iter().any(|t| t.tag == parents[0]));
            assert!(c.duration > 0);
        }
        // Completing a compute launches nothing; the reduce launches
        // round 3.
        let compute_tag = gated.iter().find(|(s, _)| s.user == 1).unwrap().0.tag;
        s.completed(SEC, &rec(compute_tag, SEC), &mut sink);
        assert!(sink.is_empty());
        let reduce_tag = reduces[0].0.tag;
        s.completed(2 * SEC, &rec(reduce_tag, SEC), &mut sink);
        let (plain, gated) = drain(&mut sink);
        assert_eq!(plain.len(), 1);
        assert_eq!(gated.len(), 5);
        assert!(!s.finished(0));
        // Remaining reduces retire the campaign.
        let second_reduce = reduces[1].0.tag;
        s.completed(3 * SEC, &rec(second_reduce, SEC), &mut sink);
        let (_, g3) = drain(&mut sink);
        let third_reduce =
            g3.iter().find(|(x, _)| x.user == 2).unwrap().0.tag;
        s.completed(4 * SEC, &rec(third_reduce, SEC), &mut sink);
        assert!(s.finished(0));
    }

    #[test]
    fn parse_levels_accepts_the_cli_shape() {
        let ls = parse_levels("32:0.5,16:1,8:2").unwrap();
        assert_eq!(ls.len(), 3);
        assert_eq!(ls[0].count, 32);
        assert!((ls[2].runtime_scale - 2.0).abs() < 1e-12);
        assert!(parse_levels("0:1").is_err());
        assert!(parse_levels("bad").is_err());
        assert!(parse_levels("4:-1").is_err());
    }
}
