//! Campaign-level metrics beyond the paper's per-job quantities:
//! time-to-Nth-result milestones, the queue-depth trajectory, and
//! per-user fairness, serialised into the JSON report.
//!
//! Per-job metrics (makespan / CPU / overhead / SLR) stay in
//! [`crate::metrics`]; this module aggregates what only exists at the
//! campaign level — how the *stream* behaved, not any one job.

use std::collections::HashMap;

use crate::clock::{Micros, SEC};
use crate::json::Value;
use crate::metrics::{Experiment, JobRecord};

/// Cap on stored queue-depth samples; beyond it the trajectory is
/// decimated (every other sample dropped, stride doubled) so memory
/// stays bounded for million-task campaigns.
const MAX_DEPTH_SAMPLES: usize = 8192;

/// Tracks the number of in-flight campaign tasks (submitted, not yet
/// completed) over virtual time, with bounded-memory decimation.
#[derive(Debug)]
pub struct DepthTrack {
    cur: u32,
    peak: u32,
    stride: u64,
    changes: u64,
    samples: Vec<(Micros, u32)>,
}

impl Default for DepthTrack {
    fn default() -> Self {
        Self::new()
    }
}

impl DepthTrack {
    pub fn new() -> DepthTrack {
        DepthTrack {
            cur: 0,
            peak: 0,
            stride: 1,
            changes: 0,
            samples: Vec::new(),
        }
    }

    pub fn submit(&mut self, t: Micros) {
        self.cur += 1;
        self.peak = self.peak.max(self.cur);
        self.record(t);
    }

    pub fn complete(&mut self, t: Micros) {
        self.cur = self.cur.saturating_sub(1);
        self.record(t);
    }

    fn record(&mut self, t: Micros) {
        self.changes += 1;
        if self.changes % self.stride != 0 {
            return;
        }
        self.samples.push((t, self.cur));
        if self.samples.len() >= MAX_DEPTH_SAMPLES {
            let mut keep = Vec::with_capacity(MAX_DEPTH_SAMPLES / 2);
            for (i, s) in self.samples.drain(..).enumerate() {
                if i % 2 == 1 {
                    keep.push(s);
                }
            }
            self.samples = keep;
            self.stride *= 2;
        }
    }

    pub fn peak(&self) -> u32 {
        self.peak
    }

    pub fn into_samples(self) -> Vec<(Micros, u32)> {
        self.samples
    }
}

/// Per-user accumulator (keyed by campaign user id).
#[derive(Debug, Default, Clone)]
struct UserAcc {
    n: u64,
    sum_makespan: f64,
    sum_overhead: f64,
    sum_slr: f64,
    /// Record end times, submission order (sorted on demand) — feeds the
    /// per-user/per-level time-to-Nth-result milestones the MLDA
    /// campaigns report (each MLDA level is a campaign user).
    ends: Vec<Micros>,
}

/// Aggregated per-user service statistics.
#[derive(Debug, Clone)]
pub struct UserStats {
    pub user: u32,
    pub completed: u64,
    pub mean_makespan_s: f64,
    pub mean_overhead_s: f64,
    pub mean_slr: f64,
}

/// Accumulates per-user stats as records complete.
#[derive(Debug, Default)]
pub struct UserTrack {
    accs: HashMap<u32, UserAcc>,
}

impl UserTrack {
    pub fn new() -> UserTrack {
        UserTrack::default()
    }

    pub fn complete(&mut self, user: u32, rec: &JobRecord) {
        let a = self.accs.entry(user).or_default();
        a.n += 1;
        a.sum_makespan += rec.makespan() as f64 / SEC as f64;
        a.sum_overhead += rec.overhead() as f64 / SEC as f64;
        a.sum_slr += rec.slr();
        a.ends.push(rec.end);
    }

    /// Per-user means, sorted by user id.
    pub fn stats(&self) -> Vec<UserStats> {
        let mut out: Vec<UserStats> = self
            .accs
            .iter()
            .map(|(&user, a)| UserStats {
                user,
                completed: a.n,
                mean_makespan_s: a.sum_makespan / a.n.max(1) as f64,
                mean_overhead_s: a.sum_overhead / a.n.max(1) as f64,
                mean_slr: a.sum_slr / a.n.max(1) as f64,
            })
            .collect();
        out.sort_by_key(|s| s.user);
        out
    }

    /// Per-user time-to-Nth-result milestones (same 1 / 10..100 %
    /// schedule as the campaign-level curve), sorted by user id.  For
    /// DAG campaigns where users encode levels (MLDA) this is the
    /// per-level completion curve.
    pub fn time_to(&self) -> Vec<(u32, Vec<(u64, Micros)>)> {
        let mut out: Vec<(u32, Vec<(u64, Micros)>)> = self
            .accs
            .iter()
            .map(|(&user, a)| {
                let mut ends = a.ends.clone();
                ends.sort_unstable();
                let n = ends.len() as u64;
                let mut ns: Vec<u64> = vec![1];
                for pct in [10u64, 25, 50, 75, 90, 100] {
                    ns.push(((n * pct) / 100).max(1));
                }
                ns.sort_unstable();
                ns.dedup();
                let ms = ns
                    .iter()
                    .map(|&k| (k, ends[(k - 1) as usize]))
                    .collect();
                (user, ms)
            })
            .collect();
        out.sort_by_key(|&(user, _)| user);
        out
    }
}

/// Jain's fairness index over per-user mean SLRs:
/// `J = (sum x)^2 / (n * sum x^2)`, 1.0 = perfectly even service.
/// SLR is used because it is scale-free (>= 1 by construction) so users
/// running different applications remain comparable.
pub fn jain_fairness(stats: &[UserStats]) -> f64 {
    if stats.len() <= 1 {
        return 1.0;
    }
    let xs: Vec<f64> = stats.iter().map(|s| s.mean_slr).collect();
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    if sumsq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sumsq)
}

/// Everything a campaign run produced beyond the per-job records.
#[derive(Debug)]
pub struct CampaignMetrics {
    /// Submitter policy label.
    pub policy: &'static str,
    /// Scheduler label ("SLURM", "UM-Bridge SLURM", "HQ").
    pub scheduler: String,
    pub submitted: u64,
    pub completed: u64,
    /// Campaign makespan (first submit to last end, virtual time).
    pub makespan: Micros,
    /// Time-to-Nth-result milestones `(n, t_end_of_nth)`.
    pub time_to: Vec<(u64, Micros)>,
    /// Decimated in-flight trajectory `(t, depth)`.
    pub depth_trajectory: Vec<(Micros, u32)>,
    pub peak_in_flight: u32,
    pub per_user: Vec<UserStats>,
    /// Per-user time-to-Nth-result milestones `(user, [(n, t)])` — the
    /// per-level completion curves for MLDA-style campaigns (level =
    /// campaign user).
    pub per_user_time_to: Vec<(u32, Vec<(u64, Micros)>)>,
    /// Jain index over per-user mean SLRs (1.0 when <= 1 user).
    pub fairness_jain: f64,
    /// DES events the run processed (cost proxy for the sim plane).
    pub des_events: u64,
    /// Retry attempts the fault plane scheduled (0 without a plan).
    pub retries: u64,
    /// Tasks quarantined after exhausting their retry budget; their
    /// truncated records stay in the experiment, never silently dropped.
    pub quarantined: u64,
    /// Workers the fault plane crashed mid-campaign.
    pub worker_crashes: u64,
    /// Decimated Blocked-state trajectory `(t, blocked count)` — tasks
    /// submitted with unresolved dependency edges, not yet released or
    /// skipped.  Empty for edge-free campaigns.
    pub blocked_trajectory: Vec<(Micros, u32)>,
    /// Peak of the Blocked-state trajectory.
    pub peak_blocked: u32,
    /// Tasks that left Blocked into Ready (all parents finished ok).
    pub released: u64,
    /// Tasks skipped because an ancestor failed/was quarantined; their
    /// truncated zero-CPU records stay in the experiment, so "records
    /// emitted == tasks submitted" holds even under `--faults`.
    pub skipped: u64,
    /// Dependency edges the campaign registered.
    pub dep_edges: u64,
}

impl CampaignMetrics {
    /// Standard milestones: first result, then 10/25/50/75/90/100 % of
    /// the completed count (deduplicated, ascending).  Sorts the end
    /// times once via [`Experiment::ends_sorted`] instead of calling
    /// `time_to_nth_result` per milestone (O(n log n) each).
    pub fn milestones(exp: &Experiment) -> Vec<(u64, Micros)> {
        let n = exp.records.len() as u64;
        if n == 0 {
            return Vec::new();
        }
        let ends = exp.ends_sorted();
        let mut ns: Vec<u64> = vec![1];
        for pct in [10u64, 25, 50, 75, 90, 100] {
            ns.push(((n * pct) / 100).max(1));
        }
        ns.sort_unstable();
        ns.dedup();
        ns.iter().map(|&k| (k, ends[(k - 1) as usize])).collect()
    }

    pub fn json(&self) -> Value {
        Value::obj(vec![
            ("policy", Value::str(self.policy)),
            ("scheduler", Value::str(&self.scheduler)),
            ("submitted", Value::num(self.submitted as f64)),
            ("completed", Value::num(self.completed as f64)),
            ("makespan_s", Value::num(self.makespan as f64 / SEC as f64)),
            (
                "time_to",
                Value::arr(
                    self.time_to
                        .iter()
                        .map(|&(n, t)| {
                            Value::obj(vec![
                                ("n", Value::num(n as f64)),
                                ("t_s", Value::num(t as f64 / SEC as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "depth_trajectory",
                Value::arr(
                    self.depth_trajectory
                        .iter()
                        .map(|&(t, d)| {
                            Value::arr(vec![
                                Value::num(t as f64 / SEC as f64),
                                Value::num(d as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("peak_in_flight", Value::num(self.peak_in_flight as f64)),
            (
                "per_user",
                Value::arr(
                    self.per_user
                        .iter()
                        .map(|u| {
                            Value::obj(vec![
                                ("user", Value::num(u.user as f64)),
                                ("completed", Value::num(u.completed as f64)),
                                (
                                    "mean_makespan_s",
                                    Value::num(u.mean_makespan_s),
                                ),
                                (
                                    "mean_overhead_s",
                                    Value::num(u.mean_overhead_s),
                                ),
                                ("mean_slr", Value::num(u.mean_slr)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "per_user_time_to",
                Value::arr(
                    self.per_user_time_to
                        .iter()
                        .map(|(user, ms)| {
                            Value::obj(vec![
                                ("user", Value::num(*user as f64)),
                                (
                                    "time_to",
                                    Value::arr(
                                        ms.iter()
                                            .map(|&(n, t)| {
                                                Value::obj(vec![
                                                    ("n", Value::num(n as f64)),
                                                    (
                                                        "t_s",
                                                        Value::num(
                                                            t as f64
                                                                / SEC as f64,
                                                        ),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("fairness_jain", Value::num(self.fairness_jain)),
            ("des_events", Value::num(self.des_events as f64)),
            ("retries", Value::num(self.retries as f64)),
            ("quarantined", Value::num(self.quarantined as f64)),
            ("worker_crashes", Value::num(self.worker_crashes as f64)),
            (
                "blocked_trajectory",
                Value::arr(
                    self.blocked_trajectory
                        .iter()
                        .map(|&(t, d)| {
                            Value::arr(vec![
                                Value::num(t as f64 / SEC as f64),
                                Value::num(d as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("peak_blocked", Value::num(self.peak_blocked as f64)),
            ("released", Value::num(self.released as f64)),
            ("skipped", Value::num(self.skipped as f64)),
            ("dep_edges", Value::num(self.dep_edges as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_track_peak_and_decimation() {
        let mut d = DepthTrack::new();
        for i in 0..(MAX_DEPTH_SAMPLES as u64 * 3) {
            d.submit(i);
            if i % 2 == 0 {
                d.complete(i);
            }
        }
        assert!(d.peak() >= 2);
        let samples = d.into_samples();
        assert!(samples.len() < MAX_DEPTH_SAMPLES);
        assert!(!samples.is_empty());
        // Monotone times.
        for w in samples.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn jain_even_service_is_one() {
        let mk = |user, slr| UserStats {
            user,
            completed: 10,
            mean_makespan_s: 1.0,
            mean_overhead_s: 0.0,
            mean_slr: slr,
        };
        let even = vec![mk(0, 2.0), mk(1, 2.0), mk(2, 2.0)];
        assert!((jain_fairness(&even) - 1.0).abs() < 1e-12);
        let skew = vec![mk(0, 1.0), mk(1, 10.0)];
        let j = jain_fairness(&skew);
        assert!(j < 0.7, "skewed service must drop the index, got {j}");
        assert_eq!(jain_fairness(&[]), 1.0);
    }

    #[test]
    fn user_track_means() {
        let mut ut = UserTrack::new();
        let rec = |submit, end, cpu| JobRecord {
            tag: 0,
            submit,
            start: submit,
            end,
            cpu,
            truncated: false,
        };
        ut.complete(1, &rec(0, 10 * SEC, 5 * SEC));
        ut.complete(1, &rec(0, 20 * SEC, 10 * SEC));
        ut.complete(2, &rec(0, 4 * SEC, 4 * SEC));
        let stats = ut.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].user, 1);
        assert_eq!(stats[0].completed, 2);
        assert!((stats[0].mean_makespan_s - 15.0).abs() < 1e-9);
        assert!((stats[1].mean_slr - 1.0).abs() < 1e-9);
    }
}
