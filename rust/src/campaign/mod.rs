//! The campaign plane: generalized workload generation and adaptive
//! submission policies, decoupled from the scheduler cores.
//!
//! The paper's evaluation fixes one protocol — 100 evaluations with a
//! constant queue depth — but its premise is that UQ workloads submit
//! *unpredictable* task streams whose total count is not known a priori.
//! This module opens that space while keeping the paper's protocol as
//! one instance:
//!
//! * [`Submitter`] — a composable workload-stream policy.  Shipped
//!   policies: [`FixedDepth`] (the paper's protocol, action-for-action),
//!   [`PoissonBurst`] (bursty open-loop arrivals), [`UserMix`]
//!   (multi-tenant closed-loop streams), [`HeteroFamilies`]
//!   (runtime-heteroskedastic task families), and [`AdaptiveBayes`]
//!   (Bayesian-inversion-style feedback batches whose size depends on
//!   completed results).
//! * DAG policies in [`dag`]: [`Mlda`] (multilevel delayed-acceptance
//!   chains — coarse gates fine via [`Sink::submit_after`], with
//!   result-dependent refinement and online level-occupancy
//!   adaptation) and [`StageInOut`] (transfer → N computes → reduce
//!   rounds).  Their edges ride the kernel's
//!   [`DepTracker`](crate::sched::DepTracker) layer: no scheduler core
//!   knows dependencies exist.
//! * [`run_slurm`] / [`run_hq`] / [`run_worksteal`] / [`run_edf`] /
//!   [`run_gang`] — thin config adapters selecting a
//!   [`SchedulerCore`](crate::sched::SchedulerCore) implementation
//!   (SLURM native/UM-Bridge, UM-Bridge + HQ, UM-Bridge + work
//!   stealing, UM-Bridge + deadline-EDF, UM-Bridge + moldable gangs)
//!   and handing it to the one generic event kernel in
//!   [`crate::sched::kernel`].
//!   `experiments::run_naive_slurm`, `run_umbridge_slurm`,
//!   `run_umbridge_hq`, `run_umbridge_worksteal` and
//!   `run_umbridge_edf` are thin wrappers over these.
//! * [`CampaignMetrics`] — what only exists at the stream level:
//!   time-to-Nth-result milestones, the queue-depth trajectory, per-user
//!   fairness (Jain index over mean SLRs), serialised into the JSON
//!   report alongside the per-job records.
//!
//! ```text
//!   Submitter (what / when)          Kernel (how)            Core (where)
//!   ┌───────────────┐  Submission  ┌──────────────┐ Event  ┌────────────┐
//!   │ fixed-depth   │ ───────────> │ sched::      │ ─────> │ SlurmSched │
//!   │ poisson-burst │  wake_at     │ kernel::run  │ <───── │ MetaStack< │
//!   │ user-mix ...  │ <─────────── │  (DES loop)  │ Effect │ Hq|Steal > │
//!   └───────────────┘  completed   └──────────────┘        └────────────┘
//! ```
//!
//! See `docs/ARCHITECTURE.md` for the driver loop diagram and PERF.md
//! for per-event complexity; `benches/scale.rs` runs bursty and adaptive
//! campaigns at 100k+ tasks.

pub mod dag;
pub mod driver;
pub mod metrics;
pub mod submitter;

pub use dag::{parse_levels, Mlda, MldaLevel, StageInOut};
pub use driver::{run_edf, run_gang, run_hq, run_slurm, run_worksteal,
                 CampaignConfig, CampaignResult, SlurmMode};
pub use metrics::{jain_fairness, CampaignMetrics, UserStats};
pub use submitter::{
    AdaptiveBayes, Family, FixedDepth, HeteroFamilies, PoissonBurst, Sink,
    Submission, Submitter, UserMix, UserStream,
};
