//! Workload-stream generators: the [`Submitter`] trait and its policies.
//!
//! A submitter decides *what* enters the system and *when*, in terms of
//! three callbacks driven by the campaign event loop
//! ([`crate::campaign::driver`]):
//!
//! * [`Submitter::start`] seeds the campaign at `t = 0`;
//! * [`Submitter::wake`] fires when a self-scheduled wake timer elapses;
//! * [`Submitter::completed`] delivers each finished evaluation record.
//!
//! All three communicate back through a [`Sink`]: immediate
//! [`Submission`]s and future wake timers.  The driver turns submissions
//! into scheduler submissions (SLURM jobs or HQ tasks) and owns every
//! scheduler-specific overhead (server init, proxy latency, registration
//! pre-jobs), so one submitter runs unchanged against every scheduler.
//!
//! Determinism contract: a submitter must derive all randomness from its
//! seed via [`crate::util::Rng`], so a campaign is a pure function of
//! `(config, policy, seed)` — the paper's "same random seed for
//! repeatability" requirement extended to open-ended streams.

use std::collections::HashMap;

use crate::clock::{Micros, SEC};
use crate::metrics::JobRecord;
use crate::util::Rng;
use crate::workload::{App, RuntimeModel};

/// One evaluation the campaign plane hands to the scheduler plane.
#[derive(Clone, Copy, Debug)]
pub struct Submission {
    /// Campaign-unique evaluation index (becomes `JobRecord::tag`).
    pub tag: u64,
    /// Campaign user (0 = primary).  Multi-user policies label streams so
    /// the driver can compute per-user fairness.
    pub user: u32,
    /// Application: resource shape (Table III) and runtime family.
    pub app: App,
    /// Sampled compute time C_i (scheduler overheads are added by the
    /// driver: prolog/server-init on SLURM, server-init on HQ).
    pub duration: Micros,
}

/// Collector the driver passes to every submitter callback.
#[derive(Debug, Default)]
pub struct Sink {
    pub(crate) submissions: Vec<Submission>,
    pub(crate) wakes: Vec<(Micros, u64)>,
    /// Dependency-carrying submissions `(task, parent tags)`: the kernel
    /// routes these through its [`DepTracker`](crate::sched::DepTracker)
    /// layer — the scheduler core sees a plain submit only once every
    /// parent reached a terminal record.
    pub(crate) gated: Vec<(Submission, Vec<u64>)>,
}

impl Sink {
    pub fn new() -> Sink {
        Sink::default()
    }

    /// Submit an evaluation at the current event time.
    pub fn submit(&mut self, s: Submission) {
        self.submissions.push(s);
    }

    /// Submit a batch of evaluations at the current event time in one
    /// call: a single buffer reservation instead of per-item growth,
    /// and one kernel drain pass for the whole burst.  Equivalent to
    /// calling [`Sink::submit`] per item, in order — burst policies
    /// (Poisson arrivals, adaptive batch rounds, DAG wave fronts) hand
    /// the kernel their whole wave at once.
    pub fn submit_many<I>(&mut self, subs: I)
    where
        I: IntoIterator<Item = Submission>,
    {
        self.submissions.extend(subs);
    }

    /// Submit an evaluation gated on `parents` (tags of previously
    /// submitted evaluations): it enters the scheduler only once every
    /// parent is terminal.  A failed/quarantined parent propagates a
    /// truncated `Skipped` record instead — the submitter still sees a
    /// `completed` callback for every gated task, so closed loops never
    /// deadlock.  `parents = &[]` is byte-identical to [`Sink::submit`].
    pub fn submit_after(&mut self, s: Submission, parents: &[u64]) {
        self.gated.push((s, parents.to_vec()));
    }

    /// Request a [`Submitter::wake`] callback at absolute time `t` with an
    /// opaque `token` (policies use it to route the wake internally).
    pub fn wake_at(&mut self, t: Micros, token: u64) {
        self.wakes.push((t, token));
    }

    /// Re-route every pending plain submission through the zero-edge
    /// dependency path (`submit_after(s, &[])`).  Test hook:
    /// `tests/campaign_equiv.rs` wraps existing policies with it to pin
    /// the zero-edge DAG path record-for-record against today's kernel.
    pub fn gate_pending(&mut self) {
        let subs: Vec<Submission> = self.submissions.drain(..).collect();
        for s in subs {
            self.gated.push((s, Vec::new()));
        }
    }

    pub fn is_empty(&self) -> bool {
        self.submissions.is_empty()
            && self.wakes.is_empty()
            && self.gated.is_empty()
    }
}

/// A composable workload-stream policy.
///
/// Object-safe: the drivers take `&mut dyn Submitter` so policies can be
/// selected at runtime (CLI, benches).
pub trait Submitter {
    /// Short policy name for reports.
    fn label(&self) -> &'static str;

    /// Called once at `t = 0` before the event loop starts.
    fn start(&mut self, sink: &mut Sink);

    /// A wake timer requested via [`Sink::wake_at`] elapsed.
    fn wake(&mut self, t: Micros, token: u64, sink: &mut Sink);

    /// An evaluation finished.  `rec.tag` is the submission's tag; times
    /// are already quantised to the scheduler's log granularity.
    fn completed(&mut self, t: Micros, rec: &JobRecord, sink: &mut Sink);

    /// A registration pre-job finished (HQ/UM-Bridge path only; the
    /// paper's readiness checks).  Most policies ignore these.
    fn registration_completed(&mut self, t: Micros, sink: &mut Sink) {
        let _ = (t, sink);
    }

    /// True once the campaign is over, given the number of completed
    /// evaluations.  Checked by the driver after every event.
    fn finished(&self, completed: u64) -> bool;
}

// ---------------------------------------------------------------------------
// Fixed depth: the paper's protocol.
// ---------------------------------------------------------------------------

/// The paper's submission protocol (section IV.B): keep exactly
/// `queue_depth` evaluations in flight; a new one is issued the moment
/// one finishes.  With the same `(app, n_evals, queue_depth, seed)` this
/// reproduces the PR 1 experiment drivers action-for-action (pinned by
/// `tests/campaign_equiv.rs`).
pub struct FixedDepth {
    app: App,
    n_evals: u64,
    queue_depth: usize,
    rtm: RuntimeModel,
    next: u64,
}

impl FixedDepth {
    pub fn new(app: App, n_evals: u64, queue_depth: usize, seed: u64) -> Self {
        FixedDepth {
            app,
            n_evals,
            queue_depth,
            rtm: RuntimeModel::new(seed),
            next: 0,
        }
    }
}

impl Submitter for FixedDepth {
    fn label(&self) -> &'static str {
        "fixed-depth"
    }

    fn start(&mut self, sink: &mut Sink) {
        for _ in 0..self.queue_depth.min(self.n_evals as usize) {
            sink.wake_at(0, 0);
        }
    }

    fn wake(&mut self, _t: Micros, _token: u64, sink: &mut Sink) {
        if self.next < self.n_evals {
            let tag = self.next;
            self.next += 1;
            sink.submit(Submission {
                tag,
                user: 0,
                app: self.app,
                duration: self.rtm.duration(self.app, tag),
            });
        }
    }

    fn completed(&mut self, t: Micros, _rec: &JobRecord, sink: &mut Sink) {
        sink.wake_at(t, 0);
    }

    fn finished(&self, completed: u64) -> bool {
        completed >= self.n_evals
    }
}

// ---------------------------------------------------------------------------
// Poisson bursts: open-loop, time-driven arrivals.
// ---------------------------------------------------------------------------

/// Bursty open-loop arrivals: bursts of `burst.0..=burst.1` evaluations
/// (uniform) arrive with exponential inter-arrival times, independent of
/// completions — the unpredictable task streams the paper's premise
/// describes, and the regime where queue depth is an *output* of the
/// system instead of a protocol constant.
pub struct PoissonBurst {
    app: App,
    total: u64,
    mean_interarrival: Micros,
    burst: (u64, u64),
    rtm: RuntimeModel,
    rng: Rng,
    next: u64,
}

impl PoissonBurst {
    pub fn new(
        app: App,
        total: u64,
        mean_interarrival: Micros,
        burst: (u64, u64),
        seed: u64,
    ) -> Self {
        assert!(burst.0 >= 1 && burst.1 >= burst.0, "bad burst range");
        PoissonBurst {
            app,
            total,
            mean_interarrival,
            burst,
            rtm: RuntimeModel::new(seed),
            rng: Rng::new(seed ^ 0xB0B5),
            next: 0,
        }
    }

    fn next_gap(&mut self) -> Micros {
        self.rng.exponential(self.mean_interarrival as f64).max(1.0) as Micros
    }
}

impl Submitter for PoissonBurst {
    fn label(&self) -> &'static str {
        "poisson-burst"
    }

    fn start(&mut self, sink: &mut Sink) {
        let t0 = self.next_gap();
        sink.wake_at(t0, 0);
    }

    fn wake(&mut self, t: Micros, _token: u64, sink: &mut Sink) {
        let span = self.burst.1 - self.burst.0 + 1;
        let k = self.burst.0 + self.rng.below(span);
        for _ in 0..k {
            if self.next >= self.total {
                break;
            }
            let tag = self.next;
            self.next += 1;
            sink.submit(Submission {
                tag,
                user: 0,
                app: self.app,
                duration: self.rtm.duration(self.app, tag),
            });
        }
        if self.next < self.total {
            let gap = self.next_gap();
            sink.wake_at(t + gap, 0);
        }
    }

    fn completed(&mut self, _t: Micros, _rec: &JobRecord, _sink: &mut Sink) {}

    fn finished(&self, completed: u64) -> bool {
        completed >= self.total
    }
}

// ---------------------------------------------------------------------------
// Multi-user mix: several closed-loop streams sharing the scheduler.
// ---------------------------------------------------------------------------

/// One user's stream inside a [`UserMix`].
#[derive(Clone, Debug)]
pub struct UserStream {
    pub user: u32,
    pub app: App,
    pub n_evals: u64,
    pub queue_depth: usize,
}

/// Several users, each running the paper's fixed-depth protocol over
/// their own application, sharing the same scheduler — the multi-tenant
/// contention scenario.  Per-user fairness becomes measurable on both
/// paths; the *mechanisms* differ: on the SLURM path the driver maps
/// each campaign user to a distinct scheduler user, so per-user quota
/// decay applies per stream, while on the HQ path all tasks share one
/// allocation pool (HQ has no user concept) and fairness emerges from
/// FCFS dispatch alone.
pub struct UserMix {
    streams: Vec<UserStream>,
    models: Vec<RuntimeModel>,
    next: Vec<u64>,
    /// Global tag -> stream index (removed on completion).
    owner: HashMap<u64, usize>,
    next_tag: u64,
    total: u64,
}

impl UserMix {
    pub fn new(streams: Vec<UserStream>, seed: u64) -> Self {
        assert!(!streams.is_empty(), "UserMix needs at least one stream");
        let total = streams.iter().map(|s| s.n_evals).sum();
        let models = streams
            .iter()
            .map(|s| RuntimeModel::new(seed ^ (s.user as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03)))
            .collect();
        let next = vec![0u64; streams.len()];
        UserMix {
            streams,
            models,
            next,
            owner: HashMap::new(),
            next_tag: 0,
            total,
        }
    }

    fn emit(&mut self, i: usize, sink: &mut Sink) {
        let s = &self.streams[i];
        if self.next[i] >= s.n_evals {
            return;
        }
        let idx = self.next[i];
        self.next[i] += 1;
        let tag = self.next_tag;
        self.next_tag += 1;
        self.owner.insert(tag, i);
        sink.submit(Submission {
            tag,
            user: s.user,
            app: s.app,
            duration: self.models[i].duration(s.app, idx),
        });
    }
}

impl Submitter for UserMix {
    fn label(&self) -> &'static str {
        "user-mix"
    }

    fn start(&mut self, sink: &mut Sink) {
        for (i, s) in self.streams.iter().enumerate() {
            for _ in 0..s.queue_depth.min(s.n_evals as usize) {
                sink.wake_at(0, i as u64);
            }
        }
    }

    fn wake(&mut self, _t: Micros, token: u64, sink: &mut Sink) {
        self.emit(token as usize, sink);
    }

    fn completed(&mut self, t: Micros, rec: &JobRecord, sink: &mut Sink) {
        if let Some(i) = self.owner.remove(&rec.tag) {
            sink.wake_at(t, i as u64);
        }
    }

    fn finished(&self, completed: u64) -> bool {
        completed >= self.total
    }
}

// ---------------------------------------------------------------------------
// Heteroskedastic task families.
// ---------------------------------------------------------------------------

/// One runtime family inside [`HeteroFamilies`].
#[derive(Clone, Debug)]
pub struct Family {
    pub app: App,
    /// Selection weight (relative).
    pub weight: f64,
    /// Extra lognormal runtime spread on top of the app's calibrated
    /// model (sigma of the underlying normal; 0 = calibrated model).
    pub sigma: f64,
}

/// Closed-loop fixed-depth stream whose tasks are drawn from a mixture
/// of runtime families with different variances — the
/// runtime-heteroskedastic workloads (e.g. chained forward solves of
/// varying resolution) that defeat uniform time-request hints.
pub struct HeteroFamilies {
    families: Vec<Family>,
    total: u64,
    queue_depth: usize,
    rtm: RuntimeModel,
    rng: Rng,
    next: u64,
}

impl HeteroFamilies {
    pub fn new(
        families: Vec<Family>,
        total: u64,
        queue_depth: usize,
        seed: u64,
    ) -> Self {
        assert!(!families.is_empty(), "need at least one family");
        HeteroFamilies {
            families,
            total,
            queue_depth,
            rtm: RuntimeModel::new(seed),
            rng: Rng::new(seed ^ 0x4E7E),
            next: 0,
        }
    }
}

impl Submitter for HeteroFamilies {
    fn label(&self) -> &'static str {
        "hetero-families"
    }

    fn start(&mut self, sink: &mut Sink) {
        for _ in 0..self.queue_depth.min(self.total as usize) {
            sink.wake_at(0, 0);
        }
    }

    fn wake(&mut self, _t: Micros, _token: u64, sink: &mut Sink) {
        if self.next >= self.total {
            return;
        }
        let tag = self.next;
        self.next += 1;
        let wsum: f64 = self.families.iter().map(|f| f.weight).sum();
        let mut pick = self.rng.uniform() * wsum;
        let mut fi = 0;
        for (i, f) in self.families.iter().enumerate() {
            if pick < f.weight {
                fi = i;
                break;
            }
            pick -= f.weight;
            fi = i;
        }
        let fam = &self.families[fi];
        let base = self.rtm.duration(fam.app, tag);
        let spread = if fam.sigma > 0.0 {
            self.rng.lognormal(0.0, fam.sigma)
        } else {
            1.0
        };
        sink.submit(Submission {
            tag,
            user: 0,
            app: fam.app,
            duration: ((base as f64) * spread).max(1.0) as Micros,
        });
    }

    fn completed(&mut self, t: Micros, _rec: &JobRecord, sink: &mut Sink) {
        sink.wake_at(t, 0);
    }

    fn finished(&self, completed: u64) -> bool {
        completed >= self.total
    }
}

// ---------------------------------------------------------------------------
// Adaptive batches: Bayesian-inversion-style feedback policy.
// ---------------------------------------------------------------------------

/// Adaptive batch policy in the style of dynamic Bayesian inversion
/// loops (Loi, Wille & Reinarz): evaluations arrive in rounds, and the
/// size of the next round is chosen from the statistics of the results
/// observed so far — the total evaluation count is *not* known a priori.
///
/// The observable is a pseudo-QoI derived from each record (log CPU
/// seconds plus seeded observation noise), so the feedback genuinely
/// flows results -> policy while staying deterministic under the seed.
/// The next batch is sized so the standard error of the QoI mean would
/// reach `tol`: `n_target = (sd / tol)^2`, clamped to
/// `[min_batch, max_batch]` and to the remaining budget.  `tol <= 0`
/// disables convergence and spends the whole budget (bench mode).
pub struct AdaptiveBayes {
    app: App,
    budget: u64,
    init_batch: u64,
    min_batch: u64,
    max_batch: u64,
    tol: f64,
    rtm: RuntimeModel,
    noise_seed: u64,
    next: u64,
    outstanding: u64,
    results: Vec<f64>,
    rounds: u64,
    done: bool,
}

impl AdaptiveBayes {
    pub fn new(app: App, budget: u64, seed: u64) -> Self {
        AdaptiveBayes {
            app,
            budget,
            init_batch: 16,
            min_batch: 4,
            max_batch: 4096,
            tol: 0.02,
            rtm: RuntimeModel::new(seed),
            noise_seed: seed ^ 0xADA7,
            next: 0,
            outstanding: 0,
            results: Vec::new(),
            rounds: 0,
            done: false,
        }
    }

    /// Override the batch clamps (initial, minimum, maximum).
    pub fn with_batches(mut self, init: u64, min: u64, max: u64) -> Self {
        assert!(init >= 1 && min >= 1 && max >= min);
        self.init_batch = init;
        self.min_batch = min;
        self.max_batch = max;
        self
    }

    /// Override the convergence tolerance (`<= 0` spends the budget).
    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Batch rounds issued so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    fn emit_batch(&mut self, k: u64, sink: &mut Sink) {
        // One batched hand-off for the whole round: same submissions in
        // the same order as per-item `submit`, one sink reservation.
        let emitted = k.min(self.budget.saturating_sub(self.next));
        let first = self.next;
        self.next += emitted;
        let (app, rtm) = (self.app, &self.rtm);
        sink.submit_many((first..first + emitted).map(|tag| Submission {
            tag,
            user: 0,
            app,
            duration: rtm.duration(app, tag),
        }));
        if emitted > 0 {
            self.rounds += 1;
            self.outstanding += emitted;
        } else {
            self.done = true;
        }
    }

    fn pseudo_qoi(&self, rec: &JobRecord) -> f64 {
        let mut r = Rng::new(
            self.noise_seed ^ (rec.tag + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let cpu_s = (rec.cpu.max(1) as f64) / SEC as f64;
        cpu_s.ln() + 0.05 * r.normal()
    }

    fn next_batch(&self) -> Option<u64> {
        let n = self.results.len() as f64;
        let mean = self.results.iter().sum::<f64>() / n;
        let var = self
            .results
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n.max(1.0);
        let sd = var.sqrt();
        if self.tol > 0.0 {
            let sem = sd / n.sqrt();
            if sem <= self.tol {
                return None; // converged
            }
            let n_target = (sd / self.tol) * (sd / self.tol);
            let want = (n_target - n).ceil().max(0.0) as u64;
            Some(want.clamp(self.min_batch, self.max_batch))
        } else {
            Some(self.max_batch)
        }
    }
}

impl Submitter for AdaptiveBayes {
    fn label(&self) -> &'static str {
        "adaptive-bayes"
    }

    fn start(&mut self, sink: &mut Sink) {
        let k = self.init_batch;
        self.emit_batch(k, sink);
    }

    fn wake(&mut self, _t: Micros, _token: u64, _sink: &mut Sink) {}

    fn completed(&mut self, _t: Micros, rec: &JobRecord, sink: &mut Sink) {
        self.outstanding = self.outstanding.saturating_sub(1);
        let q = self.pseudo_qoi(rec);
        self.results.push(q);
        if self.outstanding == 0 && !self.done {
            if self.next >= self.budget {
                self.done = true;
            } else {
                match self.next_batch() {
                    None => self.done = true,
                    Some(k) => self.emit_batch(k, sink),
                }
            }
        }
    }

    fn finished(&self, _completed: u64) -> bool {
        self.done && self.outstanding == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(sink: &mut Sink) -> (Vec<Submission>, Vec<(Micros, u64)>) {
        (
            std::mem::take(&mut sink.submissions),
            std::mem::take(&mut sink.wakes),
        )
    }

    #[test]
    fn fixed_depth_fills_then_tracks_completions() {
        let mut s = FixedDepth::new(App::Gp, 5, 2, 7);
        let mut sink = Sink::new();
        s.start(&mut sink);
        let (subs, wakes) = drain(&mut sink);
        assert!(subs.is_empty());
        assert_eq!(wakes.len(), 2);
        // Each wake emits exactly one submission with sequential tags.
        for want in 0..5u64 {
            s.wake(0, 0, &mut sink);
            let (subs, _) = drain(&mut sink);
            assert_eq!(subs.len(), 1);
            assert_eq!(subs[0].tag, want);
        }
        // Exhausted: further wakes are no-ops.
        s.wake(0, 0, &mut sink);
        assert!(sink.is_empty());
        assert!(!s.finished(4));
        assert!(s.finished(5));
    }

    #[test]
    fn poisson_burst_is_open_loop_and_bounded() {
        let mut s = PoissonBurst::new(App::Gp, 10, SEC, (2, 4), 3);
        let mut sink = Sink::new();
        s.start(&mut sink);
        let (subs, wakes) = drain(&mut sink);
        assert!(subs.is_empty());
        assert_eq!(wakes.len(), 1);
        let mut t = wakes[0].0;
        let mut total = 0;
        let mut guard = 0;
        while total < 10 {
            guard += 1;
            assert!(guard < 100);
            s.wake(t, 0, &mut sink);
            let (subs, wakes) = drain(&mut sink);
            assert!(subs.len() <= 4);
            total += subs.len();
            match wakes.first() {
                Some(&(tw, _)) => {
                    assert!(tw > t);
                    t = tw;
                }
                None => break,
            }
        }
        assert_eq!(total, 10);
        // Completions do not trigger anything (open loop).
        let rec = JobRecord {
            tag: 0,
            submit: 0,
            start: 0,
            end: SEC,
            cpu: SEC,
            truncated: false,
        };
        s.completed(2 * SEC, &rec, &mut sink);
        assert!(sink.is_empty());
    }

    #[test]
    fn user_mix_routes_completions_to_owner() {
        let streams = vec![
            UserStream { user: 0, app: App::Gp, n_evals: 2, queue_depth: 1 },
            UserStream { user: 3, app: App::Eigen100, n_evals: 2, queue_depth: 1 },
        ];
        let mut s = UserMix::new(streams, 9);
        let mut sink = Sink::new();
        s.start(&mut sink);
        let (_, wakes) = drain(&mut sink);
        assert_eq!(wakes.len(), 2);
        s.wake(0, 0, &mut sink); // user 0 stream
        s.wake(0, 1, &mut sink); // user 3 stream
        let (subs, _) = drain(&mut sink);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].user, 0);
        assert_eq!(subs[1].user, 3);
        // Completing user-3's task wakes stream 1 only.
        let rec = JobRecord {
            tag: subs[1].tag,
            submit: 0,
            start: 0,
            end: SEC,
            cpu: SEC,
            truncated: false,
        };
        s.completed(SEC, &rec, &mut sink);
        let (_, wakes) = drain(&mut sink);
        assert_eq!(wakes, vec![(SEC, 1)]);
        assert!(s.finished(4));
    }

    #[test]
    fn hetero_families_spread_exceeds_base_model() {
        let fams = vec![
            Family { app: App::Gp, weight: 1.0, sigma: 0.0 },
            Family { app: App::Gp, weight: 1.0, sigma: 1.2 },
        ];
        let mut s = HeteroFamilies::new(fams, 200, 200, 11);
        let mut sink = Sink::new();
        s.start(&mut sink);
        for _ in 0..200 {
            s.wake(0, 0, &mut sink);
        }
        let (subs, _) = drain(&mut sink);
        assert_eq!(subs.len(), 200);
        let lo = subs.iter().map(|x| x.duration).min().unwrap();
        let hi = subs.iter().map(|x| x.duration).max().unwrap();
        // The calibrated GP model alone jitters a few percent; the 1.2-
        // sigma family must widen the spread by an order of magnitude.
        assert!(hi as f64 / lo as f64 > 5.0, "spread {lo}..{hi}");
    }

    #[test]
    fn adaptive_batches_react_to_results() {
        let mut s = AdaptiveBayes::new(App::Gs2, 1000, 5).with_batches(8, 4, 64);
        let mut sink = Sink::new();
        s.start(&mut sink);
        let (subs, _) = drain(&mut sink);
        assert_eq!(subs.len(), 8);
        // Feed completions with wildly varying CPU times: the next batch
        // must be larger than the minimum (high variance -> more samples).
        for (i, sub) in subs.iter().enumerate() {
            let cpu = SEC * (1 + (i as u64 % 7) * 37);
            let rec = JobRecord {
                tag: sub.tag,
                submit: 0,
                start: 0,
                end: cpu,
                cpu,
                truncated: false,
            };
            s.completed(cpu, &rec, &mut sink);
        }
        let (batch2, _) = drain(&mut sink);
        assert!(batch2.len() >= 4, "second round size {}", batch2.len());
        assert_eq!(s.rounds(), 2);
        assert!(!s.finished(8));
    }

    #[test]
    fn adaptive_zero_tol_spends_budget_in_max_batches() {
        let mut s = AdaptiveBayes::new(App::Gp, 40, 5)
            .with_batches(10, 10, 10)
            .with_tol(0.0);
        let mut sink = Sink::new();
        s.start(&mut sink);
        let mut completed = 0u64;
        let mut guard = 0;
        while !s.finished(completed) {
            guard += 1;
            assert!(guard < 100, "adaptive policy did not terminate");
            let (subs, _) = drain(&mut sink);
            for sub in subs {
                let rec = JobRecord {
                    tag: sub.tag,
                    submit: 0,
                    start: 0,
                    end: SEC,
                    cpu: SEC,
                    truncated: false,
                };
                completed += 1;
                s.completed(SEC, &rec, &mut sink);
            }
        }
        assert_eq!(completed, 40);
    }
}
