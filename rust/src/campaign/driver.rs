//! The campaign drivers: one generic discrete-event loop per scheduler
//! core, running any [`Submitter`] against `SlurmCore` (native or
//! UM-Bridge flavoured) or `HqCore`.
//!
//! The drivers own every scheduler-specific mechanism so submitters stay
//! scheduler-agnostic:
//!
//! * **SLURM path** — per-evaluation `sbatch` submission; the UM-Bridge
//!   flavour adds the model-server start-up to each job and the
//!   balancer's proxy latency to each submission (Appendix A).
//! * **HQ path** — the UM-Bridge + HyperQueue stack: registration
//!   pre-jobs, automatic allocation against the SLURM core, worker
//!   expiry, and per-task dispatch.
//!
//! With the [`FixedDepth`](super::submitter::FixedDepth) policy these
//! loops reproduce the PR 1 experiment drivers *action-for-action* — the
//! originals are preserved verbatim in `experiments::reference` and
//! `tests/campaign_equiv.rs` pins the equivalence.
//!
//! Event cost: every event is O(core transition) — the loops add O(1)
//! bookkeeping (two `HashMap` ops and a depth-trajectory update) per
//! submission/completion, so campaign mode inherits the indexed cores'
//! million-task scaling (see PERF.md).

use std::collections::HashMap;

use crate::clock::{Des, Micros, MS, SEC};
use crate::cluster::{ClusterSpec, OverheadModel};
use crate::hqlite::{AutoAllocConfig, HqAction, HqCore, HqTimer, TaskSpec};
use crate::metrics::Experiment;
use crate::slurmlite::core::{Action, SlurmCore, Timer, USER_EXPERIMENT};
use crate::workload::{scenario, App};

use super::metrics::{jain_fairness, CampaignMetrics, DepthTrack, UserTrack};
use super::submitter::{Sink, Submission, Submitter};

/// SLURM native log granularity (whole seconds; paper section V).
const SLURM_LOG_GRAIN: Micros = SEC;

/// Campaign-plane configuration: the cluster and scheduler geometry a
/// campaign runs against (what the *system* looks like), as opposed to
/// the [`Submitter`], which decides what the *workload* looks like.
#[derive(Clone, Debug)]
pub struct CampaignConfig {
    /// Primary application: HQ allocation geometry (worker cores,
    /// allocation walltime) and registration-job shapes follow its
    /// Table III row.  Submissions may carry other apps, but on the HQ
    /// path their core request must fit this app's allocation.
    pub app: App,
    pub seed: u64,
    pub cluster: ClusterSpec,
    pub overheads: OverheadModel,
    /// Registration pre-jobs the UM-Bridge balancer issues before the
    /// first evaluation (HQ path; "at least five additional jobs").
    pub registration_jobs: u64,
    /// HQ autoalloc: allocations waiting in the native queue at once.
    pub hq_backlog: u32,
    /// HQ autoalloc: upper bound on simultaneously existing workers.
    pub hq_workers: u32,
}

impl CampaignConfig {
    /// The paper's configuration: Hamilton8, paper overheads, five
    /// registration jobs, HQ worker pool sized to the queue depth.
    pub fn paper(app: App, queue_depth: usize, seed: u64) -> Self {
        CampaignConfig {
            app,
            seed,
            cluster: ClusterSpec::hamilton8(),
            overheads: OverheadModel::paper(),
            registration_jobs: 5,
            hq_backlog: queue_depth as u32,
            hq_workers: queue_depth as u32,
        }
    }
}

/// Which SLURM submission path a campaign uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlurmMode {
    /// One plain `sbatch` job per evaluation (the paper's baseline).
    Native,
    /// UM-Bridge SLURM backend (Appendix A): model-server start-up
    /// inside each job plus the balancer's proxy latency on submission.
    UmBridge,
}

/// A finished campaign: the per-job records plus campaign-level metrics.
#[derive(Debug)]
pub struct CampaignResult {
    pub experiment: Experiment,
    pub metrics: CampaignMetrics,
}

/// Campaign user -> scheduler user.  User 0 is the experiment user; the
/// scheduler reserves user 1 for background load, so other campaign
/// users shift past it (each stream gets its own submission quota).
fn slurm_user(user: u32) -> u32 {
    if user == 0 {
        USER_EXPERIMENT
    } else {
        user + 1
    }
}

#[allow(clippy::too_many_arguments)]
fn submit_slurm(
    t: Micros,
    s: &Submission,
    per_job_extra: Micros,
    submit_extra: Micros,
    core: &mut SlurmCore,
    acts: &mut Vec<Action>,
    durations: &mut HashMap<u64, Micros>,
    users: &mut HashMap<u64, u32>,
    depth: &mut DepthTrack,
    submitted: &mut u64,
) {
    debug_assert!(s.tag != u64::MAX, "tag u64::MAX is reserved");
    let dur = s.duration + per_job_extra;
    let id = core.submit_into(
        t + submit_extra,
        slurm_user(s.user),
        s.tag,
        scenario(s.app).slurm_request(),
        acts,
    );
    durations.insert(id, dur);
    users.insert(id, s.user);
    depth.submit(t);
    *submitted += 1;
}

/// Run a campaign against the SLURM core.
///
/// Returns once the submitter reports the campaign finished (or the
/// event queue drains, whichever comes first).
pub fn run_slurm(
    cfg: &CampaignConfig,
    sub: &mut dyn Submitter,
    mode: SlurmMode,
) -> CampaignResult {
    #[derive(Debug)]
    enum Ev {
        Timer(Timer),
        Wake(u64),
        Submit(Submission),
        Finish(u64),
    }

    let (per_job_extra, submit_extra, label): (Micros, Micros, &str) =
        match mode {
            SlurmMode::Native => (0, 0, "SLURM"),
            SlurmMode::UmBridge => {
                (cfg.overheads.server_init, 50 * MS, "UM-Bridge SLURM")
            }
        };
    let mut core =
        SlurmCore::new(cfg.cluster.clone(), cfg.overheads.clone(), cfg.seed);
    let mut des: Des<Ev> = Des::new();
    let mut exp = Experiment::new(label);
    let mut durations: HashMap<u64, Micros> = HashMap::new();
    let mut users: HashMap<u64, u32> = HashMap::new();
    let mut depth = DepthTrack::new();
    let mut per_user = UserTrack::new();
    let mut submitted: u64 = 0;
    let mut completed: u64 = 0;

    for a in core.bootstrap(0) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, Ev::Timer(tm));
        }
    }
    let mut sink = Sink::new();
    sub.start(&mut sink);
    for s in sink.submissions.drain(..) {
        des.schedule(0, Ev::Submit(s));
    }
    for (tw, tok) in sink.wakes.drain(..) {
        des.schedule(tw, Ev::Wake(tok));
    }

    let mut guard: u64 = 0;
    // One reusable action buffer for the whole run (see PERF.md).
    let mut acts: Vec<Action> = Vec::new();
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway campaign");
        acts.clear();
        match ev {
            Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
            Ev::Wake(token) => {
                sub.wake(t, token, &mut sink);
                for s in sink.submissions.drain(..) {
                    submit_slurm(
                        t, &s, per_job_extra, submit_extra, &mut core,
                        &mut acts, &mut durations, &mut users, &mut depth,
                        &mut submitted,
                    );
                }
                for (tw, tok) in sink.wakes.drain(..) {
                    des.schedule(tw, Ev::Wake(tok));
                }
            }
            Ev::Submit(s) => submit_slurm(
                t, &s, per_job_extra, submit_extra, &mut core, &mut acts,
                &mut durations, &mut users, &mut depth, &mut submitted,
            ),
            Ev::Finish(id) => core.on_finish_into(t, id, &mut acts),
        }
        for a in acts.drain(..) {
            match a {
                Action::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Action::Launched { job, contention, .. } => {
                    // Background jobs self-finish and are not in the map.
                    if let Some(d) = durations.remove(&job) {
                        let dd = (d as f64 * contention) as Micros;
                        des.schedule(t + dd, Ev::Finish(job));
                    }
                }
                Action::Completed { job, record } => {
                    if record.tag != u64::MAX {
                        completed += 1;
                        let rec = record.quantised(SLURM_LOG_GRAIN);
                        let user = users.remove(&job).unwrap_or(0);
                        per_user.complete(user, &rec);
                        depth.complete(t);
                        exp.records.push(rec.clone());
                        sub.completed(t, &rec, &mut sink);
                        for s in sink.submissions.drain(..) {
                            des.schedule(t, Ev::Submit(s));
                        }
                        for (tw, tok) in sink.wakes.drain(..) {
                            des.schedule(tw, Ev::Wake(tok));
                        }
                    }
                }
                Action::TimedOut { .. } => {}
            }
        }
        if sub.finished(completed) {
            break;
        }
    }
    exp.records.sort_by_key(|r| r.tag);
    finish(exp, sub, label, submitted, completed, depth, per_user,
           des.processed())
}

#[allow(clippy::too_many_arguments)]
fn submit_hq(
    t: Micros,
    s: &Submission,
    alloc_app: App,
    server_init: Micros,
    hq: &mut HqCore,
    hq_acts: &mut Vec<HqAction>,
    task_durations: &mut HashMap<u64, Micros>,
    task_users: &mut HashMap<u64, u32>,
    depth: &mut DepthTrack,
    submitted: &mut u64,
) {
    debug_assert!(s.tag != u64::MAX, "tag u64::MAX is reserved");
    let scen = scenario(s.app);
    // Worker geometry follows the campaign's primary app: a task whose
    // shape exceeds it would sit in the HQ queue forever (autoalloc
    // cycling until the runaway guard).  Fail fast and explain instead.
    let alloc = scenario(alloc_app);
    assert!(
        scen.cpus <= alloc.cpus && scen.hq_time_request <= alloc.hq_alloc_time,
        "campaign submission '{}' (cores {}, time request {}) cannot fit \
         the '{}' allocation geometry (cores {}, walltime {}); pick a \
         CampaignConfig.app whose Table III row covers every submitted app",
        s.app.label(),
        scen.cpus,
        scen.hq_time_request,
        alloc_app.label(),
        alloc.cpus,
        alloc.hq_alloc_time,
    );
    let tid = hq.submit_task_into(
        t,
        TaskSpec {
            tag: s.tag,
            cores: scen.cpus,
            time_request: scen.hq_time_request,
            time_limit: scen.hq_time_limit + server_init,
        },
        hq_acts,
    );
    task_durations.insert(tid, s.duration + server_init);
    task_users.insert(tid, s.user);
    depth.submit(t);
    *submitted += 1;
}

/// Run a campaign against the UM-Bridge + HQ stack (tasks dispatched by
/// the HQ core onto workers inside bulk allocations obtained from the
/// SLURM core).
pub fn run_hq(cfg: &CampaignConfig, sub: &mut dyn Submitter) -> CampaignResult {
    #[derive(Debug)]
    enum Ev {
        Slurm(Timer),
        Hq(HqTimer),
        Wake(u64),
        Submit(Submission),
        RegSubmit,
        TaskDone(u64),
        SlurmFinish(u64),
    }

    let scen = scenario(cfg.app);
    let mut slurm =
        SlurmCore::new(cfg.cluster.clone(), cfg.overheads.clone(), cfg.seed);
    let mut hq = HqCore::new(AutoAllocConfig {
        backlog: cfg.hq_backlog,
        workers_per_alloc: 1,
        max_worker_count: cfg.hq_workers,
        alloc_request: scen.hq_alloc_request(),
        dispatch_latency: cfg.overheads.hq_dispatch,
    });
    let mut des: Des<Ev> = Des::new();
    let mut exp = Experiment::new("HQ");

    // alloc slurm-job id -> hq alloc tag
    let mut alloc_jobs: HashMap<u64, u64> = HashMap::new();
    let mut task_durations: HashMap<u64, Micros> = HashMap::new();
    let mut task_users: HashMap<u64, u32> = HashMap::new();
    let mut depth = DepthTrack::new();
    let mut per_user = UserTrack::new();
    let mut submitted: u64 = 0;
    let mut completed: u64 = 0;

    for a in slurm.bootstrap(0) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, Ev::Slurm(tm));
        }
    }
    // Registration pre-jobs go first (the balancer's readiness checks),
    // then the submitter seeds the campaign.
    for _ in 0..cfg.registration_jobs {
        des.schedule(0, Ev::RegSubmit);
    }
    let mut sink = Sink::new();
    sub.start(&mut sink);
    for s in sink.submissions.drain(..) {
        des.schedule(0, Ev::Submit(s));
    }
    for (tw, tok) in sink.wakes.drain(..) {
        des.schedule(tw, Ev::Wake(tok));
    }

    let mut guard: u64 = 0;
    // Reusable action buffers: the cores append into `*_acts`; the
    // routing loop swaps each into a batch buffer before interpreting,
    // so interpretation can append follow-up actions without allocating.
    let mut slurm_acts: Vec<Action> = Vec::new();
    let mut hq_acts: Vec<HqAction> = Vec::new();
    let mut slurm_batch: Vec<Action> = Vec::new();
    let mut hq_batch: Vec<HqAction> = Vec::new();
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway campaign");
        match ev {
            Ev::Slurm(tm) => slurm.on_timer_into(t, tm, &mut slurm_acts),
            Ev::Hq(tm) => hq.on_timer_into(t, tm, &mut hq_acts),
            Ev::Wake(token) => {
                sub.wake(t, token, &mut sink);
                for s in sink.submissions.drain(..) {
                    submit_hq(
                        t, &s, cfg.app, cfg.overheads.server_init, &mut hq,
                        &mut hq_acts, &mut task_durations, &mut task_users,
                        &mut depth, &mut submitted,
                    );
                }
                for (tw, tok) in sink.wakes.drain(..) {
                    des.schedule(tw, Ev::Wake(tok));
                }
            }
            Ev::Submit(s) => submit_hq(
                t, &s, cfg.app, cfg.overheads.server_init, &mut hq,
                &mut hq_acts, &mut task_durations, &mut task_users,
                &mut depth, &mut submitted,
            ),
            Ev::RegSubmit => {
                // Registration jobs: ~1 s of server init only; tagged
                // with the reserved marker so completions route back
                // here instead of into the records.
                let tid = hq.submit_task_into(
                    t,
                    TaskSpec {
                        tag: u64::MAX,
                        cores: scen.cpus,
                        time_request: scen.hq_time_request,
                        time_limit: scen.hq_time_limit
                            + cfg.overheads.server_init,
                    },
                    &mut hq_acts,
                );
                task_durations.insert(tid, cfg.overheads.server_init);
                depth.submit(t);
            }
            Ev::TaskDone(tid) => hq.on_task_done_into(t, tid, &mut hq_acts),
            Ev::SlurmFinish(id) => {
                slurm.on_finish_into(t, id, &mut slurm_acts);
                if alloc_jobs.remove(&id).is_some() {
                    // Allocation ended: expire its worker so hqlite
                    // requeues tasks and requests replacement capacity.
                    hq.expire_workers_into(t, &mut hq_acts);
                }
            }
        }

        // Route until both action queues drain (they feed each other).
        loop {
            let mut progressed = false;
            std::mem::swap(&mut slurm_acts, &mut slurm_batch);
            for a in slurm_batch.drain(..) {
                progressed = true;
                match a {
                    Action::Timer(tt, tm) => des.schedule(tt, Ev::Slurm(tm)),
                    Action::Launched { job, .. } => {
                        if alloc_jobs.contains_key(&job) {
                            // Allocation is up: a worker registers for the
                            // remaining allocation lifetime.
                            hq.on_alloc_up_into(
                                t,
                                scen.hq_alloc_time,
                                scen.cpus,
                                &mut hq_acts,
                            );
                            // The allocation job ends at its time limit.
                            des.schedule(
                                t + scen.hq_alloc_time,
                                Ev::SlurmFinish(job),
                            );
                        }
                    }
                    Action::Completed { .. } | Action::TimedOut { .. } => {}
                }
            }
            std::mem::swap(&mut hq_acts, &mut hq_batch);
            for a in hq_batch.drain(..) {
                progressed = true;
                match a {
                    HqAction::SubmitAllocation { alloc_tag, req } => {
                        let id = slurm.submit_into(
                            t,
                            USER_EXPERIMENT,
                            u64::MAX - 1,
                            req,
                            &mut slurm_acts,
                        );
                        alloc_jobs.insert(id, alloc_tag);
                    }
                    HqAction::StartTask { task, .. } => {
                        let dur = task_durations[&task];
                        des.schedule(t + dur, Ev::TaskDone(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Hq(tm)),
                    HqAction::TaskCompleted { task, record } => {
                        // HQ logs at millisecond accuracy.
                        let rec = record.quantised(MS);
                        task_durations.remove(&task);
                        depth.complete(t);
                        if rec.tag == u64::MAX {
                            // Registration pre-job: readiness check only,
                            // excluded from the records.
                            sub.registration_completed(t, &mut sink);
                        } else {
                            completed += 1;
                            let user =
                                task_users.remove(&task).unwrap_or(0);
                            per_user.complete(user, &rec);
                            exp.records.push(rec.clone());
                            sub.completed(t, &rec, &mut sink);
                        }
                        for s in sink.submissions.drain(..) {
                            des.schedule(t, Ev::Submit(s));
                        }
                        for (tw, tok) in sink.wakes.drain(..) {
                            des.schedule(tw, Ev::Wake(tok));
                        }
                    }
                    HqAction::KillTask { .. } => {}
                }
            }
            if !progressed {
                break;
            }
        }
        if sub.finished(completed) {
            break;
        }
    }
    exp.records.sort_by_key(|r| r.tag);
    finish(exp, sub, "HQ", submitted, completed, depth, per_user,
           des.processed())
}

#[allow(clippy::too_many_arguments)]
fn finish(
    exp: Experiment,
    sub: &mut dyn Submitter,
    scheduler: &str,
    submitted: u64,
    completed: u64,
    depth: DepthTrack,
    per_user: UserTrack,
    des_events: u64,
) -> CampaignResult {
    let per_user_stats = per_user.stats();
    let fairness = jain_fairness(&per_user_stats);
    let peak = depth.peak();
    let metrics = CampaignMetrics {
        policy: sub.label(),
        scheduler: scheduler.to_string(),
        submitted,
        completed,
        makespan: exp.makespan(),
        time_to: CampaignMetrics::milestones(&exp),
        depth_trajectory: depth.into_samples(),
        peak_in_flight: peak,
        per_user: per_user_stats,
        fairness_jain: fairness,
        des_events,
    };
    CampaignResult { experiment: exp, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::submitter::{
        AdaptiveBayes, FixedDepth, PoissonBurst, UserMix, UserStream,
    };

    fn small_cfg(app: App, qd: usize) -> CampaignConfig {
        let mut c = CampaignConfig::paper(app, qd, 11);
        c.cluster = ClusterSpec::small(8);
        // Keep background load light so tests are fast.
        c.overheads.bg_interarrival = 300 * SEC;
        c
    }

    #[test]
    fn fixed_depth_campaign_completes_on_both_schedulers() {
        let cfg = small_cfg(App::Eigen100, 2);
        let mut s1 = FixedDepth::new(App::Eigen100, 12, 2, cfg.seed);
        let r1 = run_slurm(&cfg, &mut s1, SlurmMode::Native);
        assert_eq!(r1.experiment.records.len(), 12);
        assert_eq!(r1.metrics.completed, 12);
        assert_eq!(r1.metrics.submitted, 12);
        assert!(r1.metrics.peak_in_flight <= 2);
        assert_eq!(r1.metrics.scheduler, "SLURM");

        let mut s2 = FixedDepth::new(App::Eigen100, 12, 2, cfg.seed);
        let r2 = run_hq(&cfg, &mut s2);
        assert_eq!(r2.experiment.records.len(), 12);
        // Registration pre-jobs ride along in the trajectory peak.
        assert!(r2.metrics.peak_in_flight as u64 <= 2 + cfg.registration_jobs);
        assert_eq!(r2.metrics.scheduler, "HQ");
    }

    #[test]
    fn milestones_are_monotone() {
        let cfg = small_cfg(App::Gp, 4);
        let mut s = FixedDepth::new(App::Gp, 20, 4, cfg.seed);
        let r = run_hq(&cfg, &mut s);
        let tt = &r.metrics.time_to;
        assert!(!tt.is_empty());
        assert_eq!(tt[0].0, 1);
        // Milestones agree with the per-N accessor on Experiment.
        assert_eq!(Some(tt[0].1), r.experiment.time_to_nth_result(1));
        for w in tt.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(tt.last().unwrap().0, 20);
    }

    #[test]
    fn bursty_campaign_builds_queue_depth() {
        let mut cfg = small_cfg(App::Gp, 2);
        cfg.hq_backlog = 2;
        cfg.hq_workers = 2;
        cfg.registration_jobs = 0;
        // Arrivals far faster than service: depth must exceed any fixed
        // protocol constant.
        let mut s = PoissonBurst::new(App::Gp, 60, SEC, (4, 8), 3);
        let r = run_hq(&cfg, &mut s);
        assert_eq!(r.experiment.records.len(), 60);
        assert!(
            r.metrics.peak_in_flight > 8,
            "open-loop arrivals should outrun service, peak {}",
            r.metrics.peak_in_flight
        );
        assert!(!r.metrics.depth_trajectory.is_empty());
    }

    #[test]
    fn user_mix_reports_per_user_fairness() {
        let cfg = small_cfg(App::Gp, 2);
        let mut s = UserMix::new(
            vec![
                UserStream {
                    user: 0,
                    app: App::Gp,
                    n_evals: 8,
                    queue_depth: 2,
                },
                UserStream {
                    user: 1,
                    app: App::Eigen100,
                    n_evals: 8,
                    queue_depth: 2,
                },
            ],
            cfg.seed,
        );
        let r = run_slurm(&cfg, &mut s, SlurmMode::Native);
        assert_eq!(r.experiment.records.len(), 16);
        assert_eq!(r.metrics.per_user.len(), 2);
        assert_eq!(r.metrics.per_user[0].completed, 8);
        assert_eq!(r.metrics.per_user[1].completed, 8);
        assert!(r.metrics.fairness_jain > 0.0);
        assert!(r.metrics.fairness_jain <= 1.0 + 1e-12);
    }

    #[test]
    fn adaptive_campaign_terminates_within_budget() {
        let cfg = small_cfg(App::Gp, 2);
        let mut s = AdaptiveBayes::new(App::Gp, 64, cfg.seed)
            .with_batches(8, 4, 16);
        let r = run_hq(&cfg, &mut s);
        assert!(r.metrics.completed <= 64);
        assert!(r.metrics.completed >= 8);
        assert_eq!(r.metrics.completed, r.metrics.submitted);
        assert!(s.rounds() >= 1);
    }
}
