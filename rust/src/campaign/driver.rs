//! Campaign entry points: thin configuration adapters over the generic
//! scheduler kernel.
//!
//! Since the `sched` redesign there is **one** event loop —
//! [`crate::sched::kernel::run`] — and this module only decides *which*
//! [`SchedulerCore`](crate::sched::SchedulerCore) implementation a
//! campaign runs against:
//!
//! * [`run_slurm`] — [`SlurmSched`](crate::sched::SlurmSched): one plain
//!   `sbatch` job per evaluation (native), or the UM-Bridge SLURM
//!   backend (Appendix A: model-server start-up per job + balancer
//!   proxy latency per submission).
//! * [`run_hq`] — [`MetaStack`](crate::sched::MetaStack)`<HqCore>`: the
//!   paper's UM-Bridge + HyperQueue stack (registration pre-jobs,
//!   automatic allocation against the SLURM core, worker expiry,
//!   per-task dispatch).
//! * [`run_worksteal`] — `MetaStack<WorkStealCore>`: the same UM-Bridge
//!   stack over the partitioned work-stealing dispatcher.
//! * [`run_edf`] — `MetaStack<EdfCore>`: the same UM-Bridge stack over
//!   the deadline-EDF dispatcher (earliest deadline first, laxity
//!   tie-break).
//! * [`run_gang`] — `MetaStack<GangCore>`: the same UM-Bridge stack over
//!   the moldable gang dispatcher (each task atomically reserves a slot
//!   on 1..=2 workers, or holds the frontier until it can).
//!
//! With the [`FixedDepth`](super::submitter::FixedDepth) policy the
//! SLURM and HQ paths reproduce the PR 1 experiment drivers
//! *record-for-record* — the originals are preserved verbatim in
//! `experiments::reference` and `tests/campaign_equiv.rs` pins the
//! equivalence through the kernel.
//!
//! Event cost: every event is O(core transition) — the kernel adds O(1)
//! bookkeeping (two `HashMap` ops and a depth-trajectory update) per
//! submission/completion, so campaign mode inherits the indexed cores'
//! million-task scaling (see PERF.md).

use crate::cluster::{ClusterSpec, OverheadModel};
use crate::hqlite::{AutoAllocConfig, HqCore};
use crate::metrics::Experiment;
use crate::sched::{kernel, EdfCore, EdfSched, FaultPlan, FaultSpec, GangCore,
                   GangSched, HqSched, MetaStack, SlurmSched, WorkStealCore,
                   WorkStealSched};
use crate::workload::{scenario, App};

use super::metrics::CampaignMetrics;
use super::submitter::Submitter;

/// Campaign-plane configuration: the cluster and scheduler geometry a
/// campaign runs against (what the *system* looks like), as opposed to
/// the [`Submitter`], which decides what the *workload* looks like.
#[derive(Clone, Debug)]
pub struct CampaignConfig {
    /// Primary application: HQ allocation geometry (worker cores,
    /// allocation walltime) and registration-job shapes follow its
    /// Table III row.  Submissions may carry other apps, but on the HQ
    /// path their core request must fit this app's allocation.
    pub app: App,
    pub seed: u64,
    pub cluster: ClusterSpec,
    pub overheads: OverheadModel,
    /// Registration pre-jobs the UM-Bridge balancer issues before the
    /// first evaluation (HQ path; "at least five additional jobs").
    pub registration_jobs: u64,
    /// HQ autoalloc: allocations waiting in the native queue at once.
    pub hq_backlog: u32,
    /// HQ autoalloc: upper bound on simultaneously existing workers.
    pub hq_workers: u32,
    /// Optional fault-injection plan (worker crashes, transient task
    /// failures, stragglers).  `None` = the paper's perfect cluster.
    pub faults: Option<FaultSpec>,
}

impl CampaignConfig {
    /// The paper's configuration: Hamilton8, paper overheads, five
    /// registration jobs, HQ worker pool sized to the queue depth.
    pub fn paper(app: App, queue_depth: usize, seed: u64) -> Self {
        CampaignConfig {
            app,
            seed,
            cluster: ClusterSpec::hamilton8(),
            overheads: OverheadModel::paper(),
            registration_jobs: 5,
            hq_backlog: queue_depth as u32,
            hq_workers: queue_depth as u32,
            faults: None,
        }
    }

    /// Compiled fault plan for this campaign (None = clean cluster).
    fn fault_plan(&self) -> Option<FaultPlan> {
        self.faults.clone().map(FaultPlan::new)
    }

    /// The automatic-allocation settings this campaign implies for an
    /// HQ-style meta-scheduler (allocation geometry from the primary
    /// app's Table III row).
    pub fn autoalloc(&self) -> AutoAllocConfig {
        let scen = scenario(self.app);
        AutoAllocConfig {
            backlog: self.hq_backlog,
            workers_per_alloc: 1,
            max_worker_count: self.hq_workers,
            alloc_request: scen.hq_alloc_request(),
            dispatch_latency: self.overheads.hq_dispatch,
        }
    }
}

/// Which SLURM submission path a campaign uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlurmMode {
    /// One plain `sbatch` job per evaluation (the paper's baseline).
    Native,
    /// UM-Bridge SLURM backend (Appendix A): model-server start-up
    /// inside each job plus the balancer's proxy latency on submission.
    UmBridge,
}

/// A finished campaign: the per-job records plus campaign-level metrics.
#[derive(Debug)]
pub struct CampaignResult {
    pub experiment: Experiment,
    pub metrics: CampaignMetrics,
}

/// Run a campaign against the SLURM core.
///
/// Returns once the submitter reports the campaign finished (or the
/// event queue drains, whichever comes first).
pub fn run_slurm(
    cfg: &CampaignConfig,
    sub: &mut dyn Submitter,
    mode: SlurmMode,
) -> CampaignResult {
    let mut core = SlurmSched::new(cfg, mode);
    let plan = cfg.fault_plan();
    kernel::run_with_faults(&mut core, sub, plan.as_ref())
}

/// Run a campaign against the UM-Bridge + HQ stack (tasks dispatched by
/// the HQ core onto workers inside bulk allocations obtained from the
/// SLURM core).
pub fn run_hq(cfg: &CampaignConfig, sub: &mut dyn Submitter) -> CampaignResult {
    let mut core: HqSched =
        MetaStack::new(cfg, HqCore::new(cfg.autoalloc()), "HQ");
    let plan = cfg.fault_plan();
    kernel::run_with_faults(&mut core, sub, plan.as_ref())
}

/// Run a campaign against the UM-Bridge + work-stealing stack (same
/// allocation mechanics as [`run_hq`], dispatch via partitioned
/// per-worker deques with stealing).
pub fn run_worksteal(
    cfg: &CampaignConfig,
    sub: &mut dyn Submitter,
) -> CampaignResult {
    let mut core: WorkStealSched =
        MetaStack::new(cfg, WorkStealCore::new(cfg.autoalloc()), "worksteal");
    let plan = cfg.fault_plan();
    kernel::run_with_faults(&mut core, sub, plan.as_ref())
}

/// Run a campaign against the UM-Bridge + deadline-EDF stack (same
/// allocation mechanics as [`run_hq`], dispatch strictly earliest
/// deadline first with laxity tie-break — each task's deadline is its
/// submission time plus its kill limit).
pub fn run_edf(cfg: &CampaignConfig, sub: &mut dyn Submitter)
               -> CampaignResult {
    let mut core: EdfSched =
        MetaStack::new(cfg, EdfCore::new(cfg.autoalloc()), "edf");
    let plan = cfg.fault_plan();
    kernel::run_with_faults(&mut core, sub, plan.as_ref())
}

/// Run a campaign against the UM-Bridge + gang stack (same allocation
/// mechanics as [`run_hq`], dispatch strictly FCFS with each task run as
/// a moldable gang: it atomically reserves one slot on every eligible
/// worker — at least 1, at most 2 — or holds the queue head until
/// enough workers are free).
pub fn run_gang(cfg: &CampaignConfig, sub: &mut dyn Submitter)
                -> CampaignResult {
    let mut core: GangSched = MetaStack::new(
        cfg,
        GangCore::new(cfg.autoalloc()).with_gang(1, 2),
        "gang",
    );
    let plan = cfg.fault_plan();
    kernel::run_with_faults(&mut core, sub, plan.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::dag::{Mlda, MldaLevel, StageInOut};
    use crate::campaign::submitter::{
        AdaptiveBayes, FixedDepth, PoissonBurst, UserMix, UserStream,
    };
    use crate::clock::SEC;

    fn small_cfg(app: App, qd: usize) -> CampaignConfig {
        let mut c = CampaignConfig::paper(app, qd, 11);
        c.cluster = ClusterSpec::small(8);
        // Keep background load light so tests are fast.
        c.overheads.bg_interarrival = 300 * SEC;
        c
    }

    #[test]
    fn fixed_depth_campaign_completes_on_all_schedulers() {
        let cfg = small_cfg(App::Eigen100, 2);
        let mut s1 = FixedDepth::new(App::Eigen100, 12, 2, cfg.seed);
        let r1 = run_slurm(&cfg, &mut s1, SlurmMode::Native);
        assert_eq!(r1.experiment.records.len(), 12);
        assert_eq!(r1.metrics.completed, 12);
        assert_eq!(r1.metrics.submitted, 12);
        assert!(r1.metrics.peak_in_flight <= 2);
        assert_eq!(r1.metrics.scheduler, "SLURM");

        let mut s2 = FixedDepth::new(App::Eigen100, 12, 2, cfg.seed);
        let r2 = run_hq(&cfg, &mut s2);
        assert_eq!(r2.experiment.records.len(), 12);
        // Registration pre-jobs ride along in the trajectory peak.
        assert!(r2.metrics.peak_in_flight as u64 <= 2 + cfg.registration_jobs);
        assert_eq!(r2.metrics.scheduler, "HQ");

        let mut s3 = FixedDepth::new(App::Eigen100, 12, 2, cfg.seed);
        let r3 = run_worksteal(&cfg, &mut s3);
        assert_eq!(r3.experiment.records.len(), 12);
        assert_eq!(r3.metrics.completed, 12);
        assert!(r3.metrics.peak_in_flight as u64 <= 2 + cfg.registration_jobs);
        assert_eq!(r3.metrics.scheduler, "worksteal");

        let mut s4 = FixedDepth::new(App::Eigen100, 12, 2, cfg.seed);
        let r4 = run_edf(&cfg, &mut s4);
        assert_eq!(r4.experiment.records.len(), 12);
        assert_eq!(r4.metrics.completed, 12);
        assert!(r4.metrics.peak_in_flight as u64 <= 2 + cfg.registration_jobs);
        assert_eq!(r4.metrics.scheduler, "edf");

        let mut s5 = FixedDepth::new(App::Eigen100, 12, 2, cfg.seed);
        let r5 = run_gang(&cfg, &mut s5);
        assert_eq!(r5.experiment.records.len(), 12);
        assert_eq!(r5.metrics.completed, 12);
        assert!(r5.metrics.peak_in_flight as u64 <= 2 + cfg.registration_jobs);
        assert_eq!(r5.metrics.scheduler, "gang");
    }

    #[test]
    fn milestones_are_monotone() {
        let cfg = small_cfg(App::Gp, 4);
        let mut s = FixedDepth::new(App::Gp, 20, 4, cfg.seed);
        let r = run_hq(&cfg, &mut s);
        let tt = &r.metrics.time_to;
        assert!(!tt.is_empty());
        assert_eq!(tt[0].0, 1);
        // Milestones agree with the per-N accessor on Experiment.
        assert_eq!(Some(tt[0].1), r.experiment.time_to_nth_result(1));
        for w in tt.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(tt.last().unwrap().0, 20);
    }

    #[test]
    fn bursty_campaign_builds_queue_depth() {
        let mut cfg = small_cfg(App::Gp, 2);
        cfg.hq_backlog = 2;
        cfg.hq_workers = 2;
        cfg.registration_jobs = 0;
        // Arrivals far faster than service: depth must exceed any fixed
        // protocol constant.
        let mut s = PoissonBurst::new(App::Gp, 60, SEC, (4, 8), 3);
        let r = run_hq(&cfg, &mut s);
        assert_eq!(r.experiment.records.len(), 60);
        assert!(
            r.metrics.peak_in_flight > 8,
            "open-loop arrivals should outrun service, peak {}",
            r.metrics.peak_in_flight
        );
        assert!(!r.metrics.depth_trajectory.is_empty());
    }

    #[test]
    fn user_mix_reports_per_user_fairness() {
        let cfg = small_cfg(App::Gp, 2);
        let mut s = UserMix::new(
            vec![
                UserStream {
                    user: 0,
                    app: App::Gp,
                    n_evals: 8,
                    queue_depth: 2,
                },
                UserStream {
                    user: 1,
                    app: App::Eigen100,
                    n_evals: 8,
                    queue_depth: 2,
                },
            ],
            cfg.seed,
        );
        let r = run_slurm(&cfg, &mut s, SlurmMode::Native);
        assert_eq!(r.experiment.records.len(), 16);
        assert_eq!(r.metrics.per_user.len(), 2);
        assert_eq!(r.metrics.per_user[0].completed, 8);
        assert_eq!(r.metrics.per_user[1].completed, 8);
        assert!(r.metrics.fairness_jain > 0.0);
        assert!(r.metrics.fairness_jain <= 1.0 + 1e-12);
    }

    #[test]
    fn adaptive_campaign_terminates_within_budget() {
        let cfg = small_cfg(App::Gp, 2);
        let mut s = AdaptiveBayes::new(App::Gp, 64, cfg.seed)
            .with_batches(8, 4, 16);
        let r = run_hq(&cfg, &mut s);
        assert!(r.metrics.completed <= 64);
        assert!(r.metrics.completed >= 8);
        assert_eq!(r.metrics.completed, r.metrics.submitted);
        assert!(s.rounds() >= 1);
    }

    #[test]
    fn worksteal_matches_protocol_invariants() {
        // The work-stealing stack honours the same campaign contract:
        // every submission completes exactly once, times are ordered,
        // and a bursty stream still drains.
        let mut cfg = small_cfg(App::Gp, 2);
        cfg.hq_backlog = 2;
        cfg.hq_workers = 2;
        cfg.registration_jobs = 0;
        let mut s = PoissonBurst::new(App::Gp, 40, SEC, (2, 6), 7);
        let r = run_worksteal(&cfg, &mut s);
        assert_eq!(r.experiment.records.len(), 40);
        let mut tags: Vec<u64> =
            r.experiment.records.iter().map(|x| x.tag).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), 40, "no duplicated/lost evaluations");
        for rec in &r.experiment.records {
            assert!(rec.submit <= rec.start && rec.start <= rec.end);
        }
    }

    fn mlda_levels() -> Vec<MldaLevel> {
        vec![
            MldaLevel { count: 12, runtime_scale: 0.5 },
            MldaLevel { count: 8, runtime_scale: 1.0 },
            MldaLevel { count: 4, runtime_scale: 2.0 },
        ]
    }

    #[test]
    fn mlda_campaign_drains_and_respects_edges_on_all_schedulers() {
        let mut cfg = small_cfg(App::Gp, 2);
        cfg.registration_jobs = 0;
        let runs: [(&str, fn(&CampaignConfig, &mut Mlda) -> CampaignResult);
            5] = [
            ("slurm", |c, s| run_slurm(c, s, SlurmMode::Native)),
            ("hq", |c, s| run_hq(c, s)),
            ("worksteal", |c, s| run_worksteal(c, s)),
            ("edf", |c, s| run_edf(c, s)),
            ("gang", |c, s| run_gang(c, s)),
        ];
        for (name, run) in runs {
            let mut s = Mlda::new(App::Gp, mlda_levels(), cfg.seed)
                .with_occupancy(3, 1, 12);
            let r = run(&cfg, &mut s);
            let m = &r.metrics;
            assert_eq!(
                m.completed, m.submitted,
                "{name}: every submitted task must report"
            );
            assert_eq!(m.completed as usize, r.experiment.records.len());
            // All 12 coarse roots ran; chains actually formed.
            assert!(m.dep_edges > 0, "{name}: chains carry edges");
            assert!(m.released > 0, "{name}: gated tasks were released");
            assert!(
                !m.per_user_time_to.is_empty(),
                "{name}: per-level milestones present"
            );
            // Level 0 (the coarse roots) always produces results.
            let users: Vec<u32> =
                m.per_user_time_to.iter().map(|(u, _)| *u).collect();
            assert!(users.contains(&0), "{name}: level 0 reported");
        }
    }

    #[test]
    fn stageio_campaign_produces_exact_round_structure() {
        let mut cfg = small_cfg(App::Gp, 2);
        cfg.registration_jobs = 0;
        let mut s = StageInOut::new(App::Gp, 4, 3, 2, cfg.seed);
        let total = s.total_tasks();
        let r = run_hq(&cfg, &mut s);
        let m = &r.metrics;
        assert_eq!(m.completed, total);
        assert_eq!(m.submitted, total);
        // Each round carries fanout compute->transfer edges plus
        // fanout reduce->compute edges: 4 rounds x (3 + 3).
        assert_eq!(m.dep_edges, 4 * (3 + 3));
        assert_eq!(m.skipped, 0);
        assert!(m.peak_blocked >= 1, "fan-in must block the reduce");
        // The per-stage users partition the records.
        let per_stage: u64 =
            m.per_user.iter().map(|u| u.completed).sum();
        assert_eq!(per_stage, total);
    }

    #[test]
    fn mlda_under_faults_still_emits_one_record_per_submission() {
        let mut cfg = small_cfg(App::Gp, 2);
        cfg.registration_jobs = 0;
        let fs = crate::sched::FaultSpec::parse(
            "crash=120s,fail=0.2,attempts=2,backoff=1s:8s,seed=5",
        )
        .expect("fault spec");
        cfg.faults = Some(fs);
        let mut s = Mlda::new(App::Gp, mlda_levels(), cfg.seed)
            .with_occupancy(3, 1, 12);
        let r = run_hq(&cfg, &mut s);
        let m = &r.metrics;
        // The drain invariant under quarantine: descendants of a
        // poisoned parent surface as truncated Skipped records, so
        // records emitted always equals tasks submitted.
        assert_eq!(m.completed, m.submitted);
        assert_eq!(m.completed as usize, r.experiment.records.len());
        assert!(m.skipped <= m.submitted);
        for rec in &r.experiment.records {
            assert!(rec.submit <= rec.start && rec.start <= rec.end);
        }
    }
}
