//! Live-plane bring-up helpers shared by the CLI, the examples and the
//! integration tests: one call starts slurmlite + backend + balancer.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::cluster::{ClusterSpec, JobRequest, OverheadModel};
use crate::runtime::Engine;
use crate::sched::LivePolicy;
use crate::slurmlite::daemon::{EventSink, SlurmDaemon};
use crate::workload::{app_for_model, scenario};

use super::{Backend, BalancerConfig, HqBackend, LoadBalancer, SlurmBackend};

/// Everything a live deployment needs, torn down on drop.
pub struct LiveStack {
    pub balancer: LoadBalancer,
    pub daemon: Arc<SlurmDaemon>,
    pub backend: Arc<dyn Backend>,
}

/// Start slurmlite + the chosen backend + the balancer, serving every
/// model in `models` through one front door.
///
/// Each model's servers are sized by its Table-III scenario (the QoI
/// integral maps to the GP row).  Unknown model names are rejected
/// here, at startup — a typo must not produce a balancer whose spawns
/// can never succeed.  `servers` is the per-model cap.  `time_scale`
/// compresses paper-scale scheduler overheads (60.0 maps one
/// paper-minute onto one live second; see DESIGN.md section 7).
/// `scheduler` picks the live dispatch policy (`fcfs` | `worksteal` |
/// `edf` — the same cores the campaign plane ablates).
pub fn start_live(
    eng: Arc<Engine>,
    models: &[&str],
    backend_kind: &str,
    servers: usize,
    time_scale: f64,
    persistent_servers: bool,
    scheduler: LivePolicy,
) -> Result<LiveStack> {
    start_live_tuned(eng, models, backend_kind, servers, time_scale,
                     persistent_servers, scheduler, |_| {})
}

/// [`start_live`] with a last-chance hook over the balancer config.
/// The CLI uses it to wire the robustness knobs (retry budget,
/// probe-eviction threshold, circuit-breaker floor) without widening
/// the common signature for every caller.
#[allow(clippy::too_many_arguments)]
pub fn start_live_tuned(
    eng: Arc<Engine>,
    models: &[&str],
    backend_kind: &str,
    servers: usize,
    time_scale: f64,
    persistent_servers: bool,
    scheduler: LivePolicy,
    tune: impl FnOnce(&mut BalancerConfig),
) -> Result<LiveStack> {
    if models.is_empty() {
        bail!("start_live needs at least one model");
    }
    for m in models {
        if app_for_model(m).is_none() {
            bail!("no live scenario for model '{m}' (known: {:?})",
                  crate::models::all_names());
        }
    }
    let overheads = OverheadModel::quiet().scaled(time_scale);
    let run_dir = std::env::temp_dir().join(format!(
        "uqsched-lb-{}-{}",
        std::process::id(),
        crate::util::Rng::new(std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1))
        .next_u64()
    ));
    let mut cfg = BalancerConfig {
        models: models.iter().map(|m| m.to_string()).collect(),
        max_servers: servers,
        persistent_servers,
        scheduler,
        ..Default::default()
    };
    tune(&mut cfg);

    // Per-model job shapes from the paper's Table III.
    let scen_of = |m: &str| {
        scenario(app_for_model(m).expect("models validated above"))
    };
    let slurm_requests: HashMap<String, JobRequest> = cfg
        .models
        .iter()
        .map(|m| (m.clone(), scen_of(m).slurm_request()))
        .collect();
    // The bulk allocation must fit the largest model in the mix on
    // every axis (component-wise max, not one model's whole row).
    let hq_alloc = cfg
        .models
        .iter()
        .map(|m| scen_of(m).hq_alloc_request())
        .reduce(|a, b| {
            JobRequest::new(
                a.cores.max(b.cores),
                a.ram_gb.max(b.ram_gb),
                a.time_limit.max(b.time_limit),
            )
        })
        .expect("at least one model");

    // The daemon needs a sink, but the backend that provides it needs the
    // daemon: a late-bound slot breaks the cycle.
    let sink_slot: Arc<Mutex<Option<EventSink>>> = Arc::new(Mutex::new(None));
    let slot2 = sink_slot.clone();
    let daemon = Arc::new(SlurmDaemon::start(
        ClusterSpec::small(8),
        overheads.clone(),
        1,
        Arc::new(move |ev| {
            if let Some(s) = slot2.lock().unwrap().as_ref() {
                s(ev)
            }
        }),
    ));

    let backend: Arc<dyn Backend> = match backend_kind {
        "slurm" => {
            let b = SlurmBackend::new(
                daemon.clone(),
                eng,
                slurm_requests,
                overheads.clone(),
                run_dir,
                true, // the paper's sync workaround, on by default
            );
            *sink_slot.lock().unwrap() = Some(b.sink(
                std::time::Duration::from_micros(overheads.server_init),
            ));
            b
        }
        "hq" => {
            let b = HqBackend::new(
                daemon.clone(),
                eng,
                hq_alloc,
                servers * cfg.models.len(),
                &overheads,
                run_dir,
            );
            *sink_slot.lock().unwrap() = Some(b.sink());
            b
        }
        other => bail!("unknown backend '{other}'"),
    };

    let balancer = LoadBalancer::start(cfg, backend.clone())?;
    Ok(LiveStack { balancer, daemon, backend })
}
