//! Live-plane bring-up helpers shared by the CLI, the examples and the
//! integration tests: one call starts slurmlite + backend + balancer.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::cluster::{ClusterSpec, OverheadModel};
use crate::runtime::Engine;
use crate::slurmlite::daemon::{EventSink, SlurmDaemon};
use crate::workload::Scenario;

use super::{Backend, BalancerConfig, HqBackend, LoadBalancer, SlurmBackend};

/// Everything a live deployment needs, torn down on drop.
pub struct LiveStack {
    pub balancer: LoadBalancer,
    pub daemon: Arc<SlurmDaemon>,
    pub backend: Arc<dyn Backend>,
}

/// Start slurmlite + the chosen backend + the balancer.
///
/// `time_scale` compresses paper-scale scheduler overheads (60.0 maps one
/// paper-minute onto one live second; see DESIGN.md section 7).
pub fn start_live(
    eng: Arc<Engine>,
    model: &'static str,
    backend_kind: &str,
    servers: usize,
    scen: &Scenario,
    time_scale: f64,
    persistent_servers: bool,
) -> Result<LiveStack> {
    let overheads = OverheadModel::quiet().scaled(time_scale);
    let run_dir = std::env::temp_dir().join(format!(
        "uqsched-lb-{}-{}",
        std::process::id(),
        crate::util::Rng::new(std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1))
        .next_u64()
    ));
    let cfg = BalancerConfig {
        model_name: model,
        max_servers: servers,
        persistent_servers,
        ..Default::default()
    };

    // The daemon needs a sink, but the backend that provides it needs the
    // daemon: a late-bound slot breaks the cycle.
    let sink_slot: Arc<Mutex<Option<EventSink>>> = Arc::new(Mutex::new(None));
    let slot2 = sink_slot.clone();
    let daemon = Arc::new(SlurmDaemon::start(
        ClusterSpec::small(8),
        overheads.clone(),
        1,
        Arc::new(move |ev| {
            if let Some(s) = slot2.lock().unwrap().as_ref() {
                s(ev)
            }
        }),
    ));

    let backend: Arc<dyn Backend> = match backend_kind {
        "slurm" => {
            let b = SlurmBackend::new(
                daemon.clone(),
                eng,
                model,
                scen.slurm_request(),
                overheads.clone(),
                run_dir,
                true, // the paper's sync workaround, on by default
            );
            *sink_slot.lock().unwrap() = Some(b.sink(
                std::time::Duration::from_micros(overheads.server_init),
            ));
            b
        }
        "hq" => {
            let b = HqBackend::new(
                daemon.clone(),
                eng,
                model,
                scen.hq_alloc_request(),
                servers,
                &overheads,
                run_dir,
            );
            *sink_slot.lock().unwrap() = Some(b.sink());
            b
        }
        other => bail!("unknown backend '{other}'"),
    };

    let balancer = LoadBalancer::start(cfg, backend.clone())?;
    Ok(LiveStack { balancer, daemon, backend })
}
