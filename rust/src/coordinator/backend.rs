//! Live scheduling backends for the balancer: per-job SLURM submission
//! vs HyperQueue-style tasks on a bulk allocation — the paper's two
//! deployment modes, running against the live `slurmlite` daemon with
//! real model-server threads (HTTP + PJRT) — plus an in-process
//! [`LocalBackend`] that serves models directly (no scheduler), used by
//! the balancer-plane tests, the `selftest` smoke and the multi-model
//! `hotpath` bench.
//!
//! All backends are **multi-model**: [`Backend::spawn_server`] takes
//! the wire name of the model the new server must serve, and spawn
//! accounting is kept per model so the balancer can scale each pool
//! independently.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cluster::{JobRequest, OverheadModel};
use crate::clock::MS;
use crate::models;
use crate::runtime::Engine;
use crate::slurmlite::daemon::{DaemonEvent, SlurmDaemon};
use crate::umbridge::{self, Model};

use super::portfile;

/// A scheduling backend the balancer spawns servers through.
pub trait Backend: Send + Sync {
    /// Request one more server for `model` (async).
    fn spawn_server(&self, model: &str);
    /// Endpoints of servers that came up since the last poll.
    fn poll_new_servers(&self) -> Vec<String>;
    /// Spawns requested for `model` but not yet surfaced by
    /// [`Backend::poll_new_servers`].
    fn spawns_in_flight(&self, model: &str) -> usize;
    /// Per-job mode: the server served its evaluation; stop it.
    fn retire_server(&self, endpoint: &str);
    /// Health check failed; reclaim resources.
    fn server_lost(&self, endpoint: &str) {
        self.retire_server(endpoint);
    }
    /// Stop everything.
    fn teardown(&self);
}

/// One live model-server instance (an HTTP server thread over the shared
/// PJRT engine) plus its scheduler bookkeeping.
struct Instance {
    server: crate::httpd::Server,
    model: String,
    slurm_job: Option<u64>,
}

struct ServerPool {
    engine: Arc<Engine>,
    run_dir: PathBuf,
    /// endpoint -> instance
    live: Mutex<HashMap<String, Instance>>,
    /// (model, slurm job) of instances that never came up — drained by
    /// the backend's poll so spawn accounting does not leak.
    failed: Mutex<Vec<(String, Option<u64>)>>,
    sync_workaround: bool,
}

impl ServerPool {
    fn new(engine: Arc<Engine>, run_dir: PathBuf, sync_workaround: bool)
           -> Arc<ServerPool> {
        Arc::new(ServerPool {
            engine,
            run_dir,
            live: Mutex::new(HashMap::new()),
            failed: Mutex::new(Vec::new()),
            sync_workaround,
        })
    }

    /// Start a server for `model` now; the port file is written last so
    /// the watcher can already resolve the endpoint's model when it
    /// polls it up.  Failures are recorded so the backend can release
    /// the spawn slot (and the scheduler job) instead of leaking it.
    fn start_instance(&self, job_tag: u64, model: &str,
                      slurm_job: Option<u64>) {
        let built = match models::by_name(self.engine.clone(), model) {
            Ok(m) => m,
            Err(e) => {
                crate::log_error!("backend", "model build failed: {e:#}");
                self.failed
                    .lock()
                    .unwrap()
                    .push((model.to_string(), slurm_job));
                return;
            }
        };
        match umbridge::serve_models(vec![built], 0) {
            Ok(server) => {
                let url = server.url();
                self.live.lock().unwrap().insert(
                    url.clone(),
                    Instance {
                        server,
                        model: model.to_string(),
                        slurm_job,
                    },
                );
                if let Err(e) = portfile::write_portfile(
                    &self.run_dir, job_tag, &url, self.sync_workaround,
                ) {
                    // The watcher can never discover this server: roll
                    // it back and release the spawn slot.
                    crate::log_error!("backend",
                                      "portfile write failed for {url}: {e:#}");
                    let inst = self.live.lock().unwrap().remove(&url);
                    if let Some(mut inst) = inst {
                        inst.server.shutdown();
                    }
                    self.failed
                        .lock()
                        .unwrap()
                        .push((model.to_string(), slurm_job));
                }
            }
            Err(e) => {
                crate::log_error!("backend", "server start failed: {e:#}");
                self.failed
                    .lock()
                    .unwrap()
                    .push((model.to_string(), slurm_job));
            }
        }
    }

    fn take_failed(&self) -> Vec<(String, Option<u64>)> {
        std::mem::take(&mut self.failed.lock().unwrap())
    }

    fn model_of(&self, endpoint: &str) -> Option<String> {
        self.live
            .lock()
            .unwrap()
            .get(endpoint)
            .map(|i| i.model.clone())
    }

    fn stop_instance(&self, endpoint: &str) -> Option<u64> {
        let mut live = self.live.lock().unwrap();
        if let Some(mut inst) = live.remove(endpoint) {
            inst.server.shutdown();
            inst.slurm_job
        } else {
            None
        }
    }

    fn stop_all(&self) -> Vec<u64> {
        let mut live = self.live.lock().unwrap();
        let mut jobs = Vec::new();
        for (_, mut inst) in live.drain() {
            inst.server.shutdown();
            if let Some(j) = inst.slurm_job {
                jobs.push(j);
            }
        }
        jobs
    }
}

/// model -> outstanding spawn count, shared helper for all backends.
#[derive(Default)]
struct InFlight(Mutex<HashMap<String, usize>>);

impl InFlight {
    fn inc(&self, model: &str) {
        *self.0.lock().unwrap().entry(model.to_string()).or_default() += 1;
    }

    fn dec(&self, model: &str) {
        if let Some(n) = self.0.lock().unwrap().get_mut(model) {
            *n = n.saturating_sub(1);
        }
    }

    fn get(&self, model: &str) -> usize {
        self.0.lock().unwrap().get(model).copied().unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------

/// Per-job SLURM backend: one slurmlite job per model server, sized by
/// the model's Table-III resource request.
pub struct SlurmBackend {
    daemon: Arc<SlurmDaemon>,
    pool: Arc<ServerPool>,
    /// model -> job shape (every servable model must have an entry;
    /// `start_live` validates the model list at startup).
    requests: HashMap<String, JobRequest>,
    /// slurm job id -> model it will serve (bridged to the sink).
    pending_jobs: Arc<Mutex<HashMap<u64, String>>>,
    in_flight: InFlight,
    stopped: Arc<AtomicBool>,
}

impl SlurmBackend {
    pub fn new(
        daemon: Arc<SlurmDaemon>,
        engine: Arc<Engine>,
        requests: HashMap<String, JobRequest>,
        _overheads: OverheadModel,
        run_dir: PathBuf,
        sync_workaround: bool,
    ) -> Arc<SlurmBackend> {
        let pool = ServerPool::new(engine, run_dir, sync_workaround);
        Arc::new(SlurmBackend {
            daemon,
            pool,
            requests,
            pending_jobs: Arc::new(Mutex::new(HashMap::new())),
            in_flight: InFlight::default(),
            stopped: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Event sink to install on the SlurmDaemon: launches model servers
    /// when their job starts (after queue + prolog), modelling the
    /// server-init cost before the port file appears.  A job that dies
    /// before launching (time limit in Starting, cancellation) releases
    /// its spawn slot instead of leaking it.
    pub fn sink(self: &Arc<Self>, server_init: Duration)
                -> crate::slurmlite::daemon::EventSink {
        let me = self.clone();
        Arc::new(move |ev: DaemonEvent| match ev {
            DaemonEvent::Launched { job, .. } => {
                if me.stopped.load(Ordering::SeqCst) {
                    return;
                }
                let Some(model) = me.pending_jobs.lock().unwrap().remove(&job)
                else {
                    return; // not one of ours
                };
                let me2 = me.clone();
                std::thread::spawn(move || {
                    // Model-server start-up (~1 s paper scale).
                    std::thread::sleep(server_init);
                    me2.pool.start_instance(job, &model, Some(job));
                });
            }
            DaemonEvent::TimedOut { job }
            | DaemonEvent::Completed { job, .. } => {
                // Still pending here means the job never launched:
                // free the spawn slot so the model can respawn.
                let gone =
                    me.pending_jobs.lock().unwrap().remove(&job);
                if let Some(model) = gone {
                    crate::log_warn!(
                        "backend",
                        "server job {job} for '{model}' died before launch");
                    me.in_flight.dec(&model);
                }
            }
        })
    }
}

impl Backend for SlurmBackend {
    fn spawn_server(&self, model: &str) {
        if self.stopped.load(Ordering::SeqCst) {
            return;
        }
        let Some(req) = self.requests.get(model).copied() else {
            crate::log_error!("backend",
                              "no job shape for model '{model}'; not spawning");
            return;
        };
        self.in_flight.inc(model);
        // Hold the pending map across submit: the daemon thread must not
        // observe the Launched event before the job->model entry exists.
        let mut pending = self.pending_jobs.lock().unwrap();
        let id = self.daemon.submit(0, 0, req);
        pending.insert(id, model.to_string());
    }

    fn poll_new_servers(&self) -> Vec<String> {
        // Failed spawns release their slot (and scheduler job).
        for (model, job) in self.pool.take_failed() {
            self.in_flight.dec(&model);
            if let Some(j) = job {
                self.daemon.finish(j);
            }
        }
        let found = portfile::poll_portfiles(&self.pool.run_dir);
        let mut endpoints = Vec::with_capacity(found.len());
        for (_, ep) in found {
            if let Some(model) = self.pool.model_of(&ep) {
                self.in_flight.dec(&model);
            }
            endpoints.push(ep);
        }
        endpoints
    }

    fn spawns_in_flight(&self, model: &str) -> usize {
        self.in_flight.get(model)
    }

    fn retire_server(&self, endpoint: &str) {
        if let Some(job) = self.pool.stop_instance(endpoint) {
            self.daemon.finish(job);
        }
    }

    fn teardown(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        for job in self.pool.stop_all() {
            self.daemon.finish(job);
        }
    }
}

// ---------------------------------------------------------------------------

/// HyperQueue-style backend: one bulk allocation absorbs the queue wait;
/// server "tasks" then start at dispatch latency inside it.
pub struct HqBackend {
    daemon: Arc<SlurmDaemon>,
    pool: Arc<ServerPool>,
    alloc_request: JobRequest,
    /// Worker concurrency inside the allocation.
    max_workers: usize,
    dispatch_latency: Duration,
    server_init: Duration,
    state: Arc<Mutex<HqState>>,
    in_flight: InFlight,
    stopped: Arc<AtomicBool>,
}

#[derive(Default)]
struct HqState {
    /// Allocation slurm job ids (pending or running).
    allocs: Vec<u64>,
    /// Allocation up (workers available).
    workers_up: usize,
    /// Queued spawn requests waiting for a worker slot.
    backlog: VecDeque<(u64, String)>,
    next_tag: u64,
    busy_workers: usize,
}

impl HqBackend {
    pub fn new(
        daemon: Arc<SlurmDaemon>,
        engine: Arc<Engine>,
        alloc_request: JobRequest,
        max_workers: usize,
        overheads: &OverheadModel,
        run_dir: PathBuf,
    ) -> Arc<HqBackend> {
        let pool = ServerPool::new(engine, run_dir, false);
        Arc::new(HqBackend {
            daemon,
            pool,
            alloc_request,
            max_workers,
            dispatch_latency: Duration::from_micros(overheads.hq_dispatch),
            server_init: Duration::from_micros(overheads.server_init.max(MS)),
            state: Arc::new(Mutex::new(HqState::default())),
            in_flight: InFlight::default(),
            stopped: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Event sink for the SlurmDaemon: allocation launches register
    /// workers and drain the backlog.
    pub fn sink(self: &Arc<Self>) -> crate::slurmlite::daemon::EventSink {
        let me = self.clone();
        Arc::new(move |ev: DaemonEvent| {
            if let DaemonEvent::Launched { job, .. } = ev {
                let is_alloc =
                    me.state.lock().unwrap().allocs.contains(&job);
                if is_alloc {
                    {
                        let mut st = me.state.lock().unwrap();
                        st.workers_up += 1;
                    }
                    me.drain();
                }
            }
        })
    }

    /// Start backlogged server tasks while workers are free.
    fn drain(&self) {
        if self.stopped.load(Ordering::SeqCst) {
            return;
        }
        loop {
            let (tag, model) = {
                let mut st = self.state.lock().unwrap();
                if st.workers_up == 0
                    || st.busy_workers >= st.workers_up
                    || st.backlog.is_empty()
                {
                    break;
                }
                st.busy_workers += 1;
                st.backlog.pop_front().unwrap()
            };
            let me_pool = self.pool.clone();
            let dispatch = self.dispatch_latency;
            let init = self.server_init;
            std::thread::spawn(move || {
                std::thread::sleep(dispatch); // HQ task dispatch (~1 ms)
                std::thread::sleep(init);     // model-server start-up
                me_pool.start_instance(tag, &model, None);
            });
        }
    }
}

impl Backend for HqBackend {
    fn spawn_server(&self, model: &str) {
        if self.stopped.load(Ordering::SeqCst) {
            return;
        }
        self.in_flight.inc(model);
        let need_alloc = {
            let mut st = self.state.lock().unwrap();
            let tag = st.next_tag;
            st.next_tag += 1;
            st.backlog.push_back((tag, model.to_string()));
            // One allocation per worker slot, up to max_workers — the
            // "--workers-per-alloc 1" configuration.
            st.allocs.len() < self.max_workers
        };
        if need_alloc {
            let id = self.daemon.submit(0, u64::MAX - 1,
                                        self.alloc_request);
            self.state.lock().unwrap().allocs.push(id);
        }
        self.drain();
    }

    fn poll_new_servers(&self) -> Vec<String> {
        // Failed spawns release their spawn slot and worker slot.
        let failed = self.pool.take_failed();
        if !failed.is_empty() {
            for (model, _) in &failed {
                self.in_flight.dec(model);
            }
            {
                let mut st = self.state.lock().unwrap();
                st.busy_workers =
                    st.busy_workers.saturating_sub(failed.len());
            }
            self.drain();
        }
        let found = portfile::poll_portfiles(&self.pool.run_dir);
        let mut endpoints = Vec::with_capacity(found.len());
        for (_, ep) in found {
            if let Some(model) = self.pool.model_of(&ep) {
                self.in_flight.dec(&model);
            }
            endpoints.push(ep);
        }
        endpoints
    }

    fn spawns_in_flight(&self, model: &str) -> usize {
        self.in_flight.get(model)
    }

    fn retire_server(&self, endpoint: &str) {
        self.pool.stop_instance(endpoint);
        {
            let mut st = self.state.lock().unwrap();
            st.busy_workers = st.busy_workers.saturating_sub(1);
        }
        self.drain();
    }

    fn teardown(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        self.pool.stop_all();
        let allocs = std::mem::take(&mut self.state.lock().unwrap().allocs);
        for a in allocs {
            self.daemon.cancel(a);
        }
    }
}

// ---------------------------------------------------------------------------

/// Builds a model-server [`Model`] by wire name (engine-free backends).
pub type ModelFactory =
    Arc<dyn Fn(&str) -> anyhow::Result<Arc<dyn Model>> + Send + Sync>;

/// In-process backend: spawns model-server threads directly, with no
/// scheduler, no port files and no PJRT engine.  This is the balancer
/// plane's test/bench substrate — routing, leasing, backpressure and
/// the forwarder pool all run exactly as in production, only server
/// placement is immediate.
pub struct LocalBackend {
    factory: ModelFactory,
    /// Endpoints started but not yet polled up by the watcher.
    fresh: Mutex<Vec<String>>,
    /// endpoint -> (server handle, model).
    live: Mutex<HashMap<String, (crate::httpd::Server, String)>>,
    in_flight: InFlight,
    stopped: AtomicBool,
}

impl LocalBackend {
    pub fn new(factory: ModelFactory) -> Arc<LocalBackend> {
        Arc::new(LocalBackend {
            factory,
            fresh: Mutex::new(Vec::new()),
            live: Mutex::new(HashMap::new()),
            in_flight: InFlight::default(),
            stopped: AtomicBool::new(false),
        })
    }
}

impl Backend for LocalBackend {
    fn spawn_server(&self, model: &str) {
        if self.stopped.load(Ordering::SeqCst) {
            return;
        }
        let built = match (self.factory)(model) {
            Ok(m) => m,
            Err(e) => {
                crate::log_error!("backend", "model build failed: {e:#}");
                return;
            }
        };
        match umbridge::serve_models(vec![built], 0) {
            Ok(server) => {
                let url = server.url();
                self.in_flight.inc(model);
                self.live
                    .lock()
                    .unwrap()
                    .insert(url.clone(), (server, model.to_string()));
                self.fresh.lock().unwrap().push(url);
            }
            Err(e) => crate::log_error!("backend", "server start failed: {e:#}"),
        }
    }

    fn poll_new_servers(&self) -> Vec<String> {
        let endpoints = std::mem::take(&mut *self.fresh.lock().unwrap());
        for ep in &endpoints {
            if let Some((_, model)) = self.live.lock().unwrap().get(ep) {
                let model = model.clone();
                self.in_flight.dec(&model);
            }
        }
        endpoints
    }

    fn spawns_in_flight(&self, model: &str) -> usize {
        self.in_flight.get(model)
    }

    fn retire_server(&self, endpoint: &str) {
        if let Some((mut server, _)) =
            self.live.lock().unwrap().remove(endpoint)
        {
            server.shutdown();
        }
    }

    fn teardown(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        let drained: Vec<(crate::httpd::Server, String)> = {
            let mut live = self.live.lock().unwrap();
            live.drain().map(|(_, v)| v).collect()
        };
        for (mut server, _) in drained {
            server.shutdown();
        }
    }
}
