//! Live scheduling backends for the balancer: per-job SLURM submission
//! vs HyperQueue-style tasks on a bulk allocation — the paper's two
//! deployment modes, running against the live `slurmlite` daemon with
//! real model-server threads (HTTP + PJRT).

use std::collections::HashMap;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cluster::{JobRequest, OverheadModel};
use crate::clock::MS;
use crate::models;
use crate::runtime::Engine;
use crate::slurmlite::daemon::{DaemonEvent, SlurmDaemon};
use crate::umbridge;

use super::portfile;

/// A scheduling backend the balancer spawns servers through.
pub trait Backend: Send + Sync {
    /// Request one more model-server instance (async).
    fn spawn_server(&self);
    /// Endpoints of servers that came up since the last poll.
    fn poll_new_servers(&self) -> Vec<String>;
    /// Spawns requested but not yet registered.
    fn spawns_in_flight(&self) -> usize;
    /// Per-job mode: the server served its evaluation; stop it.
    fn retire_server(&self, endpoint: &str);
    /// Health check failed; reclaim resources.
    fn server_lost(&self, endpoint: &str) {
        self.retire_server(endpoint);
    }
    /// Stop everything.
    fn teardown(&self);
}

/// One live model-server instance (an HTTP server thread over the shared
/// PJRT engine) plus its scheduler bookkeeping.
struct Instance {
    server: crate::httpd::Server,
    slurm_job: Option<u64>,
}

struct ServerPool {
    engine: Arc<Engine>,
    model: &'static str,
    run_dir: PathBuf,
    /// endpoint -> instance
    live: Mutex<HashMap<String, Instance>>,
    sync_workaround: bool,
}

impl ServerPool {
    /// Start a model server now; returns its endpoint after writing the
    /// port file (the registration path the balancer watches).
    fn start_instance(&self, job_tag: u64, slurm_job: Option<u64>) {
        let model = match models::by_name(self.engine.clone(), self.model) {
            Ok(m) => m,
            Err(e) => {
                crate::log_error!("backend", "model build failed: {e:#}");
                return;
            }
        };
        match umbridge::serve_models(vec![model], 0) {
            Ok(server) => {
                let url = server.url();
                let _ = portfile::write_portfile(
                    &self.run_dir, job_tag, &url, self.sync_workaround,
                );
                self.live.lock().unwrap().insert(
                    url,
                    Instance { server, slurm_job },
                );
            }
            Err(e) => crate::log_error!("backend", "server start failed: {e:#}"),
        }
    }

    fn stop_instance(&self, endpoint: &str) -> Option<u64> {
        let mut live = self.live.lock().unwrap();
        if let Some(mut inst) = live.remove(endpoint) {
            inst.server.shutdown();
            inst.slurm_job
        } else {
            None
        }
    }

    fn stop_all(&self) -> Vec<u64> {
        let mut live = self.live.lock().unwrap();
        let mut jobs = Vec::new();
        for (_, mut inst) in live.drain() {
            inst.server.shutdown();
            if let Some(j) = inst.slurm_job {
                jobs.push(j);
            }
        }
        jobs
    }
}

// ---------------------------------------------------------------------------

/// Per-job SLURM backend: one slurmlite job per model server.
pub struct SlurmBackend {
    daemon: Arc<SlurmDaemon>,
    pool: Arc<ServerPool>,
    request: JobRequest,
    in_flight: Arc<AtomicUsize>,
    stopped: Arc<AtomicBool>,
}

impl SlurmBackend {
    pub fn new(
        daemon: Arc<SlurmDaemon>,
        engine: Arc<Engine>,
        model: &'static str,
        request: JobRequest,
        _overheads: OverheadModel,
        run_dir: PathBuf,
        sync_workaround: bool,
    ) -> Arc<SlurmBackend> {
        let pool = Arc::new(ServerPool {
            engine,
            model,
            run_dir,
            live: Mutex::new(HashMap::new()),
            sync_workaround,
        });
        let backend = Arc::new(SlurmBackend {
            daemon: daemon.clone(),
            pool,
            request,
            in_flight: Arc::new(AtomicUsize::new(0)),
            stopped: Arc::new(AtomicBool::new(false)),
        });
        backend
    }

    /// Event sink to install on the SlurmDaemon: launches model servers
    /// when their job starts (after queue + prolog), modelling the
    /// server-init cost before the port file appears.
    pub fn sink(self: &Arc<Self>, server_init: Duration)
                -> crate::slurmlite::daemon::EventSink {
        let me = self.clone();
        Arc::new(move |ev: DaemonEvent| {
            if let DaemonEvent::Launched { job, .. } = ev {
                if me.stopped.load(Ordering::SeqCst) {
                    return;
                }
                let me2 = me.clone();
                std::thread::spawn(move || {
                    // Model-server start-up (~1 s paper scale).
                    std::thread::sleep(server_init);
                    me2.pool.start_instance(job, Some(job));
                });
            }
        })
    }
}

impl Backend for SlurmBackend {
    fn spawn_server(&self) {
        if self.stopped.load(Ordering::SeqCst) {
            return;
        }
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.daemon.submit(0, 0, self.request);
    }

    fn poll_new_servers(&self) -> Vec<String> {
        let found = portfile::poll_portfiles(&self.pool.run_dir);
        if !found.is_empty() {
            self.in_flight
                .fetch_sub(found.len().min(self.in_flight.load(Ordering::SeqCst)),
                           Ordering::SeqCst);
        }
        found.into_iter().map(|(_, ep)| ep).collect()
    }

    fn spawns_in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    fn retire_server(&self, endpoint: &str) {
        if let Some(job) = self.pool.stop_instance(endpoint) {
            self.daemon.finish(job);
        }
    }

    fn teardown(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        for job in self.pool.stop_all() {
            self.daemon.finish(job);
        }
    }
}

// ---------------------------------------------------------------------------

/// HyperQueue-style backend: one bulk allocation absorbs the queue wait;
/// server "tasks" then start at dispatch latency inside it.
pub struct HqBackend {
    daemon: Arc<SlurmDaemon>,
    pool: Arc<ServerPool>,
    alloc_request: JobRequest,
    /// Worker concurrency inside the allocation.
    max_workers: usize,
    dispatch_latency: Duration,
    server_init: Duration,
    state: Arc<Mutex<HqState>>,
    stopped: Arc<AtomicBool>,
}

#[derive(Default)]
struct HqState {
    /// Allocation slurm job ids (pending or running).
    allocs: Vec<u64>,
    /// Allocation up (workers available).
    workers_up: usize,
    /// Queued spawn requests waiting for a worker slot.
    backlog: VecDeque<u64>,
    in_flight: usize,
    next_tag: u64,
    busy_workers: usize,
}

impl HqBackend {
    pub fn new(
        daemon: Arc<SlurmDaemon>,
        engine: Arc<Engine>,
        model: &'static str,
        alloc_request: JobRequest,
        max_workers: usize,
        overheads: &OverheadModel,
        run_dir: PathBuf,
    ) -> Arc<HqBackend> {
        let pool = Arc::new(ServerPool {
            engine,
            model,
            run_dir,
            live: Mutex::new(HashMap::new()),
            sync_workaround: false,
        });
        Arc::new(HqBackend {
            daemon,
            pool,
            alloc_request,
            max_workers,
            dispatch_latency: Duration::from_micros(overheads.hq_dispatch),
            server_init: Duration::from_micros(overheads.server_init.max(MS)),
            state: Arc::new(Mutex::new(HqState::default())),
            stopped: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Event sink for the SlurmDaemon: allocation launches register
    /// workers and drain the backlog.
    pub fn sink(self: &Arc<Self>) -> crate::slurmlite::daemon::EventSink {
        let me = self.clone();
        Arc::new(move |ev: DaemonEvent| {
            if let DaemonEvent::Launched { job, .. } = ev {
                let is_alloc =
                    me.state.lock().unwrap().allocs.contains(&job);
                if is_alloc {
                    {
                        let mut st = me.state.lock().unwrap();
                        st.workers_up += 1;
                    }
                    me.drain();
                }
            }
        })
    }

    /// Start backlogged server tasks while workers are free.
    fn drain(&self) {
        if self.stopped.load(Ordering::SeqCst) {
            return;
        }
        loop {
            let tag = {
                let mut st = self.state.lock().unwrap();
                if st.workers_up == 0
                    || st.busy_workers >= st.workers_up
                    || st.backlog.is_empty()
                {
                    break;
                }
                st.busy_workers += 1;
                st.backlog.pop_front().unwrap()
            };
            let me_pool = self.pool.clone();
            let dispatch = self.dispatch_latency;
            let init = self.server_init;
            std::thread::spawn(move || {
                std::thread::sleep(dispatch); // HQ task dispatch (~1 ms)
                std::thread::sleep(init);     // model-server start-up
                me_pool.start_instance(tag, None);
            });
        }
    }
}

impl Backend for HqBackend {
    fn spawn_server(&self) {
        if self.stopped.load(Ordering::SeqCst) {
            return;
        }
        let need_alloc = {
            let mut st = self.state.lock().unwrap();
            let tag = st.next_tag;
            st.next_tag += 1;
            st.backlog.push_back(tag);
            st.in_flight += 1;
            // One allocation per worker slot, up to max_workers — the
            // "--workers-per-alloc 1" configuration.
            st.allocs.len() < self.max_workers
        };
        if need_alloc {
            let id = self.daemon.submit(0, u64::MAX - 1,
                                        self.alloc_request);
            self.state.lock().unwrap().allocs.push(id);
        }
        self.drain();
    }

    fn poll_new_servers(&self) -> Vec<String> {
        let found = portfile::poll_portfiles(&self.pool.run_dir);
        if !found.is_empty() {
            let mut st = self.state.lock().unwrap();
            st.in_flight = st.in_flight.saturating_sub(found.len());
        }
        found.into_iter().map(|(_, ep)| ep).collect()
    }

    fn spawns_in_flight(&self) -> usize {
        self.state.lock().unwrap().in_flight
    }

    fn retire_server(&self, endpoint: &str) {
        self.pool.stop_instance(endpoint);
        {
            let mut st = self.state.lock().unwrap();
            st.busy_workers = st.busy_workers.saturating_sub(1);
        }
        self.drain();
    }

    fn teardown(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        self.pool.stop_all();
        let allocs = std::mem::take(&mut self.state.lock().unwrap().allocs);
        for a in allocs {
            self.daemon.cancel(a);
        }
    }
}
