//! Port-file registration: the paper's mechanism for a model server on a
//! compute node to announce its address to the balancer.
//!
//! "UM-Bridge relies on a text file to communicate the IP address and
//! port number of the model running on the compute node ... we manually
//! integrated the sync command into the load balancer's source code"
//! (section IV).  Both ends are implemented here, including that fsync
//! workaround as an option.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Server side: write `host:port` atomically (tmp + rename), optionally
/// fsync'ing file and directory — the paper's Hamilton8 workaround.
pub fn write_portfile(dir: &Path, job_id: u64, endpoint: &str,
                      sync: bool) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(".srv-{job_id}.tmp"));
    let fin = dir.join(format!("srv-{job_id}.addr"));
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(endpoint.as_bytes())?;
        if sync {
            f.sync_all()?; // the paper's `sync` integration
        }
    }
    std::fs::rename(&tmp, &fin)?;
    if sync {
        // Directory entry flush (best effort).
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(fin)
}

/// Balancer side: scan for new `srv-*.addr` files, consume (delete) and
/// return their endpoints.
pub fn poll_portfiles(dir: &Path) -> Vec<(u64, String)> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else { return out };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(idpart) = name
            .strip_prefix("srv-")
            .and_then(|s| s.strip_suffix(".addr"))
        else {
            continue;
        };
        let Ok(job_id) = idpart.parse::<u64>() else { continue };
        if let Ok(endpoint) = std::fs::read_to_string(entry.path()) {
            let endpoint = endpoint.trim().to_string();
            if !endpoint.is_empty() {
                let _ = std::fs::remove_file(entry.path());
                out.push((job_id, endpoint));
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("uqsched_pf_{tag}_{}",
                                                  std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let d = tmpdir("rt");
        write_portfile(&d, 7, "http://127.0.0.1:4242", false).unwrap();
        write_portfile(&d, 3, "http://127.0.0.1:4243", true).unwrap();
        let got = poll_portfiles(&d);
        assert_eq!(got, vec![
            (3, "http://127.0.0.1:4243".to_string()),
            (7, "http://127.0.0.1:4242".to_string()),
        ]);
        // Consumed: second poll is empty.
        assert!(poll_portfiles(&d).is_empty());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn ignores_foreign_files() {
        let d = tmpdir("ff");
        std::fs::write(d.join("notes.txt"), "hi").unwrap();
        std::fs::write(d.join("srv-x.addr"), "bad id").unwrap();
        assert!(poll_portfiles(&d).is_empty());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn empty_portfile_not_consumed() {
        let d = tmpdir("ep");
        std::fs::write(d.join("srv-1.addr"), "").unwrap();
        assert!(poll_portfiles(&d).is_empty());
        // Still there for a later poll once written.
        assert!(d.join("srv-1.addr").exists());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_dir_is_empty() {
        assert!(poll_portfiles(Path::new("/nonexistent/uqsched")).is_empty());
    }
}
