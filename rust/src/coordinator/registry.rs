//! Server registry: multi-model endpoint pool with per-model idle
//! indexes, learned contracts, and RAII leases.
//!
//! * Each endpoint serves one model; idle endpoints live in a per-model
//!   ordered set, so acquiring a server is O(log n) instead of the old
//!   full-table scan (and the racy `last_acquired` side-channel is
//!   gone: [`Registry::acquire`] hands back a [`ServerLease`] that
//!   *is* the acquisition).
//! * The model's wire contract ([`ModelContract`]) is learned at
//!   registration from the preliminary checks and kept per model, so
//!   the front door answers metadata queries locally.
//! * Dropping a lease releases the server back to the idle index; a
//!   lease marked for retirement instead removes the server and parks
//!   its endpoint in a retirement queue the balancer drains into
//!   `Backend::retire_server` — the forwarder never talks to the
//!   backend while holding registry state.
//! * Every state change invokes the optional waker, which the balancer
//!   points at the dispatcher condvar: registration, release and
//!   removal are event-driven, not poll-detected.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Mutex};

use crate::umbridge::ModelContract;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServerState {
    Idle,
    Busy,
}

struct ServerInfo {
    model: String,
    state: ServerState,
}

type Waker = Arc<dyn Fn() + Send + Sync>;

#[derive(Default)]
struct Inner {
    /// endpoint -> info (ordered for deterministic iteration).
    servers: BTreeMap<String, ServerInfo>,
    /// model -> idle endpoints (ordered: FCFS by endpoint, O(log n) pop).
    idle: HashMap<String, BTreeSet<String>>,
    /// model -> live server count (idle + busy).
    totals: HashMap<String, usize>,
    /// model -> learned wire contract (survives server churn).
    contracts: HashMap<String, ModelContract>,
    /// model -> lifetime registration count (the balancer's spawn
    /// governor resets its failure backoff when this advances).
    registered_by_model: HashMap<String, u64>,
    /// Endpoints retired by lease drop, awaiting backend teardown.
    retired: Vec<String>,
    /// Lifetime counters.
    registered_total: u64,
    removed_total: u64,
}

/// Thread-safe registry of model-server endpoints.
pub struct Registry {
    inner: Mutex<Inner>,
    waker: Mutex<Option<Waker>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            inner: Mutex::new(Inner::default()),
            waker: Mutex::new(None),
        }
    }

    /// Install the dispatcher wake-up hook (called after every
    /// registration, release, retirement or removal).
    pub fn set_waker(&self, w: Waker) {
        *self.waker.lock().unwrap() = Some(w);
    }

    fn wake(&self) {
        let w = self.waker.lock().unwrap().clone();
        if let Some(w) = w {
            w();
        }
    }

    /// Register an endpoint serving `model`, learning the contract on
    /// first sight.  Idempotent: re-registering a known endpoint does
    /// not reset its state.
    pub fn register(&self, endpoint: &str, model: &str,
                    contract: &ModelContract) {
        {
            let mut g = self.inner.lock().unwrap();
            if g.servers.contains_key(endpoint) {
                return;
            }
            g.servers.insert(
                endpoint.to_string(),
                ServerInfo { model: model.to_string(), state: ServerState::Idle },
            );
            g.idle
                .entry(model.to_string())
                .or_default()
                .insert(endpoint.to_string());
            *g.totals.entry(model.to_string()).or_default() += 1;
            g.contracts
                .entry(model.to_string())
                .or_insert_with(|| contract.clone());
            *g.registered_by_model.entry(model.to_string()).or_default() += 1;
            g.registered_total += 1;
        }
        self.wake();
    }

    /// Learned contract for a model (from its first registered server).
    pub fn contract(&self, model: &str) -> Option<ModelContract> {
        self.inner.lock().unwrap().contracts.get(model).cloned()
    }

    /// Remove an endpoint entirely (health-check failure path).
    pub fn remove(&self, endpoint: &str) {
        {
            let mut g = self.inner.lock().unwrap();
            if !Self::purge(&mut g, endpoint) {
                return;
            }
        }
        self.wake();
    }

    /// Drop `endpoint` from all maps; true if it was present.
    fn purge(g: &mut Inner, endpoint: &str) -> bool {
        let Some(info) = g.servers.remove(endpoint) else {
            return false;
        };
        if let Some(set) = g.idle.get_mut(&info.model) {
            set.remove(endpoint);
        }
        if let Some(n) = g.totals.get_mut(&info.model) {
            *n = n.saturating_sub(1);
        }
        g.removed_total += 1;
        true
    }

    /// Lease the first idle server for `model` (O(log n)).  The lease
    /// releases the server on drop unless marked for retirement.
    pub fn acquire(&self, model: &str) -> Option<ServerLease<'_>> {
        let endpoint = {
            let mut g = self.inner.lock().unwrap();
            let set = g.idle.get_mut(model)?;
            let ep = set.iter().next().cloned()?;
            set.remove(&ep);
            g.servers
                .get_mut(&ep)
                .expect("idle index entry without server")
                .state = ServerState::Busy;
            ep
        };
        Some(ServerLease {
            registry: self,
            endpoint,
            model: model.to_string(),
            retire: false,
        })
    }

    /// Lease one *specific* idle endpoint — the acquisition path for the
    /// real-time scheduler core, whose `Start` effects bind work to the
    /// worker (server) the scheduling policy placed it on.  `None` if
    /// the endpoint is unknown or not idle (disambiguate with
    /// [`Registry::state`]).
    pub fn acquire_endpoint(&self, endpoint: &str) -> Option<ServerLease<'_>> {
        let model = {
            let mut g = self.inner.lock().unwrap();
            let info = g.servers.get_mut(endpoint)?;
            if info.state != ServerState::Idle {
                return None;
            }
            info.state = ServerState::Busy;
            let model = info.model.clone();
            if let Some(set) = g.idle.get_mut(&model) {
                set.remove(endpoint);
            }
            model
        };
        Some(ServerLease {
            registry: self,
            endpoint: endpoint.to_string(),
            model,
            retire: false,
        })
    }

    fn release_endpoint(&self, endpoint: &str) {
        {
            let mut g = self.inner.lock().unwrap();
            let Some(info) = g.servers.get_mut(endpoint) else {
                return; // removed while leased; nothing to release
            };
            info.state = ServerState::Idle;
            let model = info.model.clone();
            g.idle
                .entry(model)
                .or_default()
                .insert(endpoint.to_string());
        }
        self.wake();
    }

    fn retire_endpoint(&self, endpoint: &str) {
        {
            let mut g = self.inner.lock().unwrap();
            if !Self::purge(&mut g, endpoint) {
                return;
            }
            g.retired.push(endpoint.to_string());
        }
        self.wake();
    }

    /// Endpoints retired by lease drop since the last call; the
    /// balancer hands them to `Backend::retire_server`.
    pub fn take_retired(&self) -> Vec<String> {
        std::mem::take(&mut self.inner.lock().unwrap().retired)
    }

    pub fn state(&self, endpoint: &str) -> Option<ServerState> {
        self.inner
            .lock()
            .unwrap()
            .servers
            .get(endpoint)
            .map(|i| i.state)
    }

    pub fn endpoints(&self) -> Vec<String> {
        self.inner.lock().unwrap().servers.keys().cloned().collect()
    }

    pub fn total(&self) -> usize {
        self.inner.lock().unwrap().servers.len()
    }

    /// Live servers (idle + busy) for one model — O(1).
    pub fn count_for(&self, model: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .totals
            .get(model)
            .copied()
            .unwrap_or(0)
    }

    pub fn idle_count(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .idle
            .values()
            .map(|s| s.len())
            .sum()
    }

    /// Idle servers for one model — O(1).
    pub fn idle_for(&self, model: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .idle
            .get(model)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    pub fn registered_total(&self) -> u64 {
        self.inner.lock().unwrap().registered_total
    }

    /// Lifetime registrations for one model.
    pub fn registered_for(&self, model: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .registered_by_model
            .get(model)
            .copied()
            .unwrap_or(0)
    }

    pub fn removed_total(&self) -> u64 {
        self.inner.lock().unwrap().removed_total
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII acquisition of one model server.
///
/// Dropping the lease returns the server to the idle pool; after
/// [`ServerLease::mark_retire`] (failed forward, per-job mode, or a
/// panic unwinding past a poisoned evaluation path when the caller
/// pre-marks), dropping removes the server and queues its endpoint for
/// backend teardown instead.
pub struct ServerLease<'a> {
    registry: &'a Registry,
    endpoint: String,
    model: String,
    retire: bool,
}

impl ServerLease<'_> {
    pub fn endpoint(&self) -> &str {
        &self.endpoint
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Retire instead of release on drop.
    pub fn mark_retire(&mut self) {
        self.retire = true;
    }

    pub fn will_retire(&self) -> bool {
        self.retire
    }
}

impl Drop for ServerLease<'_> {
    fn drop(&mut self) {
        if self.retire {
            self.registry.retire_endpoint(&self.endpoint);
        } else {
            self.registry.release_endpoint(&self.endpoint);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn contract() -> ModelContract {
        ModelContract { input_sizes: vec![7], output_sizes: vec![2, 2] }
    }

    fn reg() -> Arc<Registry> {
        Arc::new(Registry::new())
    }

    #[test]
    fn register_acquire_release() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        r.register("http://h:2", "gp", &contract());
        assert_eq!(r.total(), 2);
        assert_eq!(r.idle_for("gp"), 2);
        let lease = r.acquire("gp").unwrap();
        assert_eq!(r.idle_for("gp"), 1);
        assert_eq!(r.state(lease.endpoint()), Some(ServerState::Busy));
        let ep = lease.endpoint().to_string();
        drop(lease); // release on drop
        assert_eq!(r.idle_for("gp"), 2);
        assert_eq!(r.state(&ep), Some(ServerState::Idle));
        assert!(r.take_retired().is_empty());
    }

    #[test]
    fn acquire_is_fcfs_and_exhausts() {
        let r = reg();
        r.register("http://h:2", "gp", &contract());
        r.register("http://h:1", "gp", &contract());
        let a = r.acquire("gp").unwrap();
        assert_eq!(a.endpoint(), "http://h:1"); // ordered index
        let b = r.acquire("gp").unwrap();
        assert_eq!(b.endpoint(), "http://h:2");
        assert!(r.acquire("gp").is_none());
        drop(a);
        assert!(r.acquire("gp").is_some());
        drop(b);
    }

    #[test]
    fn retire_on_drop_removes_and_queues() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let mut lease = r.acquire("gp").unwrap();
        lease.mark_retire(); // poisoned forward / per-job mode
        assert!(lease.will_retire());
        drop(lease);
        assert_eq!(r.total(), 0);
        assert_eq!(r.take_retired(), vec!["http://h:1".to_string()]);
        assert!(r.take_retired().is_empty()); // drained
        assert_eq!(r.registered_total(), 1);
        assert_eq!(r.removed_total(), 1);
    }

    #[test]
    fn models_are_isolated() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let beta = ModelContract { input_sizes: vec![1],
                                   output_sizes: vec![100, 1] };
        r.register("http://h:2", "eigen-100", &beta);
        let lease = r.acquire("gp").unwrap();
        // gp exhausted; eigen-100 unaffected.
        assert!(r.acquire("gp").is_none());
        assert_eq!(r.idle_for("eigen-100"), 1);
        assert_eq!(r.count_for("gp"), 1);
        let e = r.acquire("eigen-100").unwrap();
        assert_eq!(e.endpoint(), "http://h:2");
        drop(e);
        drop(lease);
        assert_eq!(r.contract("gp"), Some(contract()));
        assert_eq!(r.contract("eigen-100"), Some(beta));
    }

    #[test]
    fn remove_while_leased_does_not_resurrect() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let lease = r.acquire("gp").unwrap();
        r.remove("http://h:1"); // health check dropped it meanwhile
        assert_eq!(r.total(), 0);
        drop(lease); // release of a removed endpoint is a no-op
        assert_eq!(r.total(), 0);
        assert_eq!(r.idle_for("gp"), 0);
        assert!(r.take_retired().is_empty());
    }

    #[test]
    fn duplicate_register_is_idempotent_and_keeps_state() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let lease = r.acquire("gp").unwrap();
        r.register("http://h:1", "gp", &contract());
        // Still busy: re-registration must not reset the lease.
        assert_eq!(r.state("http://h:1"), Some(ServerState::Busy));
        assert_eq!(r.total(), 1);
        assert_eq!(r.registered_total(), 1);
        drop(lease);
    }

    #[test]
    fn acquire_endpoint_leases_exactly_that_server() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        r.register("http://h:2", "gp", &contract());
        let lease = r.acquire_endpoint("http://h:2").unwrap();
        assert_eq!(lease.endpoint(), "http://h:2");
        assert_eq!(lease.model(), "gp");
        assert_eq!(r.state("http://h:2"), Some(ServerState::Busy));
        assert_eq!(r.idle_for("gp"), 1);
        // Busy and unknown endpoints refuse.
        assert!(r.acquire_endpoint("http://h:2").is_none());
        assert!(r.acquire_endpoint("http://nope:9").is_none());
        drop(lease);
        assert_eq!(r.idle_for("gp"), 2);
        assert!(r.acquire_endpoint("http://h:2").is_some());
    }

    #[test]
    fn waker_fires_on_transitions() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let r = reg();
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        r.set_waker(Arc::new(move || {
            h2.fetch_add(1, Ordering::SeqCst);
        }));
        r.register("http://h:1", "gp", &contract()); // wake 1
        let lease = r.acquire("gp").unwrap();
        drop(lease); // wake 2 (release)
        let mut lease = r.acquire("gp").unwrap();
        lease.mark_retire();
        drop(lease); // wake 3 (retire)
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }
}
