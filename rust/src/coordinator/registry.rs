//! Server registry: multi-model endpoint pool with per-model idle
//! indexes, learned contracts, and RAII leases.
//!
//! * Each endpoint serves one model; idle endpoints live in a per-model
//!   ordered set, so acquiring a server is O(log n) instead of the old
//!   full-table scan (and the racy `last_acquired` side-channel is
//!   gone: [`Registry::acquire`] hands back a [`ServerLease`] that
//!   *is* the acquisition).
//! * **Per-model locking.**  Every model's pool (server table + idle
//!   index + contract) sits behind its own mutex, routed through a
//!   read-mostly `endpoint -> model` index, so lease traffic for model
//!   A never contends with model B — the registry-side requirement for
//!   the sharded dispatch plane.  Cross-model bookkeeping (retirement
//!   queue, lifetime counters) lives in a separate lock that is off
//!   the lease hot path.
//! * The model's wire contract ([`ModelContract`]) is learned at
//!   registration from the preliminary checks and kept per model, so
//!   the front door answers metadata queries locally.
//! * Dropping a lease releases the server back to the idle index; a
//!   lease marked for retirement instead removes the server and parks
//!   its endpoint in a retirement queue the balancer drains into
//!   `Backend::retire_server` — the forwarder never talks to the
//!   backend while holding registry state.  Leases own an
//!   `Arc<Registry>`, so they travel freely through the shard plane's
//!   work-order channels.
//! * Every state change invokes the model's waker (or the global
//!   fallback): the balancer points each model's waker at the shards
//!   that own it, so registration, release and removal poke exactly
//!   the threads that can use the freed capacity.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Mutex, RwLock};

use crate::umbridge::ModelContract;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServerState {
    Idle,
    Busy,
}

type Waker = Arc<dyn Fn() + Send + Sync>;

/// One model's servers: everything a lease operation touches, behind
/// the model's own lock.
#[derive(Default)]
struct Pool {
    /// endpoint -> state (ordered: FCFS by endpoint, deterministic).
    servers: BTreeMap<String, ServerState>,
    /// Idle endpoints (ordered subset of `servers`).
    idle: BTreeSet<String>,
    /// Learned wire contract (survives server churn).
    contract: Option<ModelContract>,
    /// Lifetime registration count (the balancer's spawn governor
    /// resets its failure backoff when this advances).
    registered: u64,
}

/// Cross-model bookkeeping, off the lease hot path.
#[derive(Default)]
struct GlobalBook {
    /// Endpoints retired by lease drop, awaiting backend teardown.
    retired: Vec<String>,
    registered_total: u64,
    removed_total: u64,
}

/// Thread-safe registry of model-server endpoints with per-model locks.
///
/// Lock discipline: `index`/`pools` guards are never held across a pool
/// mutex acquisition except `pools.read()` (shared, writer only in
/// [`Registry::pool`] which holds no pool mutex), and no operation ever
/// holds two pool mutexes — so the lock graph is acyclic.
pub struct Registry {
    /// model -> pool (created at first registration or pre-seeded).
    pools: RwLock<HashMap<String, Arc<Mutex<Pool>>>>,
    /// endpoint -> model: read-mostly routing index.
    index: RwLock<HashMap<String, String>>,
    global: Mutex<GlobalBook>,
    /// model -> waker (the dispatch shards owning that model).
    wakers: RwLock<HashMap<String, Waker>>,
    /// Fallback waker for models without a dedicated one.
    fallback: Mutex<Option<Waker>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            pools: RwLock::new(HashMap::new()),
            index: RwLock::new(HashMap::new()),
            global: Mutex::new(GlobalBook::default()),
            wakers: RwLock::new(HashMap::new()),
            fallback: Mutex::new(None),
        }
    }

    /// Install the fallback wake-up hook (called after every
    /// registration, release, retirement or removal of a model that has
    /// no dedicated waker).
    pub fn set_waker(&self, w: Waker) {
        *self.fallback.lock().unwrap() = Some(w);
    }

    /// Install a per-model wake-up hook; the sharded balancer points
    /// this at the shards owning `model`, so a freed lease pokes only
    /// the threads that can use it.
    pub fn set_model_waker(&self, model: &str, w: Waker) {
        self.wakers.write().unwrap().insert(model.to_string(), w);
    }

    fn wake(&self, model: &str) {
        if let Some(w) = self.wakers.read().unwrap().get(model) {
            let w = w.clone();
            w();
            return;
        }
        let w = self.fallback.lock().unwrap().clone();
        if let Some(w) = w {
            w();
        }
    }

    /// The pool for `model`, created if absent.
    fn pool(&self, model: &str) -> Arc<Mutex<Pool>> {
        if let Some(p) = self.pools.read().unwrap().get(model) {
            return p.clone();
        }
        self.pools
            .write()
            .unwrap()
            .entry(model.to_string())
            .or_default()
            .clone()
    }

    /// The pool for `model` if it exists (no creation on read paths).
    fn pool_of(&self, model: &str) -> Option<Arc<Mutex<Pool>>> {
        self.pools.read().unwrap().get(model).cloned()
    }

    /// The model served by `endpoint`, via the routing index.
    pub fn model_of(&self, endpoint: &str) -> Option<String> {
        self.index.read().unwrap().get(endpoint).cloned()
    }

    /// Register an endpoint serving `model`, learning the contract on
    /// first sight.  Idempotent: re-registering a known endpoint does
    /// not reset its state.
    pub fn register(&self, endpoint: &str, model: &str,
                    contract: &ModelContract) {
        {
            let mut idx = self.index.write().unwrap();
            if idx.contains_key(endpoint) {
                return;
            }
            idx.insert(endpoint.to_string(), model.to_string());
        }
        let pool = self.pool(model);
        {
            let mut p = pool.lock().unwrap();
            p.servers.insert(endpoint.to_string(), ServerState::Idle);
            p.idle.insert(endpoint.to_string());
            if p.contract.is_none() {
                p.contract = Some(contract.clone());
            }
            p.registered += 1;
        }
        self.global.lock().unwrap().registered_total += 1;
        self.wake(model);
    }

    /// Learned contract for a model (from its first registered server).
    pub fn contract(&self, model: &str) -> Option<ModelContract> {
        self.pool_of(model)?.lock().unwrap().contract.clone()
    }

    /// Remove an endpoint entirely (health-check failure path).
    pub fn remove(&self, endpoint: &str) {
        if let Some(model) = self.purge(endpoint) {
            self.wake(&model);
        }
    }

    /// Drop `endpoint` from the index and its pool; returns the model
    /// it served, if it was present.
    fn purge(&self, endpoint: &str) -> Option<String> {
        let model = self.index.write().unwrap().remove(endpoint)?;
        if let Some(pool) = self.pool_of(&model) {
            let mut p = pool.lock().unwrap();
            p.servers.remove(endpoint);
            p.idle.remove(endpoint);
        }
        self.global.lock().unwrap().removed_total += 1;
        Some(model)
    }

    /// Lease the first idle server for `model` (O(log n), touching only
    /// that model's lock).  The lease releases the server on drop
    /// unless marked for retirement.
    pub fn acquire(self: &Arc<Self>, model: &str) -> Option<ServerLease> {
        let pool = self.pool_of(model)?;
        let endpoint = {
            let mut p = pool.lock().unwrap();
            let ep = p.idle.iter().next().cloned()?;
            p.idle.remove(&ep);
            *p.servers
                .get_mut(&ep)
                .expect("idle index entry without server") =
                ServerState::Busy;
            ep
        };
        Some(ServerLease {
            registry: Arc::clone(self),
            endpoint,
            model: model.to_string(),
            retire: false,
        })
    }

    /// Lease one *specific* idle endpoint — the acquisition path for the
    /// real-time scheduler core, whose `Start` effects bind work to the
    /// worker (server) the scheduling policy placed it on.  `None` if
    /// the endpoint is unknown or not idle (disambiguate with
    /// [`Registry::state`]).
    pub fn acquire_endpoint(self: &Arc<Self>,
                            endpoint: &str) -> Option<ServerLease> {
        let model = self.model_of(endpoint)?;
        let pool = self.pool_of(&model)?;
        {
            let mut p = pool.lock().unwrap();
            match p.servers.get_mut(endpoint) {
                Some(state) if *state == ServerState::Idle => {
                    *state = ServerState::Busy;
                }
                _ => return None, // busy, or purged since the index read
            }
            p.idle.remove(endpoint);
        }
        Some(ServerLease {
            registry: Arc::clone(self),
            endpoint: endpoint.to_string(),
            model,
            retire: false,
        })
    }

    fn release_endpoint(&self, endpoint: &str) {
        let Some(model) = self.model_of(endpoint) else {
            return; // removed while leased; nothing to release
        };
        let Some(pool) = self.pool_of(&model) else {
            return;
        };
        {
            let mut p = pool.lock().unwrap();
            let Some(state) = p.servers.get_mut(endpoint) else {
                return; // purged between the index read and the lock
            };
            *state = ServerState::Idle;
            p.idle.insert(endpoint.to_string());
        }
        self.wake(&model);
    }

    fn retire_endpoint(&self, endpoint: &str) {
        let Some(model) = self.purge(endpoint) else {
            return;
        };
        self.global
            .lock()
            .unwrap()
            .retired
            .push(endpoint.to_string());
        self.wake(&model);
    }

    /// Endpoints retired by lease drop since the last call; the
    /// balancer hands them to `Backend::retire_server`.
    pub fn take_retired(&self) -> Vec<String> {
        std::mem::take(&mut self.global.lock().unwrap().retired)
    }

    pub fn state(&self, endpoint: &str) -> Option<ServerState> {
        let model = self.model_of(endpoint)?;
        self.pool_of(&model)?
            .lock()
            .unwrap()
            .servers
            .get(endpoint)
            .copied()
    }

    pub fn endpoints(&self) -> Vec<String> {
        let mut eps: Vec<String> =
            self.index.read().unwrap().keys().cloned().collect();
        eps.sort();
        eps
    }

    pub fn total(&self) -> usize {
        self.index.read().unwrap().len()
    }

    /// Live servers (idle + busy) for one model — one pool lock.
    pub fn count_for(&self, model: &str) -> usize {
        self.pool_of(model)
            .map(|p| p.lock().unwrap().servers.len())
            .unwrap_or(0)
    }

    pub fn idle_count(&self) -> usize {
        let pools: Vec<_> =
            self.pools.read().unwrap().values().cloned().collect();
        pools.iter().map(|p| p.lock().unwrap().idle.len()).sum()
    }

    /// Idle servers for one model — one pool lock.
    pub fn idle_for(&self, model: &str) -> usize {
        self.pool_of(model)
            .map(|p| p.lock().unwrap().idle.len())
            .unwrap_or(0)
    }

    pub fn registered_total(&self) -> u64 {
        self.global.lock().unwrap().registered_total
    }

    /// Lifetime registrations for one model.
    pub fn registered_for(&self, model: &str) -> u64 {
        self.pool_of(model)
            .map(|p| p.lock().unwrap().registered)
            .unwrap_or(0)
    }

    pub fn removed_total(&self) -> u64 {
        self.global.lock().unwrap().removed_total
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII acquisition of one model server.
///
/// Dropping the lease returns the server to the idle pool; after
/// [`ServerLease::mark_retire`] (failed forward, per-job mode, or a
/// panic unwinding past a poisoned evaluation path when the caller
/// pre-marks), dropping removes the server and queues its endpoint for
/// backend teardown instead.  The lease owns an `Arc` of its registry,
/// so it can ride through channels to whichever thread finishes the
/// work.
pub struct ServerLease {
    registry: Arc<Registry>,
    endpoint: String,
    model: String,
    retire: bool,
}

impl ServerLease {
    pub fn endpoint(&self) -> &str {
        &self.endpoint
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Retire instead of release on drop.
    pub fn mark_retire(&mut self) {
        self.retire = true;
    }

    pub fn will_retire(&self) -> bool {
        self.retire
    }
}

impl Drop for ServerLease {
    fn drop(&mut self) {
        if self.retire {
            self.registry.retire_endpoint(&self.endpoint);
        } else {
            self.registry.release_endpoint(&self.endpoint);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn contract() -> ModelContract {
        ModelContract { input_sizes: vec![7], output_sizes: vec![2, 2] }
    }

    fn reg() -> Arc<Registry> {
        Arc::new(Registry::new())
    }

    #[test]
    fn register_acquire_release() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        r.register("http://h:2", "gp", &contract());
        assert_eq!(r.total(), 2);
        assert_eq!(r.idle_for("gp"), 2);
        let lease = r.acquire("gp").unwrap();
        assert_eq!(r.idle_for("gp"), 1);
        assert_eq!(r.state(lease.endpoint()), Some(ServerState::Busy));
        let ep = lease.endpoint().to_string();
        drop(lease); // release on drop
        assert_eq!(r.idle_for("gp"), 2);
        assert_eq!(r.state(&ep), Some(ServerState::Idle));
        assert!(r.take_retired().is_empty());
    }

    #[test]
    fn acquire_is_fcfs_and_exhausts() {
        let r = reg();
        r.register("http://h:2", "gp", &contract());
        r.register("http://h:1", "gp", &contract());
        let a = r.acquire("gp").unwrap();
        assert_eq!(a.endpoint(), "http://h:1"); // ordered index
        let b = r.acquire("gp").unwrap();
        assert_eq!(b.endpoint(), "http://h:2");
        assert!(r.acquire("gp").is_none());
        drop(a);
        assert!(r.acquire("gp").is_some());
        drop(b);
    }

    #[test]
    fn retire_on_drop_removes_and_queues() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let mut lease = r.acquire("gp").unwrap();
        lease.mark_retire(); // poisoned forward / per-job mode
        assert!(lease.will_retire());
        drop(lease);
        assert_eq!(r.total(), 0);
        assert_eq!(r.take_retired(), vec!["http://h:1".to_string()]);
        assert!(r.take_retired().is_empty()); // drained
        assert_eq!(r.registered_total(), 1);
        assert_eq!(r.removed_total(), 1);
    }

    #[test]
    fn models_are_isolated() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let beta = ModelContract { input_sizes: vec![1],
                                   output_sizes: vec![100, 1] };
        r.register("http://h:2", "eigen-100", &beta);
        let lease = r.acquire("gp").unwrap();
        // gp exhausted; eigen-100 unaffected.
        assert!(r.acquire("gp").is_none());
        assert_eq!(r.idle_for("eigen-100"), 1);
        assert_eq!(r.count_for("gp"), 1);
        let e = r.acquire("eigen-100").unwrap();
        assert_eq!(e.endpoint(), "http://h:2");
        drop(e);
        drop(lease);
        assert_eq!(r.contract("gp"), Some(contract()));
        assert_eq!(r.contract("eigen-100"), Some(beta));
    }

    #[test]
    fn remove_while_leased_does_not_resurrect() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let lease = r.acquire("gp").unwrap();
        r.remove("http://h:1"); // health check dropped it meanwhile
        assert_eq!(r.total(), 0);
        drop(lease); // release of a removed endpoint is a no-op
        assert_eq!(r.total(), 0);
        assert_eq!(r.idle_for("gp"), 0);
        assert!(r.take_retired().is_empty());
    }

    #[test]
    fn duplicate_register_is_idempotent_and_keeps_state() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let lease = r.acquire("gp").unwrap();
        r.register("http://h:1", "gp", &contract());
        // Still busy: re-registration must not reset the lease.
        assert_eq!(r.state("http://h:1"), Some(ServerState::Busy));
        assert_eq!(r.total(), 1);
        assert_eq!(r.registered_total(), 1);
        drop(lease);
    }

    #[test]
    fn acquire_endpoint_leases_exactly_that_server() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        r.register("http://h:2", "gp", &contract());
        let lease = r.acquire_endpoint("http://h:2").unwrap();
        assert_eq!(lease.endpoint(), "http://h:2");
        assert_eq!(lease.model(), "gp");
        assert_eq!(r.state("http://h:2"), Some(ServerState::Busy));
        assert_eq!(r.idle_for("gp"), 1);
        // Busy and unknown endpoints refuse.
        assert!(r.acquire_endpoint("http://h:2").is_none());
        assert!(r.acquire_endpoint("http://nope:9").is_none());
        drop(lease);
        assert_eq!(r.idle_for("gp"), 2);
        assert!(r.acquire_endpoint("http://h:2").is_some());
    }

    #[test]
    fn waker_fires_on_transitions() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let r = reg();
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        r.set_waker(Arc::new(move || {
            h2.fetch_add(1, Ordering::SeqCst);
        }));
        r.register("http://h:1", "gp", &contract()); // wake 1
        let lease = r.acquire("gp").unwrap();
        drop(lease); // wake 2 (release)
        let mut lease = r.acquire("gp").unwrap();
        lease.mark_retire();
        drop(lease); // wake 3 (retire)
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn model_waker_overrides_fallback_per_model() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let r = reg();
        let global = Arc::new(AtomicU64::new(0));
        let gp = Arc::new(AtomicU64::new(0));
        let (g2, p2) = (global.clone(), gp.clone());
        r.set_waker(Arc::new(move || {
            g2.fetch_add(1, Ordering::SeqCst);
        }));
        r.set_model_waker("gp", Arc::new(move || {
            p2.fetch_add(1, Ordering::SeqCst);
        }));
        r.register("http://h:1", "gp", &contract());
        r.register("http://h:2", "other", &contract());
        // gp transitions hit the model waker, never the fallback.
        assert_eq!(gp.load(Ordering::SeqCst), 1);
        assert_eq!(global.load(Ordering::SeqCst), 1); // "other" only
        drop(r.acquire("gp").unwrap());
        assert_eq!(gp.load(Ordering::SeqCst), 2);
        assert_eq!(global.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn lease_is_sendable_across_threads() {
        let r = reg();
        r.register("http://h:1", "gp", &contract());
        let lease = r.acquire("gp").unwrap();
        // The Arc-owning lease rides a channel to another thread and
        // releases from there — the shard plane's work-order path.
        let (tx, rx) = std::sync::mpsc::channel::<ServerLease>();
        let h = std::thread::spawn(move || {
            let lease = rx.recv().unwrap();
            drop(lease);
        });
        tx.send(lease).unwrap();
        h.join().unwrap();
        assert_eq!(r.idle_for("gp"), 1);
    }
}
