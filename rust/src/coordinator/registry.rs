//! Server registry: endpoint pool with Idle/Busy state, FCFS acquisition.

use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServerState {
    Idle,
    Busy,
}

#[derive(Default)]
struct Inner {
    servers: BTreeMap<String, ServerState>,
    last_acquired: Option<String>,
    /// Lifetime counters.
    registered_total: u64,
    removed_total: u64,
}

/// Thread-safe registry of model-server endpoints.
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry { inner: Mutex::new(Inner::default()) }
    }

    pub fn register(&self, endpoint: &str) {
        let mut g = self.inner.lock().unwrap();
        if g.servers
            .insert(endpoint.to_string(), ServerState::Idle)
            .is_none()
        {
            g.registered_total += 1;
        }
    }

    pub fn remove(&self, endpoint: &str) {
        let mut g = self.inner.lock().unwrap();
        if g.servers.remove(endpoint).is_some() {
            g.removed_total += 1;
        }
    }

    /// Mark the first idle server busy and return it.
    pub fn acquire_idle(&self) -> Option<String> {
        let mut g = self.inner.lock().unwrap();
        let ep = g
            .servers
            .iter()
            .find(|(_, s)| **s == ServerState::Idle)
            .map(|(e, _)| e.clone())?;
        g.servers.insert(ep.clone(), ServerState::Busy);
        g.last_acquired = Some(ep.clone());
        Some(ep)
    }

    /// Endpoint returned by the most recent successful `acquire_idle`.
    pub fn last_acquired(&self) -> Option<String> {
        self.inner.lock().unwrap().last_acquired.clone()
    }

    pub fn release(&self, endpoint: &str) {
        let mut g = self.inner.lock().unwrap();
        if let Some(s) = g.servers.get_mut(endpoint) {
            *s = ServerState::Idle;
        }
    }

    pub fn state(&self, endpoint: &str) -> Option<ServerState> {
        self.inner.lock().unwrap().servers.get(endpoint).copied()
    }

    pub fn endpoints(&self) -> Vec<String> {
        self.inner.lock().unwrap().servers.keys().cloned().collect()
    }

    pub fn total(&self) -> usize {
        self.inner.lock().unwrap().servers.len()
    }

    pub fn idle_count(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .servers
            .values()
            .filter(|s| **s == ServerState::Idle)
            .count()
    }

    pub fn registered_total(&self) -> u64 {
        self.inner.lock().unwrap().registered_total
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_acquire_release() {
        let r = Registry::new();
        r.register("http://h:1");
        r.register("http://h:2");
        assert_eq!(r.total(), 2);
        assert_eq!(r.idle_count(), 2);
        let a = r.acquire_idle().unwrap();
        assert_eq!(r.idle_count(), 1);
        assert_eq!(r.state(&a), Some(ServerState::Busy));
        r.release(&a);
        assert_eq!(r.idle_count(), 2);
    }

    #[test]
    fn acquire_exhausts() {
        let r = Registry::new();
        r.register("http://h:1");
        assert!(r.acquire_idle().is_some());
        assert!(r.acquire_idle().is_none());
    }

    #[test]
    fn remove_busy_server() {
        let r = Registry::new();
        r.register("http://h:1");
        let a = r.acquire_idle().unwrap();
        r.remove(&a);
        assert_eq!(r.total(), 0);
        assert_eq!(r.registered_total(), 1);
    }

    #[test]
    fn duplicate_register_is_idempotent() {
        let r = Registry::new();
        r.register("http://h:1");
        r.register("http://h:1");
        assert_eq!(r.total(), 1);
        assert_eq!(r.registered_total(), 1);
    }
}
