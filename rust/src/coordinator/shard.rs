//! Sharded live dispatch plane.
//!
//! The front door used to funnel every `/Evaluate`, completion, timer pop and
//! `/Stats` read through a single `Mutex<Dispatch>`. This module replaces that
//! lock with one **dispatch shard per model** (times `--shards-per-model`):
//! each shard owns its own [`RtDriver`], pending-item table and endpoint↔wid
//! mirror, and runs a dedicated event thread fed by an MPSC channel. The front
//! door submits by pushing a shard event — one atomic admission-gate bump
//! plus one channel send, with zero shared-lock acquisitions and zero
//! cross-model contention. Completions, worker churn, probe evictions and
//! cancellation sweeps flow through the same channel; the shard thread drains
//! the channel in batches and pays one [`RtDriver`] pump pass per burst, not
//! one per event.
//!
//! Worker placement: every healthy endpoint of model M is announced to all of
//! M's shards, and the registry's atomic [`Registry::acquire_endpoint`] is
//! the single source of truth for who actually holds a server — a shard whose
//! driver surfaces a ready task for a momentarily-busy endpoint requeues it
//! and is poked by the model's registry waker when the lease returns. This
//! keeps every shard able to dispatch (no shard can starve behind an empty
//! worker set) while queued requests stay partitioned for lock-free
//! admission.
//!
//! `/Stats` never touches a shard thread: each shard publishes an
//! epoch-stamped [`ShardSnapshot`] of plain atomics that readers aggregate
//! lock-free. Backpressure (`Retry-After`, circuit-breaker floor) is likewise
//! recomputed from the published snapshots.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::hqlite::TaskId;
use crate::httpd::HttpClient;

use super::registry::{Registry, ServerLease};
use super::{BalancerStats, ModelStats};
use crate::sched::realtime::{LivePolicy, Recovery, RetryPolicy, RtDriver};

/// How long a shard thread sleeps waiting for events before it re-checks
/// timers and the stop flag anyway.
const SHARD_IDLE_WAIT: Duration = Duration::from_millis(50);

/// Static configuration for a [`DispatchPlane`].
#[derive(Clone)]
pub struct PlaneConfig {
    /// Model names, one shard group each.
    pub models: Vec<String>,
    /// Shards per model (>= 1); requests round-robin across them.
    pub shards_per_model: usize,
    /// Total queued-request capacity per model (split across its shards).
    pub queue_capacity: usize,
    /// Live scheduling policy for every shard's driver.
    pub scheduler: LivePolicy,
    /// Retry policy for failed dispatches.
    pub retry: RetryPolicy,
    /// Per-request budget handed to the driver (EDF deadline seed).
    pub request_timeout: Duration,
    /// Whether leases return to the idle pool after a successful forward.
    pub persistent_servers: bool,
}

/// A queued evaluation: the front door parks on
/// [`PendingEval::wait_deadline`] while a shard thread and a forwarder carry
/// the request to a backend.
pub struct PendingEval {
    model: String,
    body: String,
    enqueued: Instant,
    cancelled: AtomicBool,
    done: Mutex<Option<Result<String, String>>>,
    cv: Condvar,
}

impl PendingEval {
    fn new(model: &str, body: String) -> Arc<Self> {
        Arc::new(Self {
            model: model.to_string(),
            body,
            enqueued: Instant::now(),
            cancelled: AtomicBool::new(false),
            done: Mutex::new(None),
            cv: Condvar::new(),
        })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn body(&self) -> &str {
        &self.body
    }

    pub fn enqueued(&self) -> Instant {
        self.enqueued
    }

    /// Mark the request abandoned (client gave up). The shard thread purges
    /// cancelled items on its next sweep.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// Publish the final result and wake any waiter. First resolution wins;
    /// later calls are dropped.
    pub fn resolve(&self, result: Result<String, String>) {
        let mut slot = self.done.lock().unwrap();
        if slot.is_none() {
            *slot = Some(result);
        }
        drop(slot);
        self.cv.notify_all();
    }

    /// Block until resolved or `deadline`; `None` means timed out.
    pub fn wait_deadline(&self, deadline: Instant) -> Option<Result<String, String>> {
        let mut slot = self.done.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timeout) = self.cv.wait_timeout(slot, deadline - now).unwrap();
            slot = guard;
        }
    }
}

/// Events applied by a shard thread, in arrival order, in batches.
enum ShardEvent {
    Submit(Arc<PendingEval>),
    /// A batch admitted in one gate bump: one channel send, one thread
    /// wake and one pump pass for the whole burst.
    SubmitMany(Vec<Arc<PendingEval>>),
    /// Forward finished (success or definitive HTTP-error answer).
    Done { id: TaskId },
    /// Forward died with its server: withdraw the worker, charge a retry.
    Failed { id: TaskId, item: Arc<PendingEval>, endpoint: String, err: String },
    WorkerUp { endpoint: String },
    /// Core-state-only withdrawal; all stats accounting happens at the
    /// plane level, exactly once per actual loss.
    WorkerLost { endpoint: String },
    /// Wake the shard thread (registry waker, cancellation sweep hint).
    Poke,
    Stop,
}

/// Epoch-stamped, lock-free per-shard counters. The shard thread is the only
/// writer; `/Stats` readers aggregate these without touching the thread.
#[derive(Default)]
pub struct ShardSnapshot {
    pub epoch: AtomicU64,
    pub queued: AtomicU64,
    pub workers: AtomicU64,
    pub ready: AtomicU64,
    pub submitted: AtomicU64,
    pub dispatched: AtomicU64,
    pub served: AtomicU64,
    pub wakeups: AtomicU64,
    pub busy_us: AtomicU64,
}

/// Plain-value copy of a [`ShardSnapshot`] at one instant.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardCounts {
    pub epoch: u64,
    pub queued: u64,
    pub workers: u64,
    pub ready: u64,
    pub submitted: u64,
    pub dispatched: u64,
    pub served: u64,
    pub wakeups: u64,
    pub busy_us: u64,
}

impl ShardSnapshot {
    fn read(&self) -> ShardCounts {
        ShardCounts {
            epoch: self.epoch.load(Ordering::Acquire),
            queued: self.queued.load(Ordering::Relaxed),
            workers: self.workers.load(Ordering::Relaxed),
            ready: self.ready.load(Ordering::Relaxed),
            submitted: self.submitted.load(Ordering::Relaxed),
            dispatched: self.dispatched.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            wakeups: self.wakeups.load(Ordering::Relaxed),
            busy_us: self.busy_us.load(Ordering::Relaxed),
        }
    }
}

/// One dispatch shard: the front-door-facing half (admission gate, event
/// sender, published snapshot, order queue, connection pool). The scheduler
/// half lives in [`ShardState`] on the shard's own thread.
struct Shard {
    model: String,
    index: usize,
    capacity: usize,
    tx: Sender<ShardEvent>,
    /// Admission gate: requests admitted but not yet dispatched. Bounded
    /// here (not in the channel) so completion events can never be dropped.
    gate: AtomicUsize,
    snap: ShardSnapshot,
    /// Dispatched work waiting for a forwarder.
    orders: Mutex<VecDeque<WorkOrder>>,
    orders_cv: Condvar,
    /// Keep-alive connections used by forwarders bound to this shard —
    /// forwarders for model A never touch model B's pool lock.
    conn_pool: Mutex<HashMap<String, Vec<HttpClient>>>,
}

impl Shard {
    fn gate_dec(&self) {
        let _ = self
            .gate
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| Some(v.saturating_sub(1)));
    }
}

/// A dispatched request: item + scheduler task id + the server lease that
/// backs it. Handed from the shard thread to a forwarder.
pub struct WorkOrder {
    item: Arc<PendingEval>,
    id: TaskId,
    lease: ServerLease,
    shard: usize,
}

impl WorkOrder {
    pub fn item(&self) -> &Arc<PendingEval> {
        &self.item
    }

    pub fn endpoint(&self) -> &str {
        self.lease.endpoint()
    }

    pub fn shard(&self) -> usize {
        self.shard
    }
}

/// A failed forward. `transport: true` means the connection itself died
/// (connect/read/write failure — the server is likely gone, a retry on a
/// replacement can succeed); `false` means a live server answered with an
/// HTTP error (deterministic; not retried).
pub struct ForwardError {
    pub transport: bool,
    pub msg: String,
}

/// Outcome of a lock-free submission.
pub enum SubmitOutcome {
    /// Accepted; park on the handle.
    Queued(Arc<PendingEval>),
    /// Shard at capacity — backpressure (503 + Retry-After).
    Full,
    UnknownModel,
    /// Plane is shutting down.
    Stopping,
}

struct Group {
    start: usize,
    count: usize,
    /// Probe sequence for power-of-two-choices (not a placement: the
    /// counter only decides which two shards get depth-compared).
    rr: AtomicUsize,
}

impl Group {
    /// Power-of-two-choices shard pick: draw two distinct probe shards
    /// from the rotation counter and take the shallower queue.  The
    /// depth compared is the admission gate — the same live counter the
    /// epoch-stamped [`ShardSnapshot`] publishes as `queued`, read at
    /// its source so back-to-back submits in one burst see each other's
    /// admissions instead of herding onto a stale snapshot.  Under a
    /// skewed model mix this bounds the max/min shard imbalance where
    /// blind round-robin lets one hot shard run away (see
    /// `tests/balancer_plane.rs`).
    fn pick<'s>(&self, shards: &'s [Arc<Shard>]) -> &'s Arc<Shard> {
        let n = self.rr.fetch_add(1, Ordering::Relaxed);
        let first = &shards[self.start + n % self.count];
        if self.count == 1 {
            return first;
        }
        // Second probe: a rotating non-zero offset, so over time every
        // pair of shards gets compared (not just neighbours).
        let off = 1 + (n / self.count) % (self.count - 1);
        let second = &shards[self.start + (n + off) % self.count];
        let da = first.gate.load(Ordering::Acquire);
        let db = second.gate.load(Ordering::Acquire);
        if db < da {
            second
        } else {
            first
        }
    }
}

/// The sharded dispatch plane. See the module docs for the design.
pub struct DispatchPlane {
    cfg: PlaneConfig,
    shards: Vec<Arc<Shard>>,
    groups: HashMap<String, Group>,
    stats: Arc<BalancerStats>,
    requests_served: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl DispatchPlane {
    /// Build the plane and start one event thread per shard.
    pub fn start(
        cfg: PlaneConfig,
        registry: Arc<Registry>,
        stats: Arc<BalancerStats>,
        requests_served: Arc<AtomicU64>,
    ) -> Arc<Self> {
        let spm = cfg.shards_per_model.max(1);
        let per_shard_cap = (cfg.queue_capacity / spm).max(1);
        let budget_us = cfg.request_timeout.as_micros().max(1) as u64;

        let mut shards = Vec::new();
        let mut groups = HashMap::new();
        let mut receivers = Vec::new();
        for model in &cfg.models {
            let start = shards.len();
            for k in 0..spm {
                let (tx, rx) = mpsc::channel();
                shards.push(Arc::new(Shard {
                    model: model.clone(),
                    index: start + k,
                    capacity: per_shard_cap,
                    tx,
                    gate: AtomicUsize::new(0),
                    snap: ShardSnapshot::default(),
                    orders: Mutex::new(VecDeque::new()),
                    orders_cv: Condvar::new(),
                    conn_pool: Mutex::new(HashMap::new()),
                }));
                receivers.push(rx);
            }
            groups.insert(model.clone(), Group { start, count: spm, rr: AtomicUsize::new(0) });
        }

        let stop = Arc::new(AtomicBool::new(false));
        let plane = Arc::new(Self {
            cfg: cfg.clone(),
            shards,
            groups,
            stats: stats.clone(),
            requests_served: requests_served.clone(),
            stop: stop.clone(),
            threads: Mutex::new(Vec::new()),
        });

        let mut threads = Vec::new();
        for (shard, rx) in plane.shards.iter().cloned().zip(receivers) {
            let mut state = ShardState {
                shard: shard.clone(),
                rx,
                driver: RtDriver::for_policy(cfg.scheduler).with_retry(cfg.retry),
                budget_us,
                items: HashMap::new(),
                wid_of: HashMap::new(),
                ep_of: HashMap::new(),
                next_wid: 1,
                timeouts_seen: 0,
                registry: registry.clone(),
                stats: stats.clone(),
                requests_served: requests_served.clone(),
                stop: stop.clone(),
            };
            let name = format!("lb-shard-{}-{}", shard.model, shard.index);
            threads.push(
                std::thread::Builder::new()
                    .name(name)
                    .spawn(move || state.run())
                    .expect("spawn shard thread"),
            );
        }
        *plane.threads.lock().unwrap() = threads;

        plane.install_wakers(&registry);
        plane
    }

    /// Register per-model registry wakers: a lease release or retirement in
    /// model M pokes only M's shards, never the whole plane.
    fn install_wakers(self: &Arc<Self>, registry: &Arc<Registry>) {
        for model in &self.cfg.models {
            let weak: Weak<Self> = Arc::downgrade(self);
            let m = model.clone();
            registry.set_model_waker(
                model,
                Arc::new(move || {
                    if let Some(plane) = weak.upgrade() {
                        plane.poke_model(&m);
                    }
                }),
            );
        }
    }

    /// Wake every shard thread of one model (registry waker target; also
    /// used by the front door after flagging a client timeout so the
    /// cancellation sweep runs promptly).
    pub fn poke_model(&self, model: &str) {
        if let Some(g) = self.groups.get(model) {
            for shard in &self.shards[g.start..g.start + g.count] {
                let _ = shard.tx.send(ShardEvent::Poke);
            }
        }
    }

    /// Lock-free submission: one atomic gate bump + one channel push.
    /// The target shard is picked by power-of-two-choices on the
    /// admission-gate depths ([`Group::pick`]).
    ///
    /// Gate discipline: the bump is retracted on **every** non-`Queued`
    /// outcome.  The closed-channel path used to leak the slot (and a
    /// phantom `submitted` count) — with the gate also feeding
    /// backpressure and the p2c depth compare, a leak would permanently
    /// shrink the shard's usable capacity and skew placement away from
    /// it for the rest of the process.
    pub fn submit(&self, model: &str, body: String) -> SubmitOutcome {
        let Some(g) = self.groups.get(model) else {
            return SubmitOutcome::UnknownModel;
        };
        if self.stop.load(Ordering::Acquire) {
            return SubmitOutcome::Stopping;
        }
        let shard = g.pick(&self.shards);
        if shard
            .gate
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                if v < shard.capacity {
                    Some(v + 1)
                } else {
                    None
                }
            })
            .is_err()
        {
            return SubmitOutcome::Full;
        }
        let item = PendingEval::new(model, body);
        if shard.tx.send(ShardEvent::Submit(item.clone())).is_err() {
            shard.gate_dec();
            return SubmitOutcome::Stopping;
        }
        shard.snap.submitted.fetch_add(1, Ordering::Relaxed);
        SubmitOutcome::Queued(item)
    }

    /// Batched submission: admit as many of `bodies` as one p2c-picked
    /// shard has gate room for, in one gate transaction and **one**
    /// channel send — the whole burst costs the shard thread a single
    /// wake and a single pump pass, where per-item [`Self::submit`]
    /// would pay one of each per request.  Returns one outcome per body,
    /// in order; bodies beyond the shard's free capacity get
    /// [`SubmitOutcome::Full`] (callers may resubmit those elsewhere —
    /// the gate never over-admits).
    pub fn submit_many(&self, model: &str, bodies: Vec<String>) -> Vec<SubmitOutcome> {
        let n = bodies.len();
        let Some(g) = self.groups.get(model) else {
            return bodies.into_iter().map(|_| SubmitOutcome::UnknownModel).collect();
        };
        if self.stop.load(Ordering::Acquire) {
            return bodies.into_iter().map(|_| SubmitOutcome::Stopping).collect();
        }
        if n == 0 {
            return Vec::new();
        }
        let shard = g.pick(&self.shards);
        // One gate transaction admits the largest prefix that fits.
        let mut admitted = 0usize;
        let _ = shard.gate.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
            admitted = n.min(shard.capacity.saturating_sub(v));
            Some(v + admitted)
        });
        if admitted == 0 {
            return bodies.into_iter().map(|_| SubmitOutcome::Full).collect();
        }
        let mut out: Vec<SubmitOutcome> = Vec::with_capacity(n);
        let mut batch: Vec<Arc<PendingEval>> = Vec::with_capacity(admitted);
        for (i, body) in bodies.into_iter().enumerate() {
            if i < admitted {
                let item = PendingEval::new(model, body);
                batch.push(item.clone());
                out.push(SubmitOutcome::Queued(item));
            } else {
                out.push(SubmitOutcome::Full);
            }
        }
        if shard.tx.send(ShardEvent::SubmitMany(batch)).is_err() {
            // Retract the whole admission (closed channel: shard gone).
            for _ in 0..admitted {
                shard.gate_dec();
            }
            for slot in out.iter_mut().take(admitted) {
                if let SubmitOutcome::Queued(item) = slot {
                    item.resolve(Err("balancer shutting down".into()));
                }
                *slot = SubmitOutcome::Stopping;
            }
            return out;
        }
        shard.snap.submitted.fetch_add(admitted as u64, Ordering::Relaxed);
        out
    }

    /// Announce a healthy endpoint to every shard of its model. Idempotent
    /// per shard: re-announcing a known endpoint is a no-op.
    pub fn worker_up(&self, endpoint: &str, model: &str) {
        if let Some(g) = self.groups.get(model) {
            for shard in &self.shards[g.start..g.start + g.count] {
                let _ = shard.tx.send(ShardEvent::WorkerUp { endpoint: endpoint.to_string() });
            }
        }
    }

    /// Health watcher evicted an endpoint after K failed probes: withdraw it
    /// from every shard of its model and account the loss once.
    pub fn worker_lost_external(&self, endpoint: &str, model: &str) {
        if let Some(st) = self.stats.model(model) {
            st.worker_lost.fetch_add(1, Ordering::Relaxed);
            st.probe_evictions.fetch_add(1, Ordering::Relaxed);
        }
        self.withdraw(endpoint, model);
    }

    /// Remove an endpoint's connections and core-state from every shard of
    /// `model` (no stats — callers account the loss).
    fn withdraw(&self, endpoint: &str, model: &str) {
        if let Some(g) = self.groups.get(model) {
            for shard in &self.shards[g.start..g.start + g.count] {
                shard.conn_pool.lock().unwrap().remove(endpoint);
                let _ = shard.tx.send(ShardEvent::WorkerLost { endpoint: endpoint.to_string() });
            }
        }
    }

    /// Blocking pop for forwarders bound to `shard`.
    pub fn take_order(&self, shard: usize, timeout: Duration) -> Option<WorkOrder> {
        let s = &self.shards[shard];
        let mut q = s.orders.lock().unwrap();
        if let Some(o) = q.pop_front() {
            return Some(o);
        }
        let (mut q, _timed_out) = s.orders_cv.wait_timeout(q, timeout).unwrap();
        q.pop_front()
    }

    /// Forwarder hands back a finished attempt. Settles lease, stats and
    /// client bookkeeping, and routes the completion event to the shard that
    /// dispatched the order. (The forward-latency histogram is recorded by
    /// the forwarder itself, which knows the elapsed time.)
    pub fn complete_order(&self, order: WorkOrder, result: Result<String, ForwardError>) {
        let WorkOrder { item, id, mut lease, shard } = order;
        let endpoint = lease.endpoint().to_string();
        let model = lease.model().to_string();
        let ok = result.is_ok();
        // Per-job servers retire after one evaluation (the paper's measured
        // configuration); failed forwards retire either way.
        let retire = !self.cfg.persistent_servers || !ok;
        if retire {
            lease.mark_retire();
        }
        drop(lease); // release or retire; the model waker pokes its shards

        let st = self.stats.model(&model);
        match result {
            Err(e) if e.transport => {
                // The forward died with its server: withdraw the worker
                // from every shard, account the loss once, then charge one
                // attempt against the retry budget on the dispatching
                // shard. Within budget the core requeues the task behind
                // its backoff while the client keeps waiting; past budget
                // the error surfaces.
                if let Some(st) = st {
                    st.worker_lost.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(g) = self.groups.get(&model) {
                    for s in &self.shards[g.start..g.start + g.count] {
                        s.conn_pool.lock().unwrap().remove(&endpoint);
                        if s.index != shard {
                            let _ = s
                                .tx
                                .send(ShardEvent::WorkerLost { endpoint: endpoint.clone() });
                        }
                    }
                }
                let failed = ShardEvent::Failed {
                    id,
                    item: item.clone(),
                    endpoint,
                    err: e.msg.clone(),
                };
                if self.shards[shard].tx.send(failed).is_err() {
                    // Shard already gone (shutdown): surface the error.
                    if let Some(st) = st {
                        st.errors.fetch_add(1, Ordering::Relaxed);
                    }
                    self.requests_served.fetch_add(1, Ordering::Relaxed);
                    item.resolve(Err(e.msg));
                }
            }
            _ => {
                // A completed attempt: success, or a definitive error
                // answer from a live server.
                if let Some(st) = st {
                    if ok {
                        st.served.fetch_add(1, Ordering::Relaxed);
                    } else {
                        st.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                self.requests_served.fetch_add(1, Ordering::Relaxed);
                if ok {
                    self.shards[shard].snap.served.fetch_add(1, Ordering::Relaxed);
                }
                item.resolve(result.map_err(|e| e.msg));
                let _ = self.shards[shard].tx.send(ShardEvent::Done { id });
                if retire {
                    // Planned retirement (per-job server, or a live server
                    // that answered an HTTP error): capacity loss, no
                    // worker_lost accounting — matches the unsharded plane.
                    self.withdraw(&endpoint, &model);
                }
            }
        }
    }

    /// Per-shard connection pool (forwarders for model A never touch model
    /// B's pool lock).
    pub fn forward_pool(&self, shard: usize) -> &Mutex<HashMap<String, Vec<HttpClient>>> {
        &self.shards[shard].conn_pool
    }

    /// Drop any pooled connections to `endpoint` (retirement teardown).
    pub fn purge_conns(&self, endpoint: &str) {
        for s in &self.shards {
            s.conn_pool.lock().unwrap().remove(endpoint);
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The `(start, count)` shard-index range serving `model`.
    pub fn shards_for(&self, model: &str) -> Option<(usize, usize)> {
        self.groups.get(model).map(|g| (g.start, g.count))
    }

    /// Snapshot counters for every shard of `model`, in shard order.
    pub fn counts_for(&self, model: &str) -> Vec<ShardCounts> {
        match self.groups.get(model) {
            Some(g) => self.shards[g.start..g.start + g.count]
                .iter()
                .map(|s| s.snap.read())
                .collect(),
            None => Vec::new(),
        }
    }

    /// Snapshot counters for every shard in the plane.
    pub fn counts(&self) -> Vec<(String, ShardCounts)> {
        self.shards.iter().map(|s| (s.model.clone(), s.snap.read())).collect()
    }

    /// Queued (admitted, not yet dispatched) requests for one model.
    pub fn queued_for(&self, model: &str) -> usize {
        match self.groups.get(model) {
            Some(g) => self.shards[g.start..g.start + g.count]
                .iter()
                .map(|s| s.gate.load(Ordering::Acquire))
                .sum(),
            None => 0,
        }
    }

    /// Live workers announced to one model's shards. Every shard of a model
    /// sees the full worker set, so the model's count is the max across its
    /// shards (not the sum).
    pub fn workers_for(&self, model: &str) -> usize {
        self.counts_for(model).iter().map(|c| c.workers as usize).max().unwrap_or(0)
    }

    /// Total queued requests across the plane.
    pub fn queue_len(&self) -> usize {
        self.shards.iter().map(|s| s.gate.load(Ordering::Acquire)).sum()
    }

    /// Total forwarder wakeups issued (bench: wakeups-per-request ≈ 1).
    pub fn wakeups_total(&self) -> u64 {
        self.shards.iter().map(|s| s.snap.wakeups.load(Ordering::Relaxed)).sum()
    }

    /// Wake all forwarders parked on order queues (shutdown).
    pub fn wake_forwarders(&self) {
        for s in &self.shards {
            s.orders_cv.notify_all();
        }
    }

    /// Stop shard threads, join them, and fail any stranded work.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        for s in &self.shards {
            let _ = s.tx.send(ShardEvent::Stop);
        }
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
        // Shard threads have drained their item tables; clear any orders
        // still parked for forwarders that already exited.
        for s in &self.shards {
            let mut q = s.orders.lock().unwrap();
            while let Some(o) = q.pop_front() {
                o.item.resolve(Err("balancer shutting down".into()));
            }
            drop(q);
            s.orders_cv.notify_all();
        }
    }
}

/// The scheduler half of a shard: lives on the shard thread,
/// single-threaded, and touches no lock shared with the front door.
struct ShardState {
    shard: Arc<Shard>,
    rx: Receiver<ShardEvent>,
    driver: RtDriver,
    budget_us: u64,
    /// Submitted evaluations not yet handed to a forwarder.
    items: HashMap<TaskId, Arc<PendingEval>>,
    /// endpoint -> live worker id announced to the core.
    wid_of: HashMap<String, u64>,
    /// live worker id -> endpoint (resolves a ready binding to a lease).
    ep_of: HashMap<u64, String>,
    next_wid: u64,
    /// `timed_out` counter value at the last cancellation sweep.
    timeouts_seen: u64,
    registry: Arc<Registry>,
    stats: Arc<BalancerStats>,
    requests_served: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
}

impl ShardState {
    fn st(&self) -> Option<&ModelStats> {
        self.stats.model(&self.shard.model)
    }

    fn run(&mut self) {
        loop {
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            // Sleep until the next event or the earliest core timer, with a
            // 50 ms liveness backstop (stop flag, slow backends).
            let wait = match self.driver.next_timer_due() {
                Some(due) => {
                    let dt = due.saturating_sub(self.driver.now());
                    Duration::from_micros(dt.clamp(1_000, 50_000))
                }
                None => SHARD_IDLE_WAIT,
            };
            let first = match self.rx.recv_timeout(wait) {
                Ok(ev) => Some(ev),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            };
            let t0 = Instant::now();
            let mut stopped = false;
            if let Some(ev) = first {
                stopped = self.apply(ev);
            }
            // Batch: drain whatever arrived while we slept or applied, then
            // pay a single pump pass for the whole burst.
            while !stopped {
                match self.rx.try_recv() {
                    Ok(ev) => stopped = self.apply(ev),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        stopped = true;
                        break;
                    }
                }
            }
            self.driver.pump();
            self.sweep_cancelled();
            self.dispatch();
            self.publish();
            let busy = t0.elapsed().as_micros() as u64;
            self.shard.snap.busy_us.fetch_add(busy, Ordering::Relaxed);
            if stopped {
                break;
            }
        }
        self.drain();
    }

    /// Apply one event without pumping. Returns true on `Stop`.
    fn apply(&mut self, ev: ShardEvent) -> bool {
        match ev {
            ShardEvent::Submit(item) => {
                self.admit(item);
            }
            ShardEvent::SubmitMany(batch) => {
                for item in batch {
                    self.admit(item);
                }
            }
            ShardEvent::Done { id } => {
                self.driver.work_done_batched(id);
            }
            ShardEvent::Failed { id, item, endpoint, err } => {
                self.server_lost_local(&endpoint);
                match self.driver.work_failed_batched(id) {
                    Recovery::Retrying { backoff, .. } => {
                        if let Some(st) = self.st() {
                            st.retries.fetch_add(1, Ordering::Relaxed);
                            st.retry_backoff.record(Duration::from_micros(backoff));
                        }
                        // Back into the queue under the same task id (the
                        // retry's Start finds the waiting client), so the
                        // admission gate re-opens a slot for it. Plain add,
                        // not capped: the request was admitted once already
                        // and must not be shed.
                        self.shard.gate.fetch_add(1, Ordering::AcqRel);
                        self.items.insert(id, item);
                    }
                    Recovery::Quarantined { .. } => {
                        if let Some(st) = self.st() {
                            st.errors.fetch_add(1, Ordering::Relaxed);
                            st.quarantined.fetch_add(1, Ordering::Relaxed);
                        }
                        self.requests_served.fetch_add(1, Ordering::Relaxed);
                        item.resolve(Err(err));
                    }
                }
            }
            ShardEvent::WorkerUp { endpoint } => {
                self.server_up_local(&endpoint);
            }
            ShardEvent::WorkerLost { endpoint } => {
                self.server_lost_local(&endpoint);
            }
            ShardEvent::Poke => {}
            ShardEvent::Stop => return true,
        }
        false
    }

    /// Enter one admitted item into the scheduler (or drop it if the
    /// client already gave up — it then never enters the core).
    fn admit(&mut self, item: Arc<PendingEval>) {
        if item.is_cancelled() {
            self.shard.gate_dec();
            if let Some(st) = self.st() {
                st.cancelled.fetch_add(1, Ordering::Relaxed);
            }
            return;
        }
        let id = self.driver.submit_batched(self.budget_us);
        self.items.insert(id, item);
    }

    fn server_up_local(&mut self, endpoint: &str) {
        if self.wid_of.contains_key(endpoint) {
            return;
        }
        let wid = self.next_wid;
        self.next_wid += 1;
        self.wid_of.insert(endpoint.to_string(), wid);
        self.ep_of.insert(wid, endpoint.to_string());
        self.driver.worker_up_batched(wid, 1);
    }

    fn server_lost_local(&mut self, endpoint: &str) -> bool {
        match self.wid_of.remove(endpoint) {
            Some(wid) => {
                self.ep_of.remove(&wid);
                self.driver.worker_lost_batched(wid);
                true
            }
            None => false,
        }
    }

    /// Purge client-abandoned items. Gated on the model's timed-out counter
    /// (SeqCst on both sides) so the no-timeout hot path never scans the
    /// items map.
    fn sweep_cancelled(&mut self) {
        let seen = match self.st() {
            Some(st) => st.timed_out.load(Ordering::SeqCst),
            None => return,
        };
        if seen == self.timeouts_seen {
            return;
        }
        self.timeouts_seen = seen;
        let given_up: Vec<TaskId> = self
            .items
            .iter()
            .filter(|(_, it)| it.is_cancelled())
            .map(|(&id, _)| id)
            .collect();
        for id in given_up {
            self.items.remove(&id);
            self.shard.gate_dec();
            if let Some(st) = self.st() {
                st.cancelled.fetch_add(1, Ordering::Relaxed);
            }
            self.driver.work_done_batched(id);
        }
        self.driver.pump();
    }

    /// Pull ready tasks off the driver, pair each with a lease from the
    /// registry, and hand the orders to forwarders — one targeted
    /// `notify_one` per order, never a plane-wide broadcast.
    fn dispatch(&mut self) {
        let mut ready_orders: Vec<WorkOrder> = Vec::new();
        while let Some((id, worker)) = self.driver.next_ready() {
            let Some(item) = self.items.get(&id).cloned() else {
                // Item already resolved (shutdown drain or cancellation
                // raced a late Start): free the synthetic capacity.
                self.driver.work_done_batched(id);
                continue;
            };
            if item.is_cancelled() {
                self.items.remove(&id);
                self.shard.gate_dec();
                if let Some(st) = self.st() {
                    st.cancelled.fetch_add(1, Ordering::Relaxed);
                }
                self.driver.work_done_batched(id);
                continue;
            }
            let bound = worker.and_then(|w| self.ep_of.get(&w).cloned());
            let lease = match bound {
                Some(ep) => match self.registry.acquire_endpoint(&ep) {
                    Some(l) => Some(l),
                    None if self.registry.state(&ep).is_none() => {
                        // Endpoint vanished (health check): withdraw the
                        // worker; the core re-places this task.
                        self.server_lost_local(&ep);
                        continue;
                    }
                    None => {
                        // Momentarily busy (another shard holds it, or its
                        // lease drop has not landed): retry on the next
                        // poke.
                        self.driver.requeue_ready((id, worker));
                        break;
                    }
                },
                // Core placed without a binding: any idle server.
                None => self.registry.acquire(&self.shard.model),
            };
            let Some(lease) = lease else {
                self.driver.requeue_ready((id, worker));
                break;
            };
            self.items.remove(&id);
            self.shard.gate_dec();
            if let Some(st) = self.st() {
                st.queue_wait.record(item.enqueued.elapsed());
            }
            self.shard.snap.dispatched.fetch_add(1, Ordering::Relaxed);
            ready_orders.push(WorkOrder { item, id, lease, shard: self.shard.index });
        }
        self.driver.pump();
        if !ready_orders.is_empty() {
            let mut q = self.shard.orders.lock().unwrap();
            for order in ready_orders {
                q.push_back(order);
                self.shard.snap.wakeups.fetch_add(1, Ordering::Relaxed);
                self.shard.orders_cv.notify_one();
            }
        }
    }

    /// Publish the lock-free snapshot for `/Stats` readers.
    fn publish(&mut self) {
        let snap = &self.shard.snap;
        snap.queued.store(self.shard.gate.load(Ordering::Acquire) as u64, Ordering::Relaxed);
        snap.workers.store(self.wid_of.len() as u64, Ordering::Relaxed);
        snap.ready.store(self.driver.ready_len() as u64, Ordering::Relaxed);
        snap.epoch.fetch_add(1, Ordering::Release);
    }

    /// Fail everything still pending at shutdown.
    fn drain(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok(ShardEvent::Submit(item)) => {
                    self.shard.gate_dec();
                    item.resolve(Err("balancer shutting down".into()));
                }
                Ok(ShardEvent::SubmitMany(batch)) => {
                    for item in batch {
                        self.shard.gate_dec();
                        item.resolve(Err("balancer shutting down".into()));
                    }
                }
                Ok(ShardEvent::Failed { item, .. }) => {
                    item.resolve(Err("balancer shutting down".into()));
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        for (_, item) in self.items.drain() {
            self.shard.gate_dec();
            item.resolve(Err("balancer shutting down".into()));
        }
        self.publish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::umbridge::ModelContract;

    fn contract() -> ModelContract {
        ModelContract { input_sizes: vec![1], output_sizes: vec![1] }
    }

    fn test_cfg(models: &[&str], spm: usize, cap: usize) -> PlaneConfig {
        PlaneConfig {
            models: models.iter().map(|m| m.to_string()).collect(),
            shards_per_model: spm,
            queue_capacity: cap,
            scheduler: LivePolicy::Fcfs,
            retry: RetryPolicy::default(),
            request_timeout: Duration::from_secs(5),
            persistent_servers: true,
        }
    }

    fn start_plane(cfg: PlaneConfig) -> (Arc<DispatchPlane>, Arc<Registry>) {
        let registry = Arc::new(Registry::new());
        let stats = Arc::new(BalancerStats::new(&cfg.models));
        let served = Arc::new(AtomicU64::new(0));
        let plane = DispatchPlane::start(cfg, registry.clone(), stats, served);
        (plane, registry)
    }

    fn wait_until(mut pred: impl FnMut() -> bool, what: &str) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !pred() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn fcfs_order_holds_within_a_shard() {
        let (plane, registry) = start_plane(test_cfg(&["m"], 1, 64));
        registry.register("s1", "m", &contract());
        plane.worker_up("s1", "m");
        wait_until(|| plane.workers_for("m") == 1, "worker announce");

        let mut items = Vec::new();
        for i in 0..6 {
            match plane.submit("m", format!("req-{i}")) {
                SubmitOutcome::Queued(it) => items.push(it),
                _ => panic!("submit {i} rejected"),
            }
        }
        // Single server: orders surface strictly one at a time, FCFS.
        for i in 0..6 {
            let deadline = Instant::now() + Duration::from_secs(5);
            let order = loop {
                if let Some(o) = plane.take_order(0, Duration::from_millis(50)) {
                    break o;
                }
                assert!(Instant::now() < deadline, "order {i} never surfaced");
            };
            assert_eq!(order.item().body(), format!("req-{i}"), "FCFS violated");
            plane.complete_order(order, Ok(format!("ok-{i}")));
        }
        for (i, it) in items.iter().enumerate() {
            let r = it
                .wait_deadline(Instant::now() + Duration::from_secs(2))
                .expect("resolved");
            assert_eq!(r.unwrap(), format!("ok-{i}"));
        }
        let counts = plane.counts_for("m");
        assert_eq!(counts.len(), 1);
        assert_eq!(counts[0].submitted, 6);
        assert_eq!(counts[0].dispatched, 6);
        assert_eq!(counts[0].served, 6);
        plane.shutdown();
    }

    #[test]
    fn closed_channel_submit_retracts_the_gate() {
        // Drive the closed-channel path directly: shut the plane down
        // (threads joined, receivers dropped), then clear the stop flag
        // so `submit` gets past the early return and races the dead
        // channel. The admission bump must be retracted — a leak here
        // would permanently eat shard capacity and skew the p2c depth
        // compare.
        let (plane, _registry) = start_plane(test_cfg(&["m"], 1, 4));
        plane.shutdown();
        plane.stop.store(false, Ordering::Release);
        let before = plane.shards[0].snap.submitted.load(Ordering::Relaxed);
        assert!(matches!(plane.submit("m", "x".into()), SubmitOutcome::Stopping));
        assert_eq!(plane.queue_len(), 0, "gate slot leaked on closed channel");
        assert_eq!(
            plane.shards[0].snap.submitted.load(Ordering::Relaxed),
            before,
            "phantom submitted count on closed channel"
        );
        // Batched path: same discipline, and the stranded handles resolve.
        let outs = plane.submit_many("m", vec!["a".into(), "b".into()]);
        assert_eq!(outs.len(), 2);
        assert!(outs.iter().all(|o| matches!(o, SubmitOutcome::Stopping)));
        assert_eq!(plane.queue_len(), 0, "gate slots leaked on batched path");
    }

    #[test]
    fn p2c_steers_submissions_away_from_the_deeper_shard() {
        let (plane, _registry) = start_plane(test_cfg(&["m"], 2, 64));
        let (start, count) = plane.shards_for("m").unwrap();
        assert_eq!(count, 2);
        // Pre-load shard `start` with synthetic depth: every subsequent
        // probe pair compares both shards (count == 2), so all new work
        // must land on the shallow one until the depths meet.
        plane.shards[start].gate.store(8, Ordering::Release);
        let mut items = Vec::new();
        for i in 0..6 {
            match plane.submit("m", format!("r{i}")) {
                SubmitOutcome::Queued(it) => items.push(it),
                _ => panic!("submit {i} rejected"),
            }
        }
        assert_eq!(plane.shards[start].gate.load(Ordering::Acquire), 8,
                   "deep shard took new work under p2c");
        assert_eq!(plane.shards[start + 1].gate.load(Ordering::Acquire), 6);
        plane.shards[start].gate.store(0, Ordering::Release);
        plane.shutdown();
    }

    #[test]
    fn submit_many_admits_in_one_batch_and_sheds_the_overflow() {
        let (plane, registry) = start_plane(test_cfg(&["m"], 1, 4));
        let outs = plane.submit_many("m", (0..6).map(|i| format!("req-{i}")).collect());
        assert_eq!(outs.len(), 6);
        assert_eq!(
            outs.iter().filter(|o| matches!(o, SubmitOutcome::Queued(_))).count(),
            4,
            "batch must admit exactly the shard's free capacity"
        );
        assert!(outs[4..].iter().all(|o| matches!(o, SubmitOutcome::Full)));
        assert_eq!(plane.queued_for("m"), 4);
        // The batch entered the scheduler in order: serve it FCFS.
        registry.register("s1", "m", &contract());
        plane.worker_up("s1", "m");
        for i in 0..4 {
            let deadline = Instant::now() + Duration::from_secs(5);
            let order = loop {
                if let Some(o) = plane.take_order(0, Duration::from_millis(50)) {
                    break o;
                }
                assert!(Instant::now() < deadline, "order {i} never surfaced");
            };
            assert_eq!(order.item().body(), format!("req-{i}"), "batch order lost");
            plane.complete_order(order, Ok(format!("ok-{i}")));
        }
        for (i, o) in outs.iter().take(4).enumerate() {
            let SubmitOutcome::Queued(it) = o else { unreachable!() };
            let r = it
                .wait_deadline(Instant::now() + Duration::from_secs(2))
                .expect("resolved");
            assert_eq!(r.unwrap(), format!("ok-{i}"));
        }
        assert_eq!(plane.counts_for("m")[0].submitted, 4);
        plane.shutdown();
    }

    #[test]
    fn full_shard_sheds_load() {
        let (plane, _registry) = start_plane(test_cfg(&["m"], 1, 2));
        // No workers: submissions pile up at the admission gate.
        let a = plane.submit("m", "a".into());
        let b = plane.submit("m", "b".into());
        assert!(matches!(a, SubmitOutcome::Queued(_)));
        assert!(matches!(b, SubmitOutcome::Queued(_)));
        assert!(matches!(plane.submit("m", "c".into()), SubmitOutcome::Full));
        assert!(matches!(plane.submit("nope", "d".into()), SubmitOutcome::UnknownModel));
        assert_eq!(plane.queued_for("m"), 2);
        plane.shutdown();
        // Shutdown resolves the stranded items as errors.
        if let SubmitOutcome::Queued(it) = a {
            let r = it.wait_deadline(Instant::now() + Duration::from_secs(2)).unwrap();
            assert!(r.is_err());
        }
    }

    #[test]
    fn workers_are_shared_across_a_models_shards() {
        let (plane, registry) = start_plane(test_cfg(&["m"], 2, 16));
        for i in 0..3 {
            let ep = format!("s{i}");
            registry.register(&ep, "m", &contract());
            plane.worker_up(&ep, "m");
        }
        // Every shard of the model sees the full worker set.
        wait_until(
            || plane.counts_for("m").iter().all(|c| c.workers == 3),
            "both shards see 3 workers",
        );
        assert_eq!(plane.workers_for("m"), 3);
        // Re-announcing is idempotent per shard.
        plane.worker_up("s0", "m");
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(plane.workers_for("m"), 3);
        plane.shutdown();
    }

    #[test]
    fn snapshot_totals_track_the_gate() {
        let (plane, registry) = start_plane(test_cfg(&["m"], 2, 8));
        registry.register("s1", "m", &contract());
        plane.worker_up("s1", "m");
        wait_until(|| plane.workers_for("m") == 1, "worker announce");
        let mut handles = Vec::new();
        for i in 0..4 {
            match plane.submit("m", format!("r{i}")) {
                SubmitOutcome::Queued(it) => handles.push(it),
                _ => panic!("submit rejected"),
            }
        }
        let (start, count) = plane.shards_for("m").unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut served = 0;
        while served < 4 {
            assert!(Instant::now() < deadline, "orders stalled at {served}/4");
            for shard in start..start + count {
                if let Some(order) = plane.take_order(shard, Duration::from_millis(20)) {
                    plane.complete_order(order, Ok("done".into()));
                    served += 1;
                }
            }
        }
        for it in &handles {
            let r = it.wait_deadline(Instant::now() + Duration::from_secs(2)).unwrap();
            assert!(r.is_ok());
        }
        wait_until(|| plane.queued_for("m") == 0, "gate drains to zero");
        let total_submitted: u64 = plane.counts_for("m").iter().map(|c| c.submitted).sum();
        let total_served: u64 = plane.counts_for("m").iter().map(|c| c.served).sum();
        assert_eq!(total_submitted, 4);
        assert_eq!(total_served, 4);
        // One targeted wakeup per dispatched order — no thundering herd.
        assert_eq!(plane.wakeups_total(), 4);
        plane.shutdown();
    }
}
