//! The paper's contribution: the UM-Bridge load balancer for classical
//! HPC systems (section II.C).
//!
//! The balancer is an intermediate proxy between parallel UQ clients and
//! a pool of model-server instances it spawns on demand through one of
//! two backends — per-job SLURM submission or HyperQueue-style tasks on a
//! bulk allocation — exactly the paper's architecture (Fig 1, bottom):
//!
//! * servers register by **port file** (the server writes `host:port` to
//!   a run directory; the balancer polls it, with an optional fsync-style
//!   "sync workaround" the paper needed on Hamilton8), or by direct
//!   network registration (the paper's proposed future work);
//! * on registration, the balancer issues the **preliminary jobs** the
//!   paper describes (Info, InputSizes, OutputSizes, ModelInfo, health) —
//!   "at least five additional jobs ... verifying the readiness of the
//!   model server";
//! * client requests are queued **first-come first-served** and forwarded
//!   to idle servers; servers are per-job (paper's measured config) or
//!   **persistent** (the paper's proposed optimisation, our extension).
//!
//! # Lifecycle
//!
//! [`start_live`] assembles the whole live stack (scheduler daemon,
//! backend, balancer front door) and returns a [`LiveStack`] whose
//! `shutdown` tears it down in dependency order: the balancer front
//! door first (it holds an `httpd::Server`, see that module's shutdown
//! contract), then the backend's model-server pool, then the scheduler
//! daemon.  Every `httpd::Server` spawned by a backend is bound in its
//! `ServerPool` and shut down explicitly when its job retires — handles
//! are never left to implicit drop order.

pub mod backend;
pub mod live;
pub mod portfile;
pub mod registry;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use std::collections::HashMap;

use crate::httpd::{Handler, HttpClient, Request, Response, Server};
use crate::json::{self, Value};
use crate::umbridge::HttpModel;

pub use backend::{Backend, HqBackend, SlurmBackend};
pub use live::{start_live, LiveStack};
pub use registry::{Registry, ServerState};

/// Balancer configuration.
#[derive(Clone)]
pub struct BalancerConfig {
    /// Model served (wire name).
    pub model_name: &'static str,
    /// Max simultaneous model servers.
    pub max_servers: usize,
    /// Reuse servers across evaluations (paper section VI future work);
    /// when false each server handles one evaluation then retires —
    /// the per-job configuration the paper measured.
    pub persistent_servers: bool,
    /// Poll interval for the port-file watcher.
    pub poll_interval: Duration,
}

impl Default for BalancerConfig {
    fn default() -> Self {
        BalancerConfig {
            model_name: crate::models::GP_NAME,
            max_servers: 2,
            persistent_servers: true,
            poll_interval: Duration::from_millis(5),
        }
    }
}

struct Queued {
    body: String,
    done: Mutex<Option<Result<String, String>>>,
    cv: Condvar,
}

/// The load balancer.
pub struct LoadBalancer {
    cfg: BalancerConfig,
    backend: Arc<dyn Backend>,
    registry: Arc<Registry>,
    queue: Arc<Mutex<VecDeque<Arc<Queued>>>>,
    queue_cv: Arc<Condvar>,
    stop: Arc<AtomicBool>,
    /// Stats.
    pub requests_served: Arc<AtomicU64>,
    pub registration_queries: Arc<AtomicU64>,
    front: Option<Server>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    watcher: Option<std::thread::JoinHandle<()>>,
}

impl LoadBalancer {
    /// Start the balancer: front-door HTTP server + dispatcher + port-file
    /// watcher.  `backend` owns server spawning.
    pub fn start(
        cfg: BalancerConfig,
        backend: Arc<dyn Backend>,
    ) -> Result<LoadBalancer> {
        let registry = Arc::new(Registry::new());
        let queue: Arc<Mutex<VecDeque<Arc<Queued>>>> =
            Arc::new(Mutex::new(VecDeque::new()));
        let queue_cv = Arc::new(Condvar::new());
        let stop = Arc::new(AtomicBool::new(false));
        let requests_served = Arc::new(AtomicU64::new(0));
        let registration_queries = Arc::new(AtomicU64::new(0));

        // Front door: an UM-Bridge-compatible HTTP surface.
        let q2 = queue.clone();
        let cv2 = queue_cv.clone();
        let model_name: &'static str = cfg.model_name;
        let handler: Handler = Arc::new(move |req: &Request| {
            front_handler(req, model_name, &q2, &cv2)
        });
        let front = Server::serve(0, handler)?;

        // Port-file watcher: registers servers as they come up.
        let watcher = {
            let registry = registry.clone();
            let backend = backend.clone();
            let stop = stop.clone();
            let poll = cfg.poll_interval;
            let regq = registration_queries.clone();
            let model: &'static str = cfg.model_name;
            std::thread::Builder::new()
                .name("lb-watch".into())
                .spawn(move || {
                    watcher_loop(registry, backend, stop, poll, regq, model)
                })?
        };

        // Dispatcher: FCFS queue -> idle servers.
        let dispatcher = {
            let registry = registry.clone();
            let backend = backend.clone();
            let queue = queue.clone();
            let queue_cv = queue_cv.clone();
            let stop = stop.clone();
            let served = requests_served.clone();
            let cfg2 = cfg.clone();
            std::thread::Builder::new()
                .name("lb-dispatch".into())
                .spawn(move || {
                    dispatch_loop(cfg2, registry, backend, queue, queue_cv,
                                  stop, served)
                })?
        };

        Ok(LoadBalancer {
            cfg,
            backend,
            registry,
            queue,
            queue_cv,
            stop,
            requests_served,
            registration_queries,
            front: Some(front),
            dispatcher: Some(dispatcher),
            watcher: Some(watcher),
        })
    }

    /// Front-door URL clients connect to.
    pub fn url(&self) -> String {
        self.front.as_ref().expect("running").url()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn queue_len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue_cv.notify_all();
        if let Some(mut f) = self.front.take() {
            f.shutdown();
        }
        if let Some(t) = self.dispatcher.take() {
            let _ = t.join();
        }
        if let Some(t) = self.watcher.take() {
            let _ = t.join();
        }
        self.backend.teardown();
    }
}

impl Drop for LoadBalancer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Front door: /Evaluate enqueues; metadata endpoints answer from the
/// model contract (resolved via the registry's first healthy server or
/// statically from the models module).
fn front_handler(
    req: &Request,
    model_name: &str,
    queue: &Mutex<VecDeque<Arc<Queued>>>,
    cv: &Condvar,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/Info") => Response::ok_json(json::write(&Value::obj(vec![
            ("protocolVersion", Value::num(1.0)),
            ("models", Value::arr(vec![Value::str(model_name)])),
        ]))),
        ("POST", "/Evaluate") => {
            let body = match req.body_str() {
                Ok(b) => b.to_string(),
                Err(e) => return Response::error(&format!("{e:#}")),
            };
            let item = Arc::new(Queued {
                body,
                done: Mutex::new(None),
                cv: Condvar::new(),
            });
            queue.lock().unwrap().push_back(item.clone());
            cv.notify_all();
            // Block until the dispatcher resolves it (proxy semantics).
            let mut done = item.done.lock().unwrap();
            while done.is_none() {
                let (d, _timeout) = item
                    .cv
                    .wait_timeout(done, Duration::from_secs(600))
                    .unwrap();
                done = d;
                if done.is_none() {
                    return Response::error("evaluation timed out");
                }
            }
            match done.take().unwrap() {
                Ok(body) => Response::ok_json(body),
                Err(e) => Response::error(&e),
            }
        }
        // Metadata endpoints are proxied statically: the balancer knows
        // the model contract after registration; for simplicity answer
        // from the well-known contracts.
        ("POST", "/InputSizes") => {
            Response::ok_json(json::write(&Value::obj(vec![(
                "inputSizes",
                Value::arr(
                    contract(model_name).0
                        .into_iter()
                        .map(|s| Value::num(s as f64))
                        .collect(),
                ),
            )])))
        }
        ("POST", "/OutputSizes") => {
            Response::ok_json(json::write(&Value::obj(vec![(
                "outputSizes",
                Value::arr(
                    contract(model_name).1
                        .into_iter()
                        .map(|s| Value::num(s as f64))
                        .collect(),
                ),
            )])))
        }
        ("POST", "/ModelInfo") => {
            Response::ok_json(json::write(&Value::obj(vec![(
                "support",
                Value::obj(vec![("Evaluate", Value::Bool(true))]),
            )])))
        }
        _ => Response::not_found(),
    }
}

/// Static model contracts (sizes) for the front door.
fn contract(name: &str) -> (Vec<usize>, Vec<usize>) {
    match name {
        crate::models::GP_NAME => (vec![7], vec![2, 2]),
        crate::models::GS2_NAME => (vec![7], vec![2, 1, 1]),
        crate::models::QOI_NAME => (vec![7], vec![1, 384]),
        crate::models::EIGEN_SMALL_NAME => (vec![1], vec![100, 1]),
        crate::models::EIGEN_LARGE_NAME => (vec![1], vec![256, 1]),
        _ => (vec![], vec![]),
    }
}

fn watcher_loop(
    registry: Arc<Registry>,
    backend: Arc<dyn Backend>,
    stop: Arc<AtomicBool>,
    poll: Duration,
    regq: Arc<AtomicU64>,
    model: &'static str,
) {
    let mut last_health = std::time::Instant::now();
    while !stop.load(Ordering::SeqCst) {
        for endpoint in backend.poll_new_servers() {
            // The paper's preliminary jobs: verify readiness and the
            // input/output contract before routing work (>=5 queries).
            match preliminary_checks(&endpoint, model) {
                Ok(queries) => {
                    regq.fetch_add(queries, Ordering::Relaxed);
                    registry.register(&endpoint);
                    crate::log_info!("balancer",
                                     "registered server {endpoint}");
                }
                Err(e) => {
                    crate::log_warn!("balancer",
                                     "server {endpoint} failed checks: {e:#}");
                }
            }
        }
        // Periodic health checks on registered servers (decoupled from
        // the port-file poll so idle servers are not hammered — perf
        // pass, EXPERIMENTS.md section Perf).
        if last_health.elapsed() >= Duration::from_millis(500) {
            last_health = std::time::Instant::now();
            for ep in registry.endpoints() {
                if registry.state(&ep) == Some(ServerState::Idle)
                    && !health_check(&ep)
                {
                    crate::log_warn!("balancer",
                                     "server {ep} unhealthy, dropping");
                    registry.remove(&ep);
                    backend.server_lost(&ep);
                }
            }
        }
        std::thread::sleep(poll);
    }
}

fn preliminary_checks(endpoint: &str, model: &str) -> Result<u64> {
    let mut m = HttpModel::connect(endpoint, model)?;
    let (_ver, names) = m.info()?; // 1
    if !names.iter().any(|n| n == model) {
        return Err(anyhow!("model '{model}' not served at {endpoint}"));
    }
    let ins = m.input_sizes()?; // 2
    let outs = m.output_sizes()?; // 3
    let _info = m.model_info()?; // 4
    let (want_in, want_out) = contract(model);
    if !want_in.is_empty() && (ins != want_in || outs != want_out) {
        return Err(anyhow!(
            "contract mismatch at {endpoint}: {ins:?}/{outs:?}"
        ));
    }
    let (_ver2, _names2) = m.info()?; // 5 — final readiness probe
    Ok(5)
}

fn health_check(endpoint: &str) -> bool {
    HttpModel::connect(endpoint, "x")
        .and_then(|mut m| m.info())
        .is_ok()
}

type ConnPool = Arc<Mutex<HashMap<String, Vec<HttpClient>>>>;

fn dispatch_loop(
    cfg: BalancerConfig,
    registry: Arc<Registry>,
    backend: Arc<dyn Backend>,
    queue: Arc<Mutex<VecDeque<Arc<Queued>>>>,
    queue_cv: Arc<Condvar>,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
) {
    // Persistent connections to model servers (perf pass: the forwarder
    // previously opened a fresh TCP connection per evaluation).
    let pool: ConnPool = Arc::new(Mutex::new(HashMap::new()));
    while !stop.load(Ordering::SeqCst) {
        // Ensure capacity: spawn servers while demand outstrips supply.
        let backlog = queue.lock().unwrap().len();
        let total = registry.total() + backend.spawns_in_flight();
        if backlog > 0 && total < cfg.max_servers {
            let want = (backlog - 0).min(cfg.max_servers - total);
            for _ in 0..want {
                backend.spawn_server();
            }
        }

        // Pop one request if a server is idle.
        let item = {
            let mut q = queue.lock().unwrap();
            if q.is_empty() {
                let (q2, _t) = queue_cv
                    .wait_timeout(q, Duration::from_millis(20))
                    .unwrap();
                drop(q2);
                continue;
            }
            match registry.acquire_idle() {
                Some(_ep) => q.pop_front(),
                None => {
                    // Wait for a release/registration to wake us rather
                    // than burning a fixed 1 ms poll (perf pass: cut
                    // balancer-added latency ~8x, see EXPERIMENTS.md).
                    let (q2, _t) = queue_cv
                        .wait_timeout(q, Duration::from_micros(200))
                        .unwrap();
                    drop(q2);
                    continue;
                }
            }
        };
        let Some(item) = item else { continue };
        // We acquired an endpoint above; fetch it again from the registry
        // bookkeeping (acquire_idle marked it Busy and returned it).
        let ep = registry.last_acquired().expect("acquired endpoint");

        let registry2 = registry.clone();
        let backend2 = backend.clone();
        let served2 = served.clone();
        let wake = queue_cv.clone();
        let pool2 = pool.clone();
        let persistent = cfg.persistent_servers;
        std::thread::Builder::new()
            .name("lb-fwd".into())
            .spawn(move || {
                let result = forward(&pool2, &ep, &item.body);
                let ok = result.is_ok();
                *item.done.lock().unwrap() = Some(result);
                item.cv.notify_all();
                served2.fetch_add(1, Ordering::Relaxed);
                if persistent && ok {
                    registry2.release(&ep);
                    wake.notify_all();
                } else {
                    // Per-job servers retire after one evaluation (the
                    // paper's measured configuration), and failed servers
                    // are dropped either way.
                    registry2.remove(&ep);
                    backend2.retire_server(&ep);
                }
            })
            .expect("spawn forwarder");
    }
}

fn forward(pool: &ConnPool, endpoint: &str, body: &str)
           -> Result<String, String> {
    let mut do_it = || -> Result<String> {
        let mut c = pool
            .lock()
            .unwrap()
            .get_mut(endpoint)
            .and_then(|v| v.pop())
            .map(Ok)
            .unwrap_or_else(|| HttpClient::connect(endpoint))?;
        let resp = c.request(&Request::post("/Evaluate", body))?;
        if resp.status != 200 {
            return Err(anyhow!("{}: {}", resp.status,
                               resp.body_str().unwrap_or("")));
        }
        let out = resp.body_str()?.to_string();
        // Return the connection to the pool for reuse.
        pool.lock()
            .unwrap()
            .entry(endpoint.to_string())
            .or_default()
            .push(c);
        Ok(out)
    };
    do_it().map_err(|e| format!("{e:#}"))
}
