//! The paper's contribution: the UM-Bridge load balancer for classical
//! HPC systems (section II.C), rearchitected as a multi-model,
//! high-concurrency serving plane.
//!
//! The balancer is an intermediate proxy between parallel UQ clients and
//! per-model pools of model-server instances it spawns on demand through
//! a scheduling backend — per-job SLURM submission or HyperQueue-style
//! tasks on a bulk allocation, exactly the paper's architecture (Fig 1,
//! bottom) — or through the in-process [`LocalBackend`] for tests and
//! benches:
//!
//! * servers register by **port file** (the server writes `host:port` to
//!   a run directory; the balancer polls it, with an optional fsync-style
//!   "sync workaround" the paper needed on Hamilton8), or by direct
//!   network registration (the paper's proposed future work);
//! * on registration, the balancer issues the **preliminary jobs** the
//!   paper describes (Info, InputSizes, OutputSizes, ModelInfo, health) —
//!   "at least five additional jobs ... verifying the readiness of the
//!   model server" — and **learns the model's contract** from them;
//!   there is no static contract table;
//! * client requests are routed by the UM-Bridge `name` field into
//!   **per-model bounded FCFS queues**; a full queue answers
//!   `503 Service Unavailable` + `Retry-After` instead of growing
//!   without bound;
//! * a **fixed pool of forwarder workers** drains the queues via condvar
//!   handoff (no polling, no per-evaluation thread spawn), leasing
//!   servers from the registry ([`registry::ServerLease`]: release on
//!   drop, retire on failure/per-job mode);
//! * queue-wait and forward-latency histograms plus per-model counters
//!   are exposed on `GET /Stats` (and via [`LoadBalancer::stats_json`]).
//!
//! # Lifecycle
//!
//! [`start_live`] assembles the whole live stack (scheduler daemon,
//! backend, balancer front door) and returns a [`LiveStack`] whose
//! `shutdown` tears it down in dependency order: the balancer front
//! door first (it holds an `httpd::Server`, see that module's shutdown
//! contract), then the forwarder pool and watcher, then the backend's
//! model-server pool, then the scheduler daemon.  Every `httpd::Server`
//! spawned by a backend is bound in its pool and shut down explicitly
//! when its job retires — handles are never left to implicit drop order.

pub mod backend;
pub mod live;
pub mod portfile;
pub mod registry;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::httpd::{Handler, HttpClient, Request, Response, Server};
use crate::json::{self, Value};
use crate::metrics::Histogram;
use crate::umbridge::{HttpModel, ModelContract};

pub use backend::{Backend, HqBackend, LocalBackend, ModelFactory,
                  SlurmBackend};
pub use live::{start_live, LiveStack};
pub use registry::{Registry, ServerLease, ServerState};

/// Balancer configuration.
#[derive(Clone)]
pub struct BalancerConfig {
    /// Models served through this front door (wire names).  Contracts
    /// are learned per model at server registration.
    pub models: Vec<String>,
    /// Max simultaneous servers **per model**.
    pub max_servers: usize,
    /// Reuse servers across evaluations (paper section VI future work);
    /// when false each server handles one evaluation then retires —
    /// the per-job configuration the paper measured.
    pub persistent_servers: bool,
    /// Poll interval for the port-file watcher.
    pub poll_interval: Duration,
    /// Bound on each per-model queue; beyond it /Evaluate answers
    /// 503 + Retry-After (backpressure instead of unbounded growth).
    pub queue_capacity: usize,
    /// Minimum forwarder worker-pool size.  The pool is sized to at
    /// least `models.len() * max_servers` — the lease capacity bounds
    /// concurrent forwards, so at that size one slow model can never
    /// starve another model's dispatch.
    pub forwarders: usize,
    /// How long a client may wait end-to-end before its request is
    /// cancelled (it is also skipped at dispatch if still queued).
    pub request_timeout: Duration,
    /// Spawn one server per model at startup so contracts are learned
    /// before the first evaluation arrives.
    pub warm_start: bool,
}

impl Default for BalancerConfig {
    fn default() -> Self {
        BalancerConfig {
            models: vec![crate::models::GP_NAME.to_string()],
            max_servers: 2,
            persistent_servers: true,
            poll_interval: Duration::from_millis(5),
            queue_capacity: 256,
            forwarders: 4,
            request_timeout: Duration::from_secs(600),
            warm_start: true,
        }
    }
}

/// Per-model serving counters + latency histograms.
pub struct ModelStats {
    pub served: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub cancelled: AtomicU64,
    pub timed_out: AtomicU64,
    pub queue_wait: Histogram,
    pub forward: Histogram,
}

impl ModelStats {
    fn new() -> ModelStats {
        ModelStats {
            served: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            timed_out: AtomicU64::new(0),
            queue_wait: Histogram::new(),
            forward: Histogram::new(),
        }
    }
}

/// All per-model stats, keyed by configured model (fixed at start, so
/// the hot path reads are lock-free).
pub struct BalancerStats {
    per_model: HashMap<String, ModelStats>,
}

impl BalancerStats {
    fn new(models: &[String]) -> BalancerStats {
        BalancerStats {
            per_model: models
                .iter()
                .map(|m| (m.clone(), ModelStats::new()))
                .collect(),
        }
    }

    pub fn model(&self, name: &str) -> Option<&ModelStats> {
        self.per_model.get(name)
    }
}

/// One queued /Evaluate awaiting dispatch.
struct Queued {
    model: String,
    body: String,
    enqueued: Instant,
    /// Set when the waiting client gave up; dispatch skips it instead
    /// of burning a server on a result nobody reads.
    cancelled: AtomicBool,
    done: Mutex<Option<Result<String, String>>>,
    cv: Condvar,
}

/// State shared by the front door, the forwarder pool and the watcher.
struct Shared {
    cfg: BalancerConfig,
    /// model -> bounded FCFS queue (keys fixed to cfg.models).
    queues: Mutex<HashMap<String, VecDeque<Arc<Queued>>>>,
    cv: Condvar,
    stop: AtomicBool,
    stats: BalancerStats,
    registry: Arc<Registry>,
    /// Persistent connections to model servers, pooled per endpoint.
    conn_pool: Mutex<HashMap<String, Vec<HttpClient>>>,
    requests_served: Arc<AtomicU64>,
}

impl Shared {
    /// Wake the forwarder pool.  The lock round-trip closes the race
    /// with a forwarder that checked the queues and is about to wait.
    fn wake(&self) {
        drop(self.queues.lock().unwrap());
        self.cv.notify_all();
    }

    fn stats_json(&self) -> Value {
        let q = self.queues.lock().unwrap();
        let models: Vec<Value> = self
            .cfg
            .models
            .iter()
            .map(|m| {
                let st = self.stats.model(m).expect("configured model stats");
                let load = |c: &AtomicU64| {
                    Value::num(c.load(Ordering::Relaxed) as f64)
                };
                Value::obj(vec![
                    ("name", Value::str(m)),
                    ("queued",
                     Value::num(q.get(m).map(|d| d.len()).unwrap_or(0) as f64)),
                    ("servers", Value::num(self.registry.count_for(m) as f64)),
                    ("idle", Value::num(self.registry.idle_for(m) as f64)),
                    ("served", load(&st.served)),
                    ("errors", load(&st.errors)),
                    ("rejected", load(&st.rejected)),
                    ("cancelled", load(&st.cancelled)),
                    ("timed_out", load(&st.timed_out)),
                    ("queue_wait", st.queue_wait.json()),
                    ("forward", st.forward.json()),
                ])
            })
            .collect();
        Value::obj(vec![
            ("models", Value::arr(models)),
            ("servers_total", Value::num(self.registry.total() as f64)),
            ("servers_registered_lifetime",
             Value::num(self.registry.registered_total() as f64)),
            ("requests_served",
             Value::num(self.requests_served.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// The load balancer.
pub struct LoadBalancer {
    shared: Arc<Shared>,
    backend: Arc<dyn Backend>,
    registry: Arc<Registry>,
    /// Stats.
    pub requests_served: Arc<AtomicU64>,
    pub registration_queries: Arc<AtomicU64>,
    front: Option<Server>,
    forwarders: Vec<std::thread::JoinHandle<()>>,
    watcher: Option<std::thread::JoinHandle<()>>,
}

impl LoadBalancer {
    /// Start the balancer: front-door HTTP server + forwarder pool +
    /// port-file watcher.  `backend` owns server spawning.
    pub fn start(
        cfg: BalancerConfig,
        backend: Arc<dyn Backend>,
    ) -> Result<LoadBalancer> {
        if cfg.models.is_empty() {
            return Err(anyhow!("balancer needs at least one model"));
        }
        let registry = Arc::new(Registry::new());
        let requests_served = Arc::new(AtomicU64::new(0));
        let registration_queries = Arc::new(AtomicU64::new(0));

        let queues: HashMap<String, VecDeque<Arc<Queued>>> = cfg
            .models
            .iter()
            .map(|m| (m.clone(), VecDeque::new()))
            .collect();
        let shared = Arc::new(Shared {
            stats: BalancerStats::new(&cfg.models),
            cfg: cfg.clone(),
            queues: Mutex::new(queues),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            registry: registry.clone(),
            conn_pool: Mutex::new(HashMap::new()),
            requests_served: requests_served.clone(),
        });

        // Registry transitions (register/release/retire/remove) wake the
        // forwarder pool — dispatch is event-driven end to end.
        let weak = Arc::downgrade(&shared);
        registry.set_waker(Arc::new(move || {
            if let Some(s) = weak.upgrade() {
                s.wake();
            }
        }));

        // Front door: an UM-Bridge-compatible HTTP surface.
        let s2 = shared.clone();
        let handler: Handler =
            Arc::new(move |req: &Request| front_handler(req, &s2));
        let front = Server::serve(0, handler)?;

        // Warm start: learn contracts before the first client arrives.
        if cfg.warm_start {
            for m in &cfg.models {
                backend.spawn_server(m);
            }
        }

        // Port-file watcher: registers servers as they come up.
        let watcher = {
            let shared = shared.clone();
            let backend = backend.clone();
            let regq = registration_queries.clone();
            std::thread::Builder::new()
                .name("lb-watch".into())
                .spawn(move || watcher_loop(shared, backend, regq))?
        };

        // Fixed forwarder pool: per-model queues -> leased servers.
        // Sized to the total lease capacity so every model's full
        // server pool can forward concurrently (no cross-model
        // starvation by slow evaluations).
        let pool_size = cfg
            .forwarders
            .max(cfg.models.len() * cfg.max_servers)
            .max(1);
        let mut forwarders = Vec::with_capacity(pool_size);
        for i in 0..pool_size {
            let shared = shared.clone();
            let backend = backend.clone();
            forwarders.push(
                std::thread::Builder::new()
                    .name(format!("lb-fwd-{i}"))
                    .spawn(move || forwarder_loop(shared, backend))?,
            );
        }

        Ok(LoadBalancer {
            shared,
            backend,
            registry,
            requests_served,
            registration_queries,
            front: Some(front),
            forwarders,
            watcher: Some(watcher),
        })
    }

    /// Front-door URL clients connect to.
    pub fn url(&self) -> String {
        self.front.as_ref().expect("running").url()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Total queued requests across all models.
    pub fn queue_len(&self) -> usize {
        self.shared
            .queues
            .lock()
            .unwrap()
            .values()
            .map(|d| d.len())
            .sum()
    }

    /// Per-model serving counters and latency histograms.
    pub fn stats(&self) -> &BalancerStats {
        &self.shared.stats
    }

    /// The `/Stats` document (for bench/experiment JSON reports).
    pub fn stats_json(&self) -> Value {
        self.shared.stats_json()
    }

    /// Stop the balancer.  Blocks until the forwarder pool drains; the
    /// backend is torn down first so no new work starts, but a forward
    /// already inside a model evaluation completes (the model servers
    /// cannot abort mid-compute), so shutdown latency is bounded by the
    /// longest in-flight evaluation.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake();
        if let Some(mut f) = self.front.take() {
            f.shutdown();
        }
        // Tear the server pool down before joining the forwarders:
        // anything blocked at the connection level unblocks, and all
        // backend entry points are safe to call from draining workers
        // after teardown (idempotent).
        self.backend.teardown();
        for t in self.forwarders.drain(..) {
            let _ = t.join();
        }
        if let Some(t) = self.watcher.take() {
            let _ = t.join();
        }
        // Fail anything still queued so blocked clients return promptly.
        let drained: Vec<Arc<Queued>> = {
            let mut q = self.shared.queues.lock().unwrap();
            q.values_mut().flat_map(|dq| dq.drain(..)).collect()
        };
        for item in drained {
            *item.done.lock().unwrap() =
                Some(Err("balancer shutting down".to_string()));
            item.cv.notify_all();
        }
    }
}

impl Drop for LoadBalancer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Front door
// ---------------------------------------------------------------------------

/// Routes by the UM-Bridge `name` field; metadata endpoints answer from
/// the contracts learned at registration.
fn front_handler(req: &Request, shared: &Arc<Shared>) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/Info") => {
            // All models behind this front door.  (Registration only
            // admits configured models, so the registry can never know
            // more names than the config.)
            let mut names: Vec<String> = shared.cfg.models.clone();
            names.sort();
            Response::ok_json(json::write(&Value::obj(vec![
                ("protocolVersion", Value::num(1.0)),
                ("models",
                 Value::arr(names.iter().map(|n| Value::str(n)).collect())),
            ])))
        }
        ("GET", "/Stats") => Response::ok_json(json::write(&shared.stats_json())),
        ("POST", "/Evaluate") => evaluate_handler(req, shared),
        ("POST", "/InputSizes") => {
            match resolve_contract(req, shared) {
                Ok(c) => Response::ok_json(json::write(&Value::obj(vec![(
                    "inputSizes",
                    Value::arr(
                        c.input_sizes
                            .into_iter()
                            .map(|s| Value::num(s as f64))
                            .collect(),
                    ),
                )]))),
                Err(resp) => resp,
            }
        }
        ("POST", "/OutputSizes") => {
            match resolve_contract(req, shared) {
                Ok(c) => Response::ok_json(json::write(&Value::obj(vec![(
                    "outputSizes",
                    Value::arr(
                        c.output_sizes
                            .into_iter()
                            .map(|s| Value::num(s as f64))
                            .collect(),
                    ),
                )]))),
                Err(resp) => resp,
            }
        }
        ("POST", "/ModelInfo") => {
            match request_model(req, shared) {
                Ok(_) => Response::ok_json(json::write(&Value::obj(vec![(
                    "support",
                    Value::obj(vec![("Evaluate", Value::Bool(true))]),
                )]))),
                Err(resp) => resp,
            }
        }
        _ => Response::not_found(),
    }
}

/// Extract and validate the request's model name (UM-Bridge `name`
/// field; a single-model balancer accepts requests without one).
///
/// This parses the body — the unavoidable cost of routing by a body
/// field (the model server parses its own copy again on the far side
/// of the HTTP hop).
fn request_model(req: &Request, shared: &Shared) -> Result<String, Response> {
    let name = req
        .body_str()
        .ok()
        .and_then(|b| json::parse(b).ok())
        .and_then(|v| v.get("name").and_then(|n| n.as_str()).map(String::from));
    let name = match name {
        Some(n) => n,
        None if shared.cfg.models.len() == 1 => shared.cfg.models[0].clone(),
        None => return Err(Response::error("missing 'name'")),
    };
    if !shared.cfg.models.iter().any(|m| *m == name) {
        return Err(Response::error(&format!("unknown model '{name}'")));
    }
    Ok(name)
}

/// Look up the learned contract; before any server of that model has
/// registered the front door cannot know the sizes yet and says so with
/// a retryable 503.
fn resolve_contract(
    req: &Request,
    shared: &Shared,
) -> Result<ModelContract, Response> {
    let name = request_model(req, shared)?;
    shared.registry.contract(&name).ok_or_else(|| {
        Response::unavailable(
            &format!("model '{name}' has no registered server yet"),
            1,
        )
    })
}

/// Enqueue an /Evaluate into its model's bounded queue and block until
/// a forwarder resolves it (proxy semantics) or the deadline passes.
fn evaluate_handler(req: &Request, shared: &Arc<Shared>) -> Response {
    let body = match req.body_str() {
        Ok(b) => b.to_string(),
        Err(e) => return Response::error(&format!("{e:#}")),
    };
    let name = match request_model(req, shared) {
        Ok(n) => n,
        Err(resp) => return resp,
    };

    let item = Arc::new(Queued {
        model: name.clone(),
        body,
        enqueued: Instant::now(),
        cancelled: AtomicBool::new(false),
        done: Mutex::new(None),
        cv: Condvar::new(),
    });
    {
        let mut q = shared.queues.lock().unwrap();
        if shared.stop.load(Ordering::SeqCst) {
            return Response::error("balancer shutting down");
        }
        let dq = q.get_mut(&name).expect("configured model queue");
        if dq.len() >= shared.cfg.queue_capacity {
            if let Some(st) = shared.stats.model(&name) {
                st.rejected.fetch_add(1, Ordering::Relaxed);
            }
            return Response::unavailable(
                &format!("queue full for model '{name}'"),
                1,
            );
        }
        dq.push_back(item.clone());
        shared.cv.notify_all();
    }

    // Block until resolved, looping on the condition (spurious wakeups
    // must not be reported as timeouts) and honoring the real deadline.
    let deadline = item.enqueued + shared.cfg.request_timeout;
    let mut done = item.done.lock().unwrap();
    loop {
        if let Some(result) = done.take() {
            return match result {
                Ok(body) => Response::ok_json(body),
                Err(e) => Response::error(&e),
            };
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (g, _timeout) = item.cv.wait_timeout(done, deadline - now).unwrap();
        done = g;
    }
    // Deadline passed: cancel so a forwarder doesn't burn a server on a
    // result nobody reads.
    item.cancelled.store(true, Ordering::SeqCst);
    if let Some(st) = shared.stats.model(&name) {
        st.timed_out.fetch_add(1, Ordering::Relaxed);
    }
    Response::text(504, "evaluation timed out")
}

// ---------------------------------------------------------------------------
// Watcher
// ---------------------------------------------------------------------------

/// Per-model spawn-governor state (watcher-local): observed in-flight
/// spawn count and lifetime registrations, plus the failure backoff.
struct GovState {
    fails: u32,
    until: Instant,
    last_pending: usize,
    last_reg: u64,
}

fn watcher_loop(
    shared: Arc<Shared>,
    backend: Arc<dyn Backend>,
    regq: Arc<AtomicU64>,
) {
    let mut last_health = Instant::now();
    // Spawn governor: per-model exponential backoff while spawn
    // attempts keep failing, so a broken model retries at a bounded
    // rate instead of every poll tick.  A failure is *observed*, not
    // assumed: in-flight spawn count dropped without a registration.
    // Healthy scale-up (even bursty) is never delayed.
    let mut governor: HashMap<String, GovState> = HashMap::new();
    while !shared.stop.load(Ordering::SeqCst) {
        for endpoint in backend.poll_new_servers() {
            // The paper's preliminary jobs: verify readiness and learn
            // the input/output contract before routing work (>=5
            // queries per server).  Registration wakes the forwarders
            // through the registry waker.
            match preliminary_checks(&endpoint, &shared) {
                Ok(queries) => {
                    regq.fetch_add(queries, Ordering::Relaxed);
                    crate::log_info!("balancer",
                                     "registered server {endpoint}");
                }
                Err(e) => {
                    crate::log_warn!("balancer",
                                     "server {endpoint} failed checks: {e:#}");
                    backend.server_lost(&endpoint);
                }
            }
        }
        // Backstop drain of lease-retired endpoints (the forwarders
        // drain their own; this covers the last one before idle).
        drain_retired(&shared, &backend);
        // Capacity management: spawn while demand outstrips supply.
        // Single-threaded here (no double-spawn race) and outside the
        // queues lock, so a slow backend never stalls the front door
        // or the forwarders.
        let backlogs: Vec<(String, usize)> = {
            let q = shared.queues.lock().unwrap();
            shared
                .cfg
                .models
                .iter()
                .map(|m| (m.clone(), q.get(m).map(|d| d.len()).unwrap_or(0)))
                .collect()
        };
        for (model, mut backlog) in backlogs {
            let pending = backend.spawns_in_flight(&model);
            // A warm-start model with no server, no spawn in flight and
            // no learned contract needs a server even with an empty
            // queue — metadata-first clients only ever retry /InputSizes
            // against its 503, so Evaluate backlog alone would never
            // re-arm a failed warm spawn.
            if backlog == 0
                && shared.cfg.warm_start
                && pending == 0
                && shared.registry.count_for(&model) == 0
                && shared.registry.contract(&model).is_none()
            {
                backlog = 1;
            }
            if backlog == 0 {
                continue;
            }
            let now = Instant::now();
            let reg_now = shared.registry.registered_for(&model);
            let st = governor.entry(model.clone()).or_insert(GovState {
                fails: 0,
                until: now,
                last_pending: 0,
                last_reg: 0,
            });
            if reg_now > st.last_reg {
                // A spawn succeeded since last tick: clear the backoff.
                st.fails = 0;
                st.until = now;
            } else if pending < st.last_pending {
                // Spawn slots released without a registration: those
                // spawns failed.  Widen the retry window (50 ms → ~13 s).
                st.fails = (st.fails + 1).min(8);
                st.until = now + Duration::from_millis(50)
                    * (1u32 << st.fails);
            }
            st.last_reg = reg_now;
            st.last_pending = pending;
            if now < st.until {
                continue;
            }
            let supply = shared.registry.count_for(&model) + pending;
            if supply < shared.cfg.max_servers {
                // Demand not already covered by idle servers or spawns
                // still in flight.
                let covered = pending + shared.registry.idle_for(&model);
                let want = backlog
                    .saturating_sub(covered)
                    .min(shared.cfg.max_servers - supply);
                for _ in 0..want {
                    backend.spawn_server(&model);
                }
                if want > 0 {
                    let after = backend.spawns_in_flight(&model);
                    if after <= pending {
                        // Nothing went in flight: the spawns failed
                        // synchronously (e.g. model build error).
                        st.fails = (st.fails + 1).min(8);
                        st.until = now + Duration::from_millis(50)
                            * (1u32 << st.fails);
                    }
                    st.last_pending = after;
                }
            }
        }
        // Periodic health checks on registered servers (decoupled from
        // the port-file poll so idle servers are not hammered — perf
        // pass, EXPERIMENTS.md section Perf).
        if last_health.elapsed() >= Duration::from_millis(500) {
            last_health = Instant::now();
            for ep in shared.registry.endpoints() {
                if shared.registry.state(&ep) == Some(ServerState::Idle)
                    && !health_check(&ep)
                {
                    crate::log_warn!("balancer",
                                     "server {ep} unhealthy, dropping");
                    shared.registry.remove(&ep);
                    shared.conn_pool.lock().unwrap().remove(&ep);
                    backend.server_lost(&ep);
                }
            }
        }
        std::thread::sleep(shared.cfg.poll_interval);
    }
}

/// Hand lease-retired endpoints to the backend and drop their pooled
/// connections.
fn drain_retired(shared: &Shared, backend: &Arc<dyn Backend>) {
    for ep in shared.registry.take_retired() {
        shared.conn_pool.lock().unwrap().remove(&ep);
        backend.retire_server(&ep);
    }
}

/// The paper's five preliminary queries, now also the contract-learning
/// step: /Info names the model(s) the server hosts; sizes and ModelInfo
/// are fetched for the first configured one (each server hosts one
/// model), verified against any already-registered contract, and stored
/// in the registry.
fn preliminary_checks(endpoint: &str, shared: &Shared) -> Result<u64> {
    let mut m = HttpModel::connect(endpoint, "")?;
    let (_ver, names) = m.info()?; // 1
    let mut queries = 1u64;
    let Some(name) = names
        .iter()
        .find(|n| shared.cfg.models.iter().any(|c| c == *n))
        .cloned()
    else {
        return Err(anyhow!(
            "{endpoint} serves none of the configured models ({names:?})"
        ));
    };
    m.model_name = name.clone();
    let contract = m.fetch_contract()?; // 2, 3
    let _info = m.model_info()?; // 4
    queries += 3;
    if let Some(existing) = shared.registry.contract(&name) {
        if existing != contract {
            return Err(anyhow!(
                "contract mismatch for '{name}' at {endpoint}: \
                 {:?}/{:?} vs registered {:?}/{:?}",
                contract.input_sizes, contract.output_sizes,
                existing.input_sizes, existing.output_sizes
            ));
        }
    }
    let (_ver2, _names2) = m.info()?; // 5 — final readiness probe
    queries += 1;
    shared.registry.register(endpoint, &name, &contract);
    Ok(queries)
}

fn health_check(endpoint: &str) -> bool {
    HttpModel::connect(endpoint, "x")
        .and_then(|mut m| m.info())
        .is_ok()
}

// ---------------------------------------------------------------------------
// Forwarder pool
// ---------------------------------------------------------------------------

/// One worker of the fixed forwarder pool: waits for (queued item,
/// idle server) pairs via condvar handoff, forwards over a pooled
/// connection, and resolves the waiting client.  (Capacity scale-up
/// lives in the watcher, single-threaded and lock-free with respect to
/// the queues.)
fn forwarder_loop(shared: Arc<Shared>, backend: Arc<dyn Backend>) {
    loop {
        // (queued item, server lease) picked under the queues lock.
        let mut job = None;
        {
            let mut q = shared.queues.lock().unwrap();
            if shared.stop.load(Ordering::SeqCst) {
                return;
            }
            for model in &shared.cfg.models {
                let Some(dq) = q.get_mut(model) else { continue };
                // Skip work whose client already gave up.
                while dq
                    .front()
                    .map_or(false, |it| it.cancelled.load(Ordering::SeqCst))
                {
                    let it = dq.pop_front().unwrap();
                    if let Some(st) = shared.stats.model(&it.model) {
                        st.cancelled.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if dq.is_empty() {
                    continue;
                }
                if let Some(lease) = shared.registry.acquire(model) {
                    job = Some((dq.pop_front().unwrap(), lease));
                    break;
                }
            }
            if job.is_none() {
                // Condvar handoff; the timeout is only a liveness
                // backstop (stop flag, slow backends), not a poll loop.
                let (_q, _t) = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                continue;
            }
        }
        let (item, mut lease) = job.expect("checked above");
        if item.cancelled.load(Ordering::SeqCst) {
            // Cancelled between selection and here; lease releases.
            if let Some(st) = shared.stats.model(&item.model) {
                st.cancelled.fetch_add(1, Ordering::Relaxed);
            }
            drop(lease);
            continue;
        }
        let st = shared.stats.model(&item.model);
        if let Some(st) = st {
            st.queue_wait.record(item.enqueued.elapsed());
        }
        let t0 = Instant::now();
        let result = forward(&shared.conn_pool, lease.endpoint(), &item.body);
        let ok = result.is_ok();
        if let Some(st) = st {
            st.forward.record(t0.elapsed());
            if ok {
                st.served.fetch_add(1, Ordering::Relaxed);
            } else {
                st.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        shared.requests_served.fetch_add(1, Ordering::Relaxed);
        *item.done.lock().unwrap() = Some(result);
        item.cv.notify_all();
        // Per-job servers retire after one evaluation (the paper's
        // measured configuration); failed forwards retire either way.
        if !shared.cfg.persistent_servers || !ok {
            lease.mark_retire();
        }
        drop(lease); // release or retire; wakes the pool via the waker
        drain_retired(&shared, &backend);
    }
}

fn forward(
    pool: &Mutex<HashMap<String, Vec<HttpClient>>>,
    endpoint: &str,
    body: &str,
) -> Result<String, String> {
    let mut do_it = || -> Result<String> {
        let mut c = pool
            .lock()
            .unwrap()
            .get_mut(endpoint)
            .and_then(|v| v.pop())
            .map(Ok)
            .unwrap_or_else(|| HttpClient::connect(endpoint))?;
        let resp = c.request(&Request::post("/Evaluate", body))?;
        if resp.status != 200 {
            return Err(anyhow!("{}: {}", resp.status,
                               resp.body_str().unwrap_or("")));
        }
        let out = resp.body_str()?.to_string();
        // Return the connection to the pool for reuse.
        pool.lock()
            .unwrap()
            .entry(endpoint.to_string())
            .or_default()
            .push(c);
        Ok(out)
    };
    do_it().map_err(|e| format!("{e:#}"))
}
