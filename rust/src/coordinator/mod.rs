//! The paper's contribution: the UM-Bridge load balancer for classical
//! HPC systems (section II.C), rearchitected as a multi-model,
//! high-concurrency serving plane **dispatching through the same
//! [`SchedulerCore`](crate::sched::SchedulerCore) seam the campaigns
//! use**.
//!
//! The balancer is an intermediate proxy between parallel UQ clients and
//! per-model pools of model-server instances it spawns on demand through
//! a scheduling backend — per-job SLURM submission or HyperQueue-style
//! tasks on a bulk allocation, exactly the paper's architecture (Fig 1,
//! bottom) — or through the in-process [`LocalBackend`] for tests and
//! benches:
//!
//! * servers register by **port file** (the server writes `host:port` to
//!   a run directory; the balancer polls it, with an optional fsync-style
//!   "sync workaround" the paper needed on Hamilton8), or by direct
//!   network registration (the paper's proposed future work);
//! * on registration, the balancer issues the **preliminary jobs** the
//!   paper describes (Info, InputSizes, OutputSizes, ModelInfo, health) —
//!   "at least five additional jobs ... verifying the readiness of the
//!   model server" — and **learns the model's contract** from them;
//!   there is no static contract table;
//! * client requests are routed by the UM-Bridge `name` field into a
//!   per-model **real-time scheduler core**
//!   ([`sched::realtime`](crate::sched::realtime)): an `/Evaluate`
//!   becomes a `Submit` event, a server registration a worker
//!   `CapacityChange`, a finished forward a `WorkDone` — the dispatch
//!   *policy* is pluggable ([`LivePolicy`]: `fcfs` | `worksteal` |
//!   `edf`) and identical to the cores the campaign plane ablates;
//! * a full queue answers `503 Service Unavailable` with a `Retry-After`
//!   derived from the live queue-wait histogram's p50 (clamped to
//!   [1, 30] s) instead of growing without bound;
//! * dispatch runs on a **sharded event plane** ([`shard`]): each model
//!   owns one or more dispatch shards (`--shards-per-model`), each with
//!   its own scheduler core and a dedicated event thread fed by an MPSC
//!   channel, so an `/Evaluate` submit is one atomic admission-gate
//!   bump plus one channel push — no cross-model (or shared dispatch)
//!   lock anywhere on the hot path, and `/Stats` reads epoch-stamped
//!   per-shard snapshots without touching a shard thread;
//! * a **fixed pool of forwarder workers**, each bound to one shard,
//!   consumes dispatched work orders behind targeted per-shard
//!   `notify_one` wakeups (no thundering herd), leasing exactly the
//!   server the policy placed the work on ([`registry::ServerLease`]:
//!   release on drop, retire on failure/per-job mode);
//! * queue-wait and forward-latency histograms plus per-model counters
//!   are exposed on `GET /Stats` (and via [`LoadBalancer::stats_json`]).
//!
//! # Lifecycle
//!
//! [`start_live`] assembles the whole live stack (scheduler daemon,
//! backend, balancer front door) and returns a [`LiveStack`] whose
//! `shutdown` tears it down in dependency order: the balancer front
//! door first (it holds an `httpd::Server`, see that module's shutdown
//! contract), then the forwarder pool and watcher, then the backend's
//! model-server pool, then the scheduler daemon.  Every `httpd::Server`
//! spawned by a backend is bound in its pool and shut down explicitly
//! when its job retires — handles are never left to implicit drop order.

pub mod backend;
pub mod live;
pub mod portfile;
pub mod registry;
pub mod shard;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::httpd::{Handler, HttpClient, Request, Response, Server};
use crate::json::{self, Value};
use crate::metrics::Histogram;
use crate::sched::realtime::RetryPolicy;
use crate::sched::LivePolicy;
use crate::umbridge::{HttpModel, ModelContract};

pub use backend::{Backend, HqBackend, LocalBackend, ModelFactory,
                  SlurmBackend};
pub use live::{start_live, start_live_tuned, LiveStack};
pub use registry::{Registry, ServerLease, ServerState};
pub use shard::{DispatchPlane, ForwardError, PendingEval, PlaneConfig,
                ShardCounts, ShardSnapshot, SubmitOutcome, WorkOrder};

/// Balancer configuration.
#[derive(Clone)]
pub struct BalancerConfig {
    /// Models served through this front door (wire names).  Contracts
    /// are learned per model at server registration.
    pub models: Vec<String>,
    /// Max simultaneous servers **per model**.
    pub max_servers: usize,
    /// Reuse servers across evaluations (paper section VI future work);
    /// when false each server handles one evaluation then retires —
    /// the per-job configuration the paper measured.
    pub persistent_servers: bool,
    /// Poll interval for the port-file watcher.
    pub poll_interval: Duration,
    /// Bound on each model's undispatched queue; beyond it /Evaluate
    /// answers 503 + Retry-After (backpressure instead of unbounded
    /// growth).
    pub queue_capacity: usize,
    /// Dispatch shards per model (>= 1).  Requests round-robin across a
    /// model's shards, each with its own scheduler core, admission gate
    /// and event thread, so submission/dispatch/completion for a hot
    /// model scale across cores instead of serializing on one thread.
    /// `queue_capacity` is split evenly across a model's shards.
    pub shards_per_model: usize,
    /// Minimum forwarder worker-pool size.  The pool is sized to at
    /// least `models.len() * max_servers` — the lease capacity bounds
    /// concurrent forwards, so at that size one slow model can never
    /// starve another model's dispatch.
    pub forwarders: usize,
    /// How long a client may wait end-to-end before its request is
    /// cancelled (it is also skipped at dispatch if still queued).  On
    /// the EDF core this budget is the request's deadline.
    pub request_timeout: Duration,
    /// Spawn one server per model at startup so contracts are learned
    /// before the first evaluation arrives.
    pub warm_start: bool,
    /// Which scheduler core dispatches each model's queue
    /// (`fcfs` | `worksteal` | `edf`; default `fcfs` — the balancer's
    /// classic per-model FCFS discipline).
    pub scheduler: LivePolicy,
    /// Retry budget + backoff for evaluations whose forward dies with
    /// its server.  The default (2 attempts) retries once on a
    /// replacement server before the error surfaces to the client.
    pub retry: RetryPolicy,
    /// Consecutive health-probe failures before a registered server is
    /// evicted.  A single failed probe (GC pause, dropped packet) must
    /// not flap a healthy server out of the fleet.
    pub probe_eviction_k: u32,
    /// Circuit breaker: when a model's registered-server count falls
    /// below this fraction of the highest count it has reached,
    /// /Evaluate sheds load with 503 + Retry-After instead of queueing
    /// work the collapsed fleet cannot drain.  `0.0` disables the
    /// breaker (the default).
    pub breaker_floor: f64,
}

impl Default for BalancerConfig {
    fn default() -> Self {
        BalancerConfig {
            models: vec![crate::models::GP_NAME.to_string()],
            max_servers: 2,
            persistent_servers: true,
            poll_interval: Duration::from_millis(5),
            queue_capacity: 256,
            shards_per_model: 1,
            forwarders: 4,
            request_timeout: Duration::from_secs(600),
            warm_start: true,
            scheduler: LivePolicy::Fcfs,
            retry: RetryPolicy::default(),
            probe_eviction_k: 3,
            breaker_floor: 0.0,
        }
    }
}

/// Per-model serving counters + latency histograms.
pub struct ModelStats {
    pub served: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub cancelled: AtomicU64,
    pub timed_out: AtomicU64,
    /// Forwards that failed with their lease and were re-dispatched on
    /// a replacement server.
    pub retries: AtomicU64,
    /// Workers withdrawn by a failure (probe eviction or a forward
    /// dying with its server) — planned per-job retirement not counted.
    pub worker_lost: AtomicU64,
    /// Evaluations that exhausted their retry budget.
    pub quarantined: AtomicU64,
    /// Servers evicted by K consecutive failed health probes.
    pub probe_evictions: AtomicU64,
    /// Highest registered-server count this model has reached (the
    /// circuit breaker's 100% mark).
    pub peak_servers: AtomicU64,
    pub queue_wait: Histogram,
    pub forward: Histogram,
    /// Backoff delays applied before retries.
    pub retry_backoff: Histogram,
}

impl ModelStats {
    fn new() -> ModelStats {
        ModelStats {
            served: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            timed_out: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            worker_lost: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            probe_evictions: AtomicU64::new(0),
            peak_servers: AtomicU64::new(0),
            queue_wait: Histogram::new(),
            forward: Histogram::new(),
            retry_backoff: Histogram::new(),
        }
    }
}

/// All per-model stats, keyed by configured model (fixed at start, so
/// the hot path reads are lock-free).
pub struct BalancerStats {
    per_model: HashMap<String, ModelStats>,
}

impl BalancerStats {
    /// Fresh counters for a fixed model set (public so the benches can
    /// drive a [`DispatchPlane`] directly, without a front door).
    pub fn new(models: &[String]) -> BalancerStats {
        BalancerStats {
            per_model: models
                .iter()
                .map(|m| (m.clone(), ModelStats::new()))
                .collect(),
        }
    }

    pub fn model(&self, name: &str) -> Option<&ModelStats> {
        self.per_model.get(name)
    }
}

/// State shared by the front door, the forwarder pool and the watcher.
struct Shared {
    cfg: BalancerConfig,
    /// The sharded dispatch plane (per-model event shards; see
    /// [`shard`]).
    plane: Arc<DispatchPlane>,
    stop: AtomicBool,
    stats: Arc<BalancerStats>,
    registry: Arc<Registry>,
    requests_served: Arc<AtomicU64>,
}

impl Shared {
    /// Backpressure hint: how long a client should wait before
    /// retrying, from the model's live queue-wait p50 (the observed
    /// drain rate), clamped to [1, 30] s.  Reads only the lock-free
    /// histogram snapshot — no dispatch state is locked.
    fn retry_after_secs(&self, model: &str) -> u32 {
        let p50_us = self
            .stats
            .model(model)
            .map(|st| st.queue_wait.snapshot().p50_us)
            .unwrap_or(0);
        ((p50_us + 999_999) / 1_000_000).clamp(1, 30) as u32
    }

    /// The `/Stats` document, assembled entirely from the published
    /// per-shard snapshots, registry counters and stats atomics — no
    /// shard thread is consulted and no dispatch state is locked.
    fn stats_json(&self) -> Value {
        let models: Vec<Value> = self
            .cfg
            .models
            .iter()
            .map(|m| {
                let st = self.stats.model(m).expect("configured model stats");
                let load = |c: &AtomicU64| {
                    Value::num(c.load(Ordering::Relaxed) as f64)
                };
                let shards: Vec<Value> = self
                    .plane
                    .counts_for(m)
                    .iter()
                    .enumerate()
                    .map(|(i, c)| {
                        Value::obj(vec![
                            ("index", Value::num(i as f64)),
                            ("epoch", Value::num(c.epoch as f64)),
                            ("queued", Value::num(c.queued as f64)),
                            ("workers", Value::num(c.workers as f64)),
                            ("submitted", Value::num(c.submitted as f64)),
                            ("dispatched", Value::num(c.dispatched as f64)),
                            ("served", Value::num(c.served as f64)),
                            ("wakeups", Value::num(c.wakeups as f64)),
                            ("busy_us", Value::num(c.busy_us as f64)),
                        ])
                    })
                    .collect();
                let queued = self.plane.queued_for(m);
                Value::obj(vec![
                    ("name", Value::str(m)),
                    ("queued", Value::num(queued as f64)),
                    ("servers", Value::num(self.registry.count_for(m) as f64)),
                    ("idle", Value::num(self.registry.idle_for(m) as f64)),
                    ("served", load(&st.served)),
                    ("errors", load(&st.errors)),
                    ("rejected", load(&st.rejected)),
                    ("cancelled", load(&st.cancelled)),
                    ("timed_out", load(&st.timed_out)),
                    ("retries", load(&st.retries)),
                    ("worker_lost", load(&st.worker_lost)),
                    ("quarantined", load(&st.quarantined)),
                    ("probe_evictions", load(&st.probe_evictions)),
                    ("peak_servers", load(&st.peak_servers)),
                    ("queue_wait", st.queue_wait.json()),
                    ("forward", st.forward.json()),
                    ("retry_backoff", st.retry_backoff.json()),
                    ("shards", Value::arr(shards)),
                ])
            })
            .collect();
        Value::obj(vec![
            ("scheduler", Value::str(self.cfg.scheduler.label())),
            ("shards_per_model",
             Value::num(self.cfg.shards_per_model.max(1) as f64)),
            ("models", Value::arr(models)),
            ("servers_total", Value::num(self.registry.total() as f64)),
            ("servers_registered_lifetime",
             Value::num(self.registry.registered_total() as f64)),
            ("requests_served",
             Value::num(self.requests_served.load(Ordering::Relaxed) as f64)),
            ("forwarder_wakeups",
             Value::num(self.plane.wakeups_total() as f64)),
        ])
    }
}

/// The load balancer.
pub struct LoadBalancer {
    shared: Arc<Shared>,
    backend: Arc<dyn Backend>,
    registry: Arc<Registry>,
    /// Stats.
    pub requests_served: Arc<AtomicU64>,
    pub registration_queries: Arc<AtomicU64>,
    front: Option<Server>,
    forwarders: Vec<std::thread::JoinHandle<()>>,
    watcher: Option<std::thread::JoinHandle<()>>,
}

impl LoadBalancer {
    /// Start the balancer: front-door HTTP server + forwarder pool +
    /// port-file watcher.  `backend` owns server spawning.
    pub fn start(
        cfg: BalancerConfig,
        backend: Arc<dyn Backend>,
    ) -> Result<LoadBalancer> {
        if cfg.models.is_empty() {
            return Err(anyhow!("balancer needs at least one model"));
        }
        let registry = Arc::new(Registry::new());
        let requests_served = Arc::new(AtomicU64::new(0));
        let registration_queries = Arc::new(AtomicU64::new(0));

        let stats = Arc::new(BalancerStats::new(&cfg.models));
        // The sharded dispatch plane: one event thread per shard.  It
        // installs per-model registry wakers, so registry transitions
        // (register/release/retire/remove) poke exactly the shards that
        // can use the freed capacity — dispatch is event-driven end to
        // end, with no broadcast wakeups.
        let plane = DispatchPlane::start(
            PlaneConfig {
                models: cfg.models.clone(),
                shards_per_model: cfg.shards_per_model.max(1),
                queue_capacity: cfg.queue_capacity,
                scheduler: cfg.scheduler,
                retry: cfg.retry,
                request_timeout: cfg.request_timeout,
                persistent_servers: cfg.persistent_servers,
            },
            registry.clone(),
            stats.clone(),
            requests_served.clone(),
        );
        let shared = Arc::new(Shared {
            cfg: cfg.clone(),
            plane: plane.clone(),
            stop: AtomicBool::new(false),
            stats,
            registry: registry.clone(),
            requests_served: requests_served.clone(),
        });

        // Front door: an UM-Bridge-compatible HTTP surface.
        let s2 = shared.clone();
        let handler: Handler =
            Arc::new(move |req: &Request| front_handler(req, &s2));
        let front = Server::serve(0, handler)?;

        // Warm start: learn contracts before the first client arrives.
        if cfg.warm_start {
            for m in &cfg.models {
                backend.spawn_server(m);
            }
        }

        // Port-file watcher: registers servers as they come up.
        let watcher = {
            let shared = shared.clone();
            let backend = backend.clone();
            let regq = registration_queries.clone();
            std::thread::Builder::new()
                .name("lb-watch".into())
                .spawn(move || watcher_loop(shared, backend, regq))?
        };

        // Fixed forwarder pool, each worker bound to one shard (orders
        // hand off through that shard's own queue behind targeted
        // `notify_one` wakeups).  Sized to the total lease capacity so
        // every model's full server pool can forward concurrently (no
        // cross-model starvation by slow evaluations), and to at least
        // one forwarder per shard.
        let pool_size = cfg
            .forwarders
            .max(cfg.models.len() * cfg.max_servers)
            .max(plane.shard_count())
            .max(1);
        let mut forwarders = Vec::with_capacity(pool_size);
        for i in 0..pool_size {
            let shared = shared.clone();
            let backend = backend.clone();
            let slot = i % plane.shard_count();
            forwarders.push(
                std::thread::Builder::new()
                    .name(format!("lb-fwd-{i}"))
                    .spawn(move || forwarder_loop(shared, backend, slot))?,
            );
        }

        Ok(LoadBalancer {
            shared,
            backend,
            registry,
            requests_served,
            registration_queries,
            front: Some(front),
            forwarders,
            watcher: Some(watcher),
        })
    }

    /// Front-door URL clients connect to.
    pub fn url(&self) -> String {
        self.front.as_ref().expect("running").url()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Total queued requests across all models (from the shards'
    /// lock-free admission gates).
    pub fn queue_len(&self) -> usize {
        self.shared.plane.queue_len()
    }

    /// The dispatch plane (benches drive shard counters directly).
    pub fn plane(&self) -> &Arc<DispatchPlane> {
        &self.shared.plane
    }

    /// The live dispatch policy this balancer runs.
    pub fn scheduler(&self) -> LivePolicy {
        self.shared.cfg.scheduler
    }

    /// Per-model serving counters and latency histograms.
    pub fn stats(&self) -> &BalancerStats {
        &self.shared.stats
    }

    /// The `/Stats` document (for bench/experiment JSON reports).
    pub fn stats_json(&self) -> Value {
        self.shared.stats_json()
    }

    /// Stop the balancer.  Blocks until the forwarder pool drains; the
    /// backend is torn down first so no new work starts, but a forward
    /// already inside a model evaluation completes (the model servers
    /// cannot abort mid-compute), so shutdown latency is bounded by the
    /// longest in-flight evaluation.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(mut f) = self.front.take() {
            f.shutdown();
        }
        // Tear the server pool down before joining the forwarders:
        // anything blocked at the connection level unblocks, and all
        // backend entry points are safe to call from draining workers
        // after teardown (idempotent).
        self.backend.teardown();
        // Forwarders observe the stop flag within one order-wait tick.
        self.shared.plane.wake_forwarders();
        for t in self.forwarders.drain(..) {
            let _ = t.join();
        }
        if let Some(t) = self.watcher.take() {
            let _ = t.join();
        }
        // Stop and join the shard threads; they fail anything still
        // queued so blocked clients return promptly.
        self.shared.plane.shutdown();
    }
}

impl Drop for LoadBalancer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Front door
// ---------------------------------------------------------------------------

/// Routes by the UM-Bridge `name` field; metadata endpoints answer from
/// the contracts learned at registration.
fn front_handler(req: &Request, shared: &Arc<Shared>) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/Info") => {
            // All models behind this front door.  (Registration only
            // admits configured models, so the registry can never know
            // more names than the config.)
            let mut names: Vec<String> = shared.cfg.models.clone();
            names.sort();
            Response::ok_json(json::write(&Value::obj(vec![
                ("protocolVersion", Value::num(1.0)),
                ("models",
                 Value::arr(names.iter().map(|n| Value::str(n)).collect())),
            ])))
        }
        ("GET", "/Stats") => Response::ok_json(json::write(&shared.stats_json())),
        ("POST", "/Evaluate") => evaluate_handler(req, shared),
        ("POST", "/InputSizes") => {
            match resolve_contract(req, shared) {
                Ok(c) => Response::ok_json(json::write(&Value::obj(vec![(
                    "inputSizes",
                    Value::arr(
                        c.input_sizes
                            .into_iter()
                            .map(|s| Value::num(s as f64))
                            .collect(),
                    ),
                )]))),
                Err(resp) => resp,
            }
        }
        ("POST", "/OutputSizes") => {
            match resolve_contract(req, shared) {
                Ok(c) => Response::ok_json(json::write(&Value::obj(vec![(
                    "outputSizes",
                    Value::arr(
                        c.output_sizes
                            .into_iter()
                            .map(|s| Value::num(s as f64))
                            .collect(),
                    ),
                )]))),
                Err(resp) => resp,
            }
        }
        ("POST", "/ModelInfo") => {
            match request_model(req, shared) {
                Ok(_) => Response::ok_json(json::write(&Value::obj(vec![(
                    "support",
                    Value::obj(vec![("Evaluate", Value::Bool(true))]),
                )]))),
                Err(resp) => resp,
            }
        }
        _ => Response::not_found(),
    }
}

/// Extract and validate the request's model name (UM-Bridge `name`
/// field; a single-model balancer accepts requests without one).
///
/// This parses the body — the unavoidable cost of routing by a body
/// field (the model server parses its own copy again on the far side
/// of the HTTP hop).
fn request_model(req: &Request, shared: &Shared) -> Result<String, Response> {
    let name = req
        .body_str()
        .ok()
        .and_then(|b| json::parse(b).ok())
        .and_then(|v| v.get("name").and_then(|n| n.as_str()).map(String::from));
    let name = match name {
        Some(n) => n,
        None if shared.cfg.models.len() == 1 => shared.cfg.models[0].clone(),
        None => return Err(Response::error("missing 'name'")),
    };
    if !shared.cfg.models.iter().any(|m| *m == name) {
        return Err(Response::error(&format!("unknown model '{name}'")));
    }
    Ok(name)
}

/// Look up the learned contract; before any server of that model has
/// registered the front door cannot know the sizes yet and says so with
/// a retryable 503.
fn resolve_contract(
    req: &Request,
    shared: &Shared,
) -> Result<ModelContract, Response> {
    let name = request_model(req, shared)?;
    shared.registry.contract(&name).ok_or_else(|| {
        Response::unavailable(
            &format!("model '{name}' has no registered server yet"),
            shared.retry_after_secs(&name),
        )
    })
}

/// Submit an /Evaluate to its model's scheduler core and block until a
/// forwarder resolves it (proxy semantics) or the deadline passes.
fn evaluate_handler(req: &Request, shared: &Arc<Shared>) -> Response {
    let body = match req.body_str() {
        Ok(b) => b.to_string(),
        Err(e) => return Response::error(&format!("{e:#}")),
    };
    let name = match request_model(req, shared) {
        Ok(n) => n,
        Err(resp) => return resp,
    };

    // Circuit breaker: if the model's fleet has collapsed below the
    // configured fraction of its peak, shed immediately — queueing onto
    // a fleet that cannot drain only converts the 503 into a slower
    // 504.  Admission resumes as replacement servers register.  The
    // healthy count comes from the published shard snapshots (every
    // shard of a model sees the full announced worker set), so the
    // check is lock-free.
    if shared.cfg.breaker_floor > 0.0 {
        if let Some(st) = shared.stats.model(&name) {
            let peak = st.peak_servers.load(Ordering::Relaxed);
            let healthy = shared.plane.workers_for(&name) as f64;
            if peak > 0
                && healthy < shared.cfg.breaker_floor * peak as f64
            {
                st.rejected.fetch_add(1, Ordering::Relaxed);
                return Response::unavailable(
                    &format!(
                        "model '{name}' degraded ({healthy} of peak \
                         {peak} servers healthy)"
                    ),
                    shared.retry_after_secs(&name),
                );
            }
        }
    }

    // Lock-free admission: the submit is one atomic gate bump plus one
    // channel push into the model's shard — the evaluation becomes a
    // Submit event whose deadline budget is the request timeout (EDF
    // orders by it, every core kills past it as a backstop).
    let item = match shared.plane.submit(&name, body) {
        SubmitOutcome::Queued(item) => item,
        SubmitOutcome::Full => {
            if let Some(st) = shared.stats.model(&name) {
                st.rejected.fetch_add(1, Ordering::Relaxed);
            }
            return Response::unavailable(
                &format!("queue full for model '{name}'"),
                shared.retry_after_secs(&name),
            );
        }
        SubmitOutcome::Stopping => {
            return Response::error("balancer shutting down");
        }
        SubmitOutcome::UnknownModel => {
            // Unreachable: request_model validated the name.
            return Response::error(&format!("unknown model '{name}'"));
        }
    };

    // Block until a forwarder resolves the item or the deadline passes.
    let deadline = item.enqueued() + shared.cfg.request_timeout;
    match item.wait_deadline(deadline) {
        Some(Ok(body)) => Response::ok_json(body),
        Some(Err(e)) => Response::error(&e),
        None => {
            // Deadline passed: cancel so a forwarder doesn't burn a
            // server on a result nobody reads.  The flag is stored
            // before the counter advances (both SeqCst) so a shard
            // sweep that observes the new count is guaranteed to
            // observe the flag too; the poke makes the sweep prompt.
            item.cancel();
            if let Some(st) = shared.stats.model(&name) {
                st.timed_out.fetch_add(1, Ordering::SeqCst);
            }
            shared.plane.poke_model(&name);
            Response::text(504, "evaluation timed out")
        }
    }
}

// ---------------------------------------------------------------------------
// Watcher
// ---------------------------------------------------------------------------

/// Per-model spawn-governor state (watcher-local): observed in-flight
/// spawn count and lifetime registrations, plus the failure backoff.
struct GovState {
    fails: u32,
    until: Instant,
    last_pending: usize,
    last_reg: u64,
}

fn watcher_loop(
    shared: Arc<Shared>,
    backend: Arc<dyn Backend>,
    regq: Arc<AtomicU64>,
) {
    let mut last_health = Instant::now();
    // Spawn governor: per-model exponential backoff while spawn
    // attempts keep failing, so a broken model retries at a bounded
    // rate instead of every poll tick.  A failure is *observed*, not
    // assumed: in-flight spawn count dropped without a registration.
    // Healthy scale-up (even bursty) is never delayed.
    let mut governor: HashMap<String, GovState> = HashMap::new();
    // Consecutive failed health probes per endpoint: eviction needs
    // `probe_eviction_k` failures in a row, so one dropped probe (GC
    // pause, transient connect error) never flaps a healthy server.
    let mut probe_fails: HashMap<String, u32> = HashMap::new();
    while !shared.stop.load(Ordering::SeqCst) {
        for endpoint in backend.poll_new_servers() {
            // The paper's preliminary jobs: verify readiness and learn
            // the input/output contract before routing work (>=5
            // queries per server).  Registration announces a worker to
            // the model's scheduler core and wakes the forwarders.
            match preliminary_checks(&endpoint, &shared) {
                Ok((queries, model)) => {
                    regq.fetch_add(queries, Ordering::Relaxed);
                    // Announce the worker to every shard of its model
                    // (the WorkerUp event wakes the shard threads).
                    shared.plane.worker_up(&endpoint, &model);
                    // The breaker's 100% mark: the largest fleet this
                    // model has ever had.
                    if let Some(st) = shared.stats.model(&model) {
                        st.peak_servers.fetch_max(
                            shared.registry.count_for(&model) as u64,
                            Ordering::Relaxed,
                        );
                    }
                    crate::log_info!("balancer",
                                     "registered server {endpoint}");
                }
                Err(e) => {
                    crate::log_warn!("balancer",
                                     "server {endpoint} failed checks: {e:#}");
                    backend.server_lost(&endpoint);
                }
            }
        }
        // Backstop drain of lease-retired endpoints (the forwarders
        // drain their own; this covers the last one before idle).
        drain_retired(&shared, &backend);
        // Capacity management: spawn while demand outstrips supply.
        // Single-threaded here (no double-spawn race) and reading only
        // the shards' admission-gate atomics, so a slow backend never
        // stalls the front door or the shard threads.
        let backlogs: Vec<(String, usize)> = shared
            .cfg
            .models
            .iter()
            .map(|m| (m.clone(), shared.plane.queued_for(m)))
            .collect();
        for (model, mut backlog) in backlogs {
            let pending = backend.spawns_in_flight(&model);
            // A warm-start model with no server, no spawn in flight and
            // no learned contract needs a server even with an empty
            // queue — metadata-first clients only ever retry /InputSizes
            // against its 503, so Evaluate backlog alone would never
            // re-arm a failed warm spawn.
            if backlog == 0
                && shared.cfg.warm_start
                && pending == 0
                && shared.registry.count_for(&model) == 0
                && shared.registry.contract(&model).is_none()
            {
                backlog = 1;
            }
            if backlog == 0 {
                continue;
            }
            let now = Instant::now();
            let reg_now = shared.registry.registered_for(&model);
            let st = governor.entry(model.clone()).or_insert(GovState {
                fails: 0,
                until: now,
                last_pending: 0,
                last_reg: 0,
            });
            if reg_now > st.last_reg {
                // A spawn succeeded since last tick: clear the backoff.
                st.fails = 0;
                st.until = now;
            } else if pending < st.last_pending {
                // Spawn slots released without a registration: those
                // spawns failed.  Widen the retry window (50 ms → ~13 s).
                st.fails = (st.fails + 1).min(8);
                st.until = now + Duration::from_millis(50)
                    * (1u32 << st.fails);
            }
            st.last_reg = reg_now;
            st.last_pending = pending;
            if now < st.until {
                continue;
            }
            let supply = shared.registry.count_for(&model) + pending;
            if supply < shared.cfg.max_servers {
                // Demand not already covered by idle servers or spawns
                // still in flight.
                let covered = pending + shared.registry.idle_for(&model);
                let want = backlog
                    .saturating_sub(covered)
                    .min(shared.cfg.max_servers - supply);
                for _ in 0..want {
                    backend.spawn_server(&model);
                }
                if want > 0 {
                    let after = backend.spawns_in_flight(&model);
                    if after <= pending {
                        // Nothing went in flight: the spawns failed
                        // synchronously (e.g. model build error).
                        st.fails = (st.fails + 1).min(8);
                        st.until = now + Duration::from_millis(50)
                            * (1u32 << st.fails);
                    }
                    st.last_pending = after;
                }
            }
        }
        // Periodic health checks on registered servers (decoupled from
        // the port-file poll so idle servers are not hammered — perf
        // pass, EXPERIMENTS.md section Perf).
        if last_health.elapsed() >= Duration::from_millis(500) {
            last_health = Instant::now();
            let eps = shared.registry.endpoints();
            // Drop counters for endpoints that already left the fleet
            // (lease retirement, prior eviction).
            probe_fails.retain(|ep, _| eps.iter().any(|e| e == ep));
            let k = shared.cfg.probe_eviction_k.max(1);
            for ep in eps {
                if shared.registry.state(&ep) != Some(ServerState::Idle) {
                    // A busy server is exercised by its own forward; a
                    // probe would only race the evaluation.
                    continue;
                }
                if health_check(&ep) {
                    probe_fails.remove(&ep);
                    continue;
                }
                let fails = probe_fails.entry(ep.clone()).or_insert(0);
                *fails += 1;
                if *fails < k {
                    crate::log_warn!(
                        "balancer",
                        "server {ep} failed health probe ({fails}/{k})");
                    continue;
                }
                let f = *fails;
                probe_fails.remove(&ep);
                crate::log_warn!(
                    "balancer",
                    "server {ep} unhealthy ({f} consecutive probes), \
                     dropping");
                let model = shared.registry.model_of(&ep);
                shared.registry.remove(&ep);
                // Withdraw the worker from its model's shards (the
                // cores re-place anything bound to it); the plane
                // accounts the eviction exactly once.
                if let Some(model) = model {
                    shared.plane.worker_lost_external(&ep, &model);
                }
                backend.server_lost(&ep);
            }
        }
        std::thread::sleep(shared.cfg.poll_interval);
    }
}

/// Hand lease-retired endpoints to the backend and drop their pooled
/// connections.
fn drain_retired(shared: &Shared, backend: &Arc<dyn Backend>) {
    for ep in shared.registry.take_retired() {
        shared.plane.purge_conns(&ep);
        backend.retire_server(&ep);
    }
}

/// The paper's five preliminary queries, now also the contract-learning
/// step: /Info names the model(s) the server hosts; sizes and ModelInfo
/// are fetched for the first configured one (each server hosts one
/// model), verified against any already-registered contract, and stored
/// in the registry.  Returns (query count, model name).
fn preliminary_checks(endpoint: &str, shared: &Shared)
                      -> Result<(u64, String)> {
    let mut m = HttpModel::connect(endpoint, "")?;
    let (_ver, names) = m.info()?; // 1
    let mut queries = 1u64;
    let Some(name) = names
        .iter()
        .find(|n| shared.cfg.models.iter().any(|c| c == *n))
        .cloned()
    else {
        return Err(anyhow!(
            "{endpoint} serves none of the configured models ({names:?})"
        ));
    };
    m.model_name = name.clone();
    let contract = m.fetch_contract()?; // 2, 3
    let _info = m.model_info()?; // 4
    queries += 3;
    if let Some(existing) = shared.registry.contract(&name) {
        if existing != contract {
            return Err(anyhow!(
                "contract mismatch for '{name}' at {endpoint}: \
                 {:?}/{:?} vs registered {:?}/{:?}",
                contract.input_sizes, contract.output_sizes,
                existing.input_sizes, existing.output_sizes
            ));
        }
    }
    let (_ver2, _names2) = m.info()?; // 5 — final readiness probe
    queries += 1;
    shared.registry.register(endpoint, &name, &contract);
    Ok((queries, name))
}

fn health_check(endpoint: &str) -> bool {
    HttpModel::connect(endpoint, "x")
        .and_then(|mut m| m.info())
        .is_ok()
}

// ---------------------------------------------------------------------------
// Forwarder pool
// ---------------------------------------------------------------------------

/// One worker of the fixed forwarder pool, bound to a single shard: it
/// pops dispatched work orders from that shard's queue (each order
/// already carries the server lease the policy placed the work on),
/// forwards over the shard's own connection pool, and hands the result
/// back to the plane — which resolves the waiting client and feeds the
/// completion event to the shard thread (`WorkDone` frees the synthetic
/// worker; a transport failure charges the retry budget; a retiring
/// lease becomes a capacity loss).  Scheduling itself happens on the
/// shard threads; the forwarder only performs the blocking HTTP hop.
fn forwarder_loop(shared: Arc<Shared>, backend: Arc<dyn Backend>,
                  slot: usize) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        // Targeted handoff: this forwarder sleeps on its own shard's
        // order queue (woken by that shard's `notify_one`, never a
        // plane-wide broadcast), with a 50 ms liveness backstop.
        let Some(order) = shared
            .plane
            .take_order(slot, Duration::from_millis(50))
        else {
            continue;
        };
        let t0 = Instant::now();
        let result = forward(
            shared.plane.forward_pool(slot),
            order.endpoint(),
            order.item().body(),
        );
        if let Some(st) = shared.stats.model(order.item().model()) {
            st.forward.record(t0.elapsed());
        }
        shared.plane.complete_order(order, result);
        drain_retired(&shared, &backend);
    }
}

fn forward(
    pool: &Mutex<HashMap<String, Vec<HttpClient>>>,
    endpoint: &str,
    body: &str,
) -> Result<String, ForwardError> {
    let died = |e: anyhow::Error| ForwardError {
        transport: true,
        msg: format!("{e:#}"),
    };
    let mut c = match pool.lock().unwrap().get_mut(endpoint)
        .and_then(|v| v.pop())
    {
        Some(c) => c,
        None => HttpClient::connect(endpoint).map_err(died)?,
    };
    let resp = c.request(&Request::post("/Evaluate", body)).map_err(died)?;
    if resp.status != 200 {
        return Err(ForwardError {
            transport: false,
            msg: format!("{}: {}", resp.status,
                         resp.body_str().unwrap_or("")),
        });
    }
    let out = resp.body_str().map_err(died)?.to_string();
    // Return the connection to the pool for reuse.
    pool.lock()
        .unwrap()
        .entry(endpoint.to_string())
        .or_default()
        .push(c);
    Ok(out)
}
