//! Workloads: the paper's four benchmark applications, their Table-III
//! resource requests, the seeded LHS input sampler, and the calibrated
//! runtime models that drive the sim plane.

use crate::clock::{Micros, MIN, SEC};
use crate::cluster::JobRequest;
use crate::util::Rng;

/// The four benchmark applications (paper section IV.B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum App {
    Eigen100,
    Eigen5000,
    Gs2,
    Gp,
}

impl App {
    pub fn all() -> [App; 4] {
        [App::Eigen100, App::Eigen5000, App::Gs2, App::Gp]
    }

    pub fn label(&self) -> &'static str {
        match self {
            App::Eigen100 => "eigen-100",
            App::Eigen5000 => "eigen-5000",
            App::Gs2 => "gs2",
            App::Gp => "GP",
        }
    }

    /// Parse a CLI/report name back into an application.
    pub fn parse(s: &str) -> Option<App> {
        match s {
            "eigen-100" | "eigen100" => Some(App::Eigen100),
            "eigen-5000" | "eigen5000" => Some(App::Eigen5000),
            "gs2" => Some(App::Gs2),
            "GP" | "gp" => Some(App::Gp),
            _ => None,
        }
    }

    /// Wire name of the serving model (live plane).
    pub fn model_name(&self) -> &'static str {
        match self {
            App::Eigen100 => crate::models::EIGEN_SMALL_NAME,
            App::Eigen5000 => crate::models::EIGEN_LARGE_NAME,
            App::Gs2 => crate::models::GS2_NAME,
            App::Gp => crate::models::GP_NAME,
        }
    }
}

/// Scheduling scenario backing a live model (wire name -> Table III
/// row).  The QoI integral runs on GP-class resources.  `None` for
/// models with no paper scenario (e.g. synthetic test models):
/// `start_live` rejects those at startup — they are served through
/// `LocalBackend`, which needs no scenario.
pub fn app_for_model(model: &str) -> Option<App> {
    match model {
        crate::models::GP_NAME | crate::models::QOI_NAME => Some(App::Gp),
        crate::models::GS2_NAME => Some(App::Gs2),
        crate::models::EIGEN_SMALL_NAME => Some(App::Eigen100),
        crate::models::EIGEN_LARGE_NAME => Some(App::Eigen5000),
        _ => None,
    }
}

/// One row of the paper's Table III (all values paper-scale).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub app: App,
    /// SLURM job time limit (naive path).
    pub slurm_time: Micros,
    /// HQ allocation time limit.
    pub hq_alloc_time: Micros,
    /// HQ job time request (scheduling hint).
    pub hq_time_request: Micros,
    /// HQ job time limit.
    pub hq_time_limit: Micros,
    pub cpus: u32,
    pub ram_gb: u32,
    /// Paper's "expected time to solution" (min..max).
    pub expected: (Micros, Micros),
}

/// Table III verbatim.
pub fn scenario(app: App) -> Scenario {
    match app {
        App::Eigen100 => Scenario {
            app,
            slurm_time: 1 * MIN,
            hq_alloc_time: 10 * MIN,
            hq_time_request: 1 * MIN,
            hq_time_limit: 5 * MIN,
            cpus: 1,
            ram_gb: 4,
            expected: ((6 * SEC) / 10, (6 * SEC) / 10), // 0.01 min
        },
        App::Eigen5000 => Scenario {
            app,
            slurm_time: 5 * MIN,
            hq_alloc_time: 60 * MIN,
            hq_time_request: 5 * MIN,
            hq_time_limit: 10 * MIN,
            cpus: 1,
            ram_gb: 4,
            expected: (2 * MIN, 2 * MIN),
        },
        App::Gs2 => Scenario {
            app,
            slurm_time: 240 * MIN,
            hq_alloc_time: 36000 * MIN,
            hq_time_request: 15 * MIN,
            hq_time_limit: 240 * MIN,
            cpus: 8,
            ram_gb: 32,
            expected: (1 * MIN, 180 * MIN),
        },
        App::Gp => Scenario {
            app,
            slurm_time: 1 * MIN,
            hq_alloc_time: 10 * MIN,
            hq_time_request: 1 * MIN,
            hq_time_limit: 5 * MIN,
            cpus: 1,
            ram_gb: 4,
            expected: (6 * SEC, 6 * SEC), // 0.1 min
        },
    }
}

impl Scenario {
    pub fn slurm_request(&self) -> JobRequest {
        JobRequest::new(self.cpus, self.ram_gb, self.slurm_time)
    }

    pub fn hq_alloc_request(&self) -> JobRequest {
        JobRequest::new(self.cpus, self.ram_gb, self.hq_alloc_time)
    }
}

/// Seeded Latin hypercube over the GS2 parameter space (Table II), the
/// Rust-side equivalent of `python/compile/gp.py::lhs_sample`.
pub fn lhs(n: usize, seed: u64) -> Vec<[f64; 7]> {
    let lo = [2.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.0];
    let hi = [9.0, 5.0, 10.0, 6.0, 0.3, 0.1, 1.0];
    let mut rng = Rng::new(seed);
    let mut out = vec![[0f64; 7]; n];
    for d in 0..7 {
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        for (i, &stratum) in perm.iter().enumerate() {
            let u = (stratum as f64 + rng.uniform()) / n as f64;
            out[i][d] = lo[d] + u * (hi[d] - lo[d]);
        }
    }
    out
}

/// Calibrated per-evaluation compute times (paper scale).
///
/// The same seeded sample stream feeds both schedulers, implementing the
/// paper's "series of evaluation in each benchmark were generated with
/// the same random seed ... runtime variations do not originate from the
/// benchmark problem".
///
/// gs2 calibration: convergence-chunk distribution measured from the
/// gs2lite artifact (median ~12 chunks, lognormal body, ~9% hitting the
/// 400-chunk cap), mapped onto the paper's stated [1, 180]-minute range
/// at 27 s per chunk (180 min / 400 chunks).
pub struct RuntimeModel {
    seed: u64,
}

impl RuntimeModel {
    pub fn new(seed: u64) -> Self {
        RuntimeModel { seed }
    }

    /// Compute time C_i for evaluation `index` of `app` (paper scale).
    pub fn duration(&self, app: App, index: u64) -> Micros {
        let mut rng = Rng::new(
            self.seed ^ (index + 1).wrapping_mul(0x9E37_79B9)
                ^ (app as u64) << 56,
        );
        let jitter = rng.lognormal(0.0, 0.05);
        match app {
            // eigen-100: 0.01 min = 0.6 s
            App::Eigen100 => ((0.6 * SEC as f64) * jitter) as Micros,
            // eigen-5000: ~2 min
            App::Eigen5000 => ((120.0 * SEC as f64) * jitter) as Micros,
            // GP: ~0.1 min, dominated by fixed cost
            App::Gp => ((6.0 * SEC as f64) * jitter) as Micros,
            App::Gs2 => {
                // Chunk-count mixture calibrated from gs2lite.
                let chunks = if rng.uniform() < 0.09 {
                    400.0
                } else {
                    rng.lognormal(12f64.ln(), 0.8).clamp(3.0, 350.0)
                };
                let secs = 27.0 * chunks * jitter;
                (secs * SEC as f64) as Micros
            }
        }
    }

    /// All `n` durations (convenience).
    pub fn durations(&self, app: App, n: u64) -> Vec<Micros> {
        (0..n).map(|i| self.duration(app, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lhs_is_stratified_and_seeded() {
        let n = 32;
        let a = lhs(n, 5);
        let b = lhs(n, 5);
        assert_eq!(a, b);
        let c = lhs(n, 6);
        assert_ne!(a, c);
        // Stratification: one sample per 1/n stratum per dim.
        let lo = [2.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.0];
        let hi = [9.0, 5.0, 10.0, 6.0, 0.3, 0.1, 1.0];
        for d in 0..7 {
            let mut bins: Vec<usize> = a
                .iter()
                .map(|p| {
                    (((p[d] - lo[d]) / (hi[d] - lo[d]) * n as f64) as usize)
                        .min(n - 1)
                })
                .collect();
            bins.sort();
            assert_eq!(bins, (0..n).collect::<Vec<_>>(), "dim {d}");
        }
    }

    #[test]
    fn parse_roundtrips_labels() {
        for app in App::all() {
            assert_eq!(App::parse(app.label()), Some(app));
        }
        assert_eq!(App::parse("gp"), Some(App::Gp));
        assert_eq!(App::parse("nope"), None);
    }

    #[test]
    fn scenarios_match_table3() {
        let s = scenario(App::Gs2);
        assert_eq!(s.slurm_time, 240 * MIN);
        assert_eq!(s.hq_time_request, 15 * MIN);
        assert_eq!(s.cpus, 8);
        assert_eq!(s.ram_gb, 32);
        let e = scenario(App::Eigen100);
        assert_eq!(e.hq_alloc_time, 10 * MIN);
        assert_eq!(e.cpus, 1);
    }

    #[test]
    fn durations_seeded_and_app_dependent() {
        let m = RuntimeModel::new(42);
        assert_eq!(m.duration(App::Gs2, 3), m.duration(App::Gs2, 3));
        assert_ne!(m.duration(App::Gs2, 3), m.duration(App::Gs2, 4));
        assert_ne!(m.duration(App::Gs2, 3), m.duration(App::Gp, 3));
    }

    #[test]
    fn gs2_has_heavy_tail_within_expected_range() {
        let m = RuntimeModel::new(7);
        let ds = m.durations(App::Gs2, 200);
        let lo = *ds.iter().min().unwrap();
        let hi = *ds.iter().max().unwrap();
        assert!(lo >= 60 * SEC, "min {lo}");
        assert!(hi >= 100 * MIN, "tail missing, max {hi}");
        assert!(hi <= 200 * MIN, "max {hi}");
        // Spread of at least ~20x across the LHS space.
        assert!(hi as f64 / lo as f64 > 20.0);
    }

    #[test]
    fn cheap_apps_are_cheap() {
        let m = RuntimeModel::new(7);
        assert!(m.duration(App::Eigen100, 0) < 2 * SEC);
        assert!(m.duration(App::Gp, 0) < 15 * SEC);
        let e5 = m.duration(App::Eigen5000, 0);
        assert!(e5 > 90 * SEC && e5 < 200 * SEC);
    }
}
