//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 emits protos with 64-bit ids
//! the pinned xla_extension rejects; the text parser reassigns them.
//!
//! One [`Engine`] per process wraps the PJRT CPU client plus a cache of
//! compiled executables keyed by entry name; [`Engine::execute`] is the
//! entire request-path compute surface — Python never runs at serve time.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{self, Value};

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: HashMap<String, EntryMeta>,
    pub gs2: Gs2Meta,
    pub eigen: EigenMeta,
    pub params_lo: Vec<f64>,
    pub params_hi: Vec<f64>,
    pub param_names: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct Gs2Meta {
    pub ngrid: usize,
    pub chunk_iters: usize,
    pub theta_max: f64,
    pub residual_tol: f64,
    pub max_chunks: usize,
}

#[derive(Clone, Debug)]
pub struct EigenMeta {
    pub n_small: usize,
    pub n_large: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!("reading {}/manifest.json — run `make artifacts`",
                        dir.display())
            })?;
        let v = json::parse(&text)?;
        let mut entries = HashMap::new();
        for (name, e) in v
            .get("entries")
            .and_then(|x| x.as_obj())
            .ok_or_else(|| anyhow!("manifest: missing entries"))?
        {
            let file = e
                .get("file")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("manifest: entry {name} missing file"))?
                .to_string();
            let input_shapes = e
                .get("inputs")
                .and_then(|x| x.as_arr())
                .map(|arr| {
                    arr.iter()
                        .filter_map(|i| {
                            i.get("shape").and_then(|s| s.as_arr()).map(|dims| {
                                dims.iter()
                                    .filter_map(|d| d.as_usize())
                                    .collect::<Vec<_>>()
                            })
                        })
                        .collect::<Vec<_>>()
                })
                .unwrap_or_default();
            entries.insert(name.clone(), EntryMeta { file, input_shapes });
        }
        let g = v.get("gs2").ok_or_else(|| anyhow!("manifest: gs2"))?;
        let gs2 = Gs2Meta {
            ngrid: g.get("ngrid").and_then(|x| x.as_usize()).unwrap_or(256),
            chunk_iters: g.get("chunk_iters").and_then(|x| x.as_usize())
                .unwrap_or(64),
            theta_max: g.get("theta_max").and_then(|x| x.as_f64())
                .unwrap_or(4.0 * std::f64::consts::PI),
            residual_tol: g.get("residual_tol").and_then(|x| x.as_f64())
                .unwrap_or(1e-4),
            max_chunks: g.get("max_chunks").and_then(|x| x.as_usize())
                .unwrap_or(400),
        };
        let e = v.get("eigen").ok_or_else(|| anyhow!("manifest: eigen"))?;
        let eigen = EigenMeta {
            n_small: e.get("n_small").and_then(|x| x.as_usize()).unwrap_or(100),
            n_large: e.get("n_large").and_then(|x| x.as_usize()).unwrap_or(256),
        };
        let p = v.get("params").ok_or_else(|| anyhow!("manifest: params"))?;
        let params_lo = p.get("lo").and_then(|x| x.as_f64_vec())
            .ok_or_else(|| anyhow!("manifest: params.lo"))?;
        let params_hi = p.get("hi").and_then(|x| x.as_f64_vec())
            .ok_or_else(|| anyhow!("manifest: params.hi"))?;
        let param_names = p
            .get("names")
            .and_then(|x| x.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|s| s.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
            gs2,
            eigen,
            params_lo,
            params_hi,
            param_names,
        })
    }

    /// Default artifact location: `$UQSCHED_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("UQSCHED_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    input_shapes: Vec<Vec<usize>>,
}

/// The PJRT execution engine.
///
/// Executables compile lazily on first use and live for the process
/// lifetime.  The `xla` wrapper types hold raw pointers; the PJRT CPU
/// client is thread-safe at the C API level, so the engine is marked
/// Send+Sync with compile-time mutation gated behind the cache mutex.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, &'static Compiled>>,
    /// Executions performed (metrics).
    pub executions: std::sync::atomic::AtomicU64,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create an engine over an artifact directory.
    pub fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            executions: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Engine over the default artifact dir.
    pub fn from_default_dir() -> Result<Engine> {
        Engine::new(&Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn entry_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.entries.keys().cloned().collect();
        v.sort();
        v
    }

    fn compiled(&self, name: &str) -> Result<&'static Compiled> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(c) = cache.get(name) {
            return Ok(c);
        }
        let meta = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact entry '{name}'"))?;
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        // Executables live for the process lifetime; leaking gives a
        // stable borrow without self-referential structs.
        let leaked: &'static Compiled = Box::leak(Box::new(Compiled {
            exe,
            input_shapes: meta.input_shapes.clone(),
        }));
        cache.insert(name.to_string(), leaked);
        Ok(leaked)
    }

    /// Pre-compile entries (server start pays the compile, not request 1).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.compiled(n)?;
        }
        Ok(())
    }

    /// Execute an entry with flat f32 inputs (shapes from the manifest).
    /// Returns the flattened outputs in declaration order.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let c = self.compiled(name)?;
        if inputs.len() != c.input_shapes.len() {
            bail!(
                "entry '{name}' wants {} inputs, got {}",
                c.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&c.input_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!(
                    "entry '{name}' input {i}: {} values for shape {shape:?}",
                    data.len()
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let result = c
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output {i} of {name}: {e:?}"))?;
            out.push(v);
        }
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }
}

/// Golden-vector check: compare engine outputs against
/// `artifacts/testvec.json` for one entry.  Returns max relative |err|.
pub fn check_testvec(engine: &Engine, name: &str) -> Result<f64> {
    let path = engine.manifest().dir.join("testvec.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let v = json::parse(&text)?;
    let tv = v
        .get(name)
        .ok_or_else(|| anyhow!("testvec: no entry {name}"))?;
    let inputs: Vec<Vec<f32>> = tv
        .get("inputs")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("testvec inputs"))?
        .iter()
        .map(|a| {
            a.as_f64_vec()
                .map(|xs| xs.iter().map(|&f| f as f32).collect())
                .ok_or_else(|| anyhow!("testvec input row"))
        })
        .collect::<Result<_>>()?;
    let expected: Vec<Vec<f64>> = tv
        .get("outputs")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("testvec outputs"))?
        .iter()
        .map(|a| a.as_f64_vec().ok_or_else(|| anyhow!("testvec output row")))
        .collect::<Result<_>>()?;
    let got = engine.execute(name, &inputs)?;
    if got.len() != expected.len() {
        bail!("{name}: {} outputs, expected {}", got.len(), expected.len());
    }
    let mut max_err = 0f64;
    for (g, e) in got.iter().zip(&expected) {
        if g.len() != e.len() {
            bail!("{name}: output length {} vs {}", g.len(), e.len());
        }
        for (a, b) in g.iter().zip(e) {
            let scale = 1.0 + b.abs();
            max_err = max_err.max(((*a as f64) - b).abs() / scale);
        }
    }
    Ok(max_err)
}

/// Helper used across models: a `Value` config lookup with default.
pub fn config_f64(config: &Value, key: &str, default: f64) -> f64 {
    config.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
}
