//! uqsched CLI: the leader entrypoint.
//!
//! Subcommands:
//!   serve      — run an UM-Bridge model server (gp | gs2 | eigen-100 |
//!                eigen-5000 | qoi) on a port
//!   client     — evaluate a model through any UM-Bridge endpoint
//!   balancer   — run the load balancer live (slurm | hq backend),
//!                serving one or many models through one front door
//!   selftest   — artifact round-trip (PJRT vs golden vectors, when
//!                artifacts exist) plus a live-plane balancer smoke
//!   experiment — run one sim-plane benchmark cell and print its stats
//!   campaign   — run a campaign-plane workload policy against a
//!                scheduler and print/export the campaign metrics

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use uqsched::campaign::{
    self, parse_levels, AdaptiveBayes, CampaignConfig, Family, FixedDepth,
    HeteroFamilies, Mlda, PoissonBurst, SlurmMode, StageInOut, Submitter,
    UserMix, UserStream,
};
use uqsched::cli::Args;
use uqsched::clock::{MS, SEC};
use uqsched::coordinator::start_live_tuned;
use uqsched::experiments::{run_naive_slurm, run_umbridge_hq, Config};
use uqsched::json::Value;
use uqsched::metrics::BoxStats;
use uqsched::models;
use uqsched::runtime::{check_testvec, Engine, Manifest};
use uqsched::sched::LivePolicy;
use uqsched::umbridge::{self, HttpModel};
use uqsched::workload::App;
use uqsched::{log_info, logging};

fn main() -> Result<()> {
    let args = Args::from_env();
    logging::set_level_from_str(&args.str_or("log", "info"));
    match args.subcommand() {
        Some("serve") => serve(&args),
        Some("client") => client(&args),
        Some("balancer") => balancer(&args),
        Some("selftest") => selftest(&args),
        Some("experiment") => experiment(&args),
        Some("campaign") => campaign_cmd(&args),
        _ => {
            eprintln!(
                "usage: uqsched <serve|client|balancer|selftest|experiment|campaign>\n\
                 \n\
                 serve      --model gp|gs2|eigen-100|eigen-5000|qoi [--port N]\n\
                 client     --url http://h:p --model NAME --params 1,2,...\n\
                 balancer   --models NAME[,NAME...] --backend slurm|hq\n\
                            [--scheduler fcfs|worksteal|edf|gang] [--servers N]\n\
                            [--shards-per-model 1] [--per-job-servers]\n\
                            [--retry-attempts 2] [--retry-backoff 50ms]\n\
                            [--probe-eviction-k 3] [--breaker-floor 0.0]\n\
                 selftest   [--artifacts DIR] [--shards-per-model 1]\n\
                            (artifact check + live-plane smoke; artifacts\n\
                            optional)\n\
                 experiment --app gs2|GP|eigen-100|eigen-5000 [--queue 2]\n\
                            [--evals 100] [--seed 1]\n\
                 campaign   --policy fixed|bursty|mix|hetero|adaptive\n\
                            |mlda|stageio  (--campaign is an alias)\n\
                            --scheduler slurm|umbridge-slurm|hq|worksteal|edf|gang\n\
                            [--app gs2] [--tasks 100] [--depth 2] [--seed 1]\n\
                            [--interarrival 2s] [--burst-min 1] [--burst-max 8]\n\
                            [--users gp:50:2,eigen-100:50:2] [--sigmas 0,0.8]\n\
                            [--tol 0.02] [--workers N] [--out FILE.json]\n\
                            mlda: [--levels 32:0.5,16:1,8:2] [--promote 0.7]\n\
                                  [--refine 1.5] [--occ 8]\n\
                            stageio: [--rounds 16] [--fanout 8] [--inflight 2]\n\
                            [--faults crash=300s,fail=0.02,attempts=3,\n\
                             backoff=1s:60s,slow=0.05x8,seed=1]"
            );
            Ok(())
        }
    }
}

fn engine(args: &Args) -> Result<Arc<Engine>> {
    let dir = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    Ok(Arc::new(Engine::new(&dir)?))
}

fn serve(args: &Args) -> Result<()> {
    let name = args.str_or("model", "gp");
    let port = args.u64_or("port", 4242)? as u16;
    let eng = engine(args)?;
    let model = models::by_name(eng, &name)?;
    let srv = umbridge::serve_models(vec![model], port)?;
    log_info!("serve", "model '{name}' on {}", srv.url());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn client(args: &Args) -> Result<()> {
    let url = args.required("url")?;
    let name = args.str_or("model", "gp");
    let params: Vec<f64> = args
        .str_or("params", "5,2,6,3,0.15,0.02,0.5")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow!("{e}")))
        .collect::<Result<_>>()?;
    let mut m = HttpModel::connect(url, &name)?;
    let out = m.evaluate(&[params], &Value::Obj(Default::default()))?;
    println!("{}", uqsched::json::write(&Value::from_f64s2(&out)));
    Ok(())
}

fn balancer(args: &Args) -> Result<()> {
    // One front door, many models: --models gp,gs2 (--model also works).
    let spec = args
        .opt("models")
        .or_else(|| args.opt("model"))
        .unwrap_or("gp")
        .to_string();
    let model_names: Vec<&str> =
        spec.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
    let backend_kind = args.str_or("backend", "hq");
    let servers = args.usize_or("servers", 2)?;
    let scale = args.f64_or("time-scale", 60.0)?;
    // `--scheduler` is the canonical spelling; `--sched` is accepted as
    // an alias, matching the campaign subcommand's flag handling.
    let sched_name = args
        .opt("scheduler")
        .or_else(|| args.opt("sched"))
        .unwrap_or("fcfs");
    let scheduler = LivePolicy::parse(sched_name).ok_or_else(|| {
        anyhow!("unknown live scheduler '{sched_name}' \
                 (want fcfs|worksteal|edf|gang)")
    })?;
    // Robustness knobs (see ARCHITECTURE.md, failure model): per-task
    // retry budget, probe-eviction threshold and circuit-breaker floor.
    let retry_attempts = args.u64_or("retry-attempts", 2)? as u32;
    let retry_backoff = args.micros_or("retry-backoff", 50 * MS)?;
    let probe_k = args.u64_or("probe-eviction-k", 3)? as u32;
    let breaker_floor = args.f64_or("breaker-floor", 0.0)?;
    // Dispatch shards per model: >1 spreads a hot model's submissions,
    // scheduling and completions across event threads (see
    // ARCHITECTURE.md, sharded dispatch plane).
    let shards = args.usize_or("shards-per-model", 1)?.max(1);
    let eng = engine(args)?;
    let stack = start_live_tuned(
        eng, &model_names, &backend_kind, servers, scale,
        !args.flag("per-job-servers"), scheduler,
        |cfg| {
            cfg.retry.max_attempts = retry_attempts;
            cfg.retry.backoff_base = retry_backoff;
            cfg.probe_eviction_k = probe_k;
            cfg.breaker_floor = breaker_floor;
            cfg.shards_per_model = shards;
        },
    )?;
    log_info!("balancer",
              "front door at {} serving {:?} via {} (stats at {}/Stats)",
              stack.balancer.url(), model_names, scheduler.label(),
              stack.balancer.url());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn selftest(args: &Args) -> Result<()> {
    // Part 1: artifact round-trip (skipped cleanly when the PJRT
    // artifacts are absent, e.g. in CI — the live-plane smoke below
    // runs regardless).
    match engine(args) {
        Ok(eng) => {
            println!("artifact self-test ({} entries):",
                     eng.entry_names().len());
            let mut worst: f64 = 0.0;
            for name in eng.entry_names() {
                let err = check_testvec(&eng, &name)?;
                println!("  {name:<18} max rel err {err:.3e}");
                worst = worst.max(err);
            }
            if worst >= 1e-4 {
                bail!("selftest FAILED (worst {worst:.3e})");
            }
            println!("selftest artifacts OK (worst {worst:.3e})");
        }
        Err(e) => {
            println!("SKIP artifact self-test (no artifacts: {e:#})");
        }
    }
    balancer_smoke(args.usize_or("shards-per-model", 1)?.max(1))
}

/// Live-plane smoke: two synthetic models through one balancer front
/// door (LocalBackend — no scheduler, no artifacts), verifying routing,
/// learned contracts and the stats surface.  `shards` exercises the
/// sharded dispatch plane (CI runs it at 2).
fn balancer_smoke(shards: usize) -> Result<()> {
    use std::sync::atomic::Ordering;
    use uqsched::coordinator::{BalancerConfig, LoadBalancer, LocalBackend};
    use uqsched::models::SyntheticModel;

    let backend = LocalBackend::new(Arc::new(|name: &str| {
        Ok(match name {
            "syn-a" => Arc::new(SyntheticModel::new("syn-a", &[2], &[1]))
                as Arc<dyn uqsched::umbridge::Model>,
            "syn-b" => Arc::new(SyntheticModel::new("syn-b", &[3], &[2, 1])),
            other => bail!("unknown smoke model '{other}'"),
        })
    }));
    let cfg = BalancerConfig {
        models: vec!["syn-a".into(), "syn-b".into()],
        max_servers: 2,
        shards_per_model: shards,
        ..Default::default()
    };
    let mut lb = LoadBalancer::start(cfg, backend)?;
    let url = lb.url();
    let cfgv = Value::Obj(Default::default());
    let mut a = HttpModel::connect(&url, "syn-a")?;
    let mut b = HttpModel::connect(&url, "syn-b")?;
    for i in 0..5 {
        let x = i as f64;
        let out = a.evaluate(&[vec![x, 1.0]], &cfgv)?;
        if out != vec![vec![x + 1.0]] {
            bail!("syn-a routed wrong: {out:?}");
        }
        let out = b.evaluate(&[vec![x, 1.0, 2.0]], &cfgv)?;
        if out != vec![vec![x + 3.0, x + 3.0], vec![x + 4.0]] {
            bail!("syn-b routed wrong: {out:?}");
        }
    }
    // Contracts were learned at registration, not hardcoded.
    if a.input_sizes()? != vec![2] || b.output_sizes()? != vec![2, 1] {
        bail!("learned contracts wrong");
    }
    let served = lb.requests_served.load(Ordering::Relaxed);
    println!("selftest live-plane OK (10 evaluations across 2 models, \
              {served} served)");
    println!("{}", uqsched::json::write(&lb.stats_json()));
    lb.shutdown();
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let name = args.str_or("app", "gs2");
    let app = App::parse(&name).ok_or_else(|| anyhow!("unknown app '{name}'"))?;
    let mut cfg = Config::paper(app, args.usize_or("queue", 2)?,
                                args.u64_or("seed", 1)?);
    cfg.n_evals = args.u64_or("evals", 100)?;
    let s = run_naive_slurm(&cfg);
    let h = run_umbridge_hq(&cfg);
    for (label, e) in [("SLURM", &s), ("HQ", &h)] {
        println!("{label:<6} {} makespan[s]: {}", app.label(),
                 BoxStats::from(&e.makespans_sec()).row());
        println!("       {} cpu[s]:      {}", app.label(),
                 BoxStats::from(&e.cpus_sec()).row());
        println!("       {} overhead[s]: {}", app.label(),
                 BoxStats::from(&e.overheads_sec()).row());
        println!("       experiment SLR {:.3}", e.slr());
    }
    Ok(())
}

fn box_json(vals: &[f64]) -> Value {
    let s = BoxStats::from(vals);
    Value::obj(vec![
        ("n", Value::num(s.n as f64)),
        ("min", Value::num(s.min)),
        ("q1", Value::num(s.q1)),
        ("median", Value::num(s.median)),
        ("q3", Value::num(s.q3)),
        ("max", Value::num(s.max)),
        ("mean", Value::num(s.mean)),
    ])
}

fn campaign_cmd(args: &Args) -> Result<()> {
    let app = App::parse(&args.str_or("app", "gs2"))
        .ok_or_else(|| anyhow!("unknown --app"))?;
    // `--campaign` is an alias for `--policy` (reads naturally for the
    // DAG campaigns: `uqsched campaign --campaign mlda`).
    let policy = args
        .opt("campaign")
        .map(str::to_string)
        .unwrap_or_else(|| args.str_or("policy", "fixed"));
    // `--scheduler` is the canonical spelling; `--sched` stays accepted.
    let sched = args
        .opt("scheduler")
        .map(str::to_string)
        .unwrap_or_else(|| args.str_or("sched", "hq"));
    let tasks = args.u64_or("tasks", 100)?;
    let depth = args.usize_or("depth", 2)?;
    let seed = args.u64_or("seed", 1)?;
    let mut cfg = CampaignConfig::paper(app, depth, seed);
    if let Some(w) = args.opt("workers") {
        let w: u32 = w.parse().context("--workers")?;
        cfg.hq_backlog = w;
        cfg.hq_workers = w;
    }
    if let Some(spec) = args.opt("faults") {
        let fs = uqsched::sched::FaultSpec::parse(spec)
            .map_err(|e| anyhow!("--faults: {e}"))?;
        println!("fault plan: {}", fs.describe());
        cfg.faults = Some(fs);
    }

    let mut sub: Box<dyn Submitter> = match policy.as_str() {
        "fixed" => Box::new(FixedDepth::new(app, tasks, depth, seed)),
        "bursty" => {
            let ia = args.micros_or("interarrival", 2 * SEC)?;
            let bmin = args.u64_or("burst-min", 1)?;
            let bmax = args.u64_or("burst-max", 8)?;
            Box::new(PoissonBurst::new(app, tasks, ia, (bmin, bmax), seed))
        }
        "mix" => {
            let spec = args.str_or("users", "gp:50:2,eigen-100:50:2");
            let mut streams = Vec::new();
            for (i, part) in spec.split(',').enumerate() {
                let fields: Vec<&str> = part.trim().split(':').collect();
                if fields.len() != 3 {
                    bail!("bad --users entry '{part}' (want app:n:depth)");
                }
                let sapp = App::parse(fields[0])
                    .ok_or_else(|| anyhow!("unknown app '{}'", fields[0]))?;
                streams.push(UserStream {
                    user: i as u32,
                    app: sapp,
                    n_evals: fields[1]
                        .parse()
                        .with_context(|| format!("bad count in '{part}'"))?,
                    queue_depth: fields[2]
                        .parse()
                        .with_context(|| format!("bad depth in '{part}'"))?,
                });
            }
            Box::new(UserMix::new(streams, seed))
        }
        "hetero" => {
            let sigmas = args.str_or("sigmas", "0,0.8");
            let mut fams = Vec::new();
            for s in sigmas.split(',') {
                let sigma: f64 = s
                    .trim()
                    .parse()
                    .with_context(|| format!("bad sigma '{s}'"))?;
                fams.push(Family { app, weight: 1.0, sigma });
            }
            Box::new(HeteroFamilies::new(fams, tasks, depth, seed))
        }
        "adaptive" => {
            let tol = args.f64_or("tol", 0.02)?;
            Box::new(AdaptiveBayes::new(app, tasks, seed).with_tol(tol))
        }
        "mlda" => {
            let levels = parse_levels(&args.str_or("levels", "32:0.5,16:1,8:2"))
                .map_err(|e| anyhow!("--levels: {e}"))?;
            let promote = args.f64_or("promote", 0.7)?;
            let refine = args.f64_or("refine", 1.5)?;
            let occ = args.u64_or("occ", 8)?.max(1);
            Box::new(
                Mlda::new(app, levels, seed)
                    .with_promote(promote)
                    .with_refine_z(refine)
                    .with_occupancy(occ, 1, (occ * 8).max(occ)),
            )
        }
        "stageio" => {
            let rounds = args.u64_or("rounds", 16)?.max(1);
            let fanout = args.u64_or("fanout", 8)?.max(1);
            let inflight = args.u64_or("inflight", 2)?.max(1);
            Box::new(StageInOut::new(app, rounds, fanout, inflight, seed))
        }
        other => bail!("unknown policy '{other}'"),
    };

    let result = match sched.as_str() {
        "slurm" => campaign::run_slurm(&cfg, sub.as_mut(), SlurmMode::Native),
        "umbridge-slurm" => {
            campaign::run_slurm(&cfg, sub.as_mut(), SlurmMode::UmBridge)
        }
        "hq" => campaign::run_hq(&cfg, sub.as_mut()),
        "worksteal" => campaign::run_worksteal(&cfg, sub.as_mut()),
        "edf" => campaign::run_edf(&cfg, sub.as_mut()),
        "gang" => campaign::run_gang(&cfg, sub.as_mut()),
        other => bail!("unknown scheduler '{other}'"),
    };

    let m = &result.metrics;
    println!(
        "campaign '{}' on {}: {} completed / {} submitted",
        m.policy, m.scheduler, m.completed, m.submitted
    );
    println!(
        "  makespan {:.1} s | peak in-flight {} | fairness (Jain) {:.3} | {} DES events",
        m.makespan as f64 / SEC as f64,
        m.peak_in_flight,
        m.fairness_jain,
        m.des_events
    );
    if m.retries + m.quarantined + m.worker_crashes > 0 {
        println!(
            "  faults: {} retries | {} quarantined | {} worker crashes",
            m.retries, m.quarantined, m.worker_crashes
        );
    }
    if m.dep_edges > 0 {
        println!(
            "  dag: {} edges | {} released | {} skipped | peak blocked {}",
            m.dep_edges, m.released, m.skipped, m.peak_blocked
        );
    }
    for (n, t) in &m.time_to {
        println!("  time to {n:>7} results: {:>12.1} s", *t as f64 / SEC as f64);
    }
    if m.dep_edges > 0 {
        for (user, milestones) in &m.per_user_time_to {
            if let Some((n, t)) = milestones.last() {
                println!(
                    "  level {user}: {n} results by {:.1} s",
                    *t as f64 / SEC as f64
                );
            }
        }
    }
    for u in &m.per_user {
        println!(
            "  user {}: {} evals, mean makespan {:.1} s, mean SLR {:.2}",
            u.user, u.completed, u.mean_makespan_s, u.mean_slr
        );
    }
    let e = &result.experiment;
    println!("  makespan[s]: {}", BoxStats::from(&e.makespans_sec()).row());
    println!("  overhead[s]: {}", BoxStats::from(&e.overheads_sec()).row());

    if let Some(path) = args.opt("out") {
        let doc = Value::obj(vec![
            ("campaign", m.json()),
            (
                "boxstats",
                Value::obj(vec![
                    ("makespan_s", box_json(&e.makespans_sec())),
                    ("cpu_s", box_json(&e.cpus_sec())),
                    ("overhead_s", box_json(&e.overheads_sec())),
                    ("slr", box_json(&e.slrs())),
                ]),
            ),
        ]);
        std::fs::write(path, uqsched::json::write(&doc))
            .with_context(|| format!("write {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

