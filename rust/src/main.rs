//! uqsched CLI: the leader entrypoint.
//!
//! Subcommands:
//!   serve      — run an UM-Bridge model server (gp | gs2 | eigen-100 |
//!                eigen-5000 | qoi) on a port
//!   client     — evaluate a model through any UM-Bridge endpoint
//!   balancer   — run the load balancer live (slurm | hq backend)
//!   selftest   — artifact round-trip: PJRT vs golden test vectors
//!   experiment — run one sim-plane benchmark cell and print its stats

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use uqsched::cli::Args;
use uqsched::coordinator::start_live;
use uqsched::experiments::{run_naive_slurm, run_umbridge_hq, Config};
use uqsched::json::Value;
use uqsched::metrics::BoxStats;
use uqsched::models;
use uqsched::runtime::{check_testvec, Engine, Manifest};
use uqsched::umbridge::{self, HttpModel};
use uqsched::workload::{scenario, App};
use uqsched::{log_info, logging};

fn main() -> Result<()> {
    let args = Args::from_env();
    logging::set_level_from_str(&args.str_or("log", "info"));
    match args.subcommand() {
        Some("serve") => serve(&args),
        Some("client") => client(&args),
        Some("balancer") => balancer(&args),
        Some("selftest") => selftest(&args),
        Some("experiment") => experiment(&args),
        _ => {
            eprintln!(
                "usage: uqsched <serve|client|balancer|selftest|experiment>\n\
                 \n\
                 serve      --model gp|gs2|eigen-100|eigen-5000|qoi [--port N]\n\
                 client     --url http://h:p --model NAME --params 1,2,...\n\
                 balancer   --model NAME --backend slurm|hq [--servers N]\n\
                 selftest   [--artifacts DIR]\n\
                 experiment --app gs2|GP|eigen-100|eigen-5000 [--queue 2]\n\
                            [--evals 100] [--seed 1]"
            );
            Ok(())
        }
    }
}

fn engine(args: &Args) -> Result<Arc<Engine>> {
    let dir = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    Ok(Arc::new(Engine::new(&dir)?))
}

fn serve(args: &Args) -> Result<()> {
    let name = args.str_or("model", "gp");
    let port = args.u64_or("port", 4242)? as u16;
    let eng = engine(args)?;
    let model = models::by_name(eng, &name)?;
    let srv = umbridge::serve_models(vec![model], port)?;
    log_info!("serve", "model '{name}' on {}", srv.url());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn client(args: &Args) -> Result<()> {
    let url = args.required("url")?;
    let name = args.str_or("model", "gp");
    let params: Vec<f64> = args
        .str_or("params", "5,2,6,3,0.15,0.02,0.5")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow!("{e}")))
        .collect::<Result<_>>()?;
    let mut m = HttpModel::connect(url, &name)?;
    let out = m.evaluate(&[params], &Value::Obj(Default::default()))?;
    println!("{}", uqsched::json::write(&Value::from_f64s2(&out)));
    Ok(())
}

fn balancer(args: &Args) -> Result<()> {
    let model = leak(&args.str_or("model", "gp"));
    let backend_kind = args.str_or("backend", "hq");
    let servers = args.usize_or("servers", 2)?;
    let scale = args.f64_or("time-scale", 60.0)?;
    let eng = engine(args)?;
    let app = app_for_model(model)?;
    let scen = scenario(app);
    let stack = start_live(eng, model, &backend_kind, servers, &scen,
                           scale, !args.flag("per-job-servers"))?;
    log_info!("balancer", "front door at {}", stack.balancer.url());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn app_for_model(model: &str) -> Result<App> {
    Ok(match model {
        models::GP_NAME | models::QOI_NAME => App::Gp,
        models::GS2_NAME => App::Gs2,
        models::EIGEN_SMALL_NAME => App::Eigen100,
        models::EIGEN_LARGE_NAME => App::Eigen5000,
        other => bail!("no scenario for model '{other}'"),
    })
}

fn selftest(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    println!("artifact self-test ({} entries):", eng.entry_names().len());
    let mut worst: f64 = 0.0;
    for name in eng.entry_names() {
        let err = check_testvec(&eng, &name)?;
        println!("  {name:<18} max rel err {err:.3e}");
        worst = worst.max(err);
    }
    if worst < 1e-4 {
        println!("selftest OK (worst {worst:.3e})");
        Ok(())
    } else {
        bail!("selftest FAILED (worst {worst:.3e})")
    }
}

fn experiment(args: &Args) -> Result<()> {
    let app = match args.str_or("app", "gs2").as_str() {
        "gs2" => App::Gs2,
        "GP" | "gp" => App::Gp,
        "eigen-100" => App::Eigen100,
        "eigen-5000" => App::Eigen5000,
        other => bail!("unknown app '{other}'"),
    };
    let mut cfg = Config::paper(app, args.usize_or("queue", 2)?,
                                args.u64_or("seed", 1)?);
    cfg.n_evals = args.u64_or("evals", 100)?;
    let s = run_naive_slurm(&cfg);
    let h = run_umbridge_hq(&cfg);
    for (label, e) in [("SLURM", &s), ("HQ", &h)] {
        println!("{label:<6} {} makespan[s]: {}", app.label(),
                 BoxStats::from(&e.makespans_sec()).row());
        println!("       {} cpu[s]:      {}", app.label(),
                 BoxStats::from(&e.cpus_sec()).row());
        println!("       {} overhead[s]: {}", app.label(),
                 BoxStats::from(&e.overheads_sec()).row());
        println!("       experiment SLR {:.3}", e.slr());
    }
    Ok(())
}

fn leak(s: &str) -> &'static str {
    Box::leak(s.to_string().into_boxed_str())
}
