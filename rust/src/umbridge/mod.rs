//! The UM-Bridge protocol (Seelinger et al., JOSS 2023) in Rust.
//!
//! UM-Bridge treats UQ algorithm and numerical model as separate
//! applications linked by an HTTP+JSON protocol.  This module implements
//! both sides:
//!
//! * [`Model`] + [`serve_models`] — the model-server side (the paper's
//!   Python `umbridge.serve_models` equivalent);
//! * [`HttpModel`] — the client side (`umbridge.HTTPModel`).
//!
//! Endpoints (protocol 1.0): `GET /Info`, `POST /InputSizes`,
//! `POST /OutputSizes`, `POST /ModelInfo`, `POST /Evaluate`.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::httpd::{Handler, HttpClient, Request, Response, Server};
use crate::json::{self, Value};

/// Protocol version advertised on /Info.
pub const PROTOCOL_VERSION: f64 = 1.0;

/// A model's wire contract: the input/output vector sizes it advertises
/// on `/InputSizes` and `/OutputSizes`.  The balancer learns one per
/// model at server registration and uses it to answer metadata queries
/// without a round trip (and to reject servers whose contract diverges
/// from an already-registered sibling).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelContract {
    pub input_sizes: Vec<usize>,
    pub output_sizes: Vec<usize>,
}

/// A numerical model exposed over UM-Bridge.
pub trait Model: Send + Sync {
    fn name(&self) -> &str;
    /// Sizes of each input vector.
    fn input_sizes(&self) -> Vec<usize>;
    /// Sizes of each output vector.
    fn output_sizes(&self) -> Vec<usize>;
    /// Evaluate the map F(theta); `config` carries model-specific options.
    fn evaluate(&self, inputs: &[Vec<f64>], config: &Value) -> Result<Vec<Vec<f64>>>;
    /// Capability flags (ModelInfo).
    fn supports_gradient(&self) -> bool {
        false
    }
}

/// Serve models over HTTP; port 0 picks a free port.
///
/// The returned [`Server`] handle owns the listener: keep it alive for
/// as long as the models must be reachable, and call
/// [`Server::shutdown`] when done (dropping the handle also shuts the
/// server down — see the `Server` shutdown contract).
pub fn serve_models(models: Vec<Arc<dyn Model>>, port: u16) -> Result<Server> {
    let models = Arc::new(models);
    let handler: Handler = Arc::new(move |req: &Request| {
        match route(&models, req) {
            Ok(resp) => resp,
            Err(e) => Response::error(&format!("{e:#}")),
        }
    });
    Server::serve(port, handler)
}

fn find<'a>(models: &'a [Arc<dyn Model>], name: &str) -> Result<&'a Arc<dyn Model>> {
    models
        .iter()
        .find(|m| m.name() == name)
        .ok_or_else(|| anyhow!("unknown model '{name}'"))
}

fn parse_body(req: &Request) -> Result<Value> {
    Ok(json::parse(req.body_str()?)?)
}

fn route(models: &[Arc<dyn Model>], req: &Request) -> Result<Response> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/Info") => {
            let names: Vec<Value> =
                models.iter().map(|m| Value::str(m.name())).collect();
            Ok(Response::ok_json(json::write(&Value::obj(vec![
                ("protocolVersion", Value::num(PROTOCOL_VERSION)),
                ("models", Value::arr(names)),
            ]))))
        }
        ("POST", "/InputSizes") => {
            let body = parse_body(req)?;
            let m = find(models, model_name(&body)?)?;
            let sizes: Vec<Value> = m
                .input_sizes()
                .iter()
                .map(|&s| Value::num(s as f64))
                .collect();
            Ok(Response::ok_json(json::write(&Value::obj(vec![(
                "inputSizes",
                Value::arr(sizes),
            )]))))
        }
        ("POST", "/OutputSizes") => {
            let body = parse_body(req)?;
            let m = find(models, model_name(&body)?)?;
            let sizes: Vec<Value> = m
                .output_sizes()
                .iter()
                .map(|&s| Value::num(s as f64))
                .collect();
            Ok(Response::ok_json(json::write(&Value::obj(vec![(
                "outputSizes",
                Value::arr(sizes),
            )]))))
        }
        ("POST", "/ModelInfo") => {
            let body = parse_body(req)?;
            let m = find(models, model_name(&body)?)?;
            Ok(Response::ok_json(json::write(&Value::obj(vec![(
                "support",
                Value::obj(vec![
                    ("Evaluate", Value::Bool(true)),
                    ("Gradient", Value::Bool(m.supports_gradient())),
                    ("ApplyJacobian", Value::Bool(false)),
                    ("ApplyHessian", Value::Bool(false)),
                ]),
            )]))))
        }
        ("POST", "/Evaluate") => {
            let body = parse_body(req)?;
            let m = find(models, model_name(&body)?)?;
            let input = body
                .get("input")
                .and_then(|v| v.as_f64_vec2())
                .ok_or_else(|| anyhow!("missing/invalid 'input'"))?;
            // Validate sizes against the contract.
            let want = m.input_sizes();
            if input.len() != want.len()
                || input.iter().zip(&want).any(|(v, &w)| v.len() != w)
            {
                bail!(
                    "input sizes {:?} do not match model contract {:?}",
                    input.iter().map(|v| v.len()).collect::<Vec<_>>(),
                    want
                );
            }
            let default_cfg = Value::Obj(Default::default());
            let config = body.get("config").unwrap_or(&default_cfg);
            let output = m.evaluate(&input, config)?;
            Ok(Response::ok_json(json::write(&Value::obj(vec![(
                "output",
                Value::from_f64s2(&output),
            )]))))
        }
        _ => Ok(Response::not_found()),
    }
}

fn model_name(body: &Value) -> Result<&str> {
    body.get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing 'name'"))
}

/// Client-side handle to a remote UM-Bridge model.
pub struct HttpModel {
    client: HttpClient,
    pub model_name: String,
}

impl HttpModel {
    pub fn connect(url: &str, model_name: &str) -> Result<HttpModel> {
        Ok(HttpModel {
            client: HttpClient::connect(url)?,
            model_name: model_name.to_string(),
        })
    }

    /// GET /Info: (protocolVersion, model names).
    pub fn info(&mut self) -> Result<(f64, Vec<String>)> {
        let resp = self.client.request(&Request::get("/Info"))?;
        let v = json::parse(resp.body_str()?)?;
        let ver = v
            .get("protocolVersion")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| anyhow!("bad /Info"))?;
        let names = v
            .get("models")
            .and_then(|x| x.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|s| s.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        Ok((ver, names))
    }

    fn named_post(&mut self, path: &str) -> Result<Value> {
        let body = json::write(&Value::obj(vec![(
            "name",
            Value::str(&self.model_name),
        )]));
        let resp = self.client.request(&Request::post(path, &body))?;
        if resp.status != 200 {
            bail!("{path} -> {}: {}", resp.status,
                  resp.body_str().unwrap_or(""));
        }
        Ok(json::parse(resp.body_str()?)?)
    }

    pub fn input_sizes(&mut self) -> Result<Vec<usize>> {
        let v = self.named_post("/InputSizes")?;
        v.get("inputSizes")
            .and_then(|x| x.as_f64_vec())
            .map(|xs| xs.iter().map(|&f| f as usize).collect())
            .ok_or_else(|| anyhow!("bad /InputSizes"))
    }

    pub fn output_sizes(&mut self) -> Result<Vec<usize>> {
        let v = self.named_post("/OutputSizes")?;
        v.get("outputSizes")
            .and_then(|x| x.as_f64_vec())
            .map(|xs| xs.iter().map(|&f| f as usize).collect())
            .ok_or_else(|| anyhow!("bad /OutputSizes"))
    }

    pub fn model_info(&mut self) -> Result<Value> {
        self.named_post("/ModelInfo")
    }

    /// Fetch the model's full wire contract (two round trips).
    pub fn fetch_contract(&mut self) -> Result<ModelContract> {
        Ok(ModelContract {
            input_sizes: self.input_sizes()?,
            output_sizes: self.output_sizes()?,
        })
    }

    pub fn evaluate(
        &mut self,
        inputs: &[Vec<f64>],
        config: &Value,
    ) -> Result<Vec<Vec<f64>>> {
        let body = json::write(&Value::obj(vec![
            ("name", Value::str(&self.model_name)),
            ("input", Value::from_f64s2(inputs)),
            ("config", config.clone()),
        ]));
        let resp = self.client.request(&Request::post("/Evaluate", &body))?;
        if resp.status != 200 {
            bail!("/Evaluate -> {}: {}", resp.status,
                  resp.body_str().unwrap_or(""));
        }
        let v = json::parse(resp.body_str()?)?;
        v.get("output")
            .and_then(|x| x.as_f64_vec2())
            .ok_or_else(|| anyhow!("bad /Evaluate response"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// F(x) = (sum(x), 2*x) — two outputs exercising both directions.
    struct TestModel;

    impl Model for TestModel {
        fn name(&self) -> &str {
            "testmodel"
        }
        fn input_sizes(&self) -> Vec<usize> {
            vec![3]
        }
        fn output_sizes(&self) -> Vec<usize> {
            vec![1, 3]
        }
        fn evaluate(&self, inputs: &[Vec<f64>], _config: &Value)
                    -> Result<Vec<Vec<f64>>> {
            let x = &inputs[0];
            Ok(vec![vec![x.iter().sum()],
                    x.iter().map(|v| v * 2.0).collect()])
        }
    }

    fn serve() -> Server {
        serve_models(vec![Arc::new(TestModel)], 0).unwrap()
    }

    #[test]
    fn info_lists_models() {
        let mut srv = serve();
        let mut m = HttpModel::connect(&srv.url(), "testmodel").unwrap();
        let (ver, names) = m.info().unwrap();
        assert_eq!(ver, PROTOCOL_VERSION);
        assert_eq!(names, vec!["testmodel"]);
        srv.shutdown();
    }

    #[test]
    fn sizes_roundtrip() {
        let mut srv = serve();
        let mut m = HttpModel::connect(&srv.url(), "testmodel").unwrap();
        assert_eq!(m.input_sizes().unwrap(), vec![3]);
        assert_eq!(m.output_sizes().unwrap(), vec![1, 3]);
        srv.shutdown();
    }

    #[test]
    fn evaluate_roundtrip() {
        let mut srv = serve();
        let mut m = HttpModel::connect(&srv.url(), "testmodel").unwrap();
        let out = m
            .evaluate(&[vec![1.0, 2.0, 3.0]], &Value::Obj(Default::default()))
            .unwrap();
        assert_eq!(out, vec![vec![6.0], vec![2.0, 4.0, 6.0]]);
        srv.shutdown();
    }

    #[test]
    fn wrong_input_size_rejected() {
        let mut srv = serve();
        let mut m = HttpModel::connect(&srv.url(), "testmodel").unwrap();
        let err = m
            .evaluate(&[vec![1.0]], &Value::Obj(Default::default()))
            .unwrap_err();
        assert!(format!("{err}").contains("500"));
        srv.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let mut srv = serve();
        let mut m = HttpModel::connect(&srv.url(), "nope").unwrap();
        assert!(m.input_sizes().is_err());
        srv.shutdown();
    }

    #[test]
    fn contract_fetch_roundtrip() {
        let mut srv = serve();
        let mut m = HttpModel::connect(&srv.url(), "testmodel").unwrap();
        let c = m.fetch_contract().unwrap();
        assert_eq!(c, ModelContract {
            input_sizes: vec![3],
            output_sizes: vec![1, 3],
        });
        srv.shutdown();
    }

    #[test]
    fn model_info_flags() {
        let mut srv = serve();
        let mut m = HttpModel::connect(&srv.url(), "testmodel").unwrap();
        let v = m.model_info().unwrap();
        assert_eq!(v.get("support").unwrap().get("Evaluate").unwrap(),
                   &Value::Bool(true));
        assert_eq!(v.get("support").unwrap().get("Gradient").unwrap(),
                   &Value::Bool(false));
        srv.shutdown();
    }

    #[test]
    fn concurrent_evaluations() {
        let mut srv = serve();
        let url = srv.url();
        let threads: Vec<_> = (0..6)
            .map(|t| {
                let url = url.clone();
                std::thread::spawn(move || {
                    let mut m = HttpModel::connect(&url, "testmodel").unwrap();
                    for i in 0..20 {
                        let x = vec![t as f64, i as f64, 1.0];
                        let out = m
                            .evaluate(&[x.clone()],
                                      &Value::Obj(Default::default()))
                            .unwrap();
                        assert_eq!(out[0][0], x.iter().sum::<f64>());
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        srv.shutdown();
    }
}
