//! Tiny CLI argument parser (no clap in the offline environment).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positionals, with
//! typed accessors and an auto-generated usage line.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments (without argv[0]).
    pub fn parse(raw: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad u64 '{v}'")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad f64 '{v}'")),
        }
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.opt(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&["serve", "--model", "gp", "--port=4242", "extra",
                        "--verbose"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.opt("model"), Some("gp"));
        assert_eq!(a.u64_or("port", 0).unwrap(), 4242);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.str_or("x", "d"), "d");
        assert_eq!(a.u64_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("f", 0.5).unwrap(), 0.5);
        assert!(a.required("x").is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.u64_or("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--model", "gp", "--quiet"]);
        assert!(a.flag("quiet"));
        assert_eq!(a.opt("model"), Some("gp"));
    }
}
