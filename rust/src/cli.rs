//! Tiny CLI argument parser (no clap in the offline environment).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positionals, with
//! typed accessors, human-duration parsing (`500ms`, `2s`, `5m`, `1h`),
//! and an auto-generated usage line.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::clock::{Micros, MIN, MS, SEC};

/// Parse a human duration into [`Micros`]: `500ms`, `2s`, `5m`, `1h`,
/// or a bare number (seconds).  Fractions are allowed (`1.5s`).
pub fn parse_micros(s: &str) -> Result<Micros> {
    let s = s.trim();
    let (num, unit): (&str, f64) = if let Some(v) = s.strip_suffix("ms") {
        (v, MS as f64)
    } else if let Some(v) = s.strip_suffix('h') {
        (v, 60.0 * MIN as f64)
    } else if let Some(v) = s.strip_suffix('m') {
        (v, MIN as f64)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, SEC as f64)
    } else {
        (s, SEC as f64)
    };
    let x: f64 = num
        .trim()
        .parse()
        .with_context(|| format!("bad duration '{s}'"))?;
    if !x.is_finite() || x < 0.0 {
        bail!("duration '{s}' must be finite and non-negative");
    }
    Ok((x * unit) as Micros)
}

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments (without argv[0]).
    pub fn parse(raw: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad u64 '{v}'")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad f64 '{v}'")),
        }
    }

    /// Duration option (`--every 500ms`, `--window 2m`); bare numbers
    /// are seconds.
    pub fn micros_or(&self, name: &str, default: Micros) -> Result<Micros> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => {
                parse_micros(v).with_context(|| format!("--{name}"))
            }
        }
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.opt(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&["serve", "--model", "gp", "--port=4242", "extra",
                        "--verbose"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.opt("model"), Some("gp"));
        assert_eq!(a.u64_or("port", 0).unwrap(), 4242);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.str_or("x", "d"), "d");
        assert_eq!(a.u64_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("f", 0.5).unwrap(), 0.5);
        assert!(a.required("x").is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.u64_or("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--model", "gp", "--quiet"]);
        assert!(a.flag("quiet"));
        assert_eq!(a.opt("model"), Some("gp"));
    }

    #[test]
    fn durations_parse_units() {
        assert_eq!(parse_micros("500ms").unwrap(), 500 * MS);
        assert_eq!(parse_micros("2s").unwrap(), 2 * SEC);
        assert_eq!(parse_micros("5m").unwrap(), 5 * MIN);
        assert_eq!(parse_micros("1h").unwrap(), 60 * MIN);
        assert_eq!(parse_micros("3").unwrap(), 3 * SEC);
        assert_eq!(parse_micros("1.5s").unwrap(), 1_500 * MS);
        assert!(parse_micros("abc").is_err());
        assert!(parse_micros("-4s").is_err());
        assert!(parse_micros("nan").is_err());
        assert!(parse_micros("inf").is_err());
    }

    #[test]
    fn micros_or_reads_option() {
        let a = parse(&["--every", "250ms"]);
        assert_eq!(a.micros_or("every", SEC).unwrap(), 250 * MS);
        assert_eq!(a.micros_or("window", 2 * SEC).unwrap(), 2 * SEC);
        let bad = parse(&["--every", "xyz"]);
        assert!(bad.micros_or("every", SEC).is_err());
    }
}
