//! From-scratch JSON: parser, writer, and a small accessor API.
//!
//! This is the UM-Bridge wire format (the protocol is JSON over HTTP) and
//! the reader for `artifacts/manifest.json` / `testvec.json`.  No serde in
//! the offline environment, so the substrate is built here, with the
//! usual strictness: UTF-8 strings with escapes, nested containers,
//! numbers via the grammar in RFC 8259, and precise error positions.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

/// A JSON value.  Object keys are ordered (BTreeMap) so serialisation is
/// deterministic — useful for golden tests and reproducible logs.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `value.get("a")` on objects, None otherwise.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Index into arrays.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        self.as_arr().and_then(|a| a.get(idx))
    }
    /// Flatten a numeric array (used for tensor payloads).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Option<Vec<_>>>()
    }
    /// Flatten a nested `[[f64]]` payload (UM-Bridge input/output lists).
    pub fn as_f64_vec2(&self) -> Option<Vec<Vec<f64>>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64_vec())
            .collect::<Option<Vec<_>>>()
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(vals: Vec<Value>) -> Value {
        Value::Arr(vals)
    }
    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }
    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }
    pub fn from_f64s(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }
    pub fn from_f64s2(xs: &[Vec<f64>]) -> Value {
        Value::Arr(xs.iter().map(|r| Value::from_f64s(r)).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.to_string() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(&format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return self.err("missing low surrogate");
                            }
                            let lo = self.hex4()?;
                            let hi10 = cp - 0xD800;
                            let lo10 = lo.wrapping_sub(0xDC00);
                            char::from_u32(
                                0x10000 + ((hi10 as u32) << 10) + lo10 as u32,
                            )
                        } else {
                            char::from_u32(cp as u32)
                        };
                        match c {
                            Some(c) => s.push(c),
                            None => return self.err("invalid codepoint"),
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return self.err("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(frag) => {
                                s.push_str(frag);
                                self.pos = end;
                            }
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, ParseError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            let d = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return self.err("bad hex digit"),
            };
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| ParseError { pos: start, msg: "utf8".into() })?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| ParseError { pos: start, msg: e.to_string() })
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

/// Serialise a value to compact JSON.
pub fn write(v: &Value) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                // Integral fast path: digits straight into the output
                // buffer, no intermediate String allocation (this is the
                // UM-Bridge serve hot path measured by benches/hotpath.rs).
                write_i64(*n as i64, out);
            } else {
                // fmt::Write appends in place (format! would allocate).
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

/// itoa-style integer serialisation: digits composed in a stack buffer,
/// appended to `out` in one call.
fn write_i64(v: i64, out: &mut String) {
    if v < 0 {
        out.push('-');
    }
    let mut m = v.unsigned_abs();
    let mut buf = [0u8; 20];
    let mut i = buf.len();
    loop {
        i -= 1;
        buf[i] = b'0' + (m % 10) as u8;
        m /= 10;
        if m == 0 {
            break;
        }
    }
    // Safety by construction: ASCII digits only.
    out.push_str(std::str::from_utf8(&buf[i..]).unwrap());
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::str("hi"));
    }

    #[test]
    fn parses_containers() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b").unwrap(),
                   &Value::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀 é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀 é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn error_has_position() {
        let err = parse("[1, x]").unwrap_err();
        assert_eq!(err.pos, 4);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"nested":{"k":[[1,2],[3,4]]}}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_floats_precisely() {
        let v = Value::from_f64s(&[1.5, -0.25, 3.141592653589793, 1e-8]);
        let back = parse(&write(&v)).unwrap();
        for (a, b) in v.as_f64_vec().unwrap().iter()
            .zip(back.as_f64_vec().unwrap())
        {
            assert_eq!(*a, b);
        }
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(write(&Value::Num(3.0)), "3");
        assert_eq!(write(&Value::Num(3.5)), "3.5");
    }

    #[test]
    fn f64_vec2_accessor() {
        let v = parse("[[1,2],[3,4,5]]").unwrap();
        let vv = v.as_f64_vec2().unwrap();
        assert_eq!(vv, vec![vec![1.0, 2.0], vec![3.0, 4.0, 5.0]]);
        assert!(parse("[[1],\"x\"]").unwrap().as_f64_vec2().is_none());
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..200 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..200 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
