//! Minimal property-test harness (no proptest crate in the offline env).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded RNG streams and
//! reports the failing seed on panic, so failures are reproducible with
//! `check_seed`.  Used by the scheduler-invariant property tests.

use super::rng::Rng;

/// Run `f` for `cases` deterministic seeds; panic with the failing seed.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000_0000 + case;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with util::prop::check_seed(\"{name}\", {seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_seed<F: Fn(&mut Rng)>(_name: &str, seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("uniform-bounded", 32, |rng| {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failing_seed() {
        check("always-fails", 4, |_rng| panic!("boom"));
    }
}
