//! Small in-crate substrates: seeded RNG and a property-test harness.
//!
//! The offline build environment provides no `rand`/`proptest` crates, so
//! the deterministic pieces the schedulers and tests rely on live here.

pub mod prop;
pub mod rng;

pub use rng::Rng;
