//! SplitMix64 RNG — deterministic, seedable, dependency-free.
//!
//! The same generator (same constants, same 24-bit float mapping) is
//! implemented in `python/compile/eigen.py::random_symmetric`, so Rust and
//! Python produce bit-identical benchmark matrices from the same seed —
//! the paper's "same random seed for repeatability" requirement
//! (section IV.B) enforced across the language boundary.

/// SplitMix64 stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) using the top 24 bits (matches the Python side).
    pub fn uniform24(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) / ((1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution (general use).
    pub fn uniform(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) / ((1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Modulo bias is irrelevant for our n << 2^64 uses.
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.uniform().max(1e-300).ln()
    }

    /// Log-normal with the given location/scale of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child stream (for per-entity generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// The paper-matching symmetric benchmark matrix (row-major, n*n),
    /// bit-identical to `python/compile/eigen.py::random_symmetric`.
    pub fn symmetric_matrix(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut a = vec![0f32; n * n];
        for v in a.iter_mut() {
            *v = r.uniform24() * 2.0 - 1.0;
        }
        let mut s = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                s[i * n + j] = 0.5 * (a[i * n + j] + a[j * n + i]);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn matches_python_pin() {
        // Pinned in python/tests/test_eigen.py::test_known_first_value.
        let m = Rng::symmetric_matrix(42, 2);
        assert!((m[0] - 0.48312974).abs() < 1e-6);
    }

    #[test]
    fn symmetric_matrix_is_symmetric() {
        let n = 16;
        let m = Rng::symmetric_matrix(5, n);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(m[i * n + j], m[j * n + i]);
            }
        }
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20000).map(|_| r.exponential(3.0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 3.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
