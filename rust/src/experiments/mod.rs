//! The experiment harness: regenerates the paper's evaluation (Figs 3-6)
//! on the sim plane — the same scheduler cores the live system runs,
//! driven in virtual time with calibrated workload durations.
//!
//! Protocol (paper section IV.B): 100 evaluations per benchmark; a fixed
//! number of jobs (2 or 10) is maintained in the queue — a new submission
//! is issued whenever a job finishes.  The same seeded duration stream
//! feeds every scheduler.

use std::collections::HashMap;

use crate::cluster::{ClusterSpec, OverheadModel};
use crate::clock::{Des, Micros, MS, SEC};
use crate::hqlite::{AutoAllocConfig, HqAction, HqCore, HqTimer, TaskSpec};
use crate::metrics::{Experiment, JobRecord};
use crate::slurmlite::core::{Action, SlurmCore, Timer, USER_EXPERIMENT};
use crate::workload::{scenario, App, RuntimeModel};

/// Experiment configuration shared by all schedulers.
#[derive(Clone, Debug)]
pub struct Config {
    pub app: App,
    pub n_evals: u64,
    /// Jobs maintained in the queue (2 or 10 in the paper).
    pub queue_depth: usize,
    pub seed: u64,
    pub cluster: ClusterSpec,
    pub overheads: OverheadModel,
    /// Registration pre-jobs the UM-Bridge load balancer issues before
    /// the first evaluation ("at least five additional jobs", section V).
    pub registration_jobs: u64,
}

impl Config {
    pub fn paper(app: App, queue_depth: usize, seed: u64) -> Config {
        Config {
            app,
            n_evals: 100,
            queue_depth,
            seed,
            cluster: ClusterSpec::hamilton8(),
            overheads: OverheadModel::paper(),
            registration_jobs: 5,
        }
    }
}

/// SLURM native log granularity (whole seconds; paper section V).
const SLURM_LOG_GRAIN: Micros = SEC;

// ---------------------------------------------------------------------------
// Naive SLURM: one sbatch job per evaluation (the paper's baseline).
// ---------------------------------------------------------------------------

pub fn run_naive_slurm(cfg: &Config) -> Experiment {
    run_slurm_like(cfg, 0, 0, "SLURM")
}

/// UM-Bridge SLURM backend (Appendix A): same per-job submission path,
/// plus the model-server start-up inside each job and the balancer's
/// proxy latency on submission.
pub fn run_umbridge_slurm(cfg: &Config) -> Experiment {
    run_slurm_like(cfg, cfg.overheads.server_init, 50 * MS, "UM-Bridge SLURM")
}

fn run_slurm_like(
    cfg: &Config,
    per_job_extra: Micros,
    submit_extra: Micros,
    label: &str,
) -> Experiment {
    #[derive(Debug)]
    enum Ev {
        Timer(Timer),
        SubmitNext,
        Finish(u64),
    }

    let scen = scenario(cfg.app);
    let rtm = RuntimeModel::new(cfg.seed);
    let mut core = SlurmCore::new(cfg.cluster.clone(),
                                  cfg.overheads.clone(), cfg.seed);
    let mut des: Des<Ev> = Des::new();
    let mut exp = Experiment::new(label);
    let mut next_eval: u64 = 0;
    let mut durations: HashMap<u64, Micros> = HashMap::new();

    for a in core.bootstrap(0) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, Ev::Timer(tm));
        }
    }
    // Fill the queue.
    for _ in 0..cfg.queue_depth.min(cfg.n_evals as usize) {
        des.schedule(0, Ev::SubmitNext);
    }

    let mut completed: u64 = 0;
    let mut guard: u64 = 0;
    // One reusable action buffer for the whole run: the cores append into
    // it instead of allocating a fresh Vec per transition.
    let mut acts: Vec<Action> = Vec::new();
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway experiment");
        acts.clear();
        match ev {
            Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
            Ev::SubmitNext => {
                if next_eval < cfg.n_evals {
                    let tag = next_eval;
                    next_eval += 1;
                    let dur = rtm.duration(cfg.app, tag) + per_job_extra;
                    let id = core.submit_into(
                        t + submit_extra,
                        USER_EXPERIMENT,
                        tag,
                        scen.slurm_request(),
                        &mut acts,
                    );
                    durations.insert(id, dur);
                }
            }
            Ev::Finish(id) => core.on_finish_into(t, id, &mut acts),
        }
        for a in acts.drain(..) {
            match a {
                Action::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Action::Launched { job, contention, .. } => {
                    if let Some(d) = durations.get(&job) {
                        let dd = (*d as f64 * contention) as Micros;
                        des.schedule(t + dd, Ev::Finish(job));
                    }
                }
                Action::Completed { record, .. } => {
                    if record.tag != u64::MAX {
                        completed += 1;
                        exp.records.push(record.quantised(SLURM_LOG_GRAIN));
                        des.schedule(t, Ev::SubmitNext);
                    }
                }
                Action::TimedOut { .. } => {}
            }
        }
        if completed >= cfg.n_evals {
            break;
        }
    }
    exp.records.sort_by_key(|r| r.tag);
    exp
}

// ---------------------------------------------------------------------------
// UM-Bridge + HQ: one bulk allocation, tasks dispatched by hqlite.
// ---------------------------------------------------------------------------

pub fn run_umbridge_hq(cfg: &Config) -> Experiment {
    #[derive(Debug)]
    enum Ev {
        Slurm(Timer),
        Hq(HqTimer),
        SubmitNext,
        TaskDone(u64),
        SlurmFinish(u64),
    }

    let scen = scenario(cfg.app);
    let rtm = RuntimeModel::new(cfg.seed);
    let mut slurm = SlurmCore::new(cfg.cluster.clone(),
                                   cfg.overheads.clone(), cfg.seed);
    // Worker concurrency tracks the client's queue depth; one worker per
    // allocation, as in the paper's configuration example.
    let mut hq = HqCore::new(AutoAllocConfig {
        backlog: cfg.queue_depth as u32,
        workers_per_alloc: 1,
        max_worker_count: cfg.queue_depth as u32,
        alloc_request: scen.hq_alloc_request(),
        dispatch_latency: cfg.overheads.hq_dispatch,
    });
    let mut des: Des<Ev> = Des::new();
    let mut exp = Experiment::new("HQ");

    // alloc slurm-job id -> hq bookkeeping
    let mut alloc_jobs: HashMap<u64, u64> = HashMap::new(); // slurm id -> tag
    let mut task_durations: HashMap<u64, Micros> = HashMap::new();
    let total_tasks = cfg.registration_jobs + cfg.n_evals;
    let mut next_task: u64 = 0;

    for a in slurm.bootstrap(0) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, Ev::Slurm(tm));
        }
    }
    // Registration pre-jobs go first (the balancer's readiness checks),
    // then the client fills the queue.
    for _ in 0..cfg.registration_jobs as usize + cfg.queue_depth {
        des.schedule(0, Ev::SubmitNext);
    }

    let mut eval_records: u64 = 0;
    let mut guard: u64 = 0;
    // Reusable action buffers: the cores append into `*_acts`; the
    // routing loop swaps each into a batch buffer before interpreting,
    // so interpretation can append follow-up actions without allocating.
    let mut slurm_acts: Vec<Action> = Vec::new();
    let mut hq_acts: Vec<HqAction> = Vec::new();
    let mut slurm_batch: Vec<Action> = Vec::new();
    let mut hq_batch: Vec<HqAction> = Vec::new();
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway experiment");
        // Collect actions from whichever core fired.
        match ev {
            Ev::Slurm(tm) => slurm.on_timer_into(t, tm, &mut slurm_acts),
            Ev::Hq(tm) => hq.on_timer_into(t, tm, &mut hq_acts),
            Ev::SubmitNext => {
                if next_task < total_tasks {
                    let tag = next_task;
                    next_task += 1;
                    let is_reg = tag < cfg.registration_jobs;
                    // Registration jobs: ~1 s of server init only.
                    let dur = if is_reg {
                        cfg.overheads.server_init
                    } else {
                        rtm.duration(cfg.app, tag - cfg.registration_jobs)
                            + cfg.overheads.server_init
                    };
                    let tid = hq.submit_task_into(t, TaskSpec {
                        tag,
                        cores: scen.cpus,
                        time_request: scen.hq_time_request,
                        time_limit: scen.hq_time_limit
                            + cfg.overheads.server_init,
                    }, &mut hq_acts);
                    task_durations.insert(tid, dur);
                }
            }
            Ev::TaskDone(tid) => hq.on_task_done_into(t, tid, &mut hq_acts),
            Ev::SlurmFinish(id) => {
                slurm.on_finish_into(t, id, &mut slurm_acts);
                if alloc_jobs.contains_key(&id) {
                    // Allocation ended: expire its worker so hqlite
                    // requeues tasks and requests replacement capacity.
                    hq.expire_workers_into(t, &mut hq_acts);
                }
            }
        }

        // Route until both action queues drain (they feed each other).
        loop {
            let mut progressed = false;
            std::mem::swap(&mut slurm_acts, &mut slurm_batch);
            for a in slurm_batch.drain(..) {
                progressed = true;
                match a {
                    Action::Timer(tt, tm) => des.schedule(tt, Ev::Slurm(tm)),
                    Action::Launched { job, .. } => {
                        if alloc_jobs.contains_key(&job) {
                            // Allocation is up: a worker registers for the
                            // remaining allocation lifetime.
                            hq.on_alloc_up_into(
                                t,
                                scen.hq_alloc_time,
                                scen.cpus,
                                &mut hq_acts,
                            );
                            // The allocation job ends at its time limit.
                            des.schedule(
                                t + scen.hq_alloc_time,
                                Ev::SlurmFinish(job),
                            );
                        }
                    }
                    Action::Completed { .. } | Action::TimedOut { .. } => {}
                }
            }
            std::mem::swap(&mut hq_acts, &mut hq_batch);
            for a in hq_batch.drain(..) {
                progressed = true;
                match a {
                    HqAction::SubmitAllocation { alloc_tag, req } => {
                        let id = slurm.submit_into(
                            t,
                            USER_EXPERIMENT,
                            u64::MAX - 1,
                            req,
                            &mut slurm_acts,
                        );
                        alloc_jobs.insert(id, alloc_tag);
                    }
                    HqAction::StartTask { task, .. } => {
                        let dur = task_durations[&task];
                        des.schedule(t + dur, Ev::TaskDone(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Hq(tm)),
                    HqAction::TaskCompleted { record, .. } => {
                        // HQ logs at millisecond accuracy.
                        let rec = record.quantised(MS);
                        if rec.tag >= cfg.registration_jobs {
                            let mut rec = rec;
                            rec.tag -= cfg.registration_jobs;
                            eval_records += 1;
                            exp.records.push(rec);
                            des.schedule(t, Ev::SubmitNext);
                        } else {
                            // Registration jobs trigger the next submit
                            // too (they precede the queue fill).
                            exp.records.push(JobRecord {
                                tag: u64::MAX, // marked, excluded later
                                ..rec
                            });
                        }
                    }
                    HqAction::KillTask { .. } => {}
                }
            }
            if !progressed {
                break;
            }
        }
        if eval_records >= cfg.n_evals {
            break;
        }
    }
    // Keep registration jobs as the paper's "lower outliers"?  The paper
    // counts them as extra jobs; Fig 3 boxplots are over *evaluation*
    // jobs with registration jobs visible as low outliers for GS2.  We
    // keep them (tag u64::MAX) out of the figure records:
    exp.records.retain(|r| r.tag != u64::MAX);
    exp.records.sort_by_key(|r| r.tag);
    exp
}

/// All three schedulers on one configuration.
pub fn run_all(cfg: &Config) -> (Experiment, Experiment, Experiment) {
    (run_naive_slurm(cfg), run_umbridge_hq(cfg), run_umbridge_slurm(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::MIN;

    fn small_cfg(app: App, qd: usize) -> Config {
        let mut c = Config::paper(app, qd, 11);
        c.n_evals = 12;
        c.cluster = ClusterSpec::small(8);
        // Keep background load light so tests are fast.
        c.overheads.bg_interarrival = 300 * SEC;
        c
    }

    #[test]
    fn naive_slurm_completes_all_evals() {
        let e = run_naive_slurm(&small_cfg(App::Eigen100, 2));
        assert_eq!(e.records.len(), 12);
        for r in &e.records {
            assert!(r.end >= r.start);
            assert!(r.makespan() >= r.cpu);
        }
    }

    #[test]
    fn hq_completes_all_evals() {
        let e = run_umbridge_hq(&small_cfg(App::Eigen100, 2));
        assert_eq!(e.records.len(), 12);
    }

    #[test]
    fn hq_overhead_is_orders_of_magnitude_lower() {
        // The paper's headline: up to three orders of magnitude lower
        // scheduling overhead (excluding the first-allocation wait).
        let cfg = small_cfg(App::Eigen5000, 2);
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        let med = |mut v: Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let s_over = med(s.overheads_sec());
        let h_over = med(h.overheads_sec());
        assert!(
            s_over > h_over * 50.0,
            "SLURM {s_over} vs HQ {h_over} (want >=50x)"
        );
    }

    #[test]
    fn hq_cpu_higher_on_fast_jobs() {
        // Server init (~1 s) dominates eigen-100 (~0.6 s): the paper
        // observes HQ *loses* on CPU time for the fastest benchmark.
        let cfg = small_cfg(App::Eigen100, 2);
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        let mean = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        // SLURM cpu includes prolog; HQ cpu includes server init.  With
        // paper constants the prolog (4 s) actually exceeds server init
        // (1 s); the paper's SLURM env is faster.  What must hold is the
        // *makespan* advantage of HQ:
        assert!(mean(h.makespans_sec()) < mean(s.makespans_sec()));
    }

    #[test]
    fn gs2_makespan_reduction_tens_of_percent() {
        let mut cfg = small_cfg(App::Gs2, 2);
        cfg.n_evals = 10;
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        let mean = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let ms = mean(s.makespans_sec());
        let mh = mean(h.makespans_sec());
        assert!(mh < ms, "HQ {mh} vs SLURM {ms}");
    }

    #[test]
    fn umbridge_slurm_no_better_than_naive() {
        // Appendix A: the SLURM backend gives no gains over the baseline.
        let cfg = small_cfg(App::Eigen100, 2);
        let s = run_naive_slurm(&cfg);
        let u = run_umbridge_slurm(&cfg);
        let mean = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(u.makespans_sec()) >= mean(s.makespans_sec()) * 0.95);
    }

    #[test]
    fn slurm_records_quantised_to_seconds() {
        let e = run_naive_slurm(&small_cfg(App::Eigen100, 2));
        for r in &e.records {
            assert_eq!(r.submit % SEC, 0);
            assert_eq!(r.end % SEC, 0);
        }
    }

    #[test]
    fn queue_depth_bounds_inflight() {
        // With depth 2, at most 2 evaluation jobs overlap in time.
        let e = run_naive_slurm(&small_cfg(App::Eigen5000, 2));
        let mut events: Vec<(Micros, i32)> = Vec::new();
        for r in &e.records {
            events.push((r.submit, 1));
            events.push((r.end, -1));
        }
        events.sort();
        let mut inflight = 0;
        let mut max_inflight = 0;
        for (_, d) in events {
            inflight += d;
            max_inflight = max_inflight.max(inflight);
        }
        assert!(max_inflight <= 2, "inflight {max_inflight}");
    }

    #[test]
    fn slr_at_least_one() {
        for app in [App::Eigen100, App::Gp] {
            let cfg = small_cfg(app, 2);
            for e in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg)] {
                for r in &e.records {
                    assert!(r.slr() >= 1.0 - 1e-9, "{} slr {}", e.label,
                            r.slr());
                }
            }
        }
    }
}
