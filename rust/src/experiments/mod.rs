//! The experiment harness: regenerates the paper's evaluation (Figs 3-6)
//! on the sim plane — the same scheduler cores the live system runs,
//! driven in virtual time with calibrated workload durations.
//!
//! Protocol (paper section IV.B): 100 evaluations per benchmark; a fixed
//! number of jobs (2 or 10) is maintained in the queue — a new submission
//! is issued whenever a job finishes.  The same seeded duration stream
//! feeds every scheduler.
//!
//! The entry points are thin wrappers over the campaign adapters
//! ([`crate::campaign`]) with the
//! [`FixedDepth`](crate::campaign::FixedDepth) submitter, which all
//! route through the one generic scheduler kernel
//! ([`crate::sched::kernel`]); the original hand-written loops are
//! preserved in [`reference`] and `tests/campaign_equiv.rs` pins
//! record-for-record equivalence.  [`run_umbridge_worksteal`] and
//! [`run_umbridge_edf`] run the same protocol against the third
//! (work-stealing) and fourth (deadline-EDF) schedulers.

pub mod reference;

use crate::campaign::{self, CampaignConfig, FixedDepth, SlurmMode};
use crate::cluster::{ClusterSpec, OverheadModel};
use crate::metrics::Experiment;
use crate::workload::App;

/// Experiment configuration shared by all schedulers.
#[derive(Clone, Debug)]
pub struct Config {
    pub app: App,
    pub n_evals: u64,
    /// Jobs maintained in the queue (2 or 10 in the paper).
    pub queue_depth: usize,
    pub seed: u64,
    pub cluster: ClusterSpec,
    pub overheads: OverheadModel,
    /// Registration pre-jobs the UM-Bridge load balancer issues before
    /// the first evaluation ("at least five additional jobs", section V).
    pub registration_jobs: u64,
}

impl Config {
    pub fn paper(app: App, queue_depth: usize, seed: u64) -> Config {
        Config {
            app,
            n_evals: 100,
            queue_depth,
            seed,
            cluster: ClusterSpec::hamilton8(),
            overheads: OverheadModel::paper(),
            registration_jobs: 5,
        }
    }

    /// The campaign-plane view of this configuration (same cluster,
    /// overheads and HQ worker geometry).
    pub fn campaign(&self) -> CampaignConfig {
        CampaignConfig {
            app: self.app,
            seed: self.seed,
            cluster: self.cluster.clone(),
            overheads: self.overheads.clone(),
            registration_jobs: self.registration_jobs,
            hq_backlog: self.queue_depth as u32,
            hq_workers: self.queue_depth as u32,
            faults: None,
        }
    }

    /// The paper's submission protocol as a submitter.
    fn fixed_depth(&self) -> FixedDepth {
        FixedDepth::new(self.app, self.n_evals, self.queue_depth, self.seed)
    }
}

/// Naive SLURM: one sbatch job per evaluation (the paper's baseline).
pub fn run_naive_slurm(cfg: &Config) -> Experiment {
    let mut sub = cfg.fixed_depth();
    campaign::run_slurm(&cfg.campaign(), &mut sub, SlurmMode::Native)
        .experiment
}

/// UM-Bridge SLURM backend (Appendix A): same per-job submission path,
/// plus the model-server start-up inside each job and the balancer's
/// proxy latency on submission.
pub fn run_umbridge_slurm(cfg: &Config) -> Experiment {
    let mut sub = cfg.fixed_depth();
    campaign::run_slurm(&cfg.campaign(), &mut sub, SlurmMode::UmBridge)
        .experiment
}

/// UM-Bridge + HQ: one bulk allocation, tasks dispatched by hqlite.
pub fn run_umbridge_hq(cfg: &Config) -> Experiment {
    let mut sub = cfg.fixed_depth();
    campaign::run_hq(&cfg.campaign(), &mut sub).experiment
}

/// UM-Bridge + work stealing: the same bulk-allocation stack as
/// [`run_umbridge_hq`], with tasks dispatched by the partitioned
/// work-stealing core ([`crate::sched::WorkStealCore`]) instead of the
/// central FCFS queue.
pub fn run_umbridge_worksteal(cfg: &Config) -> Experiment {
    let mut sub = cfg.fixed_depth();
    campaign::run_worksteal(&cfg.campaign(), &mut sub).experiment
}

/// UM-Bridge + deadline-EDF: the same bulk-allocation stack as
/// [`run_umbridge_hq`], with tasks dispatched strictly earliest deadline
/// first ([`crate::sched::EdfCore`]).
pub fn run_umbridge_edf(cfg: &Config) -> Experiment {
    let mut sub = cfg.fixed_depth();
    campaign::run_edf(&cfg.campaign(), &mut sub).experiment
}

/// All three paper schedulers on one configuration.
pub fn run_all(cfg: &Config) -> (Experiment, Experiment, Experiment) {
    (run_naive_slurm(cfg), run_umbridge_hq(cfg), run_umbridge_slurm(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{Micros, SEC};

    fn small_cfg(app: App, qd: usize) -> Config {
        let mut c = Config::paper(app, qd, 11);
        c.n_evals = 12;
        c.cluster = ClusterSpec::small(8);
        // Keep background load light so tests are fast.
        c.overheads.bg_interarrival = 300 * SEC;
        c
    }

    #[test]
    fn naive_slurm_completes_all_evals() {
        let e = run_naive_slurm(&small_cfg(App::Eigen100, 2));
        assert_eq!(e.records.len(), 12);
        for r in &e.records {
            assert!(r.end >= r.start);
            assert!(r.makespan() >= r.cpu);
        }
    }

    #[test]
    fn hq_completes_all_evals() {
        let e = run_umbridge_hq(&small_cfg(App::Eigen100, 2));
        assert_eq!(e.records.len(), 12);
    }

    #[test]
    fn edf_completes_all_evals() {
        let e = run_umbridge_edf(&small_cfg(App::Eigen100, 2));
        assert_eq!(e.records.len(), 12);
        for r in &e.records {
            assert!(r.submit <= r.start && r.start <= r.end);
        }
    }

    #[test]
    fn worksteal_completes_all_evals_with_hq_class_overhead() {
        // The work-stealing stack shares HQ's bulk-allocation mechanics,
        // so once workers are up its per-task overhead must stay in HQ's
        // class (dispatch-latency scale), far below SLURM's.
        let cfg = small_cfg(App::Eigen5000, 2);
        let w = run_umbridge_worksteal(&cfg);
        assert_eq!(w.records.len(), 12);
        let s = run_naive_slurm(&cfg);
        let med = |mut v: Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let s_over = med(s.overheads_sec());
        let w_over = med(w.overheads_sec());
        assert!(
            s_over > w_over * 50.0,
            "SLURM {s_over} vs worksteal {w_over} (want >=50x)"
        );
    }

    #[test]
    fn hq_overhead_is_orders_of_magnitude_lower() {
        // The paper's headline: up to three orders of magnitude lower
        // scheduling overhead (excluding the first-allocation wait).
        let cfg = small_cfg(App::Eigen5000, 2);
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        let med = |mut v: Vec<f64>| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let s_over = med(s.overheads_sec());
        let h_over = med(h.overheads_sec());
        assert!(
            s_over > h_over * 50.0,
            "SLURM {s_over} vs HQ {h_over} (want >=50x)"
        );
    }

    #[test]
    fn hq_cpu_higher_on_fast_jobs() {
        // Server init (~1 s) dominates eigen-100 (~0.6 s): the paper
        // observes HQ *loses* on CPU time for the fastest benchmark.
        let cfg = small_cfg(App::Eigen100, 2);
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        let mean = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        // SLURM cpu includes prolog; HQ cpu includes server init.  With
        // paper constants the prolog (4 s) actually exceeds server init
        // (1 s); the paper's SLURM env is faster.  What must hold is the
        // *makespan* advantage of HQ:
        assert!(mean(h.makespans_sec()) < mean(s.makespans_sec()));
    }

    #[test]
    fn gs2_makespan_reduction_tens_of_percent() {
        let mut cfg = small_cfg(App::Gs2, 2);
        cfg.n_evals = 10;
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        let mean = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let ms = mean(s.makespans_sec());
        let mh = mean(h.makespans_sec());
        assert!(mh < ms, "HQ {mh} vs SLURM {ms}");
    }

    #[test]
    fn umbridge_slurm_no_better_than_naive() {
        // Appendix A: the SLURM backend gives no gains over the baseline.
        let cfg = small_cfg(App::Eigen100, 2);
        let s = run_naive_slurm(&cfg);
        let u = run_umbridge_slurm(&cfg);
        let mean = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(u.makespans_sec()) >= mean(s.makespans_sec()) * 0.95);
    }

    #[test]
    fn slurm_records_quantised_to_seconds() {
        let e = run_naive_slurm(&small_cfg(App::Eigen100, 2));
        for r in &e.records {
            assert_eq!(r.submit % SEC, 0);
            assert_eq!(r.end % SEC, 0);
        }
    }

    #[test]
    fn queue_depth_bounds_inflight() {
        // With depth 2, at most 2 evaluation jobs overlap in time.
        let e = run_naive_slurm(&small_cfg(App::Eigen5000, 2));
        let mut events: Vec<(Micros, i32)> = Vec::new();
        for r in &e.records {
            events.push((r.submit, 1));
            events.push((r.end, -1));
        }
        events.sort();
        let mut inflight = 0;
        let mut max_inflight = 0;
        for (_, d) in events {
            inflight += d;
            max_inflight = max_inflight.max(inflight);
        }
        assert!(max_inflight <= 2, "inflight {max_inflight}");
    }

    #[test]
    fn slr_at_least_one() {
        for app in [App::Eigen100, App::Gp] {
            let cfg = small_cfg(app, 2);
            for e in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg)] {
                for r in &e.records {
                    assert!(r.slr() >= 1.0 - 1e-9, "{} slr {}", e.label,
                            r.slr());
                }
            }
        }
    }
}
