//! The PR 1 experiment drivers, preserved verbatim as the behavioural
//! reference for the campaign plane (the same pattern as
//! `slurmlite::reference` / `hqlite::reference`): hand-written
//! fixed-depth event loops, one per scheduler.
//!
//! The production path is `experiments::run_*`, which routes through the
//! generic campaign driver with the
//! [`FixedDepth`](crate::campaign::FixedDepth) submitter;
//! `tests/campaign_equiv.rs` asserts the two produce **identical**
//! `Experiment` records for every app on every scheduler.  Keep this
//! module frozen — fix behaviour in `campaign::driver`, not here.

use std::collections::HashMap;

use crate::clock::{Des, Micros, MS, SEC};
use crate::hqlite::{AutoAllocConfig, HqAction, HqCore, HqTimer, TaskCore,
                    TaskSpec};
use crate::metrics::{Experiment, JobRecord};
use crate::slurmlite::core::{Action, BatchCore, SlurmCore, Timer,
                             USER_EXPERIMENT};
use crate::workload::{scenario, RuntimeModel};

use super::Config;

/// SLURM native log granularity (whole seconds; paper section V).
const SLURM_LOG_GRAIN: Micros = SEC;

// ---------------------------------------------------------------------------
// Naive SLURM: one sbatch job per evaluation (the paper's baseline).
// ---------------------------------------------------------------------------

pub fn run_naive_slurm(cfg: &Config) -> Experiment {
    run_slurm_like(cfg, 0, 0, "SLURM")
}

/// UM-Bridge SLURM backend (Appendix A): same per-job submission path,
/// plus the model-server start-up inside each job and the balancer's
/// proxy latency on submission.
pub fn run_umbridge_slurm(cfg: &Config) -> Experiment {
    run_slurm_like(cfg, cfg.overheads.server_init, 50 * MS, "UM-Bridge SLURM")
}

fn run_slurm_like(
    cfg: &Config,
    per_job_extra: Micros,
    submit_extra: Micros,
    label: &str,
) -> Experiment {
    #[derive(Debug)]
    enum Ev {
        Timer(Timer),
        SubmitNext,
        Finish(u64),
    }

    let scen = scenario(cfg.app);
    let rtm = RuntimeModel::new(cfg.seed);
    let mut core = SlurmCore::new(cfg.cluster.clone(),
                                  cfg.overheads.clone(), cfg.seed);
    let mut des: Des<Ev> = Des::new();
    let mut exp = Experiment::new(label);
    let mut next_eval: u64 = 0;
    let mut durations: HashMap<u64, Micros> = HashMap::new();

    for a in core.bootstrap(0) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, Ev::Timer(tm));
        }
    }
    // Fill the queue.
    for _ in 0..cfg.queue_depth.min(cfg.n_evals as usize) {
        des.schedule(0, Ev::SubmitNext);
    }

    let mut completed: u64 = 0;
    let mut guard: u64 = 0;
    // One reusable action buffer for the whole run: the cores append into
    // it instead of allocating a fresh Vec per transition.
    let mut acts: Vec<Action> = Vec::new();
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway experiment");
        acts.clear();
        match ev {
            Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
            Ev::SubmitNext => {
                if next_eval < cfg.n_evals {
                    let tag = next_eval;
                    next_eval += 1;
                    let dur = rtm.duration(cfg.app, tag) + per_job_extra;
                    let id = core.submit_into(
                        t + submit_extra,
                        USER_EXPERIMENT,
                        tag,
                        scen.slurm_request(),
                        &mut acts,
                    );
                    durations.insert(id, dur);
                }
            }
            Ev::Finish(id) => core.on_finish_into(t, id, &mut acts),
        }
        for a in acts.drain(..) {
            match a {
                Action::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Action::Launched { job, contention, .. } => {
                    if let Some(d) = durations.get(&job) {
                        let dd = (*d as f64 * contention) as Micros;
                        des.schedule(t + dd, Ev::Finish(job));
                    }
                }
                Action::Completed { record, .. } => {
                    if record.tag != u64::MAX {
                        completed += 1;
                        exp.records.push(record.quantised(SLURM_LOG_GRAIN));
                        des.schedule(t, Ev::SubmitNext);
                    }
                }
                Action::TimedOut { .. } => {}
            }
        }
        if completed >= cfg.n_evals {
            break;
        }
    }
    exp.records.sort_by_key(|r| r.tag);
    exp
}

// ---------------------------------------------------------------------------
// UM-Bridge + HQ: one bulk allocation, tasks dispatched by hqlite.
// ---------------------------------------------------------------------------

pub fn run_umbridge_hq(cfg: &Config) -> Experiment {
    #[derive(Debug)]
    enum Ev {
        Slurm(Timer),
        Hq(HqTimer),
        SubmitNext,
        TaskDone(u64),
        SlurmFinish(u64),
    }

    let scen = scenario(cfg.app);
    let rtm = RuntimeModel::new(cfg.seed);
    let mut slurm = SlurmCore::new(cfg.cluster.clone(),
                                   cfg.overheads.clone(), cfg.seed);
    // Worker concurrency tracks the client's queue depth; one worker per
    // allocation, as in the paper's configuration example.
    let mut hq = HqCore::new(AutoAllocConfig {
        backlog: cfg.queue_depth as u32,
        workers_per_alloc: 1,
        max_worker_count: cfg.queue_depth as u32,
        alloc_request: scen.hq_alloc_request(),
        dispatch_latency: cfg.overheads.hq_dispatch,
    });
    let mut des: Des<Ev> = Des::new();
    let mut exp = Experiment::new("HQ");

    // alloc slurm-job id -> hq bookkeeping
    let mut alloc_jobs: HashMap<u64, u64> = HashMap::new(); // slurm id -> tag
    let mut task_durations: HashMap<u64, Micros> = HashMap::new();
    let total_tasks = cfg.registration_jobs + cfg.n_evals;
    let mut next_task: u64 = 0;

    for a in slurm.bootstrap(0) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, Ev::Slurm(tm));
        }
    }
    // Registration pre-jobs go first (the balancer's readiness checks),
    // then the client fills the queue.
    for _ in 0..cfg.registration_jobs as usize + cfg.queue_depth {
        des.schedule(0, Ev::SubmitNext);
    }

    let mut eval_records: u64 = 0;
    let mut guard: u64 = 0;
    // Reusable action buffers: the cores append into `*_acts`; the
    // routing loop swaps each into a batch buffer before interpreting,
    // so interpretation can append follow-up actions without allocating.
    let mut slurm_acts: Vec<Action> = Vec::new();
    let mut hq_acts: Vec<HqAction> = Vec::new();
    let mut slurm_batch: Vec<Action> = Vec::new();
    let mut hq_batch: Vec<HqAction> = Vec::new();
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 50_000_000, "runaway experiment");
        // Collect actions from whichever core fired.
        match ev {
            Ev::Slurm(tm) => slurm.on_timer_into(t, tm, &mut slurm_acts),
            Ev::Hq(tm) => hq.on_timer_into(t, tm, &mut hq_acts),
            Ev::SubmitNext => {
                if next_task < total_tasks {
                    let tag = next_task;
                    next_task += 1;
                    let is_reg = tag < cfg.registration_jobs;
                    // Registration jobs: ~1 s of server init only.
                    let dur = if is_reg {
                        cfg.overheads.server_init
                    } else {
                        rtm.duration(cfg.app, tag - cfg.registration_jobs)
                            + cfg.overheads.server_init
                    };
                    let tid = hq.submit_task_into(t, TaskSpec {
                        tag,
                        cores: scen.cpus,
                        time_request: scen.hq_time_request,
                        time_limit: scen.hq_time_limit
                            + cfg.overheads.server_init,
                    }, &mut hq_acts);
                    task_durations.insert(tid, dur);
                }
            }
            Ev::TaskDone(tid) => hq.on_task_done_into(t, tid, &mut hq_acts),
            Ev::SlurmFinish(id) => {
                slurm.on_finish_into(t, id, &mut slurm_acts);
                if alloc_jobs.contains_key(&id) {
                    // Allocation ended: expire its worker so hqlite
                    // requeues tasks and requests replacement capacity.
                    hq.expire_workers_into(t, &mut hq_acts);
                }
            }
        }

        // Route until both action queues drain (they feed each other).
        loop {
            let mut progressed = false;
            std::mem::swap(&mut slurm_acts, &mut slurm_batch);
            for a in slurm_batch.drain(..) {
                progressed = true;
                match a {
                    Action::Timer(tt, tm) => des.schedule(tt, Ev::Slurm(tm)),
                    Action::Launched { job, .. } => {
                        if alloc_jobs.contains_key(&job) {
                            // Allocation is up: a worker registers for the
                            // remaining allocation lifetime.
                            let _ = hq.on_alloc_up_into(
                                t,
                                scen.hq_alloc_time,
                                scen.cpus,
                                &mut hq_acts,
                            );
                            // The allocation job ends at its time limit.
                            des.schedule(
                                t + scen.hq_alloc_time,
                                Ev::SlurmFinish(job),
                            );
                        }
                    }
                    Action::Completed { .. } | Action::TimedOut { .. } => {}
                }
            }
            std::mem::swap(&mut hq_acts, &mut hq_batch);
            for a in hq_batch.drain(..) {
                progressed = true;
                match a {
                    HqAction::SubmitAllocation { alloc_tag, req } => {
                        let id = slurm.submit_into(
                            t,
                            USER_EXPERIMENT,
                            u64::MAX - 1,
                            req,
                            &mut slurm_acts,
                        );
                        alloc_jobs.insert(id, alloc_tag);
                    }
                    HqAction::StartTask { task, .. }
                    | HqAction::StartGang { task, .. } => {
                        let dur = task_durations[&task];
                        des.schedule(t + dur, Ev::TaskDone(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Hq(tm)),
                    HqAction::TaskCompleted { record, .. } => {
                        // HQ logs at millisecond accuracy.
                        let rec = record.quantised(MS);
                        if rec.tag >= cfg.registration_jobs {
                            let mut rec = rec;
                            rec.tag -= cfg.registration_jobs;
                            eval_records += 1;
                            exp.records.push(rec);
                            des.schedule(t, Ev::SubmitNext);
                        } else {
                            // Registration jobs trigger the next submit
                            // too (they precede the queue fill).
                            exp.records.push(JobRecord {
                                tag: u64::MAX, // marked, excluded later
                                ..rec
                            });
                        }
                    }
                    HqAction::KillTask { .. } => {}
                    // This reference loop injects no faults, so nothing
                    // is ever requeued; the arm keeps the frozen module
                    // compiling as the action vocabulary grows.
                    HqAction::Requeued { .. } => {}
                }
            }
            if !progressed {
                break;
            }
        }
        if eval_records >= cfg.n_evals {
            break;
        }
    }
    // Keep registration jobs as the paper's "lower outliers"?  The paper
    // counts them as extra jobs; Fig 3 boxplots are over *evaluation*
    // jobs with registration jobs visible as low outliers for GS2.  We
    // keep them (tag u64::MAX) out of the figure records:
    exp.records.retain(|r| r.tag != u64::MAX);
    exp.records.sort_by_key(|r| r.tag);
    exp
}
