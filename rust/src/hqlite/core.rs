//! The hqlite server state machine (pure logic, both planes).
//!
//! # Scale architecture (see PERF.md)
//!
//! HyperQueue's value proposition is absorbing 10⁵–10⁶ tiny tasks, so
//! the server must not do per-task work proportional to the total number
//! of tasks or workers ever seen:
//!
//! * The task queue is a `VecDeque` scanned FCFS with a per-pass failure
//!   frontier: once a `(cores, time_request)` shape finds no worker, any
//!   shape needing at least as much is skipped, and the pass stops
//!   entirely when the frontier covers the queue-wide minimum request —
//!   O(dispatched + 1) per pass for homogeneous UQ streams (the seed
//!   cloned and rescanned the whole queue on every submission).
//! * Workers with free cores sit in an ordered `avail` set; dispatch
//!   probes candidates in worker-id order and stops at the first fit
//!   instead of scanning every worker ever registered.
//! * Each worker carries its running-task set, so losing a worker
//!   requeues exactly its own tasks (the seed scanned every task ever
//!   submitted).  Requeue order is ascending task id — deterministic,
//!   where the seed inherited HashMap iteration order.
//! * Worker expiries live in a min-heap; `expire_workers` pops due
//!   entries instead of iterating all workers.
//! * Finished tasks are evicted from the hot map (the driver owns the
//!   emitted `JobRecord`), so steady-state memory is bounded by in-flight
//!   work.  Dead workers leave the worker map entirely.
//! * Every transition appends into a caller-supplied action buffer (the
//!   [`TaskCore`] trait's `*_into` methods); the allocating wrappers are
//!   provided (default) trait methods for low-rate callers.
//!
//! The task/worker structs and the full lifecycle (timers, completion
//! records, autoalloc, Cooling/Retry recovery) live in the shared
//! [`TaskTable`](crate::sched::table::TaskTable); [`HqCore`] keeps only
//! its ready structure — the FCFS queue with the failure frontier — and
//! its lowest-id-first placement policy.  The same table carries
//! [`WorkStealCore`](crate::sched::WorkStealCore),
//! [`EdfCore`](crate::sched::EdfCore) and the gang scheduler
//! [`GangCore`](crate::sched::GangCore).

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, VecDeque};

use crate::cluster::JobRequest;
use crate::clock::Micros;
use crate::sched::table::{FailVerdict, TaskTable, TimerVerdict};

pub type TaskId = u64;
pub type WorkerId = u64;

/// One task submitted to the HQ server.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub tag: u64,
    pub cores: u32,
    /// Scheduling hint: expected runtime (HQ `--time-request`).
    pub time_request: Micros,
    /// Hard kill limit (HQ `--time-limit`).
    pub time_limit: Micros,
}

/// Automatic-allocation configuration (the paper's section II.D example:
/// `--backlog 1 --workers-per-alloc 1 --max-worker-count N`).
#[derive(Clone, Debug)]
pub struct AutoAllocConfig {
    /// Max allocations waiting in the native queue at once.
    pub backlog: u32,
    /// Workers started per allocation.
    pub workers_per_alloc: u32,
    /// Upper bound on simultaneously existing workers.
    pub max_worker_count: u32,
    /// Resources requested per allocation (cores sized for one worker).
    pub alloc_request: JobRequest,
    /// Per-task dispatch latency (server -> worker handoff).
    pub dispatch_latency: Micros,
}

/// Actions the driver must interpret.
#[derive(Clone, Debug)]
pub enum HqAction {
    /// Submit an allocation to the native scheduler (tag it so the driver
    /// can route the eventual worker registration back).
    SubmitAllocation { alloc_tag: u64, req: JobRequest },
    /// Begin task execution on a worker: the driver runs the workload and
    /// calls [`TaskCore::on_task_done`] (sim: after the sampled duration).
    StartTask { task: TaskId, worker: WorkerId },
    /// Begin a moldable gang task on its full worker set (ascending ids;
    /// the first member is the lead).  Emitted instead of `StartTask`
    /// whenever the reservation spans more than one worker — the
    /// single-worker cores never emit it.
    StartGang { task: TaskId, workers: Vec<WorkerId> },
    /// Kill the task (exceeded its time limit).
    KillTask { task: TaskId },
    /// Terminal per-task record.
    TaskCompleted { task: TaskId, record: crate::metrics::JobRecord },
    /// The task left its worker without finishing (transient failure or
    /// worker loss) and will run again later — the driver must
    /// invalidate any completion it scheduled for the aborted attempt.
    Requeued { task: TaskId },
    /// Re-invoke `on_timer` at this time.
    Timer(Micros, HqTimer),
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HqTimer {
    /// Dispatch latency elapsed: task actually starts on the worker.
    Dispatched(TaskId),
    /// Task time-limit enforcement.
    Limit(TaskId),
    /// Retry backoff elapsed: a Cooling task re-enters the queue.
    Retry(TaskId),
}

/// The HyperQueue-style task-scheduler event surface: the pluggable seam
/// between a meta-scheduler implementation and its driver.
///
/// [`HqCore`] (FCFS + failure frontier),
/// [`WorkStealCore`](crate::sched::WorkStealCore) (partitioned per-worker
/// deques with stealing), [`EdfCore`](crate::sched::EdfCore) (deadline
/// heap) and [`GangCore`](crate::sched::GangCore) (moldable multi-worker
/// gangs) all implement it, so the campaign stack
/// ([`crate::sched::MetaStack`]) and the property/bench harnesses run
/// generically over any implementation.
///
/// The `*_into` sink methods are the primary API (append into a
/// caller-supplied buffer); the Vec-returning wrappers are provided
/// methods, so the `let mut out = Vec::new()` boilerplate lives here
/// exactly once.
pub trait TaskCore {
    /// Submit a task, appending actions into a reusable buffer.  May
    /// trigger autoalloc and immediate dispatch.
    fn submit_task_into(
        &mut self,
        t: Micros,
        spec: TaskSpec,
        out: &mut Vec<HqAction>,
    ) -> TaskId;

    /// Allocation arrival, appending actions into a reusable buffer.
    /// Returns the id of the first worker admitted (None when the
    /// worker cap swallowed the allocation) so drivers can map their
    /// external worker handles onto the generational table ids.
    fn on_alloc_up_into(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
        out: &mut Vec<HqAction>,
    ) -> Option<WorkerId>;

    /// Worker loss, appending actions into a reusable buffer.  Must not
    /// lose tasks: everything Dispatched/Running on the worker requeues.
    fn on_worker_lost_into(
        &mut self,
        t: Micros,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    );

    /// Task completion, appending actions into a reusable buffer.
    fn on_task_done_into(&mut self, t: Micros, id: TaskId, out: &mut Vec<HqAction>);

    /// The task's attempt failed mid-run.  `retry_in: Some(backoff)`
    /// means the budget allows another attempt: free the worker, park
    /// the task (Cooling), arm a `Retry` timer and emit
    /// [`HqAction::Requeued`].  `None` means quarantine: kill the task
    /// and emit a truncated [`HqAction::TaskCompleted`] so the poison
    /// task is reported, never dropped.  Default: treat the failure as
    /// a (poisoned) completion so no task is lost by cores predating
    /// retry semantics.
    fn on_task_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        _retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) {
        self.on_task_done_into(t, id, out);
    }

    /// Is the task still resident (not yet completed)?  Drivers use
    /// this to drop dead dispatch/limit/retry timers at pop instead of
    /// replaying them into the core.  Default: conservatively live.
    fn task_live(&self, _id: TaskId) -> bool {
        true
    }

    /// Append the ids of live workers (crash-victim candidates for the
    /// fault plane).  Default: none (core is crash-immune).
    fn live_worker_ids_into(&self, _out: &mut Vec<u64>) {}

    /// Timer dispatch, appending actions into a reusable buffer.
    fn on_timer_into(&mut self, t: Micros, timer: HqTimer, out: &mut Vec<HqAction>);

    /// Worker expiry, appending actions into a reusable buffer.
    fn expire_workers_into(&mut self, t: Micros, out: &mut Vec<HqAction>);

    // ---- introspection ---------------------------------------------------

    /// Tasks waiting for dispatch (excluding lazily-dropped stale entries).
    fn pending_tasks(&self) -> usize;

    /// Live workers.
    fn live_workers(&self) -> usize;

    /// Allocations submitted to the native scheduler, not yet up.
    fn allocs_waiting(&self) -> u32;

    /// Tasks resident in the hot map (bounded by in-flight work).
    fn resident_tasks(&self) -> usize;

    /// Tasks completed and evicted.
    fn retired_count(&self) -> u64;

    // ---- provided allocating wrappers -------------------------------------

    /// Submit a task; may trigger autoalloc and immediate dispatch.
    fn submit_task(&mut self, t: Micros, spec: TaskSpec) -> (TaskId, Vec<HqAction>) {
        let mut out = Vec::new();
        let id = self.submit_task_into(t, spec, &mut out);
        (id, out)
    }

    /// A native allocation came up: start workers living until the
    /// allocation's time limit.
    fn on_alloc_up(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
    ) -> Vec<HqAction> {
        let mut out = Vec::new();
        let _ = self.on_alloc_up_into(t, time_limit, cores_per_worker, &mut out);
        out
    }

    /// A worker disappeared (allocation ended); requeue its tasks.
    fn on_worker_lost(&mut self, t: Micros, wid: WorkerId) -> Vec<HqAction> {
        let mut out = Vec::new();
        self.on_worker_lost_into(t, wid, &mut out);
        out
    }

    /// Driver reports a task's workload finished.
    fn on_task_done(&mut self, t: Micros, id: TaskId) -> Vec<HqAction> {
        let mut out = Vec::new();
        self.on_task_done_into(t, id, &mut out);
        out
    }

    /// Timer dispatch.
    fn on_timer(&mut self, t: Micros, timer: HqTimer) -> Vec<HqAction> {
        let mut out = Vec::new();
        self.on_timer_into(t, timer, &mut out);
        out
    }

    /// Expire workers whose allocation has ended (driver calls this when
    /// the native allocation job finishes); requeues their tasks and
    /// replaces capacity via autoalloc.
    fn expire_workers(&mut self, t: Micros) -> Vec<HqAction> {
        let mut out = Vec::new();
        self.expire_workers_into(t, &mut out);
        out
    }
}

/// Pop every worker due at or before `t` off an expiry min-heap,
/// skipping lazily-deleted entries (`alive` returns false for workers
/// already gone).  Shared by the [`TaskTable`] and the reference core —
/// both keep `(expires_t, worker)` min-heaps with lazy deletion.
pub(crate) fn drain_due_workers(
    expiry: &mut BinaryHeap<Reverse<(Micros, WorkerId)>>,
    t: Micros,
    alive: impl Fn(WorkerId) -> bool,
) -> Vec<WorkerId> {
    let mut expired = Vec::new();
    while let Some(&Reverse((et, wid))) = expiry.peek() {
        if et > t {
            break;
        }
        expiry.pop();
        if alive(wid) {
            expired.push(wid);
        }
    }
    expired
}

/// The HQ server: FCFS queue + failure frontier over the shared
/// [`TaskTable`].
pub struct HqCore {
    table: TaskTable,
    /// FCFS dispatch queue.  May lazily contain ids of tasks that
    /// finished while requeued; they are dropped when next encountered.
    queue: VecDeque<TaskId>,
    /// Live workers with at least one free core, ordered by id (HQ picks
    /// the lowest-id qualifying worker).
    avail: BTreeSet<WorkerId>,
    /// Conservative minimums over every queued request (monotone).
    min_cores_floor: u32,
    min_treq_floor: Micros,
    workers_started: u32,
}

impl HqCore {
    pub fn new(cfg: AutoAllocConfig) -> Self {
        HqCore {
            table: TaskTable::new(cfg),
            queue: VecDeque::new(),
            avail: BTreeSet::new(),
            min_cores_floor: u32::MAX,
            min_treq_floor: Micros::MAX,
            workers_started: 0,
        }
    }

    /// Stats: dispatches performed.
    pub fn dispatches(&self) -> u64 {
        self.table.dispatches()
    }
}

impl TaskCore for HqCore {
    fn submit_task_into(
        &mut self,
        t: Micros,
        spec: TaskSpec,
        out: &mut Vec<HqAction>,
    ) -> TaskId {
        self.min_cores_floor = self.min_cores_floor.min(spec.cores);
        self.min_treq_floor = self.min_treq_floor.min(spec.time_request);
        let id = self.table.admit(t, spec);
        self.queue.push_back(id);
        self.table.autoalloc_into(out);
        self.dispatch_into(t, out);
        id
    }

    /// A native allocation came up: start `workers_per_alloc` workers,
    /// each living until the allocation's time limit.
    fn on_alloc_up_into(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
        out: &mut Vec<HqAction>,
    ) -> Option<WorkerId> {
        let admitted = self.table.admit_workers(t, time_limit, cores_per_worker);
        let first = admitted.first().copied();
        for &wid in admitted {
            if cores_per_worker > 0 {
                self.avail.insert(wid);
            }
            self.workers_started += 1;
        }
        self.dispatch_into(t, out);
        first
    }

    /// A worker disappeared (allocation ended); requeue its tasks in
    /// ascending task-id order (deterministic).
    fn on_worker_lost_into(
        &mut self,
        t: Micros,
        wid: WorkerId,
        out: &mut Vec<HqAction>,
    ) {
        self.avail.remove(&wid);
        for id in self.table.worker_lost(wid, out) {
            self.queue.push_back(id);
        }
        self.table.autoalloc_into(out);
        self.dispatch_into(t, out);
    }

    fn on_task_done_into(&mut self, t: Micros, id: TaskId, out: &mut Vec<HqAction>) {
        if self.table.complete(t, id, false, out) {
            self.reindex_freed();
            self.dispatch_into(t, out);
        }
    }

    fn on_task_failed_into(
        &mut self,
        t: Micros,
        id: TaskId,
        retry_in: Option<Micros>,
        out: &mut Vec<HqAction>,
    ) {
        match self.table.fail(t, id, retry_in, out) {
            FailVerdict::Ignored => {}
            FailVerdict::Killed | FailVerdict::Cooling => {
                self.reindex_freed();
                self.dispatch_into(t, out);
            }
        }
    }

    fn task_live(&self, id: TaskId) -> bool {
        self.table.task_live(id)
    }

    fn live_worker_ids_into(&self, out: &mut Vec<u64>) {
        self.table.live_worker_ids_into(out);
    }

    fn on_timer_into(&mut self, t: Micros, timer: HqTimer, out: &mut Vec<HqAction>) {
        match self.table.timer(t, timer, out) {
            TimerVerdict::Ignored | TimerVerdict::Started => {}
            TimerVerdict::Killed => {
                self.reindex_freed();
                self.dispatch_into(t, out);
            }
            TimerVerdict::Requeue(id) => {
                self.queue.push_back(id);
                self.table.autoalloc_into(out);
                self.dispatch_into(t, out);
            }
        }
    }

    /// Cost: O(expired log workers) — due entries pop off the expiry
    /// heap instead of scanning everyone.
    fn expire_workers_into(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        for wid in self.table.expire_due(t) {
            self.on_worker_lost_into(t, wid, out);
        }
    }

    fn pending_tasks(&self) -> usize {
        self.table.pending_tasks()
    }

    fn live_workers(&self) -> usize {
        self.table.live_workers()
    }

    fn allocs_waiting(&self) -> u32 {
        self.table.allocs_waiting()
    }

    fn resident_tasks(&self) -> usize {
        self.table.resident_tasks()
    }

    fn retired_count(&self) -> u64 {
        self.table.retired_count()
    }
}

// Private placement helpers (shared by the trait impl above).
impl HqCore {
    /// Workers whose cores the table just released re-enter `avail`
    /// (a worker already present is a set no-op).
    fn reindex_freed(&mut self) {
        for &wid in self.table.freed() {
            if self.table.worker(wid).map_or(false, |w| w.cores_free > 0) {
                self.avail.insert(wid);
            }
        }
    }

    /// FCFS dispatch honouring cores and the time-request semantics.
    ///
    /// One pass over the queue; a failed `(cores, time_request)` shape is
    /// cached (worker capacity only shrinks within a pass) and the pass
    /// aborts once failures cover the queue-wide minimum request, so
    /// homogeneous queues cost O(dispatched + 1).
    fn dispatch_into(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        // Fast path: no tasks, or no worker could accept anything.  A
        // worker with zero free cores can still take a degenerate
        // zero-core task (`min_cores_floor == 0` records that one was
        // ever queued — scan conservatively from then on).  Stale queue
        // entries stay for a later pass (the effective count already
        // excludes them).
        let nothing_fits = self.avail.is_empty()
            && (self.min_cores_floor > 0 || self.table.live_workers() == 0);
        if self.queue.is_empty() || nothing_fits {
            self.table.autoalloc_into(out);
            return;
        }
        let mut failed: Vec<(u32, Micros)> = Vec::new();
        let n0 = self.queue.len();
        let mut pushed_back = 0usize;
        let mut aborted = false;
        for _ in 0..n0 {
            let Some(id) = self.queue.pop_front() else { break };
            // Drop stale entries (task finished while requeued).
            if !self.table.is_pending(id) {
                continue;
            }
            let (need, tr) = {
                let task = self.table.task(id).expect("pending task resident");
                (task.spec.cores, task.spec.time_request)
            };
            if failed.iter().any(|&(c, r)| c <= need && r <= tr) {
                self.queue.push_back(id);
                pushed_back += 1;
                continue;
            }
            // A worker qualifies if it has the cores free and its
            // allocation will outlive the task's *time request*; HQ picks
            // the lowest-id qualifying worker.
            let mut pick: Option<WorkerId> = None;
            if need == 0 {
                // Degenerate zero-core task: every live worker with
                // enough allocation left qualifies, including fully-busy
                // ones the `avail` set excludes (seed semantics).
                pick = self.table.worker_ids().find(|&wid| {
                    self.table
                        .worker(wid)
                        .map_or(false, |w| w.expires_t >= t.saturating_add(tr))
                });
            } else {
                for &wid in self.avail.iter() {
                    if self.table.can_start(t, id, wid) {
                        pick = Some(wid);
                        break;
                    }
                }
            }
            match pick {
                Some(wid) => {
                    self.table.reserve(t, id, &[wid], out);
                    if self.table.worker(wid).map_or(true, |w| w.cores_free == 0)
                    {
                        self.avail.remove(&wid);
                    }
                }
                None => {
                    // Minimal-antichain failure frontier.
                    failed.retain(|&(c, r)| !(need <= c && tr <= r));
                    failed.push((need, tr));
                    self.queue.push_back(id);
                    pushed_back += 1;
                    // Frontier covers the queue-wide minimum request:
                    // nothing further down can dispatch either.  Abort
                    // WITHOUT rotating through the rest of the queue —
                    // that rotation is itself O(n) and would make every
                    // pass linear again.
                    if need <= self.min_cores_floor && tr <= self.min_treq_floor {
                        aborted = true;
                        break;
                    }
                }
            }
        }
        if aborted && pushed_back > 0 {
            // Restore FCFS order: the re-pushed (older) entries must
            // precede the untouched remainder.  O(pushed_back), which the
            // frontier keeps small.
            self.queue.rotate_right(pushed_back);
        }
        // Unschedulable tasks may need more allocations.
        self.table.autoalloc_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{Des, MS, SEC};
    use crate::metrics::JobRecord;

    fn cfg() -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 1,
            workers_per_alloc: 1,
            max_worker_count: 4,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: 1 * MS,
        }
    }

    /// Sim-drive: allocations come up `alloc_delay` after submission;
    /// tasks run `dur(tag)`.
    fn drive(
        core: &mut HqCore,
        submissions: Vec<(Micros, TaskSpec)>,
        alloc_delay: Micros,
        dur: impl Fn(u64) -> Micros,
    ) -> Vec<JobRecord> {
        #[derive(Debug)]
        enum Ev {
            Submit(TaskSpec),
            AllocUp,
            Timer(HqTimer),
            TaskDone(TaskId),
        }
        let mut des: Des<Ev> = Des::new();
        for (t, s) in submissions {
            des.schedule(t, Ev::Submit(s));
        }
        let mut records = Vec::new();
        let mut guard = 0;
        while let Some((t, ev)) = des.pop() {
            guard += 1;
            assert!(guard < 1_000_000, "runaway");
            let acts = match ev {
                Ev::Submit(s) => core.submit_task(t, s).1,
                Ev::AllocUp => core.on_alloc_up(t, 3600 * SEC, 16),
                Ev::Timer(tm) => core.on_timer(t, tm),
                Ev::TaskDone(id) => core.on_task_done(t, id),
            };
            for a in acts {
                match a {
                    HqAction::SubmitAllocation { .. } => {
                        des.schedule(t + alloc_delay, Ev::AllocUp)
                    }
                    HqAction::StartTask { task, .. }
                    | HqAction::StartGang { task, .. } => {
                        des.schedule(t + dur(task), Ev::TaskDone(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::TaskCompleted { record, .. } => {
                        records.push(record)
                    }
                    HqAction::KillTask { .. } => {}
                    HqAction::Requeued { .. } => {}
                }
            }
        }
        records
    }

    #[test]
    fn single_task_through_alloc() {
        let mut core = HqCore::new(cfg());
        let recs = drive(
            &mut core,
            vec![(0, TaskSpec { tag: 1, cores: 1, time_request: SEC,
                                time_limit: 10 * SEC })],
            30 * SEC, // allocation queue wait
            |_| 2 * SEC,
        );
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        // Start only after the allocation came up (30 s) + dispatch (1 ms).
        assert!(r.start >= 30 * SEC);
        assert!(r.start <= 30 * SEC + 10 * MS);
        assert_eq!(r.cpu, 2 * SEC);
        // Overhead = queue wait + dispatch, NOT per-task sbatch costs.
        assert!(r.overhead() >= 30 * SEC);
    }

    #[test]
    fn later_tasks_have_tiny_overhead() {
        // The paper's core claim: after the first allocation, per-task
        // overhead collapses to dispatch latency (ms).
        let mut core = HqCore::new(cfg());
        let subs: Vec<_> = (0..10)
            .map(|i| (i as Micros, TaskSpec {
                tag: i, cores: 16, time_request: SEC, time_limit: 100 * SEC,
            }))
            .collect();
        let recs = drive(&mut core, subs, 60 * SEC, |_| SEC);
        assert_eq!(recs.len(), 10);
        let mut overheads: Vec<_> = recs.iter().map(|r| r.overhead()).collect();
        overheads.sort();
        // First task pays the allocation wait...
        assert!(*overheads.last().unwrap() >= 60 * SEC);
        // ...subsequent ones only the dispatch (served serially on one
        // 16-core worker, so overhead includes waiting for the previous
        // task; the *scheduler* overhead per task is ms).  Check that at
        // least the dispatch-only component is visible on task 2's start:
        let mut starts: Vec<_> = recs.iter().map(|r| r.start).collect();
        starts.sort();
        let gap = starts[1] - starts[0];
        assert!(gap >= SEC && gap <= SEC + 50 * MS,
                "serial tasks start back-to-back, gap {gap}");
    }

    #[test]
    fn time_request_gates_dispatch() {
        let mut core = HqCore::new(cfg());
        // Allocation lives 10 s; task requests 3600 s: must NOT dispatch.
        let (id, acts) = core.submit_task(0, TaskSpec {
            tag: 1, cores: 1, time_request: 3600 * SEC, time_limit: 2 * 3600 * SEC,
        });
        // Process the allocation coming up with a 10 s lifetime.
        let mut up = core.on_alloc_up(0, 10 * SEC, 16);
        up.extend(acts);
        assert!(core.pending_tasks() == 1,
                "task with long time request stays queued");
        let _ = id;
    }

    #[test]
    fn time_limit_kills_runaway() {
        let mut core = HqCore::new(cfg());
        let recs = drive(
            &mut core,
            vec![(0, TaskSpec { tag: 9, cores: 1, time_request: SEC,
                                time_limit: 5 * SEC })],
            SEC,
            |_| 60 * SEC, // runs way past the limit
        );
        assert_eq!(recs.len(), 1);
        assert!(recs[0].truncated);
        assert!(recs[0].cpu <= 5 * SEC + MS);
    }

    #[test]
    fn backlog_bounds_queued_allocations() {
        let mut core = HqCore::new(AutoAllocConfig { backlog: 2, ..cfg() });
        let mut alloc_submissions = 0;
        for i in 0..8 {
            let (_, acts) = core.submit_task(i, TaskSpec {
                tag: i, cores: 1, time_request: SEC, time_limit: 10 * SEC,
            });
            alloc_submissions += acts.iter()
                .filter(|a| matches!(a, HqAction::SubmitAllocation { .. }))
                .count();
        }
        assert_eq!(alloc_submissions, 2, "backlog=2 caps queued allocs");
        assert_eq!(core.allocs_waiting(), 2);
    }

    #[test]
    fn max_worker_count_respected() {
        let mut core = HqCore::new(AutoAllocConfig {
            backlog: 10, max_worker_count: 2, ..cfg()
        });
        for i in 0..10 {
            core.submit_task(i, TaskSpec {
                tag: i, cores: 16, time_request: SEC, time_limit: 10 * SEC,
            });
        }
        core.on_alloc_up(10, 3600 * SEC, 16);
        core.on_alloc_up(11, 3600 * SEC, 16);
        core.on_alloc_up(12, 3600 * SEC, 16);
        assert!(core.live_workers() <= 2);
    }

    #[test]
    fn worker_loss_requeues_tasks() {
        let mut core = HqCore::new(cfg());
        let (id, _) = core.submit_task(0, TaskSpec {
            tag: 1, cores: 1, time_request: SEC, time_limit: 100 * SEC,
        });
        let mut acts = Vec::new();
        let wid = core
            .on_alloc_up_into(0, 3600 * SEC, 16, &mut acts)
            .expect("worker admitted");
        // Fire the dispatch timer.
        let mut started = false;
        for a in acts {
            if let HqAction::Timer(t, tm) = a {
                for b in core.on_timer(t, tm) {
                    if matches!(b, HqAction::StartTask { .. }) {
                        started = true;
                    }
                }
            }
        }
        assert!(started);
        core.on_worker_lost(5 * SEC, wid);
        assert_eq!(core.pending_tasks(), 1, "running task requeued");
        let _ = id;
    }

    #[test]
    fn parallel_tasks_share_worker_cores() {
        // 16-core worker, 8-core tasks: two run concurrently.
        let mut core = HqCore::new(cfg());
        let subs: Vec<_> = (0..2)
            .map(|i| (0, TaskSpec {
                tag: i, cores: 8, time_request: SEC, time_limit: 100 * SEC,
            }))
            .collect();
        let recs = drive(&mut core, subs, SEC, |_| 10 * SEC);
        assert_eq!(recs.len(), 2);
        let starts: Vec<_> = recs.iter().map(|r| r.start).collect();
        assert!((starts[0] as i64 - starts[1] as i64).abs() < MS as i64 * 10,
                "both start together: {starts:?}");
    }

    #[test]
    fn done_tasks_evicted_from_hot_map() {
        let mut core = HqCore::new(cfg());
        let subs: Vec<_> = (0..12)
            .map(|i| (i as Micros, TaskSpec {
                tag: i, cores: 1, time_request: SEC, time_limit: 100 * SEC,
            }))
            .collect();
        let recs = drive(&mut core, subs, SEC, |_| SEC);
        assert_eq!(recs.len(), 12);
        assert_eq!(core.resident_tasks(), 0, "hot map bounded by in-flight");
        assert_eq!(core.retired_count(), 12);
        assert_eq!(core.pending_tasks(), 0);
    }

    #[test]
    fn expiry_heap_matches_worker_lifetimes() {
        let mut core = HqCore::new(AutoAllocConfig {
            backlog: 4, max_worker_count: 4, ..cfg()
        });
        for i in 0..4 {
            core.submit_task(i, TaskSpec {
                tag: i, cores: 16, time_request: SEC, time_limit: 100 * SEC,
            });
        }
        // Two allocations with different lifetimes.
        core.on_alloc_up(0, 10 * SEC, 16);
        core.on_alloc_up(0, 50 * SEC, 16);
        assert_eq!(core.live_workers(), 2);
        // Nothing due yet.
        core.expire_workers(5 * SEC);
        assert_eq!(core.live_workers(), 2);
        // First allocation lapses.
        core.expire_workers(20 * SEC);
        assert_eq!(core.live_workers(), 1);
        // Second one too; repeated calls are no-ops.
        core.expire_workers(60 * SEC);
        assert_eq!(core.live_workers(), 0);
        core.expire_workers(61 * SEC);
        assert_eq!(core.live_workers(), 0);
    }
}
